"""repro.roofline — three-term roofline extraction from compiled dry-runs."""
from . import constants  # noqa: F401
from .analyze import (  # noqa: F401
    CollectiveStats,
    Roofline,
    from_compiled,
    model_flops_for,
    parse_collectives,
)
