"""Trip-count-aware HLO cost model (flops / HBM bytes / collective bytes).

``compiled.cost_analysis()`` counts every ``while`` body exactly ONCE, so any
module with scanned layers, grad-accumulation scans, or query-block scans is
undercounted by the trip count (verified in tests/test_roofline.py).  This
module re-derives the three roofline inputs from the post-partitioning HLO
text with loop multipliers:

  flops:  2*M*N*K per dot (contracting dims parsed from the instruction),
          1 flop/element for top-level elementwise ops (negligible but free),
          everything multiplied by enclosing while trip counts.
  bytes:  per top-level instruction: operand + output sizes.  Post-fusion
          this is exactly the HBM traffic model XLA itself uses — a fusion
          reads its parameters and writes its outputs; internal values never
          touch HBM.
  collectives: ring model per op (all-reduce 2x(n-1)/n, all-gather /
          reduce-scatter / all-to-all (n-1)/n, collective-permute 1x),
          with loop multipliers — collectives inside scanned layers are
          otherwise invisible.

Trip counts: jax scans lower to ``while`` whose condition compares the
induction variable against a constant; we parse the ROOT compare of the
condition computation.  Unknown patterns fall back to multiplier 1 and are
reported in ``unknown_trip_whiles``.

Validated against cost_analysis on unrolled (scan-free) modules, and against
scan-vs-unrolled pairs of the same model (tests/test_roofline.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1, "f4e2m1fn": 1,
    "f8e8m0fnu": 1, "f8e3m4": 1, "f8e4m3": 1, "u1": 1, "s1": 1, "s2": 1, "u2": 1,
}

_SHAPE_ONE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

#: one instruction: "  %name = <shape> opcode(operands...) , attrs"  (shape may
#: be a tuple).  ROOT prefix optional.
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w\[\],{}\/#*]+))\s+"
    r"([\w\-]+)\("
)
#: computation header: "%name (params...) -> type {"  (params may nest parens)
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^=]*?\})\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONSTANT_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "negate", "abs", "rsqrt", "sqrt", "select",
    "compare", "and", "or", "xor", "not", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "clamp", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "atan2",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "opt-barrier", "custom-call", "get-dimension-size",
}
_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """(elements, bytes) for a shape string (tuples summed)."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_ONE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclasses.dataclass
class Inst:
    name: str
    shape: str
    op: str
    line: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_type: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k, v in o.coll_by_type.items():
            self.coll_by_type[k] = self.coll_by_type.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(
            self.flops * m,
            self.bytes * m,
            self.coll_bytes * m,
            {k: v * m for k, v in self.coll_by_type.items()},
        )


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[Inst]] = {}
        self.entry: Optional[str] = None
        self.shape_of: Dict[Tuple[str, str], str] = {}
        self.unknown_trip_whiles: List[str] = []
        self._memo: Dict[Tuple[str, bool], Cost] = {}
        self._parse(hlo_text)

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            m = _COMP_HEADER_RE.match(line)
            if m and line.endswith("{"):
                cur = m.group(1)
                self.comps[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            mi = _INST_RE.match(line)
            if not mi:
                continue
            name, shape, op = mi.group(1), mi.group(2), mi.group(3)
            self.comps[cur].append(Inst(name, shape, op, line))
            self.shape_of[(cur, name)] = shape
        if self.entry is None and self.comps:  # fallback: last computation
            self.entry = list(self.comps)[-1]

    # -- helpers -------------------------------------------------------------
    def _operand_shapes(self, comp: str, line: str, op: str | None = None) -> List[str]:
        """Shapes of the operands of an instruction (inline-typed or by name)."""
        # operand list opens right after the opcode (tuple-typed instructions
        # have an earlier '(' in their result shape)
        if op is not None and f" {op}(" in line:
            start = line.index(f" {op}(") + len(op) + 1
        else:
            start = line.index("(")
        depth = 0
        end = start
        for i, ch in enumerate(line[start:], start):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        inner = line[start + 1 : end]
        shapes = []
        for part in self._split_top(inner):
            part = part.strip()
            if not part:
                continue
            mt = _SHAPE_ONE_RE.search(part)
            if mt and "[" in part.split("%")[0]:
                shapes.append(part[: part.index("%")] if "%" in part else part)
            else:
                nm = part.lstrip("%")
                shapes.append(self.shape_of.get((comp, nm), ""))
        return shapes

    @staticmethod
    def _split_top(s: str) -> List[str]:
        out, depth, start = [], 0, 0
        for i, ch in enumerate(s):
            if ch in "({[":
                depth += 1
            elif ch in ")}]":
                depth -= 1
            elif ch == "," and depth == 0:
                out.append(s[start:i])
                start = i + 1
        out.append(s[start:])
        return out

    def _trip_count(self, cond_comp: str) -> Optional[int]:
        insts = self.comps.get(cond_comp, [])
        const_vals = {}
        for inst in insts:
            mc = _CONSTANT_RE.search(inst.line)
            if inst.op == "constant" and mc:
                const_vals[inst.name] = int(mc.group(1))
        for inst in reversed(insts):
            if inst.op == "compare" and "direction=LT" in inst.line:
                mc = _CONSTANT_RE.search(inst.line)
                if mc:  # inline constant operand
                    return int(mc.group(1))
                for nm, v in const_vals.items():
                    if f"%{nm}" in inst.line or f" {nm}" in inst.line:
                        return v
        return None

    def _group_size(self, line: str) -> int:
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(line)
        if m:
            first = m.group(1).lstrip("{").split("}")[0]
            return max(1, len([x for x in first.split(",") if x.strip()]))
        return 1

    # -- cost ----------------------------------------------------------------
    def _dot_flops(self, comp: str, inst: Inst) -> float:
        out_elems, _ = _shape_elems_bytes(inst.shape)
        mk = _CONTRACT_RE.search(inst.line)
        kprod = 1
        if mk:
            ops = self._operand_shapes(comp, inst.line, inst.op)
            if ops:
                dims_txt = _SHAPE_ONE_RE.search(ops[0])
                if dims_txt and dims_txt.group(2):
                    dims = [int(d) for d in dims_txt.group(2).split(",")]
                    for ci in mk.group(1).split(","):
                        if ci.strip() != "" and int(ci) < len(dims):
                            kprod *= dims[int(ci)]
        return 2.0 * out_elems * kprod

    def _collective(self, inst: Inst, comp: str | None = None) -> Tuple[str, float]:
        kind = inst.op.replace("-start", "")
        n = self._group_size(inst.line)
        _, size_out = _shape_elems_bytes(inst.shape)
        if comp is not None and size_out:
            # look through CPU bf16->f32 legalization converts: the tensor
            # that crosses the ICI on the TPU target is the narrow one
            parts = self._operand_parts(comp, inst.line, inst.op)
            raw = sum(
                _shape_elems_bytes(self.shape_of.get((comp, p.split("%")[-1].split(" ")[0].rstrip(",)")), p))[1]
                or _shape_elems_bytes(p)[1]
                for p in parts
            )
            true = sum(self._true_operand_bytes(comp, p) for p in parts)
            if raw > 0 and 0 < true < raw:
                size_out = size_out * true / raw
        if n <= 1:
            return kind, 0.0
        ring = (n - 1) / n
        if kind == "all-reduce":
            return kind, 2.0 * size_out * ring
        if kind == "all-gather":
            return kind, size_out * ring
        if kind == "reduce-scatter":
            return kind, size_out * ring  # output shard; input = out*n; ring moves in*(n-1)/n /n per dev = out*(n-1)/n
        if kind == "all-to-all":
            return kind, size_out * ring
        return kind, float(size_out)  # collective-permute

    _SLICE_OPS = ("dynamic-slice", "slice", "gather")

    def _first_operand_name(self, line: str, op: str) -> str:
        try:
            parts = self._split_top(
                line[line.index(f" {op}(") + len(op) + 2 :].rsplit(")", 1)[0]
            )
        except ValueError:
            return ""
        first = parts[0].strip() if parts else ""
        return first.split("%")[-1].split(" ")[0] if "%" in first else ""

    def _dus_update_bytes(self, comp: str, inst: Inst) -> int:
        ops = self._operand_shapes(comp, inst.line, inst.op)
        return _shape_elems_bytes(ops[1])[1] if len(ops) > 1 else 0

    def _fusion_io_bytes(self, comp: str) -> Tuple[int, Optional[int]]:
        """(input_bytes, output_bytes_override) a fusion actually moves.

        * parameters consumed only through slice-like ops are charged at the
          slice output size (a scanned layer stack is read one layer per
          iteration even though the whole stack is an operand);
        * parameters that are only the *destination* of dynamic-update-slice
          are aliased in place: charged 0, and the fusion output is the
          update region, not the whole buffer.
        """
        key = ("__fio__", comp)
        if key in self._memo:
            return self._memo[key]  # type: ignore[return-value]
        insts = self.comps.get(comp, [])
        by_name = {i.name: i for i in insts}
        # follow single-level aliases (bitcast/copy/reshape of a param)
        alias_of: Dict[str, str] = {}
        for j in insts:
            if j.op in ("bitcast", "copy", "reshape", "transpose"):
                src = self._first_operand_name(j.line, j.op)
                if src in by_name and by_name[src].op == "parameter":
                    alias_of[j.name] = src

        in_total = 0
        inplace_dus: set = set()
        for inst in insts:
            if inst.op != "parameter":
                continue
            names = {inst.name} | {a for a, s in alias_of.items() if s == inst.name}
            refs = []
            for j in insts:
                if j is inst or j.name in names:
                    continue
                if any(re.search(rf"%{re.escape(n)}\b", j.line) for n in names):
                    refs.append(j)
            if refs and all(j.op in self._SLICE_OPS for j in refs):
                in_total += sum(_shape_elems_bytes(j.shape)[1] for j in refs)
            elif refs and all(
                j.op == "dynamic-update-slice"
                and self._first_operand_name(j.line, j.op) in names
                for j in refs
            ):
                inplace_dus.update(j.name for j in refs)  # aliased destination
            else:
                in_total += _shape_elems_bytes(inst.shape)[1]

        out_override: Optional[int] = None
        if insts:
            root = insts[-1]
            if root.op == "dynamic-update-slice" and root.name in inplace_dus:
                out_override = self._dus_update_bytes(comp, root)
            elif root.op == "tuple":
                total = 0
                ok = True
                for part in self._split_top(
                    root.line[root.line.index("tuple(") + 6 :].rsplit(")", 1)[0]
                ):
                    nm = part.strip().lstrip("%").split(" ")[-1].lstrip("%")
                    j = by_name.get(nm)
                    if j is not None and j.op == "dynamic-update-slice" and j.name in inplace_dus:
                        total += self._dus_update_bytes(comp, j)
                    elif j is not None:
                        total += _shape_elems_bytes(j.shape)[1]
                    else:
                        ok = False
                        break
                if ok:
                    out_override = total
        self._memo[key] = (in_total, out_override)  # type: ignore[assignment]
        return in_total, out_override

    #: ops whose producer->consumer edges stay in registers/VMEM once the
    #: target compiler fuses elementwise chains (XLA:TPU always does; the CPU
    #: is_scheduled HLO text leaves them unfused, which would overcharge the
    #: memory term ~5x on softmax/flash chains)
    _FUSABLE = _ELEMENTWISE | {"broadcast", "reduce-precision"}

    def _fusion_maps(self, comp: str):
        """(producer_op_by_name, consumers_by_name) for elementwise elision."""
        key = ("__maps__", comp)
        if key in self._memo:
            return self._memo[key]  # type: ignore[return-value]
        insts = self.comps.get(comp, [])
        prod = {i.name: i.op for i in insts}
        consumers: Dict[str, List[str]] = {i.name: [] for i in insts}
        for j in insts:
            for n in re.findall(r"%([\w.\-]+)", j.line.split(" metadata=")[0]):
                if n != j.name and n in consumers:
                    consumers[n].append(j.op)
        self._memo[key] = (prod, consumers)  # type: ignore[assignment]
        return prod, consumers

    def _true_operand_bytes(self, comp: str, part: str) -> int:
        """Bytes of an operand, looking through dtype converts.

        XLA:CPU legalizes bf16 compute to f32 (convert pairs around every
        dot); on the TPU target the HBM tensor stays bf16, so we charge the
        *narrow* side of convert-like producers (convert / convert fusions /
        bitcast chains, followed to depth 3)."""
        nm = part.split("%")[-1].split(" ")[0].rstrip(",)")
        mt = _SHAPE_ONE_RE.search(part)
        size = _shape_elems_bytes(part)[1] if mt and "[" in part.split("%")[0] else None
        if size is None:
            size = _shape_elems_bytes(self.shape_of.get((comp, nm), ""))[1]
        cur = nm
        for _ in range(3):
            inst = next(
                (i for i in self.comps.get(comp, []) if i.name == cur), None
            )
            if inst is None:
                break
            if inst.op in ("bitcast", "copy", "reshape"):
                ops = self._operand_parts(comp, inst.line, inst.op)
                cur = ops[0].split("%")[-1].split(" ")[0].rstrip(",)") if ops else cur
                continue
            is_convert = inst.op == "convert" or (
                inst.op == "fusion" and "convert" in inst.name
            )
            if is_convert:
                ops = self._operand_parts(comp, inst.line, inst.op)
                if ops:
                    src = self._true_operand_bytes(comp, ops[0])
                    return min(size, src) if src else size
            break
        return size

    def comp_cost(self, comp: str, top_level: bool) -> Cost:
        key = (comp, top_level)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Cost()  # cycle guard
        total = Cost()
        for inst in self.comps.get(comp, []):
            total += self.inst_cost(comp, inst, top_level)
        self._memo[key] = total
        return total

    def inst_cost(self, comp: str, inst: Inst, top_level: bool) -> Cost:
        op = inst.op
        c = Cost()
        if op in _FREE:
            return c
        if op in _COLLECTIVES:
            kind, b = self._collective(inst, comp)
            c.coll_bytes += b
            c.coll_by_type[kind] = c.coll_by_type.get(kind, 0.0) + b
            _, ob = _shape_elems_bytes(inst.shape)
            c.bytes += 2 * ob  # read + write the buffer
            return c
        if op.endswith("-done"):
            return c
        if op == "while":
            body = _BODY_RE.search(inst.line)
            cond = _COND_RE.search(inst.line)
            mt = _TRIP_RE.search(inst.line)  # XLA's own annotation, if present
            trip = int(mt.group(1)) if mt else None
            if trip is None and cond:
                trip = self._trip_count(cond.group(1))
            if trip is None:
                trip = 1
                self.unknown_trip_whiles.append(f"{comp}/{inst.name}")
            inner = Cost()
            if body:
                inner += self.comp_cost(body.group(1), True)
            if cond:
                inner += self.comp_cost(cond.group(1), True)
            return inner.scaled(float(max(trip, 1)))
        if op == "conditional":
            mb = _BRANCHES_RE.search(inst.line)
            if mb:
                branches = [b.strip().lstrip("%") for b in mb.group(1).split(",")]
                costs = [self.comp_cost(b, True) for b in branches if b in self.comps]
                if costs:
                    worst = max(costs, key=lambda x: x.flops + x.bytes)
                    return worst
            return c
        if op in ("fusion", "call", "map", "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
            mcalls = _CALLS_RE.search(inst.line)
            called = mcalls.group(1) if mcalls and mcalls.group(1) in self.comps else None
            if called:
                inner = self.comp_cost(called, False)
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.coll_by_type.items():
                    c.coll_by_type[k] = c.coll_by_type.get(k, 0.0) + v
            if top_level:  # HBM traffic: fusion reads params, writes outputs
                _, ob = _shape_elems_bytes(inst.shape)
                if op == "fusion" and called:
                    body_ops = {i.op for i in self.comps.get(called, [])}
                    if body_ops <= {"parameter", "convert", "bitcast", "copy"}:
                        # pure dtype-convert fusion: a CPU bf16-legalization
                        # artifact; does not exist on the TPU target
                        return c
                    ib, ob_override = self._fusion_io_bytes(called)
                    if ob_override is not None:
                        ob = ob_override
                else:
                    ib = sum(
                        _shape_elems_bytes(s)[1]
                        for s in self._operand_shapes(comp, inst.line, inst.op)
                    )
                c.bytes += ob + ib
            return c
        if op == "dot":
            c.flops += self._dot_flops(comp, inst)
        elif op == "convolution":
            out_elems, _ = _shape_elems_bytes(inst.shape)
            ops = self._operand_shapes(comp, inst.line, inst.op)
            kelems = _shape_elems_bytes(ops[1])[0] if len(ops) > 1 else 1
            c.flops += 2.0 * out_elems * kelems
        elif op in _ELEMENTWISE or op in (
            "broadcast", "reshape", "transpose", "concatenate", "pad", "slice",
            "dynamic-slice", "dynamic-update-slice", "gather", "reverse",
            "reduce-precision", "exponential-minus-one", "copy", "copy-start",
        ):
            out_elems, _ = _shape_elems_bytes(inst.shape)
            if op in _ELEMENTWISE:
                c.flops += out_elems
        if top_level:
            _, ob = _shape_elems_bytes(inst.shape)
            if op in self._SLICE_OPS:
                c.bytes += 2 * ob  # read the slice, write the output
            elif op == "dynamic-update-slice":
                ops = self._operand_shapes(comp, inst.line, inst.op)
                upd = _shape_elems_bytes(ops[1])[1] if len(ops) > 1 else ob
                c.bytes += 2 * upd  # in-place: read update, write region
            elif op in self._FUSABLE:
                # perfect-elementwise-fusion model: an edge between two
                # fusable ops stays in registers; charge only edges to/from
                # real producers/consumers
                prod, consumers = self._fusion_maps(comp)
                cons = consumers.get(inst.name, [])
                if not cons or any(x not in self._FUSABLE for x in cons):
                    c.bytes += ob  # materialized for a real consumer
                for part in self._operand_parts(comp, inst.line, inst.op):
                    nm = part.split("%")[-1].split(" ")[0].rstrip(",)")
                    if nm in prod and prod[nm] in self._FUSABLE:
                        continue  # fused edge
                    mt = _SHAPE_ONE_RE.search(part)
                    if mt:
                        c.bytes += _shape_elems_bytes(part)[1]
                    elif nm in prod:
                        c.bytes += _shape_elems_bytes(
                            self.shape_of.get((comp, nm), "")
                        )[1]
            else:
                ib = sum(
                    self._true_operand_bytes(comp, part)
                    for part in self._operand_parts(comp, inst.line, inst.op)
                )
                c.bytes += ob + ib
        return c

    def _operand_parts(self, comp: str, line: str, op: str) -> List[str]:
        if f" {op}(" in line:
            start = line.index(f" {op}(") + len(op) + 1
        else:
            start = line.index("(")
        depth, end = 0, start
        for i, ch in enumerate(line[start:], start):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return [p.strip() for p in self._split_top(line[start + 1 : end]) if p.strip()]

    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry, True)


def analyze_text(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()
