"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in *seconds per step*:

  compute    = per-device HLO FLOPs / peak bf16 FLOP/s
  memory     = per-device HLO bytes accessed / HBM bandwidth
  collective = per-device ring-model collective bytes / ICI link bandwidth

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed — the compiled
module is the per-device SPMD program, so these are already per-chip) and the
post-partitioning HLO text for the collectives (cost_analysis does not cover
them).  The ring model per op on a group of size n:

  all-reduce      2 * size * (n-1)/n      (reduce-scatter + all-gather)
  all-gather      size_out * (n-1)/n
  reduce-scatter  size_in  * (n-1)/n
  all-to-all      size * (n-1)/n
  collective-permute  size (point-to-point)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per train step; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat recompute and dispatch waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

from . import constants as C

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

#: shapes like bf16[256,1024]{1,0} or (f32[8], u32[8]) tuples
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}[,)]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)  # [groups,group_size]<=iota form
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0].lstrip("{")
        ids = [x for x in first.split(",") if x.strip() != ""]
        return max(1, len(ids))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    per_device_bytes: float = 0.0
    by_type: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, bytes_: float):
        self.per_device_bytes += bytes_
        self.by_type[kind] = self.by_type.get(kind, 0.0) + bytes_
        self.count += 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Ring-model per-device collective bytes from post-SPMD HLO text."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        out_shape, kind = m.group(1), m.group(2)
        n = _group_size(line)
        size_out = _shape_bytes(out_shape)
        if n <= 1:
            continue
        ring = (n - 1) / n
        if kind == "all-reduce":
            stats.add(kind, 2.0 * size_out * ring)
        elif kind == "all-gather":
            stats.add(kind, size_out * ring)
        elif kind == "reduce-scatter":
            # output is the scattered shard; input = out * n
            stats.add(kind, size_out * n * ring / n)  # = size_in * ring / n per dev
        elif kind == "all-to-all":
            stats.add(kind, size_out * ring)
        else:  # collective-permute
            stats.add(kind, size_out)
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    model_flops: float = 0.0  # 6·N_active·D per step (global)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / C.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / C.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective.per_device_bytes / C.ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the dominant-term-bound step time."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        useful_t = (self.model_flops / self.chips) / C.PEAK_FLOPS_BF16
        return useful_t / t

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective.per_device_bytes,
            "collective_by_type": self.collective.by_type,
            "collective_ops": self.collective.count,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N(_active)·tokens for train; 2·N for a prefill token; 2·N per decode."""
    from repro.configs import param_count

    total, active = param_count(cfg)
    n = active
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def from_compiled(arch, shape, mesh_name, chips, compiled, cfg=None, shape_cfg=None):
    """Roofline terms from the compiled per-device SPMD module.

    Uses the trip-count-aware HLO cost model (roofline/hlo_cost.py):
    ``cost_analysis()`` counts while bodies once, so scanned layers /
    grad-accumulation would be undercounted by the trip count.  Validated
    against scan-free modules in tests/test_roofline.py (flops ~1%, bytes
    within ~40% — the residual is real loop-carry traffic).
    """
    from .hlo_cost import HloCostModel

    model = HloCostModel(compiled.as_text())
    cost = model.total()
    stats = CollectiveStats(
        per_device_bytes=cost.coll_bytes,
        by_type=dict(cost.coll_by_type),
        count=len(cost.coll_by_type),
    )
    mf = model_flops_for(cfg, shape_cfg) if cfg is not None else 0.0
    rl = Roofline(arch, shape, mesh_name, chips, cost.flops, cost.bytes, stats, mf)
    rl.unknown_trip_whiles = len(model.unknown_trip_whiles)  # type: ignore[attr-defined]
    return rl
