"""TPU v5e hardware constants (the TARGET platform; container runs CPU)."""

PEAK_FLOPS_BF16 = 197e12  # per chip, bf16
HBM_BW = 819e9  # bytes/s per chip
ICI_LINK_BW = 50e9  # bytes/s per link (~ one direction)

CHIP_HBM_BYTES = 16 * 2**30  # v5e: 16 GiB HBM per chip
