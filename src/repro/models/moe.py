"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch.

Dispatch strategy (DESIGN.md SS6): tokens are grouped per sequence (the group
axis coincides with batch, so group-local sorts shard cleanly over the data
axis with zero cross-shard traffic), then

  1. top-k gates (softmax, renormalized),
  2. group-local stable sort of the (token, expert) pairs by expert id,
  3. rank-within-expert via sorted-run offsets (= GShard's position_in_expert
     without the O(T x E x C) one-hot dispatch tensor),
  4. capacity-clipped scatter into (G, E, C, D) — experts sharded over the
     model axis, so GSPMD materializes the token all-to-all here,
  5. grouped SwiGLU einsum over experts, scatter-add combine weighted by gates.

Dropped tokens (beyond capacity) fall through on the residual path, standard
for capacity-based MoE.  A Switch-style load-balance aux loss is returned for
logging/training.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .layers import PT, mlp_template


def moe_template(cfg) -> Dict[str, PT]:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    t = {
        "router": PT((d, e), ("embed", "experts"), "normal", 0.02),
        "gate": PT((e, d, ff), ("experts", "embed", "expert_mlp")),
        "up": PT((e, d, ff), ("experts", "embed", "expert_mlp")),
        "down": PT((e, ff, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        t["shared"] = mlp_template(d, ff * cfg.n_shared_experts)
    return t


def moe_ffn(p, x, cfg):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    C = max(1, int(S * K / E * cfg.capacity_factor))

    logits = x @ p["router"]  # (B,S,E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (B,S,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance loss: E * sum_e fraction_e * prob_e
    counts = jnp.zeros((B, E), probs.dtype).at[
        jnp.arange(B)[:, None, None], gate_idx
    ].add(1.0)
    frac = counts / (S * K)
    mean_prob = probs.mean(axis=1)
    aux = E * jnp.mean(jnp.sum(frac * mean_prob, axis=-1))

    # --- group-local (per-sequence) sort dispatch --------------------------
    tk = S * K
    eid = gate_idx.reshape(B, tk)  # expert id per (token,k)
    tok = jnp.broadcast_to(jnp.arange(S)[:, None], (S, K)).reshape(tk)
    gw = gate_vals.reshape(B, tk)

    order = jnp.argsort(eid, axis=-1, stable=True)  # (B, tk)
    eid_s = jnp.take_along_axis(eid, order, axis=-1)
    tok_s = tok[order]  # (B, tk) source token per slot
    gw_s = jnp.take_along_axis(gw, order, axis=-1)

    # rank within expert = index - start_of_expert_run
    idx = jnp.arange(tk)
    starts = jax.vmap(lambda e_row: jnp.searchsorted(e_row, jnp.arange(E)))(eid_s)
    rank = idx[None, :] - jnp.take_along_axis(starts, eid_s, axis=-1)
    ok = rank < C

    # scatter tokens into (B, E, C, D); overflow dropped
    src = jnp.take_along_axis(
        x, tok_s[..., None], axis=1
    )  # (B, tk, D) gathered token embeddings
    buf = jnp.zeros((B, E, C, D), x.dtype)
    e_dst = jnp.where(ok, eid_s, E)
    r_dst = jnp.where(ok, rank, 0)
    buf = buf.at[jnp.arange(B)[:, None], e_dst, r_dst].add(
        src, mode="drop"
    )

    # grouped expert SwiGLU
    g = jnp.einsum("becd,edf->becf", buf, p["gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["up"])
    h = jax.nn.silu(g) * u
    y = jnp.einsum("becf,efd->becd", h, p["down"])  # (B,E,C,D)

    # combine: gather expert outputs back to (token,k) slots, weight, add
    y_slots = y[jnp.arange(B)[:, None], e_dst, r_dst]  # (B,tk,D); e_dst==E drops
    y_slots = jnp.where(ok[..., None], y_slots, 0.0)
    out = jnp.zeros_like(x)
    out = out.at[jnp.arange(B)[:, None], tok_s].add(
        y_slots * gw_s[..., None].astype(y_slots.dtype)
    )

    if cfg.n_shared_experts:
        from .layers import mlp_apply

        out = out + mlp_apply(p["shared"], x, cfg.act)
    return out, aux.astype(x.dtype)
