"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM: matrix memory C (hd x hd) per head with stabilized exponential gating.
Training/prefill run the *chunkwise* form — quadratic only within a chunk
(``cfg.mlstm_chunk``), linear across chunks via a ``lax.scan`` carrying
(C, n, m) — so a 32 K prefill costs O(S * chunk) not O(S^2), and decode is the
O(1) recurrent step (what makes the long_500k cell feasible, DESIGN.md SS5).
Both forms are equivalence-tested against each other in tests/.

sLSTM: scalar memory with recurrent gate connections (block-diagonal per
head) — genuinely sequential, implemented as a per-timestep ``lax.scan``.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from .layers import PT, rmsnorm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_template(cfg) -> Dict[str, PT]:
    d = cfg.d_model
    du = int(d * cfg.mlstm_proj_factor)
    h = cfg.n_heads
    return {
        "up_x": PT((d, du), ("embed", "mlp")),
        "up_g": PT((d, du), ("embed", "mlp")),
        "wq": PT((du, du), ("mlp", "mlp2")),
        "wk": PT((du, du), ("mlp", "mlp2")),
        "wv": PT((du, du), ("mlp", "mlp2")),
        "wi": PT((du, h), ("mlp", "heads"), "normal", 0.01),
        "wf": PT((du, h), ("mlp", "heads"), "normal", 0.01),
        "bi": PT((h,), ("heads",), "zeros"),
        "bf": PT((h,), ("heads",), "ones"),  # forget-bias > 0
        "out_norm": PT((du,), ("mlp",), "ones"),
        "down": PT((du, d), ("mlp", "embed")),
    }


class MLSTMState(NamedTuple):
    C: jax.Array  # (B, H, hd, hd)
    n: jax.Array  # (B, H, hd)
    m: jax.Array  # (B, H)


def mlstm_init_state(batch: int, heads: int, hd: int, dtype=jnp.float32):
    return MLSTMState(
        jnp.zeros((batch, heads, hd, hd), dtype),
        jnp.zeros((batch, heads, hd), dtype),
        jnp.full((batch, heads), -1e30, dtype),
    )


def _gates(p, xu):
    """log-input-gate a (B,S,H), log-forget logf (B,S,H) (logsigmoid)."""
    a = (xu @ p["wi"] + p["bi"]).astype(jnp.float32)
    f_pre = (xu @ p["wf"] + p["bf"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_pre)
    return a, logf


def mlstm_chunkwise(p, xu, cfg, state: MLSTMState | None = None):
    """xu: (B, S, du) -> (h (B,S,du), final state).

    Ragged sequences (S % chunk != 0) run the whole multiple through the
    chunkwise scan and the remainder as one short chunk carrying the state —
    exactly equivalent (the recurrence is associative across chunk splits).
    """
    B, S, du = xu.shape
    H = cfg.n_heads
    hd = du // H
    L = min(cfg.mlstm_chunk, S)
    if S % L != 0:
        main = (S // L) * L
        h1, st = mlstm_chunkwise(p, xu[:, :main], cfg, state)
        h2, st = mlstm_chunkwise(p, xu[:, main:], cfg, st)
        return jnp.concatenate([h1, h2], axis=1), st
    nc = S // L
    scale = 1.0 / (hd**0.5)

    q = (xu @ p["wq"]).reshape(B, nc, L, H, hd)
    k = (xu @ p["wk"]).reshape(B, nc, L, H, hd)
    v = (xu @ p["wv"]).reshape(B, nc, L, H, hd)
    a, logf = _gates(p, xu)  # (B,S,H) f32
    a = a.reshape(B, nc, L, H)
    logf = logf.reshape(B, nc, L, H)

    if state is None:
        state = mlstm_init_state(B, H, hd, jnp.float32)

    tri = jnp.tril(jnp.ones((L, L), bool))  # j >= l

    def chunk_step(st, xs):
        C0, n0, m0 = st  # (B,H,hd,hd), (B,H,hd), (B,H)
        qc, kc, vc, ac, fc = xs  # (B,L,H,hd) x3, (B,L,H) x2
        b = jnp.cumsum(fc, axis=1)  # inclusive log-decay (B,L,H)
        Btot = b[:, -1]  # (B,H)
        # intra weights D[j,l] = b_j - b_l + a_l  (l <= j)
        D = b[:, :, None, :] - b[:, None, :, :] + ac[:, None, :, :]  # (B,j,l,H)
        D = jnp.where(tri[None, :, :, None], D, -jnp.inf)
        g = b + m0[:, None, :]  # state path log-decay (B,L,H)
        m_j = jnp.maximum(g, jnp.max(D, axis=2))  # (B,L,H)
        sD = jnp.exp(D - m_j[:, :, None, :])  # (B,j,l,H)
        sG = jnp.exp(g - m_j)  # (B,L,H)

        qk = jnp.einsum("bjhd,blhd->bjlh", qc, kc) * scale
        num_intra = jnp.einsum("bjlh,bjlh,blhd->bjhd", qk, sD, vc)
        num_inter = jnp.einsum("bjhd,bhde->bjhe", qc, C0) * sG[..., None] * scale
        num = num_intra + num_inter
        n_j = jnp.einsum("bjlh,blhd->bjhd", sD, kc) + sG[..., None] * n0[:, None]
        qn = jnp.abs(jnp.einsum("bjhd,bjhd->bjh", qc * scale, n_j))
        denom = jnp.maximum(qn, jnp.exp(-m_j))
        h = num / denom[..., None]  # (B,L,H,hd)

        # carry state to chunk end
        m1 = jnp.maximum(Btot + m0, jnp.max(Btot[:, None] - b + ac, axis=1))
        w = jnp.exp(Btot[:, None] - b + ac - m1[:, None])  # (B,L,H)
        C1 = jnp.exp(Btot + m0 - m1)[:, :, None, None] * C0 + jnp.einsum(
            "blh,blhd,blhe->bhde", w, kc, vc
        )
        n1 = jnp.exp(Btot + m0 - m1)[:, :, None] * n0 + jnp.einsum(
            "blh,blhd->bhd", w, kc
        )
        return MLSTMState(C1, n1, m1), h

    # lead with the chunk axis for lax.scan
    qf = q.astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    kf = k.astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    vf = v.astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    af = a.transpose(1, 0, 2, 3)
    ff = logf.transpose(1, 0, 2, 3)
    st, hs = jax.lax.scan(chunk_step, state, (qf, kf, vf, af, ff))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd).reshape(B, S, du)
    return h.astype(xu.dtype), st


def mlstm_step(p, xu, cfg, state: MLSTMState):
    """Single-token recurrence.  xu: (B, 1, du)."""
    B, _, du = xu.shape
    H = cfg.n_heads
    hd = du // H
    scale = 1.0 / (hd**0.5)
    q = (xu @ p["wq"]).reshape(B, H, hd).astype(jnp.float32)
    k = (xu @ p["wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xu @ p["wv"]).reshape(B, H, hd).astype(jnp.float32)
    a, logf = _gates(p, xu)  # (B,1,H)
    a, logf = a[:, 0], logf[:, 0]
    C0, n0, m0 = state
    m1 = jnp.maximum(logf + m0, a)
    fp = jnp.exp(logf + m0 - m1)
    ip = jnp.exp(a - m1)
    C1 = fp[..., None, None] * C0 + ip[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v
    )
    n1 = fp[..., None] * n0 + ip[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q * scale, C1)
    qn = jnp.abs(jnp.einsum("bhd,bhd->bh", q * scale, n1))
    denom = jnp.maximum(qn, jnp.exp(-m1))
    h = (num / denom[..., None]).reshape(B, 1, du)
    return h.astype(xu.dtype), MLSTMState(C1, n1, m1)


def mlstm_block(p, x, cfg, *, state=None, decode=False):
    """Full block: norm -> up -> mLSTM -> gate -> norm -> down (+ residual by caller)."""
    xu = x @ p["up_x"]
    gate = x @ p["up_g"]
    if decode:
        h, st = mlstm_step(p, xu, cfg, state)
    else:
        h, st = mlstm_chunkwise(p, xu, cfg, state)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps)
    out = (h * jax.nn.silu(gate)) @ p["down"]
    return out, st


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_template(cfg) -> Dict[str, PT]:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    t = {}
    for gname in ("i", "f", "z", "o"):
        t[f"w{gname}"] = PT((d, d), ("embed", "embed2"))
        t[f"r{gname}"] = PT((h, hd, hd), ("heads", "head_dim", "head_dim2"), "normal", 0.02)
        t[f"b{gname}"] = PT((d,), ("embed",), "ones" if gname == "f" else "zeros")
    t["out_norm"] = PT((d,), ("embed",), "ones")
    return t


class SLSTMState(NamedTuple):
    h: jax.Array  # (B, D)
    c: jax.Array
    n: jax.Array
    m: jax.Array


def slstm_init_state(batch: int, d: int, dtype=jnp.float32):
    z = jnp.zeros((batch, d), dtype)
    return SLSTMState(z, z, z, jnp.full((batch, d), -1e30, dtype))


def _slstm_cell(p, xt_gates, st: SLSTMState, heads: int):
    """xt_gates: dict g -> (B, D) input contributions at time t."""
    B, D = st.h.shape
    hd = D // heads
    hh = st.h.reshape(B, heads, hd)

    def rec(g):
        return jnp.einsum("bhd,hde->bhe", hh, p[f"r{g}"]).reshape(B, D)

    i_pre = (xt_gates["i"] + rec("i")).astype(jnp.float32)
    f_pre = (xt_gates["f"] + rec("f")).astype(jnp.float32)
    z = jnp.tanh((xt_gates["z"] + rec("z")).astype(jnp.float32))
    o = jax.nn.sigmoid((xt_gates["o"] + rec("o")).astype(jnp.float32))
    m1 = jnp.maximum(f_pre + st.m, i_pre)
    ip = jnp.exp(i_pre - m1)
    fp = jnp.exp(f_pre + st.m - m1)
    c1 = fp * st.c + ip * z
    n1 = jnp.maximum(fp * st.n + ip, 1e-6)
    h1 = o * (c1 / n1)
    return SLSTMState(h1, c1, n1, m1)


def slstm_block(p, x, cfg, *, state=None, decode=False):
    """x: (B,S,D) scan over S (train/prefill) or (B,1,D) single step."""
    B, S, D = x.shape
    if state is None:
        state = slstm_init_state(B, D)
    gates = {g: x @ p[f"w{g}"] + p[f"b{g}"] for g in ("i", "f", "z", "o")}
    if decode:
        st = _slstm_cell(p, {g: gates[g][:, 0] for g in gates}, state, cfg.n_heads)
        out = st.h[:, None, :]
    else:

        def step(st, xs):
            st = _slstm_cell(p, dict(zip(("i", "f", "z", "o"), xs)), st, cfg.n_heads)
            return st, st.h

        xs = tuple(gates[g].transpose(1, 0, 2) for g in ("i", "f", "z", "o"))
        st, hs = jax.lax.scan(step, state, xs)
        out = hs.transpose(1, 0, 2)
    out = rmsnorm(out.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    return out, st
