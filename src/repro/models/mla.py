"""DeepSeek-V3 Multi-head Latent Attention (MLA).

Prefill/train: reconstruct full K/V from the compressed latents (standard).
Decode: the *absorbed* formulation — the KV up-projection is folded into the
query/output sides so the cache holds only (c_kv: kv_lora_rank) + (k_rope:
qk_rope_dim) per token: 512+64 floats vs n_heads*head_dim*2 = 32768 for MHA.
That 57x cache compression is what makes the deepseek decode_32k/serve cells
memory-feasible, and is reflected in the roofline table.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from .layers import PT, apply_rope, rmsnorm

NEG_INF = -1e30


def mla_template(cfg) -> Dict[str, PT]:
    d, h = cfg.d_model, cfg.n_heads
    ql, kl = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "wq_a": PT((d, ql), ("embed", "q_lora")),
        "q_norm": PT((ql,), ("q_lora",), "ones"),
        "wq_b": PT((ql, h, dn + dr), ("q_lora", "heads", "head_dim")),
        "wkv_a": PT((d, kl + dr), ("embed", "kv_lora")),
        "kv_norm": PT((kl,), ("kv_lora",), "ones"),
        "wk_b": PT((kl, h, dn), ("kv_lora", "heads", "head_dim")),
        "wv_b": PT((kl, h, dv), ("kv_lora", "heads", "head_dim")),
        "wo": PT((h, dv, d), ("heads", "head_dim", "embed")),
    }


def _latents(p, x, cfg, positions):
    """Shared down-projections.  Returns (q_nope, q_rope, c_kv, k_rope)."""
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = x @ p["wkv_a"]  # (B,S,kl+dr)
    c_kv = rmsnorm(ckv_full[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(ckv_full[..., cfg.kv_lora_rank :], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(p, x, cfg, positions):
    """Train/prefill path: materialize K/V per head, query-block scanned."""
    B, S, _ = x.shape
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _latents(p, x, cfg, positions)
    k_nope = jnp.einsum("bsl,lhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsl,lhk->bshk", c_kv, p["wv_b"])
    scale = 1.0 / ((dn + dr) ** 0.5)
    kpos = jnp.arange(S)
    qb = cfg.attn_q_block

    def block(qn, qr, qpos):
        # shared k_rope across heads (MQA-style rope channel)
        s = jnp.einsum("bqhk,bshk->bhqs", qn, k_nope) + jnp.einsum(
            "bqhk,bsk->bhqs", qr, k_rope
        )
        s = s.astype(jnp.float32) * scale
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqs,bshk->bqhk", w, v)

    if S <= qb:
        ctx = block(q_nope, q_rope, jnp.arange(S))
    else:
        assert S % qb == 0
        nb = S // qb
        qn_b = q_nope.reshape(B, nb, qb, *q_nope.shape[2:]).transpose(1, 0, 2, 3, 4)
        qr_b = q_rope.reshape(B, nb, qb, *q_rope.shape[2:]).transpose(1, 0, 2, 3, 4)

        def step(_, xs):
            qn, qr, i = xs
            return None, block(qn, qr, i * qb + jnp.arange(qb))

        _, ctxs = jax.lax.scan(step, None, (qn_b, qr_b, jnp.arange(nb)))
        ctx = ctxs.transpose(1, 0, 2, 3, 4).reshape(B, S, cfg.n_heads, dv)
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


class MLACache(NamedTuple):
    c_kv: jax.Array  # (B, S_cache, kv_lora_rank)
    k_rope: jax.Array  # (B, S_cache, qk_rope_dim)


def init_mla_cache(cfg, batch: int, cache_len: int, dtype) -> MLACache:
    return MLACache(
        jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
    )


def mla_prefill(p, x, cfg, positions, cache_len: int):
    """Full-sequence pass that also fills the compressed decode cache."""
    out = mla_attention(p, x, cfg, positions)
    _, _, c_kv, k_rope = _latents(p, x, cfg, positions)
    S = x.shape[1]
    pad = [(0, 0), (0, cache_len - S), (0, 0)]
    return out, MLACache(jnp.pad(c_kv, pad), jnp.pad(k_rope, pad))


def mla_decode(p, x, cfg, cache: MLACache, pos):
    """Absorbed decode: cache stays compressed; per-head K/V never built."""
    B = x.shape[0]
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope, c_kv, k_rope = _latents(p, x, cfg, positions)

    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_kv.astype(cache.c_kv.dtype), pos, axis=1
    )
    krp = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, k_rope.astype(cache.k_rope.dtype), pos, axis=1
    )

    # absorb wk_b into the query: q_abs (B,1,H,kv_lora)
    q_abs = jnp.einsum("bqhk,lhk->bqhl", q_nope, p["wk_b"])
    scale = 1.0 / ((dn + dr) ** 0.5)
    s = (
        jnp.einsum("bqhl,bsl->bhqs", q_abs, ckv)
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, krp)
    ).astype(jnp.float32) * scale
    S_c = ckv.shape[1]
    mask = (jnp.arange(S_c) <= pos)[None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(ckv.dtype)
    ctx_l = jnp.einsum("bhqs,bsl->bqhl", w, ckv)  # latent-space context
    ctx = jnp.einsum("bqhl,lhk->bqhk", ctx_l, p["wv_b"])  # absorb wv_b out
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    return out, MLACache(ckv, krp)
