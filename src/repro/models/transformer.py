"""Model assembly: blocks, run-length layer segmentation, scan-over-layers.

Layers are segmented into maximal runs of identical block kind; runs of
length >= 2 are executed as a ``lax.scan`` over stacked parameters (compact
HLO, fast compiles for the 61/80-layer archs), shorter runs are unrolled
(hybrid patterns).  Remat policy wraps the per-block function.

Block kinds: dense | moe | mla_dense | mla_moe | attn (hybrid local-window)
| mlstm | slstm | rglru.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .layers import PT, mlp_apply, mlp_template, norm_template, rmsnorm, stack_template


def layer_kinds(cfg) -> List[str]:
    kinds = []
    for i in range(cfg.n_layers):
        k = cfg.block_kind(i)
        if cfg.use_mla:
            k = "mla_dense" if k == "dense" else ("mla_moe" if k == "moe" else k)
        kinds.append(k)
    return kinds


def segments(cfg) -> List[Tuple[str, int]]:
    """Run-length encoding of layer kinds."""
    out: List[Tuple[str, int]] = []
    for k in layer_kinds(cfg):
        if out and out[-1][0] == k:
            out[-1] = (k, out[-1][1] + 1)
        else:
            out.append((k, 1))
    return out


# ---------------------------------------------------------------------------
# per-kind templates
# ---------------------------------------------------------------------------


def block_template(kind: str, cfg) -> Dict[str, Any]:
    d = cfg.d_model
    if kind in ("dense", "attn"):
        return {
            "ln1": norm_template(d),
            "attn": attn_mod.attn_template(cfg),
            "ln2": norm_template(d),
            "mlp": mlp_template(d, cfg.d_ff),
        }
    if kind == "moe":
        return {
            "ln1": norm_template(d),
            "attn": attn_mod.attn_template(cfg),
            "ln2": norm_template(d),
            "moe": moe_mod.moe_template(cfg),
        }
    if kind == "mla_dense":
        return {
            "ln1": norm_template(d),
            "mla": mla_mod.mla_template(cfg),
            "ln2": norm_template(d),
            "mlp": mlp_template(d, cfg.d_ff),
        }
    if kind == "mla_moe":
        return {
            "ln1": norm_template(d),
            "mla": mla_mod.mla_template(cfg),
            "ln2": norm_template(d),
            "moe": moe_mod.moe_template(cfg),
        }
    if kind == "mlstm":
        return {"ln": norm_template(d), "cell": ssm_mod.mlstm_template(cfg)}
    if kind == "slstm":
        return {"ln": norm_template(d), "cell": ssm_mod.slstm_template(cfg)}
    if kind == "rglru":
        return {
            "ln1": norm_template(d),
            "rec": rglru_mod.rglru_template(cfg),
            "ln2": norm_template(d),
            "mlp": mlp_template(d, cfg.d_ff),
        }
    raise ValueError(kind)


def init_block_cache(kind: str, cfg, batch: int, cache_len: int, dtype):
    """Decode-state pytree for one layer of the given kind."""
    if kind in ("dense", "moe"):
        return attn_mod.init_cache(cfg, batch, cache_len, dtype)
    if kind in ("mla_dense", "mla_moe"):
        return mla_mod.init_mla_cache(cfg, batch, cache_len, dtype)
    if kind == "attn":  # hybrid local window: rolling buffer
        win = min(cfg.window_size, cache_len) or cache_len
        return attn_mod.init_cache(cfg, batch, win, dtype)
    if kind == "mlstm":
        du = int(cfg.d_model * cfg.mlstm_proj_factor)
        return ssm_mod.mlstm_init_state(batch, cfg.n_heads, du // cfg.n_heads)
    if kind == "slstm":
        return ssm_mod.slstm_init_state(batch, cfg.d_model)
    if kind == "rglru":
        return rglru_mod.rglru_init_state(batch, cfg.lru_width, cfg.conv_width)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# per-kind forward (sequence) and decode (single token)
# ---------------------------------------------------------------------------


def block_forward(kind: str, cfg, p, x, positions, state=None):
    """Full-sequence pass.  Returns (x, new_state_or_None, aux)."""
    from repro.distributed.sharding import constrain

    # anchor the residual stream once per block: batch stays on (pod, data),
    # d_model replicated — otherwise GSPMD propagates weight shardings into
    # activations and inserts per-block reshards
    x = constrain(x, "batch", "seq", None)
    aux = jnp.zeros((), x.dtype)
    if kind in ("dense", "attn", "moe"):
        win = cfg.window_size if kind == "attn" else 0
        h = attn_mod.attention(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, positions, window=win)
        x = x + h
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            out, aux = moe_mod.moe_ffn(p["moe"], y, cfg)
        else:
            out = mlp_apply(p["mlp"], y, cfg.act)
        return x + out, None, aux
    if kind in ("mla_dense", "mla_moe"):
        h = mla_mod.mla_attention(p["mla"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, positions)
        x = x + h
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "mla_moe":
            out, aux = moe_mod.moe_ffn(p["moe"], y, cfg)
        else:
            out = mlp_apply(p["mlp"], y, cfg.act)
        return x + out, None, aux
    if kind == "mlstm":
        out, st = ssm_mod.mlstm_block(p["cell"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg, state=state)
        return x + out, st, aux
    if kind == "slstm":
        out, st = ssm_mod.slstm_block(p["cell"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg, state=state)
        return x + out, st, aux
    if kind == "rglru":
        out, st = rglru_mod.rglru_block(p["rec"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, state=state)
        x = x + out
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_apply(p["mlp"], y, cfg.act), st, aux
    raise ValueError(kind)


def block_prefill(kind: str, cfg, p, x, positions, cache_len: int):
    """Full-sequence pass that also produces the decode cache.

    Returns (x, cache).  Attention caches are filled at slots [0, S) (rolling
    for local windows); SSM/hybrid recurrences return their final state.
    """
    if kind in ("dense", "attn", "moe"):
        win = cfg.window_size if kind == "attn" else 0
        h, cache = attn_mod.prefill_attention(
            p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, positions,
            cache_len, window=win,
        )
        x = x + h
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            out, _ = moe_mod.moe_ffn(p["moe"], y, cfg)
        else:
            out = mlp_apply(p["mlp"], y, cfg.act)
        return x + out, cache
    if kind in ("mla_dense", "mla_moe"):
        h, cache = mla_mod.mla_prefill(
            p["mla"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, positions, cache_len
        )
        x = x + h
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "mla_moe":
            out, _ = moe_mod.moe_ffn(p["moe"], y, cfg)
        else:
            out = mlp_apply(p["mlp"], y, cfg.act)
        return x + out, cache
    # recurrent kinds: the forward state IS the decode cache
    x, st, _ = block_forward(kind, cfg, p, x, positions, state=None)
    return x, st


def block_decode(kind: str, cfg, p, x, cache, pos):
    """Single-token pass.  Returns (x, new_cache)."""
    if kind in ("dense", "attn", "moe"):
        win = cfg.window_size if kind == "attn" else 0
        h, cache = attn_mod.decode_attention(
            p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, cache, pos, window=win
        )
        x = x + h
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "moe":
            out, _ = moe_mod.moe_ffn(p["moe"], y, cfg)
        else:
            out = mlp_apply(p["mlp"], y, cfg.act)
        return x + out, cache
    if kind in ("mla_dense", "mla_moe"):
        h, cache = mla_mod.mla_decode(p["mla"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, cache, pos)
        x = x + h
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "mla_moe":
            out, _ = moe_mod.moe_ffn(p["moe"], y, cfg)
        else:
            out = mlp_apply(p["mlp"], y, cfg.act)
        return x + out, cache
    if kind == "mlstm":
        out, cache = ssm_mod.mlstm_block(
            p["cell"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg, state=cache, decode=True
        )
        return x + out, cache
    if kind == "slstm":
        out, cache = ssm_mod.slstm_block(
            p["cell"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg, state=cache, decode=True
        )
        return x + out, cache
    if kind == "rglru":
        out, cache = rglru_mod.rglru_block(
            p["rec"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg, state=cache, decode=True
        )
        x = x + out
        y = rmsnorm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_apply(p["mlp"], y, cfg.act), cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    raise ValueError(cfg.remat)


def stack_templates(cfg) -> List[Tuple[str, int, Any]]:
    """[(kind, n, template)] per segment; n>1 -> stacked parameters.

    Parameters are stacked whenever a segment has more than one layer, whether
    it executes as a ``lax.scan`` (scan_layers=True) or unrolled — so the
    parameter pytree (and checkpoints) are identical across the toggle.
    """
    out = []
    for kind, n in segments(cfg):
        t = block_template(kind, cfg)
        if n > 1:
            t = stack_template(t, n)
        out.append((kind, n, t))
    return out


def forward_stack(cfg, seg_params, x, positions, states=None):
    """Run all segments over a full sequence.

    states: optional list (per segment) of stacked/single block states
    (SSM/hybrid prefill); returns (x, new_states, aux_total).
    """
    aux_total = jnp.zeros((), x.dtype)
    new_states = []
    for si, ((kind, n, _), p) in enumerate(zip(stack_templates(cfg), seg_params)):
        st_in = states[si] if states is not None else None

        if n == 1 or not cfg.scan_layers:
            if n == 1:
                block = _maybe_remat(
                    functools.partial(block_forward, kind, cfg), cfg
                )
                x, st, aux = block(p, x, positions, st_in)
                new_states.append(st)
                aux_total = aux_total + aux
            else:  # unrolled stack (scan_layers=False): params are stacked
                sts = []
                for li in range(n):
                    pl = jax.tree.map(lambda a: a[li], p)
                    sl = jax.tree.map(lambda a: a[li], st_in) if st_in is not None else None
                    block = _maybe_remat(
                        functools.partial(block_forward, kind, cfg), cfg
                    )
                    x, st, aux = block(pl, x, positions, sl)
                    sts.append(st)
                    aux_total = aux_total + aux
                new_states.append(
                    jax.tree.map(lambda *a: jnp.stack(a), *sts) if sts[0] is not None else None
                )
            continue

        has_state = kind in ("mlstm", "slstm", "rglru")

        def body(carry, xs):
            xc, auxc = carry
            if has_state:
                pl, sl = xs
                xc, st, aux = block_fn(pl, xc, positions, sl)
            else:
                pl = xs
                xc, st, aux = block_fn(pl, xc, positions, None)
            return (xc, auxc + aux), st

        block_fn = _maybe_remat(functools.partial(block_forward, kind, cfg), cfg)
        xs = (p, st_in) if has_state else p
        (x, aux_total), sts = jax.lax.scan(body, (x, aux_total), xs)
        new_states.append(sts if has_state else None)
    return x, new_states, aux_total


def prefill_stack(cfg, seg_params, x, positions, cache_len: int):
    """Full-sequence pass through all segments, producing decode caches.

    Returns (x, caches) with caches parallel to the segment structure
    (stacked along the scan dim where layers are scanned) — the exact pytree
    :func:`decode_stack` consumes.
    """
    caches = []
    for (kind, n, _), p in zip(stack_templates(cfg), seg_params):
        # cache_len is shape-determining: keep it static by closing over it
        # (never pass it through the jax.checkpoint boundary).
        def pf(pl, xc, pos, _kind=kind):
            return block_prefill(_kind, cfg, pl, xc, pos, cache_len)

        block_fn = _maybe_remat(pf, cfg)
        if n == 1 or not cfg.scan_layers:
            if n == 1:
                x, c = block_fn(p, x, positions)
                caches.append(c)
            else:
                cs = []
                for li in range(n):
                    pl = jax.tree.map(lambda a: a[li], p)
                    x, c = block_fn(pl, x, positions)
                    cs.append(c)
                caches.append(jax.tree.map(lambda *a: jnp.stack(a), *cs))
            continue

        def body(xc, pl, _fn=block_fn):
            xc, c = _fn(pl, xc, positions)
            return xc, c

        x, cs = jax.lax.scan(body, x, p)
        caches.append(cs)
    return x, caches


def decode_stack(cfg, seg_params, x, caches, pos):
    """Single-token pass through all segments; returns (x, new_caches)."""
    new_caches = []
    for (kind, n, _), p, cache in zip(stack_templates(cfg), seg_params, caches):
        if n == 1 or not cfg.scan_layers:
            if n == 1:
                x, c = block_decode(kind, cfg, p, x, cache, pos)
                new_caches.append(c)
            else:
                cs = []
                for li in range(n):
                    pl = jax.tree.map(lambda a: a[li], p)
                    cl = jax.tree.map(lambda a: a[li], cache)
                    x, c = block_decode(kind, cfg, pl, x, cl, pos)
                    cs.append(c)
                new_caches.append(jax.tree.map(lambda *a: jnp.stack(a), *cs))
            continue

        def body(xc, xs):
            pl, cl = xs
            xc, c = block_decode(kind, cfg, pl, xc, cl, pos)
            return xc, c

        x, cs = jax.lax.scan(body, x, (p, cache))
        new_caches.append(cs)
    return x, new_caches


def init_stack_states(cfg, batch: int, cache_len: int, dtype):
    """Decode caches parallel to the segment structure (stacked where scanned)."""
    out = []
    for kind, n, _ in stack_templates(cfg):
        one = init_block_cache(kind, cfg, batch, cache_len, dtype)
        if n > 1:
            out.append(jax.tree.map(lambda a: jnp.stack([a] * n), one))
        else:
            out.append(one)
    return out


# ---------------------------------------------------------------------------
# cache templates (shapes + logical sharding axes, for the dry-run/launcher)
# ---------------------------------------------------------------------------


def cache_template(kind: str, cfg, batch: int, cache_len: int):
    """PT template mirroring :func:`init_block_cache` (same pytree structure).

    Gives every cache leaf logical axes so distributed.sharding can derive
    PartitionSpecs for decode-cell inputs the same way it does for params.
    """
    from . import attention as A
    from . import mla as M

    B = batch
    if kind in ("dense", "moe", "attn"):
        S = cache_len
        if kind == "attn":
            S = min(cfg.window_size, cache_len) or cache_len
        kv, hd = cfg.n_kv_heads, cfg.head_dim
        leaf = PT((B, S, kv, hd), ("batch", "seq_kv", "kv_heads", "head_dim"), "zeros")
        return A.KVCache(leaf, leaf)
    if kind in ("mla_dense", "mla_moe"):
        return M.MLACache(
            PT((B, cache_len, cfg.kv_lora_rank), ("batch", "seq_kv", None), "zeros"),
            PT((B, cache_len, cfg.qk_rope_dim), ("batch", "seq_kv", None), "zeros"),
        )
    if kind == "mlstm":
        du = int(cfg.d_model * cfg.mlstm_proj_factor)
        hd = du // cfg.n_heads
        return ssm_mod.MLSTMState(
            PT((B, cfg.n_heads, hd, hd), ("batch", "heads", None, None), "zeros"),
            PT((B, cfg.n_heads, hd), ("batch", "heads", None), "zeros"),
            PT((B, cfg.n_heads), ("batch", "heads"), "zeros"),
        )
    if kind == "slstm":
        leaf = PT((B, cfg.d_model), ("batch", None), "zeros")
        return ssm_mod.SLSTMState(leaf, leaf, leaf, leaf)
    if kind == "rglru":
        return rglru_mod.RGLRUState(
            PT((B, cfg.lru_width), ("batch", "lru"), "zeros"),
            PT((B, cfg.conv_width - 1, cfg.lru_width), ("batch", None, "lru"), "zeros"),
        )
    raise ValueError(kind)


def stack_cache_template(cfg, batch: int, cache_len: int):
    """Cache templates parallel to init_stack_states' pytree structure."""
    from .layers import stack_template as _stack

    out = []
    for kind, n, _ in stack_templates(cfg):
        one = cache_template(kind, cfg, batch, cache_len)
        if n > 1:
            one = _stack(one, n)
        out.append(one)
    return out
