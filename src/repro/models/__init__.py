"""repro.models — the LM family backing the 10 assigned architectures."""
from .lm import (  # noqa: F401
    decode_step,
    embed_inputs,
    forward,
    init_caches,
    init_params,
    lm_template,
    loss_and_metrics,
    prefill_step,
)
