"""Parameter templates, initializers, and core layers (RMSNorm/RoPE/MLP).

Models are pure-functional: each module exposes ``<mod>_template(cfg)``
returning a tree of :class:`PT` (shape + logical axes + init), from which
``init_tree`` materializes parameters and ``distributed.sharding`` derives
PartitionSpecs — one source of truth, so param trees and sharding specs can
never drift apart.

Logical axes used across the zoo: batch, seq, embed, vocab, heads, kv_heads,
head_dim, mlp, experts, expert_mlp, q_lora, kv_lora, lru, conv, stack (the
scan-over-layers dim, never sharded).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PT:
    """Parameter template: shape, per-dim logical axes, init spec."""

    shape: tuple
    axes: tuple
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None  # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(t: PT, key, dtype):
    if t.init == "zeros":
        return jnp.zeros(t.shape, dtype)
    if t.init == "ones":
        return jnp.ones(t.shape, dtype)
    if t.init == "embed":
        scale = t.scale if t.scale is not None else 1.0
        return (jax.random.normal(key, t.shape) * scale).astype(dtype)
    fan_in = t.shape[0] if len(t.shape) == 1 else int(np.prod(t.shape[:-1]))
    scale = t.scale if t.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, t.shape) * scale).astype(dtype)


def init_tree(template: Dict[str, Any], key, dtype=jnp.float32):
    """Materialize a parameter tree from a template tree (dict-of-dicts)."""
    leaves, treedef = jax.tree_util.tree_flatten(
        template, is_leaf=lambda x: isinstance(x, PT)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(t, k, dtype) for t, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def template_map(fn, template):
    """Map over PT leaves of a template tree."""
    return jax.tree_util.tree_map(
        fn, template, is_leaf=lambda x: isinstance(x, PT)
    )


def stack_template(template: Dict[str, Any], n: int):
    """Prepend a ``stack`` dim of size n to every leaf (scan-over-layers)."""
    return template_map(
        lambda t: PT((n,) + t.shape, ("stack",) + t.axes, t.init, t.scale),
        template,
    )


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def norm_template(d: int) -> PT:
    return PT((d,), ("embed",), "ones")


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, half, dtype=np.float64) / half))
    return jnp.asarray(inv, dtype=jnp.float32)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    if x.ndim == ang.ndim + 1:  # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_template(d: int, d_ff: int) -> Dict[str, PT]:
    return {
        "gate": PT((d, d_ff), ("embed", "mlp")),
        "up": PT((d, d_ff), ("embed", "mlp")),
        "down": PT((d_ff, d), ("mlp", "embed")),
    }


def mlp_apply(p, x, act: str = "silu"):
    g = x @ p["gate"]
    u = x @ p["up"]
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return (a * u) @ p["down"]


def embed_template(vocab: int, d: int) -> PT:
    return PT((vocab, d), ("vocab", "embed"), "embed", 0.02)


def unembed_apply(params, x, cfg):
    """Logits head; tied or untied."""
    if cfg.tie_embeddings:
        w = params["embed"]
    else:
        w = params["unembed"]
    logits = x @ w.T if cfg.tie_embeddings else x @ w
    if cfg.logits_soft_cap:
        c = cfg.logits_soft_cap
        logits = jnp.tanh(logits / c) * c
    return logits
