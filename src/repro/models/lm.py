"""Top-level language model: embed -> block stack -> norm -> logits.

Covers all three input modes of the assigned architectures:

* ``tokens``      — standard LM (8 of 10 archs): int32 token ids.
* ``embeddings``  — modality-frontend stub (musicgen): the EnCodec frame
  embeddings arrive precomputed as (B, S, D); the output head still predicts
  codec token ids over ``vocab_size``.
* ``mixed``       — VLM backbone stub (llava-next): precomputed anyres patch
  embeddings (B, S_img, D) are prepended to embedded text tokens; labels for
  image positions are masked with -1.

All functions are pure; parameters follow the template produced by
:func:`lm_template` (one source of truth for shapes, logical sharding axes,
and initializers — see models/layers.py).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from . import transformer as tfm
from .layers import PT, embed_template, init_tree, norm_template, rmsnorm, unembed_apply

Params = Dict[str, Any]


def compute_dtype(cfg):
    return jnp.dtype(cfg.compute_dtype)


def param_dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def lm_template(cfg) -> Params:
    t: Params = {
        "segments": [tpl for (_, _, tpl) in tfm.stack_templates(cfg)],
        "final_norm": norm_template(cfg.d_model),
    }
    if cfg.input_mode in ("tokens", "mixed"):
        t["embed"] = embed_template(cfg.vocab_size, cfg.d_model)
    if not cfg.tie_embeddings:
        t["unembed"] = PT(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), "normal", 0.02
        )
    return t


def init_params(cfg, key) -> Params:
    return init_tree(lm_template(cfg), key, dtype=param_dtype(cfg))


def embed_inputs(cfg, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
    """(B, S, D) input activations from the arch's input mode.

    The output is sharding-constrained to (batch, seq, -) — without the
    constraint GSPMD propagates the *table's* sharding (vocab on model, embed
    on fsdp) into the activations and every block pays a reshard (the
    "involuntary full rematerialization" warning in the first dry-runs).
    """
    dt = compute_dtype(cfg)
    if cfg.input_mode == "tokens":
        x = params["embed"][batch["tokens"]].astype(dt)
    elif cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(dt)
    elif cfg.input_mode == "mixed":
        xt = params["embed"][batch["tokens"]].astype(dt)
        x = jnp.concatenate([batch["embeds"].astype(dt), xt], axis=1)
    else:
        raise ValueError(cfg.input_mode)
    return constrain(x, "batch", "seq", None)


def _head(cfg, params: Params, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = unembed_apply(params, x, cfg)
    # (B, S, V) logits are the single largest activation at vocab 50k-256k:
    # shard the vocab dim over the model axis (1/16th per device); the loss
    # computes its reductions on the shards and psums (B, S) partials.
    return constrain(logits, "batch", "seq", "vocab")


def forward(cfg, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
    """Full-sequence logits (B, S, V) (training / evaluation path)."""
    x = embed_inputs(cfg, params, batch)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, _, _ = tfm.forward_stack(cfg, params["segments"], x, positions)
    return _head(cfg, params, x)


def loss_and_metrics(cfg, params: Params, batch: Dict[str, jax.Array]):
    """Next-token cross entropy (f32 reductions) + MoE aux loss.

    ``batch["labels"]`` is (B, S) int32 with -1 = masked (padding, image
    positions).  Returns (loss, metrics dict).
    """
    x = embed_inputs(cfg, params, batch)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, _, aux = tfm.forward_stack(cfg, params["segments"], x, positions)
    logits = _head(cfg, params, x)

    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    # max-shifted logsumexp: the f32 exp/sum fuses over the vocab-sharded
    # logits without materializing a second (B, S, V) f32 buffer
    logits32 = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits32, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits32 - m), axis=-1)) + m[..., 0]
    tgt = jnp.take_along_axis(logits32, lab[..., None], axis=-1)[..., 0]
    nll = (lse - tgt) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = nll.sum() / denom
    aux32 = aux.astype(jnp.float32)
    loss = ce + cfg.moe_aux_coef * aux32
    return loss, {"loss": loss, "ce": ce, "aux": aux32, "tokens": mask.sum()}


# ---------------------------------------------------------------------------
# serving path
# ---------------------------------------------------------------------------


def init_caches(cfg, batch: int, cache_len: int, dtype=None):
    """Decode caches, parallel to the segment structure."""
    return tfm.init_stack_states(cfg, batch, cache_len, dtype or compute_dtype(cfg))


def prefill_step(cfg, params: Params, batch: Dict[str, jax.Array], cache_len: int):
    """Process the prompt; returns (last-token logits (B, V), caches).

    Only the final position's logits are materialized — at 32 K prompts the
    full (B, S, V) logits tensor would dominate HBM for nothing.
    """
    x = embed_inputs(cfg, params, batch)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x, caches = tfm.prefill_stack(cfg, params["segments"], x, positions, cache_len)
    logits = _head(cfg, params, x[:, -1:, :])
    return logits[:, 0], caches


def decode_step(cfg, params: Params, caches, tokens: jax.Array, pos):
    """One decode step.  tokens (B, 1) int32, pos scalar int32 (absolute).

    Returns (logits (B, V), new caches).  For ``embeddings`` input mode the
    generated codec ids are embedded with the output head's transpose (the
    frontend stub has no encoder at decode time).
    """
    dt = compute_dtype(cfg)
    if cfg.input_mode in ("tokens", "mixed"):
        x = params["embed"][tokens].astype(dt)
    else:
        w = params["embed"] if cfg.tie_embeddings else params["unembed"].T
        x = w[tokens].astype(dt)
    x, caches = tfm.decode_stack(cfg, params["segments"], x, caches, pos)
    logits = _head(cfg, params, x)
    return logits[:, 0], caches
