"""RecurrentGemma blocks: RG-LRU recurrence + causal conv, local-window MQA.

The RG-LRU is a *diagonal linear* recurrence (gates depend on the input, not
the hidden state), so training/prefill lower to ``lax.associative_scan`` —
O(log S) depth, fully parallel — and decode is a 1-step update with constant
state (lru h + a conv_width-1 input tail + a window-sized attention cache):
the reason recurrentgemma-2b runs the long_500k cell (DESIGN.md SS5).
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from .layers import PT

_C = 8.0  # RG-LRU decay sharpness constant (Griffin paper)


def rglru_template(cfg) -> Dict[str, PT]:
    d, w = cfg.d_model, cfg.lru_width
    cw = cfg.conv_width
    return {
        "in_x": PT((d, w), ("embed", "lru")),
        "in_y": PT((d, w), ("embed", "lru")),
        "conv": PT((cw, w), ("conv", "lru"), "normal", 0.1),
        "conv_b": PT((w,), ("lru",), "zeros"),
        "wr": PT((w, w), ("lru", "lru2"), "normal", 0.02),
        "br": PT((w,), ("lru",), "zeros"),
        "wi": PT((w, w), ("lru", "lru2"), "normal", 0.02),
        "bi": PT((w,), ("lru",), "zeros"),
        "lam": PT((w,), ("lru",), "ones"),  # softplus(lam) > 0
        "out": PT((w, d), ("lru", "embed")),
    }


class RGLRUState(NamedTuple):
    h: jax.Array  # (B, W) recurrent state
    conv_tail: jax.Array  # (B, conv_width-1, W) last inputs


def rglru_init_state(batch: int, width: int, conv_width: int, dtype=jnp.float32):
    return RGLRUState(
        jnp.zeros((batch, width), dtype),
        jnp.zeros((batch, conv_width - 1, width), dtype),
    )


def _causal_conv(p, u, tail):
    """u: (B,S,W); tail: (B,cw-1,W) previous inputs.  Returns same-shape out."""
    cw = p["conv"].shape[0]
    ext = jnp.concatenate([tail.astype(u.dtype), u], axis=1)  # (B, S+cw-1, W)
    out = sum(
        ext[:, j : j + u.shape[1]] * p["conv"][j][None, None, :] for j in range(cw)
    )
    return out + p["conv_b"], ext[:, -(cw - 1) :]


def _lru_coeffs(p, u):
    """a (decay) and b (input) coefficients, f32.  u: (..., W)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["wr"].astype(jnp.float32) + p["br"])
    i = jax.nn.sigmoid(uf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * uf)
    return a, b


def rglru_scan(p, u, h0):
    """Parallel RG-LRU over (B,S,W) with initial state h0 (B,W)."""
    a, b = _lru_coeffs(p, u)
    # fold h0 into the first input term
    b = b.at[:, 0].add(a[:, 0] * h0.astype(b.dtype))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh.astype(u.dtype), hh[:, -1]


def rglru_block(p, x, cfg, *, state: RGLRUState | None = None, decode=False):
    """Full recurrent block (norm/residual by caller)."""
    B = x.shape[0]
    if state is None:
        state = rglru_init_state(B, cfg.lru_width, cfg.conv_width)
    y = jax.nn.gelu(x @ p["in_y"])
    u = x @ p["in_x"]
    u, tail = _causal_conv(p, u, state.conv_tail)
    if decode:
        a, b = _lru_coeffs(p, u[:, 0])
        h1 = a * state.h.astype(jnp.float32) + b
        out = (h1[:, None, :].astype(x.dtype) * y) @ p["out"]
        return out, RGLRUState(h1.astype(state.h.dtype), tail)
    hh, h_last = rglru_scan(p, u, state.h)
    out = (hh * y) @ p["out"]
    return out, RGLRUState(h_last.astype(state.h.dtype), tail)
