"""GQA/MQA/MHA attention with RoPE, KV caches, local windows, query-block scan.

One implementation serves nine of the ten architectures (DeepSeek's MLA lives
in mla.py).  Memory discipline: sequences >= ``cfg.attn_q_block`` use a
``lax.scan`` over query blocks so the materialized score tile is
(q_block x S) instead of (S x S) — mandatory for the 32 K prefill cells.

KV cache layout: (B, S_max, n_kv, head_dim) per layer, updated with
``dynamic_update_slice_in_dim`` at the decode position; local-window archs
(RecurrentGemma) keep a rolling cache of ``window`` entries instead.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

from .layers import PT, apply_rope, rmsnorm

NEG_INF = -1e30


def _h_eff(cfg) -> int:
    """Head count used for attention *activations*: padded to the TP degree
    when the real count doesn't divide the model axis (phi3 40->48,
    llava 56->64, recurrentgemma 10->16).  Parameters keep the exact public
    head count; the pad rows are zeros appended to activations and sliced
    off after the context einsum (EXPERIMENTS.md SSPerf iteration 2)."""
    return max(cfg.tp_head_pad, cfg.n_heads)


def attn_template(cfg) -> Dict[str, PT]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = {
        "wq": PT((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": PT((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": PT((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": PT((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = PT((h, hd), ("heads", "head_dim"), "zeros")
        t["bk"] = PT((kv, hd), ("kv_heads", "head_dim"), "zeros")
        t["bv"] = PT((kv, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        t["q_norm"] = PT((hd,), ("head_dim",), "ones")
        t["k_norm"] = PT((hd,), ("head_dim",), "ones")
    return t


def _qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _scores_softmax_ctx(q, k, v, mask, scale, *, pad_to: int = 0):
    """Attention core for train/prefill: repeat-KV form.

    q (B,Sq,H,hd), k/v (B,Sk,KV,hd).  K/V are expanded to H heads and
    (optionally) zero-padded to ``pad_to`` so every activation shards the
    SAME ``heads_act`` axis — the (KV, G) reshape of the grouped form moves
    the head sharding onto the (usually non-divisible) KV dim and pays a
    reshard per layer (measured in EXPERIMENTS.md SSPerf).  The causal mask
    enters as an additive bias (one fused add) instead of a select.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    if pad_to and pad_to > H:
        pad = [(0, 0), (0, 0), (0, pad_to - H), (0, 0)]
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    q = constrain(q, "batch", None, "heads_act", None)
    k = constrain(k, "batch", None, "heads_act", None)
    v = constrain(v, "batch", None, "heads_act", None)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    s = s + jnp.where(mask[:, None, :, :], 0.0, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bhqs,bshd->bqhd", w, v)
    if pad_to and pad_to > H:
        ctx = ctx[:, :, :H]
    return ctx


def _decode_ctx(q, k, v, mask, scale):
    """Attention core for decode: grouped-query form against a cache whose
    *sequence* dim is sharded over the model axis (seq_kv rule) — each device
    scores its cache slice, the softmax reduces with a tiny psum, and the
    GQA cache stays at KV width (no repeat: decode is cache-bandwidth-bound).
    q (B,1,H,hd), k/v (B,S_c,KV,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    s = s + jnp.where(mask[:, None, None, :, :], 0.0, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    ctx = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return ctx.reshape(B, Sq, H, hd)


def _flash_attention(q, k, v, cfg, scale, *, window: int = 0, pad_to: int = 0):
    """Online-softmax attention: lax.scan over query blocks x kv blocks.

    The flash-attention insight expressed at the XLA level (DESIGN.md SS2
    hardware-adaptation note): score tiles live at (qb, kvb) and are consumed
    immediately by the running (m, l, acc) update, so HBM traffic per layer
    drops from O(S^2) (the materialized-score path measured at 2.9 TiB/device
    for phi3 prefill_32k) to O(S * d).  Both loops are constant-trip scans —
    the roofline cost model multiplies them exactly.  Numerics: f32 running
    max/denominator; equals the reference softmax path to fp tolerance
    (tests/test_models.py::test_flash_equals_reference).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    if pad_to and pad_to > H:
        pad = [(0, 0), (0, 0), (0, pad_to - H), (0, 0)]
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    q = constrain(q, "batch", None, "heads_act", None)
    k = constrain(k, "batch", None, "heads_act", None)
    v = constrain(v, "batch", None, "heads_act", None)
    Hp = q.shape[2]
    qb = min(cfg.attn_q_block, S)
    kvb = min(cfg.attn_kv_block or S, S)
    assert S % qb == 0 and S % kvb == 0, (S, qb, kvb)
    nq, nkv = S // qb, S // kvb

    qs = q.transpose(0, 2, 1, 3).reshape(B, Hp, nq, qb, hd).transpose(2, 0, 1, 3, 4)
    ks = k.transpose(0, 2, 1, 3).reshape(B, Hp, nkv, kvb, hd).transpose(2, 0, 1, 3, 4)
    vs = v.transpose(0, 2, 1, 3).reshape(B, Hp, nkv, kvb, hd).transpose(2, 0, 1, 3, 4)

    def q_step(_, xs):
        qi, i = xs  # (B,Hp,qb,hd), scalar block index
        qpos = i * qb + jnp.arange(qb)

        def kv_step(carry, ys):
            m, l, acc = carry
            kj, vj, j = ys  # (B,Hp,kvb,hd), scalar
            kpos = j * kvb + jnp.arange(kvb)
            s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj).astype(jnp.float32) * scale
            msk = kpos[None, :] <= qpos[:, None]
            if window:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = s + jnp.where(msk, 0.0, NEG_INF)[None, None]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hp, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hp, qb), jnp.float32)
        a0 = jnp.zeros((B, Hp, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, vs, jnp.arange(nkv))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # (nq, B, Hp, qb, hd) -> (B, S, Hp, hd)
    ctx = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, Hp, hd)
    if pad_to and pad_to > H:
        ctx = ctx[:, :, :H]
    return ctx


def causal_attention(q, k, v, cfg, *, window: int = 0):
    """Full-sequence causal attention, scanning query blocks when long."""
    B, S, H, hd = q.shape
    scale = 1.0 / (hd**0.5) if not cfg.use_mla else 1.0 / ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** 0.5)
    if cfg.attn_kv_block and S > cfg.attn_kv_block:
        return _flash_attention(
            q, k, v, cfg, scale, window=window, pad_to=_h_eff(cfg)
        )
    qb = cfg.attn_q_block
    kpos = jnp.arange(S)

    def block_mask(qpos):
        m = kpos[None, :] <= qpos[:, None]
        if window:
            m &= kpos[None, :] > qpos[:, None] - window
        return m

    pad_to = _h_eff(cfg)
    if S <= qb:
        mask = jnp.broadcast_to(block_mask(jnp.arange(S)), (B, S, S))
        return _scores_softmax_ctx(q, k, v, mask, scale, pad_to=pad_to)

    assert S % qb == 0, (S, qb)
    nb = S // qb
    qblocks = q.reshape(B, nb, qb, H, hd).transpose(1, 0, 2, 3, 4)

    def step(_, xs):
        qi, i = xs
        qpos = i * qb + jnp.arange(qb)
        mask = jnp.broadcast_to(block_mask(qpos), (B, qb, S))
        ctx = _scores_softmax_ctx(qi, k, v, mask, scale, pad_to=pad_to)
        return None, ctx

    _, ctxs = jax.lax.scan(step, None, (qblocks, jnp.arange(nb)))
    return ctxs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)


def attention(p, x, cfg, positions, *, window: int = 0):
    q, k, v = _qkv(p, x, cfg, positions)
    ctx = causal_attention(q, k, v, cfg, window=window)
    return jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_cache, KV, hd)
    v: jax.Array


def init_cache(cfg, batch: int, cache_len: int, dtype) -> KVCache:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, cache_len, kv, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill_attention(p, x, cfg, positions, cache_len: int, *, window: int = 0):
    """Full-sequence pass that also fills the decode cache.

    Returns (out (B,S,D), KVCache).  Full caches hold token t at slot t
    (padded to ``cache_len``); windowed caches are rolling buffers with token
    t at slot ``t % window`` — the same layout :func:`decode_attention`
    expects, so prefill -> decode is seamless (equivalence-tested).
    """
    q, k, v = _qkv(p, x, cfg, positions)
    ctx = causal_attention(q, k, v, cfg, window=window)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    B, S = x.shape[:2]
    if window:
        win = min(window, cache_len)
        cache = init_cache(cfg, B, win, k.dtype)
        keep = min(S, win)
        slots = (jnp.arange(S - keep, S) % win).astype(jnp.int32)
        ck = cache.k.at[:, slots].set(k[:, S - keep :])
        cv = cache.v.at[:, slots].set(v[:, S - keep :])
    else:
        pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
        ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
    return out, KVCache(ck, cv)


def decode_attention(p, x, cfg, cache: KVCache, pos, *, window: int = 0):
    """One-token decode.  x: (B, 1, D); pos: scalar int32 (current index).

    For windowed archs the cache is a rolling buffer of ``window`` slots
    (slot = pos % window); otherwise a full-length buffer indexed by pos.
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    S_c = cache.k.shape[1]
    slot = jnp.where(window, pos % jnp.maximum(S_c, 1), pos)
    ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)
    # decode shards the cache's *sequence* dim (seq_kv -> model): each device
    # scores its slice, softmax psums — the KV cache is the decode working
    # set and must not be replicated across the model axis
    ck = constrain(ck, "batch", "seq_kv", "kv_heads", "head_dim")
    cv = constrain(cv, "batch", "seq_kv", "kv_heads", "head_dim")

    scale = 1.0 / (cfg.head_dim**0.5)
    idx = jnp.arange(S_c)
    if window:
        valid = (idx <= slot) | (pos >= S_c)  # rolling buffer fully valid once wrapped
        # entries newer than `window` ago: all slots valid after wrap
        mask = jnp.broadcast_to(valid[None, None, :], (B, 1, S_c))
    else:
        mask = jnp.broadcast_to((idx <= pos)[None, None, :], (B, 1, S_c))
    ctx = _decode_ctx(q, ck, cv, mask, scale)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"])
    return out, KVCache(ck, cv)
