"""Batched serving engine: slot-based continuous batching.

Requests are prefilled individually (prompt lengths vary), their caches are
stacked into fixed batch *slots*, and decode advances every active slot in a
single vmapped step with per-slot positions — the vLLM-style decode batching
pattern expressed in pure JAX.  Finished slots free immediately and are
refilled from the queue without stalling the others (continuous batching).

The per-slot position vector works because every cache write is a
``dynamic_update_slice`` at the slot's own ``pos`` — under ``vmap`` those
become batched scatters, so one XLA program serves any mix of progress.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm


@dataclasses.dataclass
class ServeConfig:
    max_slots: int = 4
    cache_len: int = 512
    max_new_tokens: int = 64
    eos_id: int = -1  # -1: never stops early
    greedy: bool = True
    temperature: float = 1.0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._next_rid = 0
        self._slots: List[Optional[Request]] = [None] * serve_cfg.max_slots
        self._caches = None  # stacked caches, batch = max_slots
        self._pos = np.zeros(serve_cfg.max_slots, dtype=np.int32)
        self._last_tok = np.zeros(serve_cfg.max_slots, dtype=np.int32)
        self._key = jax.random.PRNGKey(0)

        self._prefill = jax.jit(
            lambda p, b: lm.prefill_step(cfg, p, b, self.scfg.cache_len),
            static_argnames=(),
        )
        # batched decode: vmap over the slot axis of (caches, token, pos);
        # params broadcast.  Each slot keeps its own B=1 cache pytree intact
        # (cache leaves have heterogeneous batch positions once layers are
        # scan-stacked, so the slot axis is a fresh leading axis).
        self._decode = jax.jit(
            jax.vmap(
                lambda p, c, t, pos: lm.decode_step(cfg, p, c, t.reshape(1, 1), pos),
                in_axes=(None, 0, 0, 0),
            )
        )

    # -- public -----------------------------------------------------------------
    def submit(self, prompt) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, dtype=np.int32)))
        return rid

    def run(self) -> Dict[int, List[int]]:
        """Run until every submitted request completes."""
        while self.queue or any(s is not None for s in self._slots):
            self.step()
        return {rid: r.generated for rid, r in sorted(self.done.items())}

    # -- internals ----------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            tokens = jnp.asarray(req.prompt)[None, :]
            logits, caches = self._prefill(self.params, {"tokens": tokens})
            tok = self._sample(logits)[0]
            req.generated.append(int(tok))
            self._place(slot, req, caches, len(req.prompt), int(tok))

    def _place(self, slot: int, req: Request, caches, pos: int, tok: int):
        if self._caches is None:
            self._caches = jax.tree.map(
                lambda a: jnp.stack([jnp.zeros_like(a)] * self.scfg.max_slots),
                caches,
            )
        self._caches = jax.tree.map(
            lambda full, one: full.at[slot].set(one), self._caches, caches
        )
        self._slots[slot] = req
        self._pos[slot] = pos
        self._last_tok[slot] = tok

    def _sample(self, logits) -> np.ndarray:
        if self.scfg.greedy:
            return np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)
        self._key, k = jax.random.split(self._key)
        return np.asarray(
            jax.random.categorical(k, logits / self.scfg.temperature, axis=-1),
            dtype=np.int32,
        )

    def _retire(self, slot: int):
        req = self._slots[slot]
        req.done = True
        self.done[req.rid] = req
        self._slots[slot] = None

    def step(self):
        self._admit()
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return
        toks = jnp.asarray(self._last_tok)
        pos = jnp.asarray(self._pos)
        logits, self._caches = self._decode(self.params, self._caches, toks, pos)
        nxt = self._sample(logits[:, 0])
        for i in active:
            req = self._slots[i]
            req.generated.append(int(nxt[i]))
            self._pos[i] += 1
            self._last_tok[i] = int(nxt[i])
            stop = len(req.generated) >= self.scfg.max_new_tokens or (
                self.scfg.eos_id >= 0 and int(nxt[i]) == self.scfg.eos_id
            )
            if stop or self._pos[i] >= self.scfg.cache_len - 1:
                self._retire(i)
