"""repro.serve — slot-based continuous-batching inference engine."""
from .engine import Engine, Request, ServeConfig  # noqa: F401
