"""Object recipes: the manifest layer between objects and chunk keys.

An object put through the service is recorded as a *recipe* — the ordered
list of content-addressed chunk keys that reassemble it, plus the whole-object
SHA-256 for end-to-end restore verification (chunk keys already verify each
chunk; the object digest additionally catches recipe corruption, i.e. right
chunks in the wrong order).  Recipes are the GC roots: a block is live iff
some recipe references it.

``RecipeTable`` persists as one JSON file with atomic replace, same crash
discipline as ``DirBlockStore``'s manifest: a torn write never corrupts the
previous committed table, and blocks orphaned by a crash between block write
and recipe commit are reclaimed by the service's mark-and-sweep GC.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, Iterator, List, Optional


@dataclasses.dataclass
class ObjectRecipe:
    name: str
    size: int  # logical bytes
    sha256: str  # digest of the reassembled object
    keys: List[str]  # chunk keys, in stream order
    chunk_lens: List[int]
    #: owner shard per chunk (sharded service only; None = single-store).
    #: Routing is by accelerator fingerprint, which a restore cannot recompute
    #: from the SHA key alone, so the owner must be recorded at commit time.
    shards: Optional[List[int]] = None
    #: per-chunk 62-bit accelerator fingerprint, packed ``(h1 << 32) | h2``
    #: (None = ingested before fps were recorded, or fingerprints disabled).
    #: This is what lets scripts/reshard.py re-route every chunk with the
    #: shared consistent-hash rule without re-chunking or re-hashing.
    fps: Optional[List[int]] = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        for opt in ("shards", "fps"):  # keep legacy tables byte-stable
            if d[opt] is None:
                d.pop(opt)
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ObjectRecipe":
        shards = d.get("shards")
        fps = d.get("fps")
        return cls(name=d["name"], size=int(d["size"]), sha256=d["sha256"],
                   keys=list(d["keys"]), chunk_lens=[int(x) for x in d["chunk_lens"]],
                   shards=[int(s) for s in shards] if shards is not None else None,
                   fps=[int(f) for f in fps] if fps is not None else None)


class RecipeTable:
    """Name -> recipe mapping, optionally file-backed (atomic JSON)."""

    def __init__(self, path: str | None = None):
        self.path = path
        self._recipes: Dict[str, ObjectRecipe] = {}
        if path is not None and os.path.exists(path):
            with open(path) as f:
                for d in json.load(f)["objects"]:
                    r = ObjectRecipe.from_json(d)
                    self._recipes[r.name] = r

    def __contains__(self, name: str) -> bool:
        return name in self._recipes

    def __len__(self) -> int:
        return len(self._recipes)

    def __iter__(self) -> Iterator[ObjectRecipe]:
        return iter(self._recipes.values())

    def get(self, name: str) -> ObjectRecipe:
        return self._recipes[name]

    def add(self, recipe: ObjectRecipe):
        self._recipes[recipe.name] = recipe

    def remove(self, name: str) -> ObjectRecipe:
        return self._recipes.pop(name)

    def names(self) -> List[str]:
        return sorted(self._recipes)

    def sync(self):
        """Atomically persist the table (no-op for in-memory tables)."""
        if self.path is None:
            return
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"objects": [r.to_json() for r in self._recipes.values()]}, f)
        os.replace(tmp, self.path)
