"""RemoteShardClient: a shard server spoken to through the store interface.

The sharded service's writer seam is the per-shard ``BlockStore`` surface —
``put``/``get``/``release``/``sync`` plus the accounting properties and the
GC ``sweep``.  This client implements exactly that surface over the framed
protocol, so ``ShardedDedupService(transport="remote")`` swaps it in where
a ``DirBlockStore`` sat and *nothing else changes*: the scheduler, the
Pallas mask path, fp routing via ``dist_index.owner_of``, the writer-queue
ordering, and the flush protocol are all bit-identical to the local
transport.

Thread-safety: one client is shared between a shard's writer thread
(puts/releases) and the ingest thread (gets, sync, stats), so every RPC is
one lock-held request/response round trip on a single connection.  Cross-
shard parallelism is unaffected — each shard has its own client, socket,
and server process.

Failure model: any transport fault (dead server, torn frame) marks the
client dead and raises :class:`ShardTransportError` from the current and
all subsequent ops — fail-fast, no silent retry.  Inside a flush that
surfaces as ``AsyncWriteError`` at the writer barrier, *before* any recipe
is committed; the depot is left in the orphan-blocks-only state the GC
already knows how to repair (docs/SHARDING.md has the full kill matrix).

Telemetry: when the owning service attaches its registry (``.registry``),
every RPC is counted, timed, and blob-byte-accounted client-side
(``rpc.client.*``, labeled by op) — mirroring the ``rpc.server.*`` metrics
each server keeps, with identical byte semantics (payload blob only), so
the two ends of the wire can be reconciled exactly.  :meth:`metrics`
fetches a server's live snapshot via the v2 ``metrics`` op.

``ShardServerProcess`` spawns/stops the actual server processes; the
service's ``open(root, N, transport="remote")`` uses it, and tests use its
``kill()`` for SIGKILL crash injection.
"""
from __future__ import annotations

import os
import re
import selectors
import socket
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.dedup.store import encode_block, resolve_codec, sha256_key
from repro.obs import MetricsRegistry, current_context, labeled, span

from . import protocol as P
from .protocol import ShardTransportError


class RemoteShardClient:
    """Store-shaped proxy for one shard server (see module docstring).

    Protocol v4: the client sends its preferred ``codec`` in a ``hello``
    right after connect; every later :meth:`put_blocks` hashes and
    compresses the chunks *client-side under the negotiated codec* — and
    since the sharded service calls ``put_blocks`` from the per-shard
    writer thread, the encode runs off the ingest thread and the bytes
    travel compressed.  ``codec="none"`` (the default) keeps the legacy
    raw frames byte-for-byte.
    """

    def __init__(self, host: str, port: int, *, timeout: float = 120.0,
                 registry: Optional[MetricsRegistry] = None,
                 codec: Optional[str] = None, shard: int = 0):
        self.host, self.port = host, int(port)
        self._timeout = timeout
        self._lock = threading.Lock()
        self._dead: Optional[str] = None
        #: owning service's registry; None → RPCs go uncounted.  Settable
        #: after construction (the sharded service attaches its own).
        self.registry = registry
        self.shard = int(shard)
        self._sock = socket.create_connection((host, self.port),
                                              timeout=timeout)
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        #: wire codec for put_blocks payloads, fixed by the v4 hello
        self.codec = "none"
        preferred = resolve_codec(codec)
        if preferred != "none":
            meta, _ = self._rpc(P.OP_HELLO, {"codec": preferred})
            self.codec = str(meta["codec"])

    # -- transport core ---------------------------------------------------------
    def _rpc(self, op: int, meta: Optional[dict] = None,
             blob: bytes = b"", *, unbounded: bool = False) -> Tuple[dict, bytes]:
        """One locked request/response round trip.

        ``unbounded`` lifts the socket timeout for ops whose server-side
        work scales with store size (a full GC sweep, a directory scan) —
        a slow-but-healthy server must not be declared dead mid-sweep.
        """
        reg, opname = self.registry, P.OP_NAMES.get(op, str(op))
        if reg is not None:
            reg.inc(labeled("rpc.client.calls", op=opname))
            reg.inc(labeled("rpc.client.send_bytes", op=opname), len(blob))
        t0 = time.perf_counter()
        with span("rpc.client", op=opname,
                  peer=f"{self.host}:{self.port}", send_bytes=len(blob)):
            # protocol v3: ship this span's context in frame meta so the
            # server's rpc.server span becomes our child (copy, never
            # mutate the caller's dict); absent entirely when tracing is
            # off, so the off path stays byte-identical on the wire
            tctx = current_context()
            if tctx is not None:
                meta = {**(meta or {}), "trace": tctx}
            with self._lock:
                if self._dead is not None:
                    raise ShardTransportError(
                        f"shard server {self.host}:{self.port} is down "
                        f"({self._dead})"
                    )
                try:
                    if unbounded:
                        self._sock.settimeout(None)
                    P.send_frame(self._sock, op, meta, blob)
                    rop, rmeta, rblob = P.recv_frame(self._sock)
                except (OSError, P.ProtocolError) as e:
                    self._mark_dead(e)
                    if reg is not None:
                        reg.inc(labeled("rpc.client.errors", op=opname))
                    raise ShardTransportError(
                        f"shard server {self.host}:{self.port} unreachable "
                        f"during {P.OP_NAMES.get(op, op)}: {e}"
                    ) from e
                finally:
                    if unbounded and self._dead is None:
                        self._sock.settimeout(self._timeout)
        if reg is not None:
            # latency includes lock wait: that's the caller-observed RPC
            # cost when the writer and ingest threads contend for the
            # single connection, which is exactly what we want visible
            reg.observe(labeled("rpc.client.latency_s", op=opname),
                        time.perf_counter() - t0)
            reg.inc(labeled("rpc.client.recv_bytes", op=opname), len(rblob))
            if rop == P.OP_ERROR:
                reg.inc(labeled("rpc.client.errors", op=opname))
        if rop == P.OP_ERROR:
            P.raise_remote(rmeta)
        return rmeta, rblob

    def _mark_dead(self, cause):
        self._dead = f"{type(cause).__name__}: {cause}"
        try:
            self._sock.close()
        except OSError:
            pass

    def close(self):
        """Close the connection (idempotent; further ops fail fast)."""
        with self._lock:
            if self._dead is None:
                self._dead = "closed"
                try:
                    self._sock.close()
                except OSError:
                    pass

    # -- the writer-seam store surface ------------------------------------------
    def put(self, chunk: bytes) -> str:
        return self.put_blocks([bytes(chunk)])[0]

    def put_blocks(self, chunks: List[bytes]) -> List[str]:
        if self.codec != "none":
            # v4 pre-compressed frame: hash + encode here (the caller is
            # the shard's writer thread, so this is off the ingest thread),
            # ship payloads compressed, server files them as-is.  Per-item
            # ``codecs``: encode_block falls back to raw on incompressible
            # chunks, and those ship (and are stored) raw in the same frame.
            keys, raw_sizes, codecs, payloads = [], [], [], []
            t0 = time.perf_counter()
            for c in chunks:
                keys.append(sha256_key(c))
                raw_sizes.append(len(c))
                eff, payload = encode_block(self.codec, c)
                codecs.append(eff)
                payloads.append(payload)
            reg = self.registry
            if reg is not None:
                reg.observe("store.compress_s", time.perf_counter() - t0)
                reg.inc(labeled("store.compressed_bytes", shard=self.shard),
                        sum(len(p) for p, e in zip(payloads, codecs)
                            if e != "none"))
            self._rpc(P.OP_PUT_BLOCKS, {
                "codec": self.codec,
                "codecs": codecs,
                "keys": keys,
                "raw_sizes": raw_sizes,
                "sizes": [len(p) for p in payloads],
            }, b"".join(payloads))
            return keys
        meta, _ = self._rpc(P.OP_PUT_BLOCKS,
                            {"sizes": [len(c) for c in chunks]},
                            b"".join(chunks))
        return list(meta["keys"])

    def get(self, key: str) -> bytes:
        return self.get_blocks([key])[0]

    def get_blocks(self, keys: List[str]) -> List[bytes]:
        meta, blob = self._rpc(P.OP_GET_BLOCKS, {"keys": list(keys)})
        return P.split_blob(blob, meta["sizes"])

    def get_stream(self, keys) -> bytes:
        return b"".join(self.get_blocks(list(keys)))

    def release(self, key: str) -> bool:
        return self.release_many([key])[0]

    def release_many(self, keys) -> List[bool]:
        meta, _ = self._rpc(P.OP_RELEASE, {"keys": list(keys)})
        return [bool(f) for f in meta["freed"]]

    def put_recipe(self, recipe) -> None:
        d = recipe.to_json() if hasattr(recipe, "to_json") else dict(recipe)
        self._rpc(P.OP_PUT_RECIPE, {"recipe": d})

    def sync(self):
        """put_manifest: server syncs its refcount manifest + recipe table."""
        self._rpc(P.OP_PUT_MANIFEST)

    def stat(self, *, scan: bool = False) -> dict:
        meta, _ = self._rpc(P.OP_STAT, {"scan": scan} if scan else None,
                            unbounded=scan)  # scan walks the blocks dir
        return meta

    def scan_keys(self) -> List[str]:
        return list(self.stat(scan=True)["keys"])

    #: live entries per gc_mark frame: ~70 JSON bytes each keeps every
    #: frame a few MB, far under protocol.MAX_META however large the shard
    GC_MARK_BATCH = 100_000

    def sweep(self, live: Dict[str, int]) -> Tuple[int, int, int]:
        """Server-side GC: upload recomputed liveness, sweep next to the data.

        Same semantics as :meth:`BlockStore.sweep`, but the per-key loop
        runs on the server.  The live table is uploaded in
        :data:`GC_MARK_BATCH`-entry ``gc_mark`` frames (the server
        accumulates; ``reset`` on the first frame starts a fresh mark), so
        a shard with tens of millions of live chunks never produces a
        frame the protocol would reject.
        """
        items = [(k, int(v)) for k, v in live.items()]
        # max(1, ...): an empty table still sends one reset frame so a
        # stale mark from an aborted earlier pass cannot leak into this one
        for off in range(0, max(1, len(items)), self.GC_MARK_BATCH):
            self._rpc(P.OP_GC_MARK, {
                "reset": off == 0,
                "live": dict(items[off:off + self.GC_MARK_BATCH]),
            })
        meta, _ = self._rpc(P.OP_GC_SWEEP, unbounded=True)  # scales with store
        return (int(meta["freed_blocks"]), int(meta["freed_bytes"]),
                int(meta["repaired_refs"]))

    def ping(self) -> dict:
        meta, _ = self._rpc(P.OP_PING)
        return meta

    def metrics(self) -> dict:
        """Live server-side MetricsRegistry snapshot (v2 ``metrics`` op)."""
        meta, _ = self._rpc(P.OP_METRICS)
        return meta["metrics"]

    def shutdown(self):
        """Ask the server to sync and exit (the graceful stop path)."""
        self._rpc(P.OP_SHUTDOWN)
        self.close()

    # -- accounting properties (the service's stats surface) ---------------------
    @property
    def stored_bytes(self) -> int:
        return int(self.stat()["stored_bytes"])

    @property
    def logical_bytes(self) -> int:
        return int(self.stat()["logical_bytes"])

    @property
    def unique_chunks(self) -> int:
        return int(self.stat()["unique_chunks"])

    @property
    def compressed_bytes(self) -> int:
        return int(self.stat()["compressed_bytes"])

    def __repr__(self):
        state = "dead" if self._dead else "up"
        return f"RemoteShardClient({self.host}:{self.port}, {state})"


_READY_RE = re.compile(r"SHARD_SERVER_READY port=(\d+) pid=(\d+)")


class ShardServerProcess:
    """One spawned ``shard_server`` subprocess (spawn, announce, stop, kill)."""

    def __init__(self, root: str, *, host: str = "127.0.0.1", port: int = 0,
                 timeout: float = 60.0, codec: Optional[str] = None,
                 hot_bytes: int = 0, shard: int = 0):
        self.root = root
        self.host = host
        self.port: Optional[int] = None
        self._deadline = time.monotonic() + timeout
        env = dict(os.environ)
        src = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..", "..")
        )
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        cmd = [sys.executable, "-m", "repro.service.transport.shard_server",
               "--root", root, "--host", host, "--port", str(port),
               "--shard", str(shard)]
        if codec is not None:
            cmd += ["--codec", codec]
        if hot_bytes:
            cmd += ["--hot-bytes", str(hot_bytes)]
        self.proc = subprocess.Popen(
            cmd, stdout=subprocess.PIPE, env=env, text=True, bufsize=1,
        )

    @classmethod
    def spawn(cls, root: str, **kwargs) -> "ShardServerProcess":
        return cls(root, **kwargs).wait_ready()

    def wait_ready(self) -> "ShardServerProcess":
        """Block until the READY line announces the bound port."""
        if self.port is not None:
            return self
        sel = selectors.DefaultSelector()
        sel.register(self.proc.stdout, selectors.EVENT_READ)
        try:
            while time.monotonic() < self._deadline:
                if sel.select(timeout=0.1):
                    line = self.proc.stdout.readline()
                    if not line:
                        raise ShardTransportError(
                            f"shard server for {self.root!r} exited before "
                            f"announcing (rc={self.proc.poll()})"
                        )
                    m = _READY_RE.search(line)
                    if m:
                        self.port = int(m.group(1))
                        return self
                elif self.proc.poll() is not None:
                    raise ShardTransportError(
                        f"shard server for {self.root!r} died on startup "
                        f"(rc={self.proc.returncode})"
                    )
            raise ShardTransportError(
                f"shard server for {self.root!r} did not announce in time"
            )
        finally:
            sel.close()

    def connect(self, **kwargs) -> RemoteShardClient:
        self.wait_ready()
        return RemoteShardClient(self.host, self.port, **kwargs)

    def stop(self, client: Optional[RemoteShardClient] = None,
             timeout: float = 10.0):
        """Graceful shutdown (via ``client`` when given), escalating to
        terminate/kill; safe on an already-dead process."""
        if client is not None:
            try:
                client.shutdown()
            except (ShardTransportError, KeyError, OSError):
                pass
        try:
            self.proc.wait(timeout=timeout if client is not None else 0.1)
        except subprocess.TimeoutExpired:
            self.proc.terminate()
            try:
                self.proc.wait(timeout=5)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
        if self.proc.stdout is not None:
            self.proc.stdout.close()

    def kill(self):
        """SIGKILL, no warning — the crash-injection path for tests."""
        self.proc.kill()
        self.proc.wait()


def spawn_shard_servers(roots: List[str], **kwargs) -> List[ShardServerProcess]:
    """Spawn one server per root *in parallel*, waiting for every announce;
    on any failure the already-started processes are killed before raising.
    Each server gets its root's index as its ``shard`` metric label."""
    procs: List[ShardServerProcess] = []
    try:
        for i, r in enumerate(roots):
            procs.append(ShardServerProcess(r, shard=i, **kwargs))
        for p in procs:
            p.wait_ready()
        return procs
    except BaseException:
        for p in procs:
            try:
                p.kill()
            except OSError:
                pass
        raise
