"""Standalone shard server: one owner-local block store behind the protocol.

    python -m repro.service.transport.shard_server --root DEPOT/shard-00 \\
        --host 127.0.0.1 --port 0

Wraps exactly one :class:`~repro.dedup.store.DirBlockStore` (the same
on-disk layout the local transport uses, so a depot moves freely between
``transport="local"`` and ``transport="remote"``) plus a shard-local
:class:`~repro.service.objects.RecipeTable` for the ``put_recipe`` op, and
serves the framed op set from ``protocol.py`` over TCP.

Crash-safe ordering is the store's own discipline, unchanged by the
transport: ``put_blocks`` writes and atomically renames the block file into
place *before* the RPC is acknowledged, so by the time the writer barrier
on the client has every ack, every block has landed; ``put_manifest`` syncs
the refcount manifest strictly afterwards.  Killing the server at any point
(SIGKILL included) therefore leaves orphan blocks or a stale manifest —
both repaired by the service's mark-and-sweep GC on restart — never a
manifest naming bytes that don't exist.  Note the guarantee is
*process*-crash safety, matching ``DirBlockStore``: surviving power loss
would additionally require fsync of the block file and its directory
before the ack (a deliberate future hardening, not done here).

Concurrency: connections are handled on threads (a service's writer client
plus a restore-path client may talk at once), but every store/recipe op runs
under one server-wide lock — the single-writer discipline the local
transport gets from the per-shard writer thread, enforced here at the op
boundary.

On startup the server prints ``SHARD_SERVER_READY port=<p> pid=<p>`` to
stdout (after binding, so ``--port 0`` ephemeral ports are announced);
spawners key on that line.  ``shutdown`` syncs the store and exits cleanly.

Telemetry: every handled frame is counted, timed, and byte-accounted into
a process-wide :class:`~repro.obs.MetricsRegistry` (``rpc.server.*``,
labeled by op — the server-side mirror of the client's ``rpc.client.*``
metrics), and the ``metrics`` op exports the live snapshot, which is how
``ShardedDedupService.metrics()`` aggregates per-shard-server telemetry
(docs/OBSERVABILITY.md).  Failed ops are logged to stderr with a
structured one-line ``SHARD_SERVER_ERROR`` prefix (op name, shard root,
pid, error type) followed by the traceback *before* the typed error frame
is sent — so a server-side failure is diagnosable in the server's log,
not only client-side.

The module deliberately imports no jax: with the lazy package inits a shard
server is a numpy+stdlib process, so spawning N of them costs process
startup, not N accelerator-runtime initializations (``repro.obs`` is
stdlib-only by contract).
"""
from __future__ import annotations

import argparse
import os
import socketserver
import sys
import threading
import time
import traceback

from repro.dedup.store import (DirBlockStore, available_codecs,
                               negotiate_codec)
from repro.obs import MetricsRegistry, labeled, scope, span
from repro.service.objects import ObjectRecipe, RecipeTable

from . import protocol as P


class _Handler(socketserver.BaseRequestHandler):
    def handle(self):
        shard: "ShardServer" = self.server.shard  # type: ignore[attr-defined]
        sock = self.request
        while True:
            try:
                op, meta, blob = P.recv_frame(sock)
            except (ConnectionError, OSError):
                return  # client went away: nothing to clean up, ops are atomic
            except P.ProtocolError as e:
                shard.log_error("recv", e)
                self._send_error(sock, e)
                return  # stream offset untrusted past a framing error
            opname = P.OP_NAMES.get(op, str(op))
            # v3 trace propagation: the client's span context rides in the
            # reserved "trace" meta entry; pop it *before* dispatch (op
            # handlers never see it) and adopt it as the parent of this
            # frame's rpc.server span — absent/None is a clean no-op
            tctx = meta.pop("trace", None) if isinstance(meta, dict) else None
            # the server-side mirror of the client's rpc.client.* metrics:
            # every received frame is counted and blob-byte-accounted (the
            # two ends agree exactly — payload blob bytes, headers/meta
            # excluded on both sides)
            shard.registry.inc(labeled("rpc.server.calls", op=opname))
            shard.registry.inc(labeled("rpc.server.recv_bytes", op=opname),
                               len(blob))
            if op == P.OP_SHUTDOWN:
                with shard.lock:
                    shard.store.sync()
                    shard.sync_recipes()
                try:
                    P.send_frame(sock, op, {"ok": True})
                except OSError:
                    pass
                self.server.shutdown()  # handler thread: unblocks serve_forever
                return
            try:
                t0 = time.perf_counter()
                with scope(tctx), \
                        span("rpc.server", op=opname, recv_bytes=len(blob)):
                    with shard.lock:
                        rmeta, rblob = shard.dispatch(op, meta, blob)
                shard.registry.observe(
                    labeled("rpc.server.latency_s", op=opname),
                    time.perf_counter() - t0,
                )
                shard.registry.inc(
                    labeled("rpc.server.send_bytes", op=opname), len(rblob)
                )
                P.send_frame(sock, op, rmeta, rblob)
            except OSError:
                return
            except BaseException as e:  # noqa: BLE001 — propagated to client
                shard.registry.inc(labeled("rpc.server.errors", op=opname))
                shard.log_error(opname, e)
                self._send_error(sock, e)

    @staticmethod
    def _send_error(sock, exc):
        try:
            P.send_frame(sock, P.OP_ERROR, P.error_meta(exc))
        except OSError:
            pass


class _TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class ShardServer:
    """One shard's store + recipe table behind the framed protocol."""

    def __init__(self, root: str, host: str = "127.0.0.1", port: int = 0,
                 codec: str = None, hot_bytes: int = 0, shard: int = 0):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.store = DirBlockStore(root, codec=codec, hot_bytes=hot_bytes)
        self.recipes = RecipeTable(os.path.join(root, "recipes.json"))
        self.lock = threading.RLock()
        self.registry = MetricsRegistry()
        # server-side encodes (raw puts under a compressing codec, tier
        # demotions) land in this registry's store.* series, exported
        # through the metrics op like every rpc.server.* series
        self.store.attach_obs(self.registry, shard=shard)
        self._gc_live: dict[str, int] = {}
        self._tcp = _TCPServer((host, port), _Handler)
        self._tcp.shard = self  # type: ignore[attr-defined]

    def log_error(self, opname: str, exc: BaseException):
        """Structured one-line error prefix + traceback on stderr — the
        server-side record of a failed op (the client sees only the typed
        ``OP_ERROR`` frame; before this, failures were invisible here)."""
        print(
            f"SHARD_SERVER_ERROR op={opname} root={self.root} "
            f"pid={os.getpid()} etype={type(exc).__name__}: {exc}",
            file=sys.stderr, flush=True,
        )
        traceback.print_exception(type(exc), exc, exc.__traceback__,
                                  file=sys.stderr)
        sys.stderr.flush()

    @property
    def port(self) -> int:
        return self._tcp.server_address[1]

    def serve_forever(self):
        self._tcp.serve_forever()

    def shutdown(self):
        self._tcp.shutdown()

    def close(self):
        self._tcp.server_close()

    def sync_recipes(self):
        """Persist the shard-local recipe table — but never materialize an
        empty one: today's sharded service keeps its recipe table at the
        depot root and uses ``put_recipe`` not at all (the op exists for
        the full-remote commit a multi-host deployment needs), so a shard
        dir should not grow a zero-object recipes.json as a side effect."""
        if len(self.recipes) or os.path.exists(self.recipes.path):
            self.recipes.sync()

    # -- op dispatch -------------------------------------------------------------
    def dispatch(self, op: int, meta: dict, blob: bytes):
        """Execute one op (caller holds the lock) -> (meta, blob)."""
        if op == P.OP_PING:
            return {"ok": True, "root": self.root, "pid": os.getpid(),
                    "version": P.VERSION}, b""
        if op == P.OP_PUT_BLOCKS:
            before = self.store.unique_chunks
            if meta.get("codec", "none") != "none":
                # v4 pre-compressed form: the client's writer thread
                # already hashed + compressed; file the payloads as-is
                keys = self.store.put_compressed_blocks(
                    meta["keys"], meta["raw_sizes"],
                    meta.get("codecs", meta["codec"]),
                    P.split_blob(blob, meta["sizes"]),
                )
            else:
                keys = [self.store.put(c)
                        for c in P.split_blob(blob, meta["sizes"])]
            # hit = a put whose key was already stored (dedup did its job);
            # measured by the unique-count delta so no extra hashing runs
            self.registry.inc("store.put_chunks", len(keys))
            self.registry.inc("store.put_bytes", len(blob))
            self.registry.inc("store.dedup_hit_chunks",
                              len(keys) - (self.store.unique_chunks - before))
            return {"keys": keys}, b""
        if op == P.OP_GET_BLOCKS:
            blocks = self.store.get_blocks(meta["keys"])  # KeyError crosses typed
            return {"sizes": [len(b) for b in blocks]}, b"".join(blocks)
        if op == P.OP_RELEASE:
            return {"freed": [bool(self.store.release(k))
                              for k in meta["keys"]]}, b""
        if op == P.OP_PUT_RECIPE:
            self.recipes.add(ObjectRecipe.from_json(meta["recipe"]))
            return {"ok": True}, b""
        if op == P.OP_PUT_MANIFEST:
            self.store.sync()
            self.sync_recipes()
            return {"ok": True}, b""
        if op == P.OP_HELLO:
            # codec negotiation: preference honored when this process can
            # decode it, degraded lz4 -> zlib -> none otherwise.  The
            # store's *write* codec is its own (manifest/env/ctor) — hello
            # only fixes how put_blocks payloads travel on this connection.
            offered = available_codecs()
            return {"codec": negotiate_codec(meta.get("codec", "none"),
                                             offered),
                    "available": list(offered),
                    "store_codec": self.store.codec}, b""
        if op == P.OP_STAT:
            st = self.store.stat()
            out = {
                "stored_bytes": self.store.stored_bytes,
                "logical_bytes": self.store.logical_bytes,
                "compressed_bytes": self.store.compressed_bytes,
                "compressed_ratio": st["compressed_ratio"],
                "unique_chunks": self.store.unique_chunks,
                "objects": len(self.recipes),
            }
            if meta.get("scan"):
                out["keys"] = self.store.scan_keys()
            return out, b""
        if op == P.OP_GC_MARK:
            if meta.get("reset"):
                self._gc_live.clear()
            for k, v in meta.get("live", {}).items():
                self._gc_live[k] = self._gc_live.get(k, 0) + int(v)
            return {"marked": len(self._gc_live)}, b""
        if op == P.OP_METRICS:
            return {"metrics": self.registry.snapshot()}, b""
        if op == P.OP_GC_SWEEP:
            freed_blocks, freed_bytes, repaired = self.store.sweep(
                self._gc_live
            )
            self._gc_live.clear()
            self.store.sync()
            return {"freed_blocks": freed_blocks, "freed_bytes": freed_bytes,
                    "repaired_refs": repaired}, b""
        raise ValueError(f"unknown op {op}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", required=True,
                    help="shard store directory (created if missing)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port (0 = ephemeral, announced on stdout)")
    ap.add_argument("--codec", default=None,
                    help="write codec for new blocks (none|zlib|lz4); "
                         "default: manifest codec, else $REPRO_STORE_CODEC")
    ap.add_argument("--hot-bytes", type=int, default=0,
                    help="cold-tiering hot budget in bytes (0 = off)")
    ap.add_argument("--shard", type=int, default=0,
                    help="shard index for metric labels")
    args = ap.parse_args(argv)
    srv = ShardServer(args.root, args.host, args.port, codec=args.codec,
                      hot_bytes=args.hot_bytes, shard=args.shard)
    print(f"SHARD_SERVER_READY port={srv.port} pid={os.getpid()}", flush=True)
    try:
        srv.serve_forever()
    finally:
        srv.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
