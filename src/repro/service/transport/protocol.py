"""Wire protocol for the shard transport: length-prefixed, versioned frames.

One frame = a fixed 20-byte header followed by two length-delimited bodies:

    +--------+---------+------+----------+----------+----------+
    | magic  | version | op   | reserved | meta_len | blob_len |
    | 4B     | u8      | u8   | u16      | u32      | u64      |
    +--------+---------+------+----------+----------+----------+
    | meta: ``meta_len`` bytes of UTF-8 JSON (op arguments/results) |
    | blob: ``blob_len`` bytes of raw payload (chunk data)          |

The meta/blob split keeps chunk payloads out of JSON (no base64, no copies
beyond the socket) while op arguments stay debuggable.  Requests and
responses share the framing; a response echoes the request's op code on
success or carries :data:`OP_ERROR` with ``{"etype", "message"}`` meta on
failure, which the client re-raises (:func:`raise_remote`) — ``KeyError``
crosses the boundary as ``KeyError``, everything else surfaces as
:class:`ShardTransportError` so a caller can tell "the remote op failed"
from "the transport died".

Versioning: ``VERSION`` is checked on every frame by both ends; a mismatch
raises :class:`ProtocolError` before any payload is interpreted, so mixed
deployments fail loudly at the first frame instead of corrupting a store.

The op set is the full writer seam of the sharded service (the contract in
docs/SHARDING.md): block puts/gets, release, manifest sync, recipe commit,
stat/scan, mark-and-sweep GC, ping and shutdown — plus ``metrics``, which
returns the server's live :class:`~repro.obs.MetricsRegistry` snapshot so
``ShardedDedupService.metrics()`` can aggregate per-shard-server telemetry
(docs/OBSERVABILITY.md).  Adding ``metrics`` bumped ``VERSION`` to 2: a
v1 peer fails loudly at the first frame instead of choking on an op it
does not know.

``VERSION`` 3 adds distributed-trace propagation: a request frame's meta
MAY carry a ``trace`` entry — ``{"trace_id": hex, "span_id": hex}``, the
client's active span context — which the server pops before op dispatch
and adopts as the parent of its per-op ``rpc.server`` span
(``repro.obs.trace.scope``), stitching client and server JSONL spans into
one causal tree.  The entry is optional (absent when tracing is off), is
never interpreted by op handlers, and changes no op semantics; the bump
exists because frame meta gained a reserved key that a v2 server would
silently pass into handler kwargs, and mixed deployments must fail at the
first frame, not on a surprise argument.

``VERSION`` 4 adds codec negotiation and pre-compressed block transfer:

* :data:`OP_HELLO` — sent by the client once per connection, right after
  connect, with ``{"codec": <preferred>}``.  The server answers with
  ``{"codec": <negotiated>, "available": [...]}`` — the client's
  preference when the server store can decode it, degraded along
  lz4 -> zlib -> none otherwise (:func:`repro.dedup.store.negotiate_codec`).
  Every later ``put_blocks`` uses the negotiated codec.
* ``put_blocks`` frames MAY carry **pre-compressed payloads**: meta
  ``{"codec": c, "codecs": [...], "keys": [...], "raw_sizes": [...],
  "sizes": [...]}`` with the blob holding the concatenated payloads —
  ``codecs`` gives each item's *effective* codec ("none" for chunks the
  encode could not shrink, which ship raw in the same frame).  The
  client's writer thread compressed the chunks (and computed their
  SHA-256 keys) once, off the ingest thread; the server files the
  payloads as-is (``BlockStore.put_compressed_blocks``) — bytes compress
  once and travel compressed.  The legacy meta shape (``{"sizes": ...}``
  with a raw blob) remains valid and is what a ``codec="none"``
  negotiation produces.  ``get_blocks`` responses stay raw: restores are
  latency-sensitive and the server already decodes to serve hot reads.
* ``BlockCorruptionError`` joins ``KeyError`` as a typed error that
  crosses the boundary as itself (:func:`raise_remote`), so a client-side
  restore can map a corrupt remote block to the service's
  ``IntegrityError`` instead of a generic transport failure.
"""
from __future__ import annotations

import json
import socket
import struct
from typing import Optional, Tuple

MAGIC = b"SCDC"
VERSION = 4  # v4: OP_HELLO codec negotiation + pre-compressed put_blocks

#: header: magic, version, op, reserved, meta_len (u32), blob_len (u64)
HEADER = struct.Struct("!4sBBHIQ")

#: sanity caps — a torn/foreign stream must not turn into a huge allocation
MAX_META = 1 << 28
MAX_BLOB = 1 << 34

# -- op codes (the writer-seam op set) -----------------------------------------
OP_PING = 1
OP_PUT_BLOCKS = 2
OP_GET_BLOCKS = 3
OP_RELEASE = 4
OP_PUT_RECIPE = 5
OP_PUT_MANIFEST = 6
OP_STAT = 7
OP_GC_MARK = 8
OP_GC_SWEEP = 9
OP_SHUTDOWN = 10
#: v2: server returns {"metrics": <MetricsRegistry.snapshot()>}
OP_METRICS = 11
#: v4: codec negotiation; request {"codec"} -> reply {"codec", "available"}
OP_HELLO = 12
#: response-only: remote op raised; meta = {"etype", "message"}
OP_ERROR = 0xFF

OP_NAMES = {
    OP_PING: "ping",
    OP_PUT_BLOCKS: "put_blocks",
    OP_GET_BLOCKS: "get_blocks",
    OP_RELEASE: "release",
    OP_PUT_RECIPE: "put_recipe",
    OP_PUT_MANIFEST: "put_manifest",
    OP_STAT: "stat",
    OP_GC_MARK: "gc_mark",
    OP_GC_SWEEP: "gc_sweep",
    OP_SHUTDOWN: "shutdown",
    OP_METRICS: "metrics",
    OP_HELLO: "hello",
    OP_ERROR: "error",
}


class ProtocolError(RuntimeError):
    """Malformed or version-mismatched frame: the stream cannot be trusted."""


class ShardTransportError(RuntimeError):
    """A remote shard op failed or its server became unreachable.

    Raised client-side both for propagated remote exceptions (other than
    ``KeyError``, which crosses as itself) and for dead connections.  Inside
    a flush this surfaces through the writer queue as ``AsyncWriteError`` at
    the barrier — before any recipe is committed.
    """


def send_frame(sock: socket.socket, op: int, meta: Optional[dict] = None,
               blob: bytes = b""):
    """Serialize and send one frame (sendall: complete or raise)."""
    mb = json.dumps(meta or {}, separators=(",", ":")).encode()
    sock.sendall(HEADER.pack(MAGIC, VERSION, op, 0, len(mb), len(blob)))
    sock.sendall(mb)
    if blob:
        sock.sendall(blob)


def _read_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        part = sock.recv(min(n - len(buf), 1 << 20))
        if not part:
            raise ConnectionError(
                f"peer closed mid-frame ({len(buf)}/{n} bytes)"
            )
        buf += part
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Tuple[int, dict, bytes]:
    """Receive one frame -> (op, meta, blob).

    ``ConnectionError`` on clean or mid-frame EOF; :class:`ProtocolError`
    on bad magic, version mismatch, or an implausible length — the caller
    must drop the connection, the stream offset can no longer be trusted.
    """
    hdr = _read_exact(sock, HEADER.size)
    magic, version, op, _reserved, meta_len, blob_len = HEADER.unpack(hdr)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r} (not a shard-transport peer)")
    if version != VERSION:
        raise ProtocolError(
            f"protocol version {version} != supported {VERSION}"
        )
    if meta_len > MAX_META or blob_len > MAX_BLOB:
        raise ProtocolError(
            f"implausible frame lengths meta={meta_len} blob={blob_len}"
        )
    meta = json.loads(_read_exact(sock, meta_len)) if meta_len else {}
    blob = _read_exact(sock, blob_len) if blob_len else b""
    return op, meta, blob


# -- error propagation ----------------------------------------------------------
def error_meta(exc: BaseException) -> dict:
    return {"etype": type(exc).__name__, "message": str(exc)}


def raise_remote(meta: dict) -> None:
    """Re-raise a remote error locally.  ``KeyError`` keeps its type (store
    lookups depend on it), as does ``BlockCorruptionError`` (restores map
    it to ``IntegrityError``); everything else becomes ShardTransportError."""
    etype = meta.get("etype", "RuntimeError")
    message = meta.get("message", "")
    if etype == "KeyError":
        raise KeyError(message)
    if etype == "BlockCorruptionError":
        from repro.dedup.store import BlockCorruptionError

        raise BlockCorruptionError(message)
    raise ShardTransportError(f"remote {etype}: {message}")


def split_blob(blob: bytes, sizes: list) -> list:
    """Cut a concatenated blob back into per-item byte strings."""
    out, off = [], 0
    for n in sizes:
        out.append(blob[off:off + int(n)])
        off += int(n)
    if off != len(blob):
        raise ProtocolError(
            f"blob length {len(blob)} != declared sizes total {off}"
        )
    return out
