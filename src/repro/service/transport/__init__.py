"""repro.service.transport — shard stores behind a process/RPC boundary.

The writer seam of the sharded service, made remote (docs/SHARDING.md):

* ``protocol``     — length-prefixed, versioned binary frames covering the
                     full writer-seam op set (put_blocks, put_recipe,
                     put_manifest, release, stat, get_blocks, gc_mark/sweep,
                     ping/shutdown) with typed error propagation;
* ``shard_server`` — a standalone, jax-free process wrapping one owner-local
                     ``DirBlockStore`` (``python -m
                     repro.service.transport.shard_server --root ... --port ...``);
* ``client``       — ``RemoteShardClient`` (the store surface over RPC) and
                     ``ShardServerProcess`` (spawn/stop/kill lifecycle).

Everything here is stdlib + numpy; the package is what makes later
multi-host steps (chunk-data all_to_all, real RPC backends) a transport
swap instead of a service rewrite.
"""
from .client import (  # noqa: F401
    RemoteShardClient,
    ShardServerProcess,
    spawn_shard_servers,
)
from .protocol import (  # noqa: F401
    OP_NAMES,
    VERSION,
    ProtocolError,
    ShardTransportError,
)
