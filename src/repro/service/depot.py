"""Depot layout rules: where a sharded depot keeps what, stdlib-only.

One module owns the on-disk naming contract — ``shard-NN`` store
directories and the ``sharding.json`` shard-count pin — so every consumer
(`ShardedDedupService.open`, the shard servers' spawner, and the offline
``scripts/reshard.py``) reads and writes the same layout.  Deliberately
free of numpy/jax imports: the reshard CLI and other offline tooling can
use it without paying accelerator-runtime startup.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional


def shard_roots(root: str, num_shards: int) -> List[str]:
    """Per-shard store directories of a depot — the one place the
    ``shard-NN`` naming rule lives."""
    return [os.path.join(root, f"shard-{s:02d}") for s in range(num_shards)]


def read_depot_shards(root: str) -> Optional[int]:
    """Pinned shard count of a depot, or None when ``root`` holds none."""
    meta_path = os.path.join(root, "sharding.json")
    if not os.path.exists(meta_path):
        return None
    with open(meta_path) as f:
        return int(json.load(f)["num_shards"])


def pin_depot_shards(root: str, num_shards: int) -> None:
    """Atomically pin a depot's shard count in ``root/sharding.json``."""
    meta_path = os.path.join(root, "sharding.json")
    tmp = meta_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"num_shards": int(num_shards)}, f)
    os.replace(tmp, meta_path)
