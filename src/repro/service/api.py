"""DedupService: the streaming deduplication service (put/get/stat/delete).

One object ties the repo's pieces into a serving system:

    submit/put --> ChunkScheduler (length-bucketed device batches,
                   vmapped two-phase SeqCDC + fingerprints)
               --> BlockStore     (SHA-256 content-addressed, refcounted)
               --> RecipeTable    (object -> chunk keys + object digest)
    get        --> reassemble from recipe, SHA-256 verify
    delete     --> release refcounts; gc() mark-and-sweeps crash orphans

Ingest is continuous-batching style: ``submit`` enqueues without blocking,
``flush`` drains the scheduler and commits recipes, ``put`` is the one-shot
convenience (submit + flush).  Submitting many objects before flushing is
what keeps device batches full — the estimator CLI and benchmarks do that.

Accounting: the store's SHA-256 keys give *exact* dedup (logical vs stored
bytes); the accelerator's 62-bit fingerprints feed a ``FingerprintIndex``
whose savings estimate is reported alongside — the paper's fast fingerprint
as an estimator, the collision-resistant hash as ground truth.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import threading
import time
from collections import Counter
from typing import Dict, List, Optional

from repro.core.params import SeqCDCParams, derived_params
from repro.dedup import BlockStore, DirBlockStore, FingerprintIndex
from repro.dedup.store import BlockCorruptionError
from repro.obs import (
    MetricsRegistry,
    PhaseClock,
    labeled,
    merge_snapshots,
    span,
)

from .objects import ObjectRecipe, RecipeTable
from .scheduler import ChunkResult, ChunkScheduler

#: the calling thread's active request (one per thread: requests on the
#: public surface don't nest except put = submit+flush, which reuses the
#: outer request so its phases attribute to op=put, not op=flush)
_REQ_TLS = threading.local()


@dataclasses.dataclass
class _Request:
    """One in-flight request: its id, op label, and phase partition clock."""

    op: str
    rid: str
    clock: PhaseClock


class IntegrityError(RuntimeError):
    """Restore produced bytes whose digest does not match the recipe."""


def verify_restore(r: ObjectRecipe, data: bytes) -> bytes:
    """The one restore-verification rule, shared by both services: length
    and whole-object SHA-256 must match the recipe or nothing is returned."""
    if len(data) != r.size or hashlib.sha256(data).hexdigest() != r.sha256:
        raise IntegrityError(
            f"object {r.name!r}: restored {len(data)}B, digest mismatch "
            f"(expected {r.size}B sha256={r.sha256[:12]}...)"
        )
    return data


def sweep_store(store: BlockStore, live: Dict[str, int]) -> "GCStats":
    """One store's mark-and-sweep pass, shared by both services.

    ``live`` is the recomputed truth (key -> reference count from the recipe
    roots).  The pass itself is :meth:`~repro.dedup.BlockStore.sweep` —
    store-local so a remote store (``transport/client.py``) runs it next to
    its data in one RPC instead of one round trip per key.
    """
    return GCStats(*store.sweep(live))


def pack_fps(fps) -> List[int]:
    """Per-chunk 62-bit fingerprints packed to ``(h1 << 32) | h2`` ints for
    the recipe (``ObjectRecipe.fps``).

    Recording them is what makes a depot *reshardable*: routing is by
    ``owner_of(fp.h1, N)``, which the SHA-256 key cannot reproduce, so an
    N→M repartition (scripts/reshard.py) would otherwise have to re-chunk
    and re-hash every object.
    """
    import numpy as np

    return [(int(h1) << 32) | int(h2) for h1, h2 in np.asarray(fps).tolist()]


def recipe_totals(recipes: RecipeTable) -> tuple[int, int, Dict[int, int]]:
    """(logical_bytes, total_chunks, log2-bucket histogram) over a table —
    the recipe-derived half of ServiceStats, shared by both services."""
    hist: Counter = Counter()
    logical = 0
    total_chunks = 0
    for r in recipes:
        logical += r.size
        total_chunks += len(r.keys)
        for ln in r.chunk_lens:
            hist[max(0, int(ln).bit_length() - 1)] += 1
    return logical, total_chunks, dict(sorted(hist.items()))


@dataclasses.dataclass
class ObjectStat:
    name: str
    size: int
    chunks: int
    sha256: str
    mean_chunk: float

    @classmethod
    def of(cls, r: ObjectRecipe) -> "ObjectStat":
        return cls(name=r.name, size=r.size, chunks=len(r.keys), sha256=r.sha256,
                   mean_chunk=r.size / len(r.keys) if r.keys else 0.0)


@dataclasses.dataclass
class ServiceStats:
    objects: int
    logical_bytes: int  # sum of live object sizes
    stored_bytes: int  # unique chunk bytes on disk/in memory
    total_chunks: int
    unique_chunks: int
    chunk_size_hist: Dict[int, int]  # log2-bucket -> live chunk refs
    fp_estimated_savings: float  # 62-bit fp estimate, cumulative over ingests
    batches: int
    batch_occupancy: float
    #: payload bytes the store actually holds (== stored_bytes when the
    #: store codec is "none"; smaller under compression)
    compressed_bytes: int = 0
    codec: str = "none"  # the store's write codec

    @property
    def dedup_ratio(self) -> float:
        return self.logical_bytes / self.stored_bytes if self.stored_bytes else 1.0

    @property
    def compressed_ratio(self) -> float:
        """End-to-end reduction: logical bytes per *payload* byte held —
        dedup x compression (== :attr:`dedup_ratio` for codec-less stores),
        the ratio the exemplar estimators report."""
        if not self.compressed_bytes:
            return self.dedup_ratio
        return self.logical_bytes / self.compressed_bytes

    @property
    def space_savings(self) -> float:
        if not self.logical_bytes:
            return 0.0
        return (self.logical_bytes - self.stored_bytes) / self.logical_bytes


@dataclasses.dataclass
class GCStats:
    freed_blocks: int
    freed_bytes: int
    repaired_refs: int


class ServiceBase:
    """The scheduler-facing ingest/serve surface shared by both services.

    Subclasses (:class:`DedupService`, single store;
    :class:`~repro.service.sharded.ShardedDedupService`, fingerprint
    partitioned) provide ``recipes``, ``scheduler``, an ``_in_flight`` name
    set, and their own ``flush``/``get``/``delete``/``gc``; everything here
    is backend-agnostic, so the two services cannot drift on the ingest
    contract (name collisions, in-flight bookkeeping, stat/names shape).
    """

    recipes: RecipeTable
    scheduler: "ChunkScheduler"
    _in_flight: set
    #: the service-wide MetricsRegistry every layer under this service
    #: reports into (scheduler, writers, transport clients)
    obs: MetricsRegistry

    def submit(self, name: str, data, *, overwrite: bool = False) -> int:
        """Queue one object for ingest; returns its ticket (a sequence id).

        Nothing is chunked, stored, or committed until :meth:`flush` — the
        object is not restorable and not visible in :meth:`names` yet.
        ``data`` is raw bytes or anything numpy turns into a uint8 vector;
        raises ``KeyError`` if ``name`` already exists (committed or
        in-flight) and ``overwrite`` is False.  Submitting many objects
        before one flush is what fills device batches (continuous batching).
        """
        if not overwrite and (name in self.recipes or name in self._in_flight):
            raise KeyError(f"object {name!r} already exists (overwrite=False)")
        seq = self.scheduler.submit(data, tag=name)
        self._in_flight.add(name)
        return seq

    def put(self, name: str, data, *, overwrite: bool = False) -> ObjectStat:
        """Store one object now (submit + flush); returns its ObjectStat.

        Convenience for interactive/one-shot use — batched ingest via
        :meth:`submit` + :meth:`flush` is the throughput path.  After
        ``put`` returns, the object is durable (for file-backed stores)
        and restorable via ``get``.
        """
        with self._request("put", object=name):
            self.submit(name, data, overwrite=overwrite)
            return self.flush()[-1]

    def flush(self) -> List[ObjectStat]:
        raise NotImplementedError

    def stat(self, name: str) -> ObjectStat:
        """Recipe-level summary of one committed object (size, chunk count,
        digest, mean chunk) without touching block data.  ``KeyError`` for
        unknown or not-yet-flushed names."""
        return ObjectStat.of(self.recipes.get(name))

    def names(self) -> List[str]:
        """Sorted names of all committed objects (in-flight ones excluded)."""
        return self.recipes.names()

    # -- request attribution ----------------------------------------------------
    @contextlib.contextmanager
    def _request(self, op: str, **attrs):
        """Root of one public-surface request (put/get/delete/flush/gc).

        Opens a ``request`` root span carrying a fresh request id (every
        span under it — scheduler dispatches, writer tasks, shard RPCs,
        server-side ops — shares its ``trace_id``) and a
        :class:`~repro.obs.PhaseClock` whose partition lands in the
        ``req.latency_s{op=,phase=}`` histograms at close, plus
        ``req.total_s{op=}`` and a ``req.requests{op=}`` counter.  The
        clock tiles the request's wall time exactly, so the per-phase sums
        reconcile with the root span's ``wall_s``.

        Re-entrant per thread: a request started while another is active
        on the same thread joins it (``put`` = submit + ``flush``; the
        phases attribute to the outer op).  Error paths still record — a
        failed request's time is the tail latency you most want to see.
        """
        active = getattr(_REQ_TLS, "active", None)
        if active is not None:
            yield active
            return
        req = _Request(op=op, rid=os.urandom(6).hex(), clock=PhaseClock())
        _REQ_TLS.active = req
        try:
            with span("request", op=op, req=req.rid, **attrs) as sp:
                try:
                    yield req
                finally:
                    # stop() is idempotent: the same partition recorded on
                    # the root span here lands in the histograms below, so
                    # a trace file alone carries the phase attribution
                    _, phases = req.clock.stop()
                    sp["phases"] = {p: round(s, 6)
                                    for p, s in phases.items()}
        finally:
            _REQ_TLS.active = None
            total, phases = req.clock.stop()
            self.obs.inc(labeled("req.requests", op=op))
            self.obs.observe(labeled("req.total_s", op=op), total)
            for ph, secs in phases.items():
                self.obs.observe(
                    labeled("req.latency_s", op=op, phase=ph), secs
                )

    def _phase(self, name: str):
        """Attribute the ``with`` body's wall time to phase ``name`` of the
        thread's active request; a plain no-op outside any request, so
        helpers shared by instrumented and bare call paths need no guard."""
        active = getattr(_REQ_TLS, "active", None)
        if active is None:
            return contextlib.nullcontext()
        return active.clock.phase(name)

    def _move_phase(self, src: str, dst: str, seconds: float):
        """Reattribute seconds between phases of the active request (the
        scheduler's host tail redo runs *inside* the drain call, so its
        self-reported seconds move chunk-dispatch -> tail after the fact)."""
        active = getattr(_REQ_TLS, "active", None)
        if active is not None:
            active.clock.move(src, dst, seconds)

    # -- observability ----------------------------------------------------------
    def metrics(self) -> dict:
        """Live telemetry snapshot (docs/OBSERVABILITY.md has the catalog).

        ``service`` is this process's registry — ingest/restore counters,
        scheduler occupancy and dispatch latency, writer backpressure,
        client-side RPC metrics.  ``shards`` holds one server-side snapshot
        per shard store (remote transport only: fetched live over the wire
        via the ``metrics`` op; empty otherwise), with ``None`` standing in
        for an unreachable server.  ``aggregate`` merges the reachable
        shard snapshots: counters sum, histograms merge bucket-wise and
        re-derive their percentiles.
        """
        shards = self._shard_metric_snapshots()
        return {
            "service": self.obs.snapshot(),
            "shards": shards,
            "aggregate": merge_snapshots(shards) if shards else None,
        }

    def _shard_metric_snapshots(self) -> List[Optional[dict]]:
        """Per-shard server-side snapshots; base services have none."""
        return []


class DedupService(ServiceBase):
    """Streaming dedup: batched chunking in front of a GC-capable chunk store."""

    def __init__(
        self,
        store: Optional[BlockStore] = None,
        params: Optional[SeqCDCParams] = None,
        *,
        avg_chunk: int = 8192,
        slots: int = 8,
        min_bucket: int = 1 << 14,
        recipes: Optional[RecipeTable] = None,
        mask_impl: str = "jnp",
        step_impl: str = "wide",
        fp_impl: str = "reference",
        pipeline_impl: str | None = None,
        packing_impl: str | None = None,
        with_fingerprints: bool = True,
        cross_check_masks: bool = False,
        cross_check_fps: bool = False,
        cross_check_pipeline: bool = False,
        cross_check_packing: bool = False,
        codec: Optional[str] = None,
    ):
        self.params = params or derived_params(avg_chunk)
        # codec applies to the default store only; an explicit ``store``
        # arrives already configured (None resolves $REPRO_STORE_CODEC)
        self.store = store if store is not None else BlockStore(codec=codec)
        self.recipes = recipes if recipes is not None else RecipeTable()
        # per-service (not global) registry: tests and side-by-side services
        # never share counters; the scheduler reports into the same one
        self.obs = MetricsRegistry()
        if hasattr(self.store, "attach_obs"):
            self.store.attach_obs(self.obs)
        self.scheduler = ChunkScheduler(
            self.params, registry=self.obs, slots=slots, min_bucket=min_bucket,
            mask_impl=mask_impl, step_impl=step_impl, fp_impl=fp_impl,
            pipeline_impl=pipeline_impl,
            packing_impl=packing_impl,
            with_fingerprints=with_fingerprints,
            cross_check_masks=cross_check_masks,
            cross_check_fps=cross_check_fps,
            cross_check_pipeline=cross_check_pipeline,
            cross_check_packing=cross_check_packing,
        )
        # ingest-cumulative: tracks every chunk ever ingested (the estimator
        # semantics); deletes/overwrites do not shrink it, unlike the exact
        # store accounting
        self.fp_index = FingerprintIndex()
        self._in_flight: set[str] = set()  # names submitted, not yet flushed

    @classmethod
    def open(cls, root: str, *, codec: Optional[str] = None,
             hot_bytes: int = 0, **kwargs) -> "DedupService":
        """File-backed service at ``root``: blocks + recipes survive restarts.

        ``codec`` selects the store's write codec (None: the depot's
        manifest codec, else ``$REPRO_STORE_CODEC``); ``hot_bytes`` enables
        cold tiering on the underlying :class:`DirBlockStore`.
        """
        os.makedirs(root, exist_ok=True)
        store = DirBlockStore(root, codec=codec, hot_bytes=hot_bytes)
        recipes = RecipeTable(os.path.join(root, "recipes.json"))
        return cls(store=store, recipes=recipes, **kwargs)

    # -- ingest -----------------------------------------------------------------
    def flush(self) -> List[ObjectStat]:
        """Drain the scheduler, store chunks, commit recipes.  FIFO order.

        Durability order: new blocks and recipes are synced *before* any
        block superseded by an overwrite is released, so a crash mid-flush
        leaves orphan blocks (reclaimable by :meth:`gc`), never a committed
        recipe pointing at missing blocks.
        """
        # whatever drain() does — return results, or lose requests to a
        # device-side error — the submitted names are no longer pending, so
        # they must stop blocking resubmission
        with self._request("flush"):
            t0 = time.perf_counter()
            with span("service.flush") as sp:
                tail0 = self.scheduler.stats.tail_s
                with self._phase("chunk-dispatch"):
                    try:
                        results = self.scheduler.drain()
                    finally:
                        self._in_flight.clear()
                # the host tail redo ran inside drain(); reattribute its
                # self-reported seconds so tail latency is its own phase
                self._move_phase("chunk-dispatch", "tail",
                                 self.scheduler.stats.tail_s - tail0)
                out = []
                stale: List[str] = []
                with self._phase("commit"):
                    for res in results:
                        stat, old_keys = self._commit(res)
                        out.append(stat)
                        stale.extend(old_keys)
                with self._phase("sync"):
                    self.sync()
                if stale:
                    for k in stale:
                        self.store.release(k)
                    with self._phase("sync"):
                        self.sync()
                sp["objects"] = len(out)
            self.obs.observe("service.flush_s", time.perf_counter() - t0)
            return out

    def _commit(self, res: ChunkResult) -> tuple[ObjectStat, List[str]]:
        """Store one result; returns (stat, keys superseded by an overwrite).

        Superseded keys are *not* released here — the caller releases them
        only after the new recipes are durable (see :meth:`flush`).
        """
        name = str(res.tag)
        old = self.recipes.get(name) if name in self.recipes else None
        before = self.store.unique_chunks
        keys = self.store.put_stream(res.data, res.bounds.tolist())
        # a dedup hit = a chunk whose key the store already held; measured
        # by the unique-count delta so no second hash pass runs
        self.obs.inc("ingest.objects")
        self.obs.inc("ingest.bytes", res.size)
        self.obs.inc("ingest.chunks", len(keys))
        self.obs.inc("ingest.dedup_hit_chunks",
                     len(keys) - (self.store.unique_chunks - before))
        recipe = ObjectRecipe(
            name=name,
            size=res.size,
            sha256=hashlib.sha256(res.data).hexdigest(),
            keys=keys,
            chunk_lens=res.lengths.astype(int).tolist(),
            # recorded when the scheduler fingerprinted (reshardability);
            # with_fingerprints=False leaves the field absent
            fps=pack_fps(res.fps) if res.fps.shape[0] == len(keys) else None,
        )
        if res.fps.size:
            with self._phase("fp"):
                self.fp_index.add_batch(res.fps, res.lengths)
        self.recipes.add(recipe)
        return ObjectStat.of(recipe), (old.keys if old is not None else [])

    # -- serve ------------------------------------------------------------------
    def get(self, name: str) -> bytes:
        """Reassemble an object from its chunks, end-to-end verified.

        Both the restored length and the whole-object SHA-256 must match
        the recipe; any mismatch (corrupt block, recipe naming the right
        chunks in the wrong order) raises :class:`IntegrityError` rather
        than returning wrong bytes.  ``KeyError`` for unknown names.
        """
        r = self.recipes.get(name)
        with self._request("get", object=name):
            t0 = time.perf_counter()
            with span("service.get", object=name, bytes=r.size):
                # "rpc" = the block-gather seam; for this single-store
                # service it is the same seam served in-process
                with self._phase("rpc"):
                    try:
                        data = self.store.get_stream(r.keys)
                    except BlockCorruptionError as e:
                        # a block that fails to decode is the same contract
                        # breach as a digest mismatch: corrupt storage
                        raise IntegrityError(
                            f"object {name!r}: {e}"
                        ) from e
                with self._phase("verify"):
                    data = verify_restore(r, data)
            self.obs.observe("service.get_s", time.perf_counter() - t0)
            self.obs.inc("restore.objects")
            self.obs.inc("restore.bytes", r.size)
            return data

    # -- delete / GC ------------------------------------------------------------
    def delete(self, name: str) -> int:
        """Remove an object; returns stored bytes actually reclaimed.

        The recipe removal is made durable *before* any block file is
        unlinked: a crash mid-delete leaves orphan blocks for :meth:`gc`,
        never a surviving recipe pointing at missing blocks.
        """
        with self._request("delete", object=name):
            r = self.recipes.remove(name)  # KeyError for unknown objects
            with self._phase("sync"):
                self.recipes.sync()
            freed = 0
            with self._phase("commit"):
                for k, ln in zip(r.keys, r.chunk_lens):
                    if self.store.release(k):
                        freed += ln
            with self._phase("sync"):
                self.sync()
            return freed

    def gc(self) -> GCStats:
        """Mark-and-sweep: recipes are roots; everything else is garbage.

        Sweeps :meth:`~repro.dedup.BlockStore.scan_keys` — which for
        file-backed stores includes block files the refcount manifest never
        recorded — so it reclaims blocks orphaned by a crash at any point
        (the write order everywhere is blocks-then-recipes, so orphans,
        never dangling recipes, are the one reachable inconsistency).  Also
        repairs refcount drift against the recomputed truth.
        """
        live: Counter = Counter()
        for r in self.recipes:
            live.update(r.keys)
        stats = sweep_store(self.store, live)
        self.sync()
        return stats

    def sync(self):
        """Persist recipes + store manifest (no-op for in-memory backends)."""
        self.recipes.sync()
        self.store.sync()

    # -- accounting -------------------------------------------------------------
    def stats(self) -> ServiceStats:
        logical, total_chunks, hist = recipe_totals(self.recipes)
        sched = self.scheduler.stats
        return ServiceStats(
            objects=len(self.recipes),
            logical_bytes=logical,
            stored_bytes=self.store.stored_bytes,
            total_chunks=total_chunks,
            unique_chunks=self.store.unique_chunks,
            chunk_size_hist=hist,
            fp_estimated_savings=self.fp_index.savings,
            batches=sched.dispatches,
            batch_occupancy=sched.occupancy,
            compressed_bytes=self.store.compressed_bytes,
            codec=self.store.codec,
        )
