"""repro.service — streaming dedup service on top of SeqCDC (docs/SERVICE.md).

Layers: ChunkScheduler (batched device chunking) -> BlockStore (content
addressed, refcounted) -> RecipeTable (object manifests, GC roots), fronted
by DedupService (put/get/stat/delete + mark-and-sweep gc).
"""
from .api import (  # noqa: F401
    DedupService,
    GCStats,
    IntegrityError,
    ObjectStat,
    ServiceStats,
)
from .objects import ObjectRecipe, RecipeTable  # noqa: F401
from .scheduler import ChunkResult, ChunkScheduler, SchedulerStats  # noqa: F401
