"""repro.service — streaming dedup service on top of SeqCDC (docs/SERVICE.md).

Layers: ChunkScheduler (batched device chunking) -> BlockStore (content
addressed, refcounted) -> RecipeTable (object manifests, GC roots), fronted
by DedupService (put/get/stat/delete + mark-and-sweep gc) and its
fingerprint-partitioned multi-shard form ShardedDedupService
(docs/SHARDING.md): owner-local stores/refcounts/GC behind per-shard async
write queues, routed by dedup/dist_index's consistent-hash rule.
"""
from .api import (  # noqa: F401
    DedupService,
    GCStats,
    IntegrityError,
    ObjectStat,
    ServiceStats,
)
from .objects import ObjectRecipe, RecipeTable  # noqa: F401
from .scheduler import (  # noqa: F401
    ChunkResult,
    ChunkScheduler,
    MaskDivergenceError,
    SchedulerStats,
)
from .sharded import ShardedDedupService  # noqa: F401
from .writer import AsyncWriteError, ShardWriter, WriterPool  # noqa: F401
