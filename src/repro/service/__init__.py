"""repro.service — streaming dedup service on top of SeqCDC (docs/SERVICE.md).

Layers: ChunkScheduler (batched device chunking) -> BlockStore (content
addressed, refcounted) -> RecipeTable (object manifests, GC roots), fronted
by DedupService (put/get/stat/delete + mark-and-sweep gc) and its
fingerprint-partitioned multi-shard form ShardedDedupService
(docs/SHARDING.md): owner-local stores/refcounts/GC behind per-shard async
write queues, routed by dedup/dist_index's consistent-hash rule, with the
per-shard stores either in-process or behind the transport package's RPC
boundary (``transport/``, docs/SHARDING.md).

Exports resolve lazily (``repro._lazy``): the jax-heavy modules (api/
scheduler/sharded) only import when first touched, so transport-only
consumers — most importantly a spawned ``shard_server`` process, which
imports ``repro.service.objects`` — stay numpy+stdlib and start in
milliseconds.
"""
from repro._lazy import install as _install

#: public name -> defining submodule (resolved on first attribute access)
_EXPORTS = {
    "DedupService": ".api",
    "GCStats": ".api",
    "IntegrityError": ".api",
    "ObjectStat": ".api",
    "ServiceStats": ".api",
    "ObjectRecipe": ".objects",
    "RecipeTable": ".objects",
    "ChunkResult": ".scheduler",
    "ChunkScheduler": ".scheduler",
    "FingerprintDivergenceError": ".scheduler",
    "MaskDivergenceError": ".scheduler",
    "PackingDivergenceError": ".scheduler",
    "PipelineDivergenceError": ".scheduler",
    "SchedulerStats": ".scheduler",
    "ShardedDedupService": ".sharded",
    "AsyncWriteError": ".writer",
    "ShardWriter": ".writer",
    "WriterPool": ".writer",
    "RemoteShardClient": ".transport",
    "ShardServerProcess": ".transport",
    "ShardTransportError": ".transport",
}

_SUBMODULES = ("api", "depot", "objects", "scheduler", "sharded", "transport",
               "writer")

__all__ = sorted(_EXPORTS) + list(_SUBMODULES)

__getattr__, __dir__ = _install(__name__, _EXPORTS, _SUBMODULES)
