"""ShardedDedupService: fingerprint-partitioned multi-shard dedup.

Scales the stage *after* chunking.  The single-store :class:`DedupService`
serializes fingerprint comparison and block IO behind one refcount table;
this service partitions the fingerprint space across ``num_shards`` owner
shards — the HYDRAstor-style design ``dedup/dist_index.py`` expresses with
jax collectives — so index lookups, refcounting, GC, and block IO all
become owner-local and embarrassingly parallel:

    submit/put ──► ChunkScheduler (shared; batched SeqCDC + fingerprints)
               ──► owner_of(fp.h1, N)  — dist_index's consistent-hash rule
               ──► ShardWriter[owner]  — async bounded queue, one per shard
               ──► BlockStore[owner]   — owner-local refcounts + accounting
    flush      ──► writer barrier ──► recipes commit ──► manifests sync
    get        ──► gather chunks across shards ──► SHA-256 verify

**Routing.**  ``owner_of`` (fp.h1 mod N) is the single partition rule; equal
chunks have equal fingerprints, land on the same owner, and dedup there —
owner-local dedup is therefore globally exact, and an N-shard service stores
byte-for-byte the same unique chunks as the 1-shard service.  When a jax
``Mesh`` with N devices is supplied, per-flush fingerprint records travel
the real ``all_to_all`` path (:func:`~repro.dedup.dist_index.routed_fp_tables`)
into per-owner tables; a batch that overflows the capacity-padded buckets is
re-routed host-side (counted in ``overflow_rerouted``, never dropped — see
docs/SHARDING.md).  Without a mesh, :func:`~repro.dedup.dist_index.route_host`
is the host/threaded fallback.  Both derive from the same ``owner_of``.

**Async flush.**  Store writes run on per-shard writer threads behind a
bounded queue (``max_pending`` chunks of backpressure), so SHA-256 hashing
and block-file IO overlap with device chunking instead of serializing after
it.  Crash-safe ordering is preserved: the flush barrier guarantees every
block durably landed *before* any recipe is committed or any manifest
synced, so a crash at any point leaves orphan blocks (reclaimed by
:meth:`gc`), never a manifest or recipe naming bytes that don't exist.

**Restores.**  Recipes record each chunk's owner shard (routing is by
accelerator fingerprint, which the SHA key alone cannot reproduce); ``get``
gathers chunks across shards and verifies the whole-object SHA-256, exactly
like the single-store service.

**Transports.**  ``transport="local"`` (default) keeps every shard's
``BlockStore`` in-process.  ``transport="remote"`` moves each shard behind
a process boundary: :meth:`open` spawns one ``shard_server`` process per
shard directory and wires a :class:`~repro.service.transport.RemoteShardClient`
— which implements the same store surface — into the writer seam.  Nothing
else changes: the scheduler, the Pallas mask path, and fp routing via
``dist_index.owner_of`` are bit-identical across transports, and the
on-disk layout is too, so a depot reopens under either transport
(docs/SHARDING.md documents the wire protocol and failure semantics).
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from collections import Counter
from typing import List, Optional, Sequence

import numpy as np

from repro.core.params import SeqCDCParams, derived_params
from repro.dedup import BlockStore, DirBlockStore, FingerprintIndex
from repro.dedup.store import BlockCorruptionError
from repro.dedup.dist_index import route_host, routed_fp_tables
from repro.obs import MetricsRegistry, span

from .api import (
    GCStats,
    IntegrityError,
    ObjectStat,
    ServiceBase,
    ServiceStats,
    pack_fps,
    recipe_totals,
    sweep_store,
    verify_restore,
)
from .depot import pin_depot_shards, read_depot_shards, shard_roots
from .objects import ObjectRecipe, RecipeTable
from .scheduler import ChunkResult, ChunkScheduler
from .transport.client import spawn_shard_servers
from .transport.protocol import ShardTransportError
from .writer import WriterPool

TRANSPORTS = ("local", "remote")


class ShardedDedupService(ServiceBase):
    """Fingerprint-partitioned dedup across N owner-local shards."""

    def __init__(
        self,
        num_shards: int = 4,
        stores: Optional[Sequence[BlockStore]] = None,
        params: Optional[SeqCDCParams] = None,
        *,
        avg_chunk: int = 8192,
        slots: int = 8,
        min_bucket: int = 1 << 14,
        recipes: Optional[RecipeTable] = None,
        mask_impl: str = "jnp",
        step_impl: str = "wide",
        fp_impl: str = "reference",
        pipeline_impl: str | None = None,
        packing_impl: str | None = None,
        cross_check_masks: bool = False,
        cross_check_fps: bool = False,
        cross_check_pipeline: bool = False,
        cross_check_packing: bool = False,
        async_flush: bool = True,
        max_pending: int = 256,
        mesh=None,
        mesh_axis: str = "data",
        capacity_factor: float = 1.5,
        transport: str = "local",
        codec: Optional[str] = None,
    ):
        if stores is not None and len(stores) != num_shards:
            raise ValueError(f"{len(stores)} stores for {num_shards} shards")
        if transport not in TRANSPORTS:
            raise ValueError(f"transport must be one of {TRANSPORTS}, "
                             f"got {transport!r}")
        if transport == "remote" and stores is None:
            raise ValueError(
                "transport='remote' needs shard servers: use "
                "ShardedDedupService.open(root, N, transport='remote') to "
                "spawn them, or pass stores=[RemoteShardClient(...), ...]"
            )
        self.transport = transport
        #: ShardServerProcess handles when :meth:`open` spawned the servers
        #: (empty for user-provided clients and for the local transport)
        self._servers: list = []
        self.num_shards = int(num_shards)
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        self.params = params or derived_params(avg_chunk)
        # codec applies to default-constructed stores only; explicit stores
        # (including remote clients) arrive already configured
        self.stores: List[BlockStore] = (
            list(stores) if stores is not None
            else [BlockStore(codec=codec) for _ in range(self.num_shards)]
        )
        self.recipes = recipes if recipes is not None else RecipeTable()
        # one registry for the whole service: scheduler dispatches, writer
        # queues, and client-side RPCs all report here; remote servers keep
        # their own, aggregated live by :meth:`metrics`
        self.obs = MetricsRegistry()
        if self.transport == "remote":
            for st in self.stores:
                # RemoteShardClient contract: a settable .registry turns on
                # its per-op rpc.client.* accounting
                st.registry = self.obs
        else:
            for s, st in enumerate(self.stores):
                if hasattr(st, "attach_obs"):
                    # shard-labeled compression telemetry (store.compress_s,
                    # store.compressed_bytes{shard=}) into the one registry
                    st.attach_obs(self.obs, shard=s)
        # fingerprints are mandatory: they are the routing key
        self.scheduler = ChunkScheduler(
            self.params, registry=self.obs, slots=slots, min_bucket=min_bucket,
            mask_impl=mask_impl, step_impl=step_impl, fp_impl=fp_impl,
            pipeline_impl=pipeline_impl, packing_impl=packing_impl,
            with_fingerprints=True, cross_check_masks=cross_check_masks,
            cross_check_fps=cross_check_fps,
            cross_check_pipeline=cross_check_pipeline,
            cross_check_packing=cross_check_packing,
        )
        # validate the mesh before anything spawns threads: a constructor
        # that raises must not leak per-shard writer workers
        self.mesh = mesh
        self.mesh_axis = mesh_axis
        if mesh is not None:
            if mesh_axis not in mesh.shape:
                raise ValueError(
                    f"mesh has no axis {mesh_axis!r} (axes: "
                    f"{list(mesh.shape)}); pass mesh_axis=<name>"
                )
            if mesh.shape[mesh_axis] != self.num_shards:
                raise ValueError(
                    f"mesh axis {mesh_axis!r} has {mesh.shape[mesh_axis]} "
                    f"devices but the service has {self.num_shards} shards; "
                    f"the all_to_all route needs one device per owner shard"
                )
        self._routed_fn = (
            routed_fp_tables(mesh, mesh_axis, capacity_factor=capacity_factor)
            if mesh is not None else None
        )
        self.async_flush = bool(async_flush)
        self.writers = WriterPool(
            self.num_shards, max_pending if self.async_flush else 0,
            registry=self.obs,
        )
        # owner-local fingerprint indexes (the paper's estimator layer),
        # partitioned by the same rule as the stores
        self.fp_index: List[FingerprintIndex] = [
            FingerprintIndex() for _ in range(self.num_shards)
        ]
        #: fp records that overflowed the mesh all_to_all capacity and were
        #: re-routed host-side (docs/SHARDING.md: counted, never dropped)
        self.overflow_rerouted = 0
        self._in_flight: set[str] = set()  # names submitted, not yet flushed

    @classmethod
    def open(cls, root: str, num_shards: int = 4, *,
             codec: Optional[str] = None, hot_bytes: int = 0,
             **kwargs) -> "ShardedDedupService":
        """File-backed sharded service: one block depot per shard under
        ``root/shard-NN/`` plus a shared recipe table.  The shard count is
        pinned in ``root/sharding.json`` — reopening with a different N would
        scatter the partition map, so it is a hard error (repartitioning is
        what ``scripts/reshard.py`` is for).

        ``transport="remote"`` spawns one ``shard_server`` process per shard
        directory and wires remote clients in place of the in-process
        stores; the servers are stopped by :meth:`close`.  The on-disk
        layout is transport-independent, so the same depot reopens under
        either transport.
        """
        if num_shards < 1:  # validate before the depot meta is persisted:
            # a bad first call must not poison root/sharding.json
            raise ValueError("num_shards must be >= 1")
        os.makedirs(root, exist_ok=True)
        want = read_depot_shards(root)
        if want is not None and want != num_shards:
            raise ValueError(
                f"depot {root!r} was created with num_shards={want}, "
                f"reopen requested {num_shards}"
            )
        pinned_here = want is None
        if pinned_here:
            pin_depot_shards(root, num_shards)
        servers = []
        try:
            roots = shard_roots(root, num_shards)
            if kwargs.get("transport") == "remote":
                # each server resolves codec itself (arg > shard manifest >
                # env); the client hello then negotiates the wire codec
                servers = spawn_shard_servers(roots, codec=codec,
                                              hot_bytes=hot_bytes)
                stores = [h.connect(codec=codec, shard=i)
                          for i, h in enumerate(servers)]
            else:
                stores = [DirBlockStore(r, codec=codec, hot_bytes=hot_bytes)
                          for r in roots]
            recipes = RecipeTable(os.path.join(root, "recipes.json"))
            svc = cls(num_shards, stores=stores, recipes=recipes,
                      codec=codec, **kwargs)
        except BaseException:
            for h in servers:
                h.stop()
            if pinned_here:
                # the open never produced a service: a retry must be free
                # to pick a different N, so un-poison the fresh pin
                try:
                    os.remove(os.path.join(root, "sharding.json"))
                except OSError:
                    pass
            raise
        svc._servers = servers
        return svc

    # -- ingest -----------------------------------------------------------------
    def flush(self) -> List[ObjectStat]:
        """Drain the scheduler, write blocks to owner shards, commit recipes.

        Durability protocol (the async generalization of the single-store
        flush):

        1. every chunk's ``put`` is enqueued on its owner shard's writer;
        2. the writer barrier waits until all blocks durably landed — a
           failed write raises here and *nothing* below runs;
        3. recipes (with per-chunk owners) are committed and synced;
        4. shard manifests are synced — only after their blocks landed;
        5. blocks superseded by overwrites are released, manifests re-synced.

        A crash after (1) leaves orphan blocks for :meth:`gc`; a crash
        between (3) and (4) leaves stale manifests that :meth:`gc` repairs
        against the recipe roots.  No ordering leaves a recipe or manifest
        naming bytes that were never written.
        """
        # whatever drain() does — return results, or lose requests to a
        # device-side error — the submitted names are no longer pending, so
        # they must stop blocking resubmission
        with self._request("flush"):
            t0 = time.perf_counter()
            with span("service.flush") as sp:
                out = self._flush(sp)
            self.obs.observe("service.flush_s", time.perf_counter() - t0)
            return out

    def _flush(self, sp) -> List[ObjectStat]:
        tail0 = self.scheduler.stats.tail_s
        with self._phase("chunk-dispatch"):
            try:
                results = self.scheduler.drain()
            finally:
                self._in_flight.clear()
        # the host tail redo ran inside drain(); reattribute its
        # self-reported seconds so tail latency is its own phase
        self._move_phase("chunk-dispatch", "tail",
                         self.scheduler.stats.tail_s - tail0)
        staged = []  # (result, owners, keys)
        # coalesce each shard's puts: the writer seam accepts batches
        # (``put_blocks``), so a flush submits one task per shard —
        # one RPC on the remote transport where the old path paid one
        # round trip per chunk — split only at ``put_batch_bytes`` so an
        # arbitrarily large flush cannot buffer unbounded chunk bytes
        # in a single frame
        batches: dict[int, list] = {}  # shard -> [(keys, i, chunk view)]
        with self._phase("routing"):
            for res in results:
                owners = self._owners_for(res)
                keys: List[Optional[str]] = [None] * len(owners)
                s = 0
                for i, e in enumerate(res.bounds.tolist()):
                    batches.setdefault(int(owners[i]), []).append(
                        (keys, i, res.data[s:e])
                    )
                    s = e
                staged.append((res, owners, keys))
        # writer-queue-wait = submit backpressure + the barrier: the time
        # this request spent waiting on writer queues (which is where the
        # store writes and shard RPCs happen) before its blocks were durable
        with self._phase("writer-queue-wait"):
            for shard, items in batches.items():
                for group in self._split_batches(items):
                    self.writers.submit(
                        shard, self._put_blocks_task(shard, group),
                        nbytes=sum(c.size for _, _, c in group),
                    )
            self.writers.barrier()  # blocks are durable past this point

        out = []
        stale: List[tuple[int, str]] = []
        with self._phase("commit"):
            for res, owners, keys in staged:
                name = str(res.tag)
                old = self.recipes.get(name) if name in self.recipes else None
                recipe = ObjectRecipe(
                    name=name,
                    size=res.size,
                    sha256=hashlib.sha256(res.data).hexdigest(),
                    keys=list(keys),  # type: ignore[arg-type]
                    chunk_lens=res.lengths.astype(int).tolist(),
                    shards=[int(o) for o in owners],
                    fps=pack_fps(res.fps),  # fps mandatory here: reshardable
                )
                self.recipes.add(recipe)
                out.append(ObjectStat.of(recipe))
                self.obs.inc("ingest.objects")
                self.obs.inc("ingest.bytes", res.size)
                self.obs.inc("ingest.chunks", len(keys))
                if old is not None:
                    stale.extend(zip(self._recipe_shards(old), old.keys))
        sp["objects"] = len(out)
        with self._phase("fp"):
            self._ingest_fps(results)
        with self._phase("sync"):
            self.sync()
        if stale:
            by_shard: dict[int, List[str]] = {}
            for shard, key in stale:
                by_shard.setdefault(shard, []).append(key)
            with self._phase("writer-queue-wait"):
                for shard, keys in by_shard.items():
                    self.writers.submit(shard,
                                        self._release_task(shard, keys))
                self.writers.barrier()
            with self._phase("sync"):
                self.sync()
        return out

    #: max chunk payload per coalesced ``put_blocks`` call: a typical flush
    #: is one batch per shard; a huge one splits so neither the writer task
    #: nor a remote frame materializes unbounded bytes at once
    put_batch_bytes = 16 << 20

    def _split_batches(self, items: list) -> list:
        """Split one shard's (keys, i, chunk) puts at ``put_batch_bytes``."""
        groups, cur, size = [], [], 0
        for it in items:
            cur.append(it)
            size += it[2].size
            if size >= self.put_batch_bytes:
                groups.append(cur)
                cur, size = [], 0
        if cur:
            groups.append(cur)
        return groups

    def _put_blocks_task(self, owner: int, items: list):
        """One coalesced batched put on the owner's writer thread; the
        returned keys are scattered back into each recipe's key slots."""
        store = self.stores[owner]

        def task():
            got = store.put_blocks([c.tobytes() for _, _, c in items])
            for (keys, i, _), key in zip(items, got):
                keys[i] = key

        return task

    def _release_task(self, shard: int, keys: List[str]):
        store = self.stores[shard]
        return lambda: store.release_many(keys)

    def _owners_for(self, res: ChunkResult) -> np.ndarray:
        """Owner shard per chunk of one result (dist_index's hash rule)."""
        if self.num_shards == 1 or res.fps.size == 0:
            return np.zeros(len(res.bounds), dtype=np.int32)
        return route_host(res.fps, self.num_shards)

    def _recipe_shards(self, r: ObjectRecipe) -> List[int]:
        """Per-chunk owners of a recipe; tolerate single-store tables at N=1
        (migration path: a DedupService depot opens as a 1-shard service)."""
        if r.shards is not None:
            return r.shards
        if self.num_shards == 1:
            return [0] * len(r.keys)
        raise IntegrityError(
            f"recipe {r.name!r} has no shard map but the service has "
            f"{self.num_shards} shards"
        )

    # -- fingerprint-estimator ingestion ---------------------------------------
    def _ingest_fps(self, results: List[ChunkResult]):
        """Feed owner-local fp indexes, via the mesh all_to_all when present."""
        live = [r for r in results if r.fps.size]
        if not live:
            return
        fps = np.concatenate([r.fps for r in live])
        lengths = np.concatenate([r.lengths for r in live]).astype(np.int32)
        if self._routed_fn is not None and self._mesh_ingest(fps, lengths):
            return
        owners = route_host(fps, self.num_shards)
        for s in range(self.num_shards):
            m = owners == s
            if m.any():
                new = self.fp_index[s].add_batch(fps[m], lengths[m])
                # estimator-level dup count (62-bit fp re-seen), the sharded
                # analogue of the single-store exact ingest.dedup_hit_chunks;
                # the exact per-shard truth lives in each remote server's
                # store.dedup_hit_chunks
                self.obs.inc("ingest.fp_dup_chunks",
                             int(len(new) - np.count_nonzero(new)))

    def _mesh_ingest(self, fps: np.ndarray, lengths: np.ndarray) -> bool:
        """Route fp records through the all_to_all path into owner tables.

        Returns False (caller falls back to :func:`route_host`) when the
        capacity-padded buckets overflowed — the overflow is counted in
        ``overflow_rerouted`` and the whole batch is re-routed host-side so
        no record is lost (the contract in docs/SHARDING.md).
        """
        ns = self.mesh.shape[self.mesh_axis]
        rows = len(lengths)
        # pad to ns * next-power-of-two rows-per-shard: flush sizes vary per
        # call, and padding only to a multiple of ns would retrace the jitted
        # all_to_all for nearly every flush; the pow2 grid bounds the compile
        # cache logarithmically (zero-length pad rows are dropped in-route)
        per_shard = max(1, -(-rows // ns))
        target = ns * (1 << (per_shard - 1).bit_length())
        pad = target - rows
        if pad:
            fps = np.concatenate([fps, np.zeros((pad, 2), dtype=fps.dtype)])
            lengths = np.concatenate([lengths, np.zeros(pad, dtype=lengths.dtype)])
        with self.mesh:
            tables, overflow = self._routed_fn(fps, lengths)
        if int(overflow) > 0:
            self.overflow_rerouted += int(overflow)
            return False
        tables = np.asarray(tables)  # (owner, src, capacity, 3)
        for s in range(self.num_shards):
            flat = tables[s].reshape(-1, 3)
            valid = flat[:, 2] > 0
            if valid.any():
                new = self.fp_index[s].add_batch(
                    flat[valid, :2].astype(np.uint32),
                    flat[valid, 2].astype(np.int64),
                )
                self.obs.inc("ingest.fp_dup_chunks",
                             int(len(new) - np.count_nonzero(new)))
        return True

    # -- serve ------------------------------------------------------------------
    def get(self, name: str) -> bytes:
        """Reassemble an object, gathering chunks across owner shards;
        verifies length and whole-object SHA-256 (:class:`IntegrityError`).

        Chunk fetches are batched per owner shard (one ``get_blocks`` call
        each) — for the remote transport that is one RPC per shard instead
        of one per chunk — then spliced back into stream order.
        """
        r = self.recipes.get(name)
        with self._request("get", object=name):
            t0 = time.perf_counter()
            with span("service.get", object=name, bytes=r.size):
                with self._phase("routing"):
                    owners = self._recipe_shards(r)
                    by_shard: dict[int, List[int]] = {}
                    for i, shard in enumerate(owners):
                        by_shard.setdefault(shard, []).append(i)
                # "rpc" = the per-shard block gather (one get_blocks call
                # per owner shard; a real RPC on the remote transport, the
                # same seam served in-process on the local one)
                parts: List[Optional[bytes]] = [None] * len(r.keys)
                with self._phase("rpc"):
                    try:
                        for shard, idxs in by_shard.items():
                            blocks = self.stores[shard].get_blocks(
                                [r.keys[i] for i in idxs]
                            )
                            for i, b in zip(idxs, blocks):
                                parts[i] = b
                    except BlockCorruptionError as e:
                        # a block that fails to decode (locally or typed
                        # across the wire) is corrupt storage, the same
                        # contract breach as a digest mismatch
                        raise IntegrityError(f"object {name!r}: {e}") from e
                with self._phase("verify"):
                    data = verify_restore(
                        r, b"".join(parts)  # type: ignore[arg-type]
                    )
            self.obs.observe("service.get_s", time.perf_counter() - t0)
            self.obs.inc("restore.objects")
            self.obs.inc("restore.bytes", r.size)
            return data

    # -- delete / GC ------------------------------------------------------------
    def delete(self, name: str) -> int:
        """Remove an object; returns stored bytes actually reclaimed.

        Same ordering as the single-store service: recipe removal is made
        durable first, then block releases run on the owner shards' writers
        (keeping every store single-writer), so a crash mid-delete leaves
        reclaimable orphans, never a recipe naming missing blocks.
        """
        with self._request("delete", object=name):
            r = self.recipes.remove(name)  # KeyError for unknown objects
            with self._phase("sync"):
                self.recipes.sync()
            freed = [0] * self.num_shards
            by_shard: dict[int, List[tuple[str, int]]] = {}
            for shard, key, ln in zip(self._recipe_shards(r), r.keys,
                                      r.chunk_lens):
                by_shard.setdefault(shard, []).append((key, ln))
            with self._phase("writer-queue-wait"):
                for shard, pairs in by_shard.items():
                    self.writers.submit(shard,
                                        self._free_task(shard, pairs, freed))
                self.writers.barrier()
            with self._phase("sync"):
                self.sync()
            return sum(freed)

    def _free_task(self, shard: int, pairs: List[tuple[str, int]],
                   freed: List[int]):
        """One shard's batched release — a single RPC for a remote store."""
        store = self.stores[shard]

        def task():
            flags = store.release_many([k for k, _ in pairs])
            freed[shard] = sum(ln for (_, ln), f in zip(pairs, flags) if f)

        return task

    def gc(self) -> GCStats:
        """Owner-local mark-and-sweep on every shard, recipes as roots.

        Each shard sweeps only the keys it owns (its store's
        ``scan_keys``), on its own writer thread, in parallel; the recipe
        scan partitions the roots by recorded owner.  Semantics per shard
        are identical to the single-store :meth:`DedupService.gc`: crash
        orphans reclaimed, refcount drift repaired.
        """
        live: List[Counter] = [Counter() for _ in range(self.num_shards)]
        for r in self.recipes:
            for shard, key in zip(self._recipe_shards(r), r.keys):
                live[shard][key] += 1
        totals = [GCStats(0, 0, 0) for _ in range(self.num_shards)]
        for s in range(self.num_shards):
            self.writers.submit(s, self._gc_task(s, live[s], totals))
        self.writers.barrier()
        self.sync()
        return GCStats(
            freed_blocks=sum(t.freed_blocks for t in totals),
            freed_bytes=sum(t.freed_bytes for t in totals),
            repaired_refs=sum(t.repaired_refs for t in totals),
        )

    def _gc_task(self, s: int, live: Counter, totals: List[GCStats]):
        store = self.stores[s]

        def task():
            totals[s] = sweep_store(store, live)

        return task

    def sync(self):
        """Persist recipes, then every shard manifest (in-memory: no-op)."""
        self.recipes.sync()
        for store in self.stores:
            store.sync()

    def close(self):
        """Drain writers and stop their threads (propagates write errors);
        spawned shard servers are shut down even when the drain fails."""
        try:
            self.writers.close()
        finally:
            for h, st in zip(self._servers, self.stores):
                try:
                    h.stop(st)
                except Exception:  # noqa: BLE001 — dead server is fine here
                    pass
            self._servers = []
            if self.transport == "remote":
                for st in self.stores:
                    try:
                        st.close()
                    except Exception:  # noqa: BLE001
                        pass

    def __enter__(self) -> "ShardedDedupService":
        return self

    def __exit__(self, *exc):
        self.close()

    # -- accounting -------------------------------------------------------------
    def stats(self) -> ServiceStats:
        """Aggregate accounting, same shape as the single-store service
        (which makes N-vs-1 equivalence directly assertable)."""
        logical, total_chunks, hist = recipe_totals(self.recipes)
        fp_orig = sum(ix.original_bytes for ix in self.fp_index)
        fp_dedup = sum(ix.dedup_bytes for ix in self.fp_index)
        sched = self.scheduler.stats
        per = [st.stat() for st in self.stores]  # one RPC per remote shard
        return ServiceStats(
            objects=len(self.recipes),
            logical_bytes=logical,
            stored_bytes=sum(p["stored_bytes"] for p in per),
            total_chunks=total_chunks,
            unique_chunks=sum(p["unique_chunks"] for p in per),
            chunk_size_hist=hist,
            fp_estimated_savings=(fp_orig - fp_dedup) / fp_orig if fp_orig else 0.0,
            batches=sched.dispatches,
            batch_occupancy=sched.occupancy,
            compressed_bytes=sum(
                int(p.get("compressed_bytes", p["stored_bytes"]))
                for p in per
            ),
            codec=getattr(self.stores[0], "codec", "none"),
        )

    def _shard_metric_snapshots(self) -> List[Optional[dict]]:
        """One live server-side snapshot per remote shard (the v2 ``metrics``
        op); ``None`` for a shard whose server is unreachable, so one dead
        server degrades the aggregate instead of failing :meth:`metrics`.
        Local-transport shards have no server process and report nothing —
        their writers/stores already count into the service registry."""
        if self.transport != "remote":
            return []
        out: List[Optional[dict]] = []
        for st in self.stores:
            try:
                out.append(st.metrics())
            except (ShardTransportError, KeyError):
                out.append(None)
        return out

    def shard_stats(self) -> List[dict]:
        """Per-shard breakdown: balance of the fingerprint partition."""
        out = []
        for s, st in enumerate(self.stores):
            acct = st.stat()  # one RPC per remote shard
            out.append({
                "shard": s,
                "stored_bytes": acct["stored_bytes"],
                "logical_bytes": acct["logical_bytes"],
                "compressed_bytes": int(
                    acct.get("compressed_bytes", acct["stored_bytes"])
                ),
                "unique_chunks": acct["unique_chunks"],
                "fp_entries": len(self.fp_index[s].seen),
            })
        return out
