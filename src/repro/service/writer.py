"""Async store writers: block flushing off the ingest thread.

The single-store service does store puts inline with ingest, so SHA-256
hashing and block-file IO serialize with chunking.  The sharded service
instead hands each chunk to its owner shard's :class:`ShardWriter` — one
worker thread per shard, consuming a bounded FIFO queue:

* **one thread per shard** — a shard's ``BlockStore`` (refcount dicts,
  accounting counters, block files) is only ever mutated by its own writer
  thread, so no store needs locks; cross-shard writes proceed in parallel.
* **bounded backpressure** — ``submit`` blocks once ``max_pending`` tasks
  are queued, so a fast ingest thread cannot buffer an unbounded number of
  chunk payloads in memory.
* **crash-safe ordering** — the queue is FIFO and :meth:`barrier` returns
  only after every submitted task ran, so the flush protocol "blocks land,
  *then* recipes commit, *then* manifests sync" holds under async exactly
  as it does inline (the commit/sync steps run on the ingest thread after
  the barrier).

Errors raised by a task are captured and re-raised (first one wins) from
the next :meth:`barrier`/:meth:`close` on the ingest thread — a failed
block write therefore aborts the flush *before* any recipe is committed,
which is the same orphan-blocks-never-dangling-recipes guarantee the sync
path has.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, List, Optional

_STOP = object()


class AsyncWriteError(RuntimeError):
    """A queued store write failed; the flush that submitted it must abort."""


class ShardWriter:
    """One shard's write queue: a single worker thread, bounded FIFO.

    ``max_pending <= 0`` selects synchronous mode: ``submit`` runs the task
    inline and ``barrier`` is a no-op — same interface, no thread, used for
    the sync-flush configuration and as the degenerate 1-shard case.
    """

    def __init__(self, max_pending: int = 256, name: str = "shard-writer"):
        self.async_mode = max_pending > 0
        self._err: Optional[BaseException] = None
        if not self.async_mode:
            return
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    def _loop(self):
        while True:
            task = self._q.get()
            if task is _STOP:
                self._q.task_done()
                return
            try:
                if self._err is None:  # fail fast: drop work after an error
                    task()
            except BaseException as e:  # noqa: BLE001 — re-raised at barrier
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, fn: Callable[[], None]):
        """Queue one write; blocks when the queue is full (backpressure)."""
        if not self.async_mode:
            if self._err is None:
                try:
                    fn()
                except BaseException as e:  # noqa: BLE001
                    self._err = e
            return
        self._q.put(fn)

    def barrier(self):
        """Wait until every submitted write ran; re-raise the first failure."""
        if self.async_mode:
            self._q.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise AsyncWriteError("store write failed during flush") from err

    def close(self):
        """Drain and stop the worker; propagates any pending failure."""
        if self.async_mode and self._thread.is_alive():
            self._q.put(_STOP)
            self._thread.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise AsyncWriteError("store write failed during flush") from err


class WriterPool:
    """Per-shard :class:`ShardWriter` fan-out with a pool-wide barrier."""

    def __init__(self, num_shards: int, max_pending: int = 256):
        self.writers: List[ShardWriter] = [
            ShardWriter(max_pending, name=f"shard-writer-{s}")
            for s in range(num_shards)
        ]

    def submit(self, shard: int, fn: Callable[[], None]):
        self.writers[shard].submit(fn)

    def barrier(self):
        """Block until all shards drained; raise the first captured error."""
        first: Optional[BaseException] = None
        for w in self.writers:
            try:
                w.barrier()
            except AsyncWriteError as e:
                first = first or e
        if first is not None:
            raise first

    def close(self):
        first: Optional[BaseException] = None
        for w in self.writers:
            try:
                w.close()
            except AsyncWriteError as e:
                first = first or e
        if first is not None:
            raise first
