"""Async store writers: block flushing off the ingest thread.

The single-store service does store puts inline with ingest, so SHA-256
hashing and block-file IO serialize with chunking.  The sharded service
instead hands each chunk to its owner shard's :class:`ShardWriter` — one
worker thread per shard, consuming a bounded FIFO queue:

* **one thread per shard** — a shard's ``BlockStore`` (refcount dicts,
  accounting counters, block files) is only ever mutated by its own writer
  thread, so no store needs locks; cross-shard writes proceed in parallel.
* **bounded backpressure** — ``submit`` blocks once ``max_pending`` tasks
  are queued, so a fast ingest thread cannot buffer an unbounded number of
  chunk payloads in memory.
* **crash-safe ordering** — the queue is FIFO and :meth:`barrier` returns
  only after every submitted task ran, so the flush protocol "blocks land,
  *then* recipes commit, *then* manifests sync" holds under async exactly
  as it does inline (the commit/sync steps run on the ingest thread after
  the barrier).

Errors raised by a task are captured and re-raised (first one wins) from
the next :meth:`barrier`/:meth:`close` on the ingest thread — a failed
block write therefore aborts the flush *before* any recipe is committed,
which is the same orphan-blocks-never-dangling-recipes guarantee the sync
path has.

Every writer reports into a :class:`~repro.obs.MetricsRegistry`
(docs/OBSERVABILITY.md): queue depth gauge, backpressure stall-time
counter (seconds ``submit`` spent blocked on a full queue), per-task
queue-wait and flush latency histograms, flushed-byte and error counters —
all labeled by shard.  Metrics outlive a failed flush: the error is
consumed at the barrier but the counters keep counting, so backpressure
and failure rates stay observable across retries.

Tracing crosses the queue: ``submit`` captures the enqueuing thread's
span context (:func:`~repro.obs.current_context`) alongside the task, and
the worker adopts it (:func:`~repro.obs.scope`) around the ``writer.task``
span — so a task's spans (including the shard RPCs it makes) are children
of the *request that enqueued it*, and the recorded queue wait is charged
to the request that paid it, not smeared across whoever happened to be
flushing.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, List, Optional

from repro.obs import MetricsRegistry, current_context, labeled, scope, span

_STOP = object()


class AsyncWriteError(RuntimeError):
    """A queued store write failed; the flush that submitted it must abort."""


class ShardWriter:
    """One shard's write queue: a single worker thread, bounded FIFO.

    ``max_pending <= 0`` selects synchronous mode: ``submit`` runs the task
    inline and ``barrier`` is a no-op — same interface, no thread, used for
    the sync-flush configuration and as the degenerate 1-shard case.
    ``shard`` labels this writer's metrics; ``registry`` is the owning
    service's (a bare writer gets its own).
    """

    def __init__(self, max_pending: int = 256, name: str = "shard-writer",
                 registry: Optional[MetricsRegistry] = None, shard: int = 0):
        self.async_mode = max_pending > 0
        self._err: Optional[BaseException] = None
        self.obs = registry if registry is not None else MetricsRegistry()
        self._m_depth = labeled("writer.queue_depth", shard=shard)
        self._m_stall = labeled("writer.stall_s", shard=shard)
        self._m_tasks = labeled("writer.tasks", shard=shard)
        self._m_task_s = labeled("writer.task_s", shard=shard)
        self._m_wait_s = labeled("writer.queue_wait_s", shard=shard)
        self._m_bytes = labeled("writer.flushed_bytes", shard=shard)
        self._m_errors = labeled("writer.task_errors", shard=shard)
        if not self.async_mode:
            return
        self._q: queue.Queue = queue.Queue(maxsize=max_pending)
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    def _run_task(self, fn: Callable[[], None], nbytes: int,
                  ctx: Optional[dict] = None,
                  t_enq: Optional[float] = None):
        """Execute one task with timing/accounting; captures the first
        error (re-raised at the barrier) and counts every failure.

        ``ctx``/``t_enq`` arrive from the queue in async mode: the
        enqueuer's span context (adopted so the task traces as a child of
        the request that submitted it) and the enqueue timestamp (the
        delta to now is the queue wait that request paid).  The sync path
        passes neither — the task runs on the submitting thread where the
        context is already live and there is no queue to wait in.
        """
        t0 = time.perf_counter()
        if t_enq is not None:
            self.obs.observe(self._m_wait_s, t0 - t_enq)
        try:
            if self._err is None:  # fail fast: drop work after an error
                with scope(ctx), span("writer.task", bytes=nbytes) as sp:
                    if t_enq is not None:
                        sp["queue_wait_s"] = t0 - t_enq
                    fn()
                self.obs.inc(self._m_bytes, nbytes)
        except BaseException as e:  # noqa: BLE001 — re-raised at barrier
            self._err = e
            self.obs.inc(self._m_errors)
        finally:
            self.obs.inc(self._m_tasks)
            self.obs.observe(self._m_task_s, time.perf_counter() - t0)

    def _loop(self):
        while True:
            task = self._q.get()
            if task is _STOP:
                self._q.task_done()
                return
            try:
                self._run_task(*task)
            finally:
                self._q.task_done()

    def submit(self, fn: Callable[[], None], nbytes: int = 0):
        """Queue one write; blocks when the queue is full (backpressure).

        ``nbytes`` is the task's payload size, counted into the shard's
        ``writer.flushed_bytes`` when the task succeeds.
        """
        if not self.async_mode:
            self._run_task(fn, nbytes)
            return
        # the task carries its enqueuer's span context (the worker adopts
        # it) and the enqueue time (worker-side delta = queue wait)
        task = (fn, nbytes, current_context(), time.perf_counter())
        try:
            self._q.put_nowait(task)
        except queue.Full:
            # backpressure stall: the producer is now blocked until the
            # worker frees a slot — that wait is the metric, not the
            # uncontended enqueue cost (which is sub-microsecond).  The
            # full/blocked decision is one atomic put_nowait: a separate
            # full() pre-check would miss a queue that fills between the
            # check and the put, leaving that stall unmeasured.
            t0 = time.perf_counter()
            self._q.put(task)
            self.obs.inc(self._m_stall, time.perf_counter() - t0)
        self.obs.set_gauge(self._m_depth, self._q.qsize())

    def barrier(self):
        """Wait until every submitted write ran; re-raise the first failure."""
        if self.async_mode:
            self._q.join()
            self.obs.set_gauge(self._m_depth, 0)
        if self._err is not None:
            err, self._err = self._err, None
            raise AsyncWriteError("store write failed during flush") from err

    def close(self):
        """Drain and stop the worker; propagates any pending failure."""
        if self.async_mode and self._thread.is_alive():
            self._q.put(_STOP)
            self._thread.join()
        if self._err is not None:
            err, self._err = self._err, None
            raise AsyncWriteError("store write failed during flush") from err


class WriterPool:
    """Per-shard :class:`ShardWriter` fan-out with a pool-wide barrier."""

    def __init__(self, num_shards: int, max_pending: int = 256,
                 registry: Optional[MetricsRegistry] = None):
        self.obs = registry if registry is not None else MetricsRegistry()
        self.writers: List[ShardWriter] = [
            ShardWriter(max_pending, name=f"shard-writer-{s}",
                        registry=self.obs, shard=s)
            for s in range(num_shards)
        ]

    def submit(self, shard: int, fn: Callable[[], None], nbytes: int = 0):
        self.writers[shard].submit(fn, nbytes)

    def barrier(self):
        """Block until all shards drained; raise the first captured error."""
        first: Optional[BaseException] = None
        for w in self.writers:
            try:
                w.barrier()
            except AsyncWriteError as e:
                first = first or e
        if first is not None:
            raise first

    def close(self):
        first: Optional[BaseException] = None
        for w in self.writers:
            try:
                w.close()
            except AsyncWriteError as e:
                first = first or e
        if first is not None:
            raise first
