"""Batched chunking scheduler: length-bucketed continuous batching for SeqCDC.

The serving problem: dedup traffic is a stream of *variable-length* byte
objects, but the TPU pipeline (``boundaries_batch`` — the vmapped two-phase
SeqCDC — plus vmapped ``chunk_fingerprints``) wants fixed ``(B, S)`` device
batches so one compiled XLA program stays hot.  This module bridges the two
with the same slot discipline as ``serve/engine.py``: requests queue per
*length bucket* (padded length from the half-octave grid {1, 1.5}x2^k —
two buckets per octave, capping row padding at 50%), a bucket dispatches
the moment its ``slots`` rows fill, and ``drain`` flushes partial buckets
padded with zero rows.  Distinct device shapes stay logarithmic (2 per
octave) in the stream-length range, so the jit cache is tiny and every
dispatch after warmup is a replay.

Exactness under padding (the part that is not just batching): chunking a
stream padded to bucket size S is *not* the same as chunking the stream —
the max-size/file-end cut consults the stream end.  But SeqCDC is memoryless
at chunk starts, so the decision for a chunk starting at ``s`` depends only
on bytes ``[s, s + max_size]``; while ``s + max_size <= n`` (true length),
the padded run and the exact run see identical windows and emit identical
boundaries.  The scheduler therefore keeps padded boundaries up to the last
chunk start with a full in-bounds window and re-chunks only the final
``< max_size`` tail with the event-driven host oracle (bit-identical to the
device pipeline by the tier-1 equivalence suite).  Result: boundaries (and
fingerprints) bit-identical to per-stream ``boundaries_two_phase``, at
device-batch throughput.

Both device stages have selectable backends (docs/KERNELS.md):
``mask_impl`` for the phase-1 bitmaps and ``fp_impl`` for chunk hashing
(the fused Pallas fingerprint kernel vs the gather/segment_sum reference),
each guarded by a first-dispatch bit-identity cross-check
(``cross_check_masks`` / ``cross_check_fps``).  Above both sits
``pipeline_impl``: ``"split"`` runs the stages as separate dispatches,
``"fused"`` collapses mask + boundary scan + fingerprints into the single
``kernels/fused_pipeline.py`` dispatch (one byte read instead of three),
guarded by its own first-dispatch cross-check against the composed split
path (``cross_check_pipeline`` / ``PipelineDivergenceError``).  The
default comes from ``REPRO_PIPELINE_IMPL`` (else ``"split"``), which is
how CI runs the whole tier-1 suite through the fused path.

Sub-bucket streams and segment packing: the length-bucket grid bottoms out
at ``min_bucket``, so a 300-byte object occupies a 16 KiB device row —
sub-2% occupancy on small-object traffic no batching discipline can fix.
``packing_impl="segments"`` (default from ``REPRO_PACKING_IMPL``) routes
every sub-``min_bucket`` stream to a separate pack queue; when enough
payload accumulates (or at ``drain``), the streams are shelf-packed back
to back into shared ``min_bucket``-wide rows and dispatched once through
the segment-aware device pipeline (``seqcdc.boundaries_packed_batch`` or
the packed fused kernel), whose automaton resets at every segment end —
each packed stream's chunks and fingerprints are bit-identical to
chunking it alone, so the demuxed per-request results are *exact* and
skip the host tail redo entirely.  The first packed dispatch is replayed
stream-by-stream through the unpacked pipeline and compared bit-for-bit
(``cross_check_packing`` / ``PackingDivergenceError``), the same guard
discipline as every other impl knob.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Dict, List, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oracle
from repro.obs import MetricsRegistry, labeled, span
from repro.core.automaton import max_chunks_for
from repro.core.params import SeqCDCParams
from repro.core.seqcdc import MaskImpl, StepImpl, boundaries_batch
from repro.dedup.fingerprint import (
    MAX_CHUNK,
    FpImpl,
    chunk_fingerprints,
    fingerprints_numpy,
)

#: mirrors kernels/fused_pipeline.py's PipelineImpl — declared locally so
#: importing the service does not pull the Pallas toolchain in eagerly
#: (the kernel module is imported lazily, like every other kernel here)
PipelineImpl = Literal["split", "fused"]

PIPELINE_IMPLS = ("split", "fused")

#: sub-bucket stream handling: "off" pads every stream to its own bucket
#: row; "segments" packs sub-min_bucket streams into shared device rows
PackingImpl = Literal["off", "segments"]

PACKING_IMPLS = ("off", "segments")


def _default_pipeline_impl() -> str:
    """``REPRO_PIPELINE_IMPL`` (CI's fused tier-1 leg sets it), else split."""
    return os.environ.get("REPRO_PIPELINE_IMPL", "split")


def _default_packing_impl() -> str:
    """``REPRO_PACKING_IMPL`` (CI's packing-on leg sets it), else off."""
    return os.environ.get("REPRO_PACKING_IMPL", "off")


def _run_fused(x, p, mc):
    """The fused single-dispatch pipeline (module-level so the divergence
    tests can interpose a corrupted kernel, like ``chunk_fingerprints``)."""
    from repro.kernels import ops as kernel_ops

    return kernel_ops.fused_pipeline(x, p, max_chunks=mc)


def _run_split(x, p, mc, mask_impl, step_impl, fp_impl):
    """The composed three-dispatch pipeline (the fused kernel's oracle)."""
    bounds, counts = boundaries_batch(
        x, p, mask_impl=mask_impl, step_impl=step_impl, max_chunks=mc
    )
    fps, lens = jax.vmap(
        lambda d, b, c: chunk_fingerprints(d, b, c, max_chunks=mc,
                                           fp_impl=fp_impl)
    )(x, bounds, counts)
    return bounds, counts, fps, lens


@functools.partial(
    jax.jit,
    static_argnames=("p", "mc", "mask_impl", "step_impl", "with_fp", "fp_impl",
                     "pipeline_impl"),
)
def _device_chunk(x, *, p, mc, mask_impl, step_impl, with_fp, fp_impl,
                  pipeline_impl="split"):
    """(B, S) uint8 -> (bounds, counts[, fps, lens]).  One module-level jit
    (not a per-scheduler closure) so the compile cache is shared: a device
    shape compiles once per process, not once per service instance.

    ``pipeline_impl="fused"`` runs the whole thing — masks, boundary scan,
    fingerprints — as the one ``kernels/fused_pipeline.py`` dispatch
    (``mask_impl``/``fp_impl`` then select only the cross-check replays);
    a fingerprint-less batch has nothing to fuse and takes the split path.
    """
    if pipeline_impl == "fused" and with_fp:
        return _run_fused(x, p, mc)
    if not with_fp:
        bounds, counts = boundaries_batch(
            x, p, mask_impl=mask_impl, step_impl=step_impl, max_chunks=mc
        )
        return bounds, counts, None, None
    return _run_split(x, p, mc, mask_impl, step_impl, fp_impl)


def _run_packed_fused(x, sep, ends, p, mc):
    """The packed fused kernel dispatch (module-level so the divergence
    tests can interpose a corrupted kernel, like ``_run_fused``)."""
    from repro.kernels import ops as kernel_ops

    return kernel_ops.packed_pipeline(x, sep, ends, p, max_chunks=mc)


def _run_packed_split(x, sep, ends, p, mc, mask_impl, fp_impl, with_fp):
    """The composed packed pipeline: segment-aware boundary scan plus the
    vmapped fingerprint stage (fps are translation invariant, so the packed
    bounds feed ``chunk_fingerprints`` with no correction)."""
    from repro.core.seqcdc import boundaries_packed_batch

    bounds, counts = boundaries_packed_batch(
        x, sep, ends, p, mask_impl=mask_impl, max_chunks=mc
    )
    if not with_fp:
        return bounds, counts, None, None
    fps, lens = jax.vmap(
        lambda d, b, c: chunk_fingerprints(d, b, c, max_chunks=mc,
                                           fp_impl=fp_impl)
    )(x, bounds, counts)
    return bounds, counts, fps, lens


@functools.partial(
    jax.jit,
    static_argnames=("p", "mc", "mask_impl", "with_fp", "fp_impl",
                     "pipeline_impl"),
)
def _device_chunk_packed(x, sep, ends, *, p, mc, mask_impl, with_fp,
                         fp_impl, pipeline_impl="split"):
    """(R, S) packed rows -> (bounds, counts[, fps, lens]) in row
    coordinates.  The packed twin of ``_device_chunk``: ``sep`` is the
    per-position segment-end operand, ``ends`` the (R, G) segment-end
    table.  Packed rows have no ``step_impl`` selector — the segment-
    resetting automaton only exists in ``wide`` form (which the packed
    fused kernel mirrors block-for-block)."""
    if pipeline_impl == "fused" and with_fp:
        return _run_packed_fused(x, sep, ends, p, mc)
    return _run_packed_split(x, sep, ends, p, mc, mask_impl, fp_impl,
                             with_fp)


def _trim_exact(data: np.ndarray, padded: np.ndarray,
                padded_fps: np.ndarray | None, p: SeqCDCParams):
    """Trim a padded-run boundary list to the exact per-stream result.

    Keeps every boundary whose chunk started with a full in-bounds
    ``max_size`` window (identical to the exact run by memorylessness) and
    re-chunks the remaining tail with the host oracle.  Returns
    ``(bounds, fps, lengths, tail_bytes)`` where ``tail_bytes`` is how many
    bytes the host redid (0 when the stream length fell on a boundary).
    Module-level so the packing cross-check can replay the unpacked
    pipeline end to end without a scheduler instance.
    """
    n = data.size
    kept = 0
    s = 0
    for b in padded:
        if s + p.max_size > n:
            break
        kept += 1
        s = int(b)
    if s == n:  # stream length hit a boundary exactly: nothing to redo
        bounds = padded[:kept].astype(np.int64)
        tail_rel = np.zeros(0, dtype=np.int64)
        tail_bytes = 0
    else:
        tail_rel = oracle.boundaries_numpy(data[s:], p)
        tail_bytes = n - s
        bounds = np.concatenate([padded[:kept].astype(np.int64),
                                 tail_rel + s])
    lengths = np.diff(np.concatenate([[0], bounds]))
    if padded_fps is None:
        fps = np.zeros((0, 2), dtype=np.uint32)
    elif tail_rel.size:
        fps = np.concatenate([
            padded_fps[:kept],
            fingerprints_numpy(data[s:], tail_rel),
        ])
    else:
        fps = padded_fps[:kept].copy()
    return bounds, fps, lengths, tail_bytes


class MaskDivergenceError(AssertionError):
    """The Pallas and lax mask kernels disagreed on a dispatched batch."""


class FingerprintDivergenceError(AssertionError):
    """The Pallas and reference fingerprint paths disagreed on a batch."""


class PipelineDivergenceError(AssertionError):
    """The fused and split pipelines disagreed on a dispatched batch.

    ``stage`` names what diverged first: ``"boundaries"`` (the mask/scan
    lanes emitted different chunking) or ``"fingerprints"`` (same chunks,
    different hashes) — the first question a kernel regression asks.
    """

    def __init__(self, message: str, stage: str):
        super().__init__(message)
        self.stage = stage


class PackingDivergenceError(AssertionError):
    """A packed-row dispatch disagreed with the per-stream unpacked replay.

    Raised by the first-packed-dispatch guard: every stream of the packed
    batch is rerun as its own unpacked device row and the demuxed packed
    results must match bit-for-bit — a divergence means the segment-reset
    bookkeeping (automaton ``se`` register, mask clipping, or the packed
    fingerprint prefix operands) regressed.
    """


@dataclasses.dataclass
class ChunkRequest:
    seq: int  # submission order (results are returned in this order)
    tag: Any
    data: np.ndarray  # (n,) uint8


@dataclasses.dataclass
class ChunkResult:
    """Exact chunking of one stream: what the store/restore path consumes."""

    tag: Any
    data: np.ndarray  # the original stream (uint8)
    bounds: np.ndarray  # (C,) int64 exclusive chunk ends, bounds[-1] == size
    fps: np.ndarray  # (C, 2) uint32 accelerator fingerprints
    lengths: np.ndarray  # (C,) int64 chunk lengths

    @property
    def size(self) -> int:
        return int(self.data.size)


@dataclasses.dataclass
class SchedulerStats:
    dispatches: int = 0
    padded_rows: int = 0  # zero rows used to square off partial batches
    device_rows: int = 0  # total device rows shipped (real + padded)
    device_bytes: int = 0  # bytes shipped to the device (incl. padding)
    stream_bytes: int = 0  # real payload bytes
    tail_bytes: int = 0  # bytes re-chunked host-side (exactness fixup)
    tail_s: float = 0.0  # wall seconds the host tail redo cost (inside drain)
    packed_streams: int = 0  # streams that rode a shared packed row

    @property
    def occupancy(self) -> float:
        """Real payload fraction of device traffic (batching efficiency)."""
        return self.stream_bytes / self.device_bytes if self.device_bytes else 0.0


class ChunkScheduler:
    """Length-bucketed continuous batching over the vmapped SeqCDC pipeline."""

    def __init__(
        self,
        params: SeqCDCParams | None = None,
        *,
        slots: int = 8,
        min_bucket: int = 1 << 14,
        max_batch_bytes: int = 8 << 20,
        mask_impl: MaskImpl = "jnp",
        step_impl: StepImpl = "wide",
        fp_impl: FpImpl = "reference",
        pipeline_impl: PipelineImpl | None = None,
        packing_impl: PackingImpl | None = None,
        with_fingerprints: bool = True,
        cross_check_masks: bool = False,
        cross_check_fps: bool = False,
        cross_check_pipeline: bool = False,
        cross_check_packing: bool = False,
        registry: MetricsRegistry | None = None,
    ):
        from repro.core.params import derived_params

        self.params = params or derived_params(8192)
        if with_fingerprints and self.params.max_size > MAX_CHUNK:
            raise ValueError(
                f"max_size {self.params.max_size} exceeds the fingerprint "
                f"limit {MAX_CHUNK}; pass with_fingerprints=False"
            )
        self.slots = slots
        self.max_batch_bytes = max_batch_bytes
        self.min_bucket = max(min_bucket, self.params.max_size)
        self.mask_impl = mask_impl
        self.step_impl = step_impl
        self.fp_impl = fp_impl
        if pipeline_impl is None:
            pipeline_impl = _default_pipeline_impl()
        if pipeline_impl not in PIPELINE_IMPLS:
            raise ValueError(
                f"pipeline_impl must be one of {PIPELINE_IMPLS}, "
                f"got {pipeline_impl!r}"
            )
        self.pipeline_impl = pipeline_impl
        if packing_impl is None:
            packing_impl = _default_packing_impl()
        if packing_impl not in PACKING_IMPLS:
            raise ValueError(
                f"packing_impl must be one of {PACKING_IMPLS}, "
                f"got {packing_impl!r}"
            )
        if packing_impl == "segments" and self.min_bucket > MAX_CHUNK:
            raise ValueError(
                f"packing_impl='segments' requires min_bucket <= "
                f"{MAX_CHUNK} (the packed-row limb-exactness bound), "
                f"got {self.min_bucket}"
            )
        self.packing_impl = packing_impl
        self.with_fingerprints = with_fingerprints
        # bit-identity guard for the Pallas hot path: the first dispatch of
        # every device shape is replayed through the other mask backend and
        # compared — a cheap one-time check per compiled program that turns a
        # kernel regression into a loud MaskDivergenceError instead of silent
        # chunk-boundary drift (which dedup would quietly absorb as a worse
        # ratio, the nastiest possible failure mode).
        self.cross_check_masks = cross_check_masks
        self._checked_buckets: set[int] = set()
        # the fingerprint twin: first dispatch per bucket replays the other
        # fp_impl and compares bit-for-bit (FingerprintDivergenceError) — a
        # silently wrong fingerprint would mis-route chunks across shards
        # and poison the estimator index, so it gets the same guard
        self.cross_check_fps = cross_check_fps
        self._fp_checked_buckets: set[int] = set()
        # and the pipeline-level guard: the first dispatch of every bucket
        # is replayed through the *other* pipeline (fused <-> composed
        # split) and compared bit-for-bit across bounds, counts, fps and
        # lengths — PipelineDivergenceError names the stage that diverged
        self.cross_check_pipeline = cross_check_pipeline
        self._pipeline_checked_buckets: set[int] = set()
        # packing guard: the first packed dispatch replays every stream as
        # its own unpacked device row and compares the demuxed results
        # bit-for-bit (PackingDivergenceError) — the packing layer's whole
        # contract is "identical to not packing", so it gets the same
        # one-time-per-process-shape check as every other impl knob
        self.cross_check_packing = cross_check_packing
        self._packing_checked = False
        self._pack_queue: List[ChunkRequest] = []
        self._pack_bytes = 0
        # dispatch the pack queue once it can fill a whole device batch of
        # packed rows (drain() flushes whatever is left)
        self._pack_capacity = (
            self._slots_for(self.min_bucket) * self.min_bucket
        )
        self.stats = SchedulerStats()
        # always-on metrics (docs/OBSERVABILITY.md): the owning service
        # passes its registry so scheduler metrics land in its snapshot;
        # a bare scheduler gets its own
        self.obs = registry if registry is not None else MetricsRegistry()
        # the dispatch-latency histogram is labeled by the static pipeline
        # configuration, so a BENCH trajectory can attribute a latency
        # shift to an impl flip; rendered once, not per dispatch
        self._dispatch_hist = labeled(
            "sched.dispatch_s", pipeline=self.pipeline_impl,
            mask=self.mask_impl, fp=self.fp_impl,
        )
        self._bucket_metric_names: Dict[Any, tuple[str, str, str]] = {}
        self._pending: Dict[int, List[ChunkRequest]] = {}
        self._ready: List[tuple[int, ChunkResult]] = []
        self._jit_cache: Dict[int, Any] = {}
        self._next_seq = 0

    # -- public -----------------------------------------------------------------
    def submit(self, data, tag: Any = None) -> int:
        """Queue one stream for chunking; dispatches when its bucket fills.

        ``data``: raw bytes-like (bytes/bytearray/memoryview) or anything
        ``np.ascontiguousarray`` turns into a uint8 vector.
        """
        if isinstance(data, (bytes, bytearray, memoryview)):
            arr = np.frombuffer(data, dtype=np.uint8)
        else:
            arr = np.ascontiguousarray(data, dtype=np.uint8).reshape(-1)
        seq = self._next_seq
        self._next_seq += 1
        self.stats.stream_bytes += arr.size
        if arr.size == 0:  # no chunks; never touches the device
            empty = np.zeros(0, dtype=np.int64)
            self._ready.append(
                (seq, ChunkResult(tag, arr, empty,
                                  np.zeros((0, 2), dtype=np.uint32), empty))
            )
            return seq
        if self.packing_impl == "segments" and arr.size < self.min_bucket:
            # sub-bucket streams share device rows instead of padding one
            # bucket row each; exactness comes from the segment-resetting
            # packed pipeline, not from this queue's geometry
            self._pack_queue.append(ChunkRequest(seq, tag, arr))
            self._pack_bytes += arr.size
            if self._pack_bytes >= self._pack_capacity:
                self._dispatch_packed()
            return seq
        bucket = self._bucket_for(arr.size)
        q = self._pending.setdefault(bucket, [])
        q.append(ChunkRequest(seq, tag, arr))
        if len(q) >= self._slots_for(bucket):
            self._dispatch(bucket)
        return seq

    def drain(self) -> List[ChunkResult]:
        """Flush every partial bucket and return all results, FIFO order."""
        if self._pack_queue:
            self._dispatch_packed()
        for bucket in sorted(self._pending):
            if self._pending[bucket]:
                self._dispatch(bucket)
        self._ready.sort(key=lambda t: t[0])
        out = [r for _, r in self._ready]
        self._ready.clear()
        return out

    # -- internals ----------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        # two buckets per octave ({1, 1.5} x 2^k): caps row padding at 50%
        # while keeping the set of compiled device shapes logarithmic
        b = self.min_bucket
        while b < n:
            if n <= b + (b >> 1):
                return b + (b >> 1)
            b <<= 1
        return b

    def _slots_for(self, bucket: int) -> int:
        """Rows per device batch: ``slots``, capped so a batch stays within
        ``max_batch_bytes`` (big streams dispatch in small, even solo, rows
        rather than waiting to fill a huge batch)."""
        return max(1, min(self.slots, self.max_batch_bytes // bucket))

    def _device_fn(self, bucket: int):
        fn = self._jit_cache.get(bucket)
        if fn is None:
            fn = functools.partial(
                _device_chunk,
                p=self.params,
                mc=max_chunks_for(bucket, self.params),
                mask_impl=self.mask_impl,
                step_impl=self.step_impl,
                with_fp=self.with_fingerprints,
                fp_impl=self.fp_impl,
                pipeline_impl=self.pipeline_impl,
            )
            self._jit_cache[bucket] = fn
        return fn

    def _bucket_names(self, bucket: int,
                      packed: bool = False) -> tuple[str, str, str]:
        """(occupancy, pad_waste, batch_rows) gauge names for one bucket,
        rendered once per bucket rather than once per dispatch.  Packed
        dispatches get their own ``packed=1`` series so occupancy under
        packing is visible next to (not averaged into) the bucket rows."""
        key = (bucket, packed)
        names = self._bucket_metric_names.get(key)
        if names is None:
            labels = {"bucket": bucket, "packed": 1} if packed else {
                "bucket": bucket}
            names = (
                labeled("sched.occupancy", **labels),
                labeled("sched.pad_waste", **labels),
                labeled("sched.batch_rows", **labels),
            )
            self._bucket_metric_names[key] = names
        return names

    def _dispatch(self, bucket: int):
        # a partial batch (drain of a part-filled bucket) dispatches only
        # the rows it has — padding to the full slot count shipped zero
        # rows the device then chunked for nothing
        reqs = self._pending[bucket]
        rows = len(reqs)
        self._pending[bucket] = []
        payload = sum(r.data.size for r in reqs)
        batch = np.zeros((rows, bucket), dtype=np.uint8)
        for row, r in enumerate(reqs):
            batch[row, : r.data.size] = r.data
        with span("sched.dispatch", bucket=bucket, rows=len(reqs),
                  payload_bytes=payload, device_bytes=batch.size):
            t0 = time.perf_counter()
            bounds, counts, fps, lens = self._device_fn(bucket)(
                jnp.asarray(batch)
            )
            # np.asarray forces device completion, so the elapsed time is
            # the real dispatch latency, not the async-enqueue cost
            bounds = np.asarray(bounds)
            counts = np.asarray(counts)
            if fps is not None:
                fps, lens = np.asarray(fps), np.asarray(lens)
            dispatch_s = time.perf_counter() - t0
        # cross-check replays are excluded from the latency histogram: they
        # are a one-time-per-bucket guard, not steady-state dispatch cost
        if self.cross_check_masks and bucket not in self._checked_buckets:
            self._checked_buckets.add(bucket)
            self.obs.inc(labeled("sched.cross_checks", kind="masks"))
            self._cross_check(bucket, batch, bounds, counts)
        if fps is not None:
            if self.cross_check_fps and bucket not in self._fp_checked_buckets:
                self._fp_checked_buckets.add(bucket)
                self.obs.inc(labeled("sched.cross_checks", kind="fps"))
                self._cross_check_fp(bucket, batch, bounds, counts, fps, lens)
            if (self.cross_check_pipeline
                    and bucket not in self._pipeline_checked_buckets):
                self._pipeline_checked_buckets.add(bucket)
                self.obs.inc(labeled("sched.cross_checks", kind="pipeline"))
                self._cross_check_pipeline(bucket, batch, bounds, counts,
                                           fps, lens)
        self.stats.dispatches += 1
        self.stats.device_bytes += batch.size
        self.stats.device_rows += rows
        self.obs.inc("sched.dispatches")
        self.obs.inc("sched.device_bytes", batch.size)
        self.obs.inc("sched.payload_bytes", payload)
        # partial batches no longer ship zero rows, so padded_rows stays 0;
        # register the counter anyway so BENCH series keep the key
        self.obs.inc("sched.padded_rows", 0)
        self.obs.observe(self._dispatch_hist, dispatch_s)
        occ_name, waste_name, rows_name = self._bucket_names(bucket)
        occ = payload / batch.size if batch.size else 0.0
        self.obs.set_gauge(occ_name, occ)
        self.obs.set_gauge(waste_name, 1.0 - occ)
        self.obs.set_gauge(rows_name, len(reqs))
        for row, r in enumerate(reqs):
            self._ready.append((r.seq, self._exactify(
                r, bounds[row, : counts[row]],
                fps[row] if fps is not None else None,
            )))

    def _dispatch_packed(self):
        """Shelf-pack the sub-bucket queue into shared rows and dispatch."""
        reqs = self._pack_queue
        self._pack_queue = []
        self._pack_bytes = 0
        if not reqs:
            return
        S = self.min_bucket
        # next-fit shelf packing in arrival order: a stream that no longer
        # fits opens a new row — keeps demux order equal to submission
        # order and the packing O(n), at a small fill cost vs best-fit
        rows: List[List[ChunkRequest]] = [[]]
        fill = 0
        for r in reqs:
            if fill + r.data.size > S:
                rows.append([])
                fill = 0
            rows[-1].append(r)
            fill += r.data.size
        slots = self._slots_for(S)
        for i in range(0, len(rows), slots):
            self._dispatch_packed_rows(rows[i:i + slots], S)

    def _dispatch_packed_rows(self, rows: List[List[ChunkRequest]], S: int):
        """One packed device dispatch: R rows of back-to-back segments."""
        R = len(rows)
        G = 4  # segment-table width rounded to a power of two: the jit
        while G < max(len(rr) for rr in rows):  # cache stays logarithmic
            G <<= 1  # in the per-row stream count
        batch = np.zeros((R, S), dtype=np.uint8)
        sep = np.zeros((R, S), dtype=np.int32)
        ends = np.zeros((R, G), dtype=np.int32)
        layout: List[List[tuple[ChunkRequest, int, int]]] = []
        payload = 0
        for ri, rr in enumerate(rows):
            off = 0
            row_layout = []
            for gi, r in enumerate(rr):
                m = r.data.size
                batch[ri, off:off + m] = r.data
                sep[ri, off:off + m] = off + m
                ends[ri, gi] = off + m
                row_layout.append((r, off, off + m))
                off += m
            sep[ri, off:] = off  # padding: its own (empty) tail segment
            ends[ri, len(rr):] = off  # pad entries carry the payload end
            layout.append(row_layout)
            payload += off
        # per-segment bound on chunks: sum of per-stream max_chunks_for
        mc = S // self.params.min_size + 2 * G + 2
        key = ("packed", G)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = functools.partial(
                _device_chunk_packed,
                p=self.params,
                mc=mc,
                mask_impl=self.mask_impl,
                with_fp=self.with_fingerprints,
                fp_impl=self.fp_impl,
                pipeline_impl=self.pipeline_impl,
            )
            self._jit_cache[key] = fn
        with span("sched.dispatch", bucket=S, rows=R, packed=1,
                  payload_bytes=payload, device_bytes=batch.size):
            t0 = time.perf_counter()
            bounds, counts, fps, lens = fn(
                jnp.asarray(batch), jnp.asarray(sep), jnp.asarray(ends)
            )
            bounds = np.asarray(bounds)
            counts = np.asarray(counts)
            if fps is not None:
                fps = np.asarray(fps)
            dispatch_s = time.perf_counter() - t0
        # demux: each stream's chunks are the row bounds in (off, end] —
        # exact results (the packed automaton consulted the true segment
        # ends), so no host tail redo
        results: List[tuple[ChunkRequest, ChunkResult]] = []
        for ri, row_layout in enumerate(layout):
            bs = bounds[ri, : counts[ri]]
            for r, off, end in row_layout:
                i0 = int(np.searchsorted(bs, off, side="right"))
                i1 = int(np.searchsorted(bs, end, side="right"))
                rb = bs[i0:i1].astype(np.int64) - off
                lengths = np.diff(np.concatenate([[0], rb]))
                rf = (fps[ri, i0:i1].copy() if fps is not None
                      else np.zeros((0, 2), dtype=np.uint32))
                results.append(
                    (r, ChunkResult(r.tag, r.data, rb, rf, lengths))
                )
        if self.cross_check_packing and not self._packing_checked:
            self._packing_checked = True
            self.obs.inc(labeled("sched.cross_checks", kind="packing"))
            self._cross_check_packing(S, results)
        self.stats.dispatches += 1
        self.stats.device_bytes += batch.size
        self.stats.device_rows += R
        self.stats.packed_streams += len(results)
        self.obs.inc("sched.dispatches")
        self.obs.inc("sched.device_bytes", batch.size)
        self.obs.inc("sched.payload_bytes", payload)
        self.obs.inc("sched.packed_streams", len(results))
        self.obs.observe(self._dispatch_hist, dispatch_s)
        occ_name, waste_name, rows_name = self._bucket_names(S, packed=True)
        occ = payload / batch.size if batch.size else 0.0
        self.obs.set_gauge(occ_name, occ)
        self.obs.set_gauge(waste_name, 1.0 - occ)
        self.obs.set_gauge(rows_name, R)
        for r, res in results:
            self._ready.append((r.seq, res))

    def _cross_check_packing(self, S: int,
                             results: List[tuple[ChunkRequest, ChunkResult]]):
        """Replay every packed stream as its own unpacked device row and
        compare the demuxed packed results bit-for-bit.  The replay goes
        through ``_device_chunk`` + the host tail trim — the exact pipeline
        a ``packing_impl="off"`` scheduler would run — so this guard pins
        the packing layer's whole contract: packed == not packed."""
        reqs = [r for r, _ in results]
        xb = np.zeros((len(reqs), S), dtype=np.uint8)
        for i, r in enumerate(reqs):
            xb[i, : r.data.size] = r.data
        mc = max_chunks_for(S, self.params)
        b2, c2, f2, l2 = _device_chunk(
            jnp.asarray(xb),
            p=self.params,
            mc=mc,
            mask_impl=self.mask_impl,
            step_impl=self.step_impl,
            with_fp=self.with_fingerprints,
            fp_impl=self.fp_impl,
            pipeline_impl=self.pipeline_impl,
        )
        b2, c2 = np.asarray(b2), np.asarray(c2)
        if f2 is not None:
            f2 = np.asarray(f2)
        bad = []
        for i, (r, res) in enumerate(results):
            eb, ef, el, _ = _trim_exact(
                r.data, b2[i, : c2[i]],
                f2[i] if f2 is not None else None, self.params,
            )
            if not (np.array_equal(res.bounds, eb)
                    and np.array_equal(res.fps, ef)
                    and np.array_equal(res.lengths, el)):
                bad.append(i)
        if bad:
            raise PackingDivergenceError(
                f"packed dispatch diverged from the per-stream unpacked "
                f"replay on streams {bad} (row width {S}): the segment-"
                f"packed pipeline no longer chunks each stream exactly as "
                f"it would chunk alone"
            )

    def _cross_check(self, bucket: int, batch: np.ndarray,
                     bounds: np.ndarray, counts: np.ndarray):
        """Replay one batch through the other mask backend; raise on any bit."""
        from repro.core.seqcdc import boundaries_batch

        other = "jnp" if self.mask_impl == "pallas" else "pallas"
        b2, c2 = boundaries_batch(
            jnp.asarray(batch), self.params, mask_impl=other,
            step_impl=self.step_impl,
            max_chunks=max_chunks_for(bucket, self.params),
        )
        b2, c2 = np.asarray(b2), np.asarray(c2)
        if not (np.array_equal(counts, c2) and np.array_equal(bounds, b2)):
            rows = np.nonzero(
                (counts != c2) | (bounds != b2).any(axis=-1)
            )[0].tolist()
            raise MaskDivergenceError(
                f"mask_impl={self.mask_impl!r} and {other!r} diverged on "
                f"bucket {bucket} (rows {rows}): the Pallas phase-1 kernel "
                f"no longer matches the lax reference bit-for-bit"
            )

    def _cross_check_fp(self, bucket: int, batch: np.ndarray,
                        bounds: np.ndarray, counts: np.ndarray,
                        fps: np.ndarray, lens: np.ndarray):
        """Replay one batch's fingerprints through the other fp backend;
        raise on any differing bit (the ``_cross_check`` twin for fps)."""
        other = "reference" if self.fp_impl == "pallas" else "pallas"
        mc = max_chunks_for(bucket, self.params)
        f2, l2 = jax.vmap(
            lambda d, b, c: chunk_fingerprints(d, b, c, max_chunks=mc,
                                               fp_impl=other)
        )(jnp.asarray(batch), jnp.asarray(bounds), jnp.asarray(counts))
        f2, l2 = np.asarray(f2), np.asarray(l2)
        if not (np.array_equal(fps, f2) and np.array_equal(lens, l2)):
            rows = np.nonzero(
                (fps != f2).any(axis=(-2, -1)) | (lens != l2).any(axis=-1)
            )[0].tolist()
            raise FingerprintDivergenceError(
                f"fp_impl={self.fp_impl!r} and {other!r} diverged on bucket "
                f"{bucket} (rows {rows}): the Pallas fingerprint kernel no "
                f"longer matches the gather-chain reference bit-for-bit"
            )

    def _cross_check_pipeline(self, bucket: int, batch: np.ndarray,
                              bounds: np.ndarray, counts: np.ndarray,
                              fps: np.ndarray, lens: np.ndarray):
        """Replay one batch through the *other* pipeline (fused <-> composed
        split) and compare everything bit-for-bit; the raised error names
        the first stage that diverged — a wrong boundary and a wrong hash
        point at different kernel lanes."""
        mc = max_chunks_for(bucket, self.params)
        x = jnp.asarray(batch)
        if self.pipeline_impl == "fused":
            other = "split"
            b2, c2, f2, l2 = _run_split(x, self.params, mc, self.mask_impl,
                                        self.step_impl, self.fp_impl)
        else:
            other = "fused"
            b2, c2, f2, l2 = _run_fused(x, self.params, mc)
        b2, c2 = np.asarray(b2), np.asarray(c2)
        f2, l2 = np.asarray(f2), np.asarray(l2)
        if not (np.array_equal(counts, c2) and np.array_equal(bounds, b2)):
            rows = np.nonzero(
                (counts != c2) | (bounds != b2).any(axis=-1)
            )[0].tolist()
            raise PipelineDivergenceError(
                f"pipeline_impl={self.pipeline_impl!r} and {other!r} "
                f"diverged on bucket {bucket} (rows {rows}) in the "
                f"boundary stage: the fused kernel's mask/scan lanes no "
                f"longer match the split path bit-for-bit",
                stage="boundaries",
            )
        if not (np.array_equal(fps, f2) and np.array_equal(lens, l2)):
            rows = np.nonzero(
                (fps != f2).any(axis=(-2, -1)) | (lens != l2).any(axis=-1)
            )[0].tolist()
            raise PipelineDivergenceError(
                f"pipeline_impl={self.pipeline_impl!r} and {other!r} "
                f"diverged on bucket {bucket} (rows {rows}) in the "
                f"fingerprint stage: identical chunk boundaries but the "
                f"fused kernel's hash limb path no longer matches",
                stage="fingerprints",
            )

    def _exactify(self, req: ChunkRequest, padded: np.ndarray,
                  padded_fps: np.ndarray | None) -> ChunkResult:
        """Trim a padded-run boundary list to the exact per-stream result."""
        t0 = time.perf_counter()
        bounds, fps, lengths, tail_bytes = _trim_exact(
            req.data, padded, padded_fps, self.params
        )
        if tail_bytes:
            # tail_s counts only redos that did work: the kept-boundary
            # trim is O(chunks) bookkeeping, the oracle re-chunk is the
            # latency phase (the service reattributes it out of its
            # chunk-dispatch phase via this accumulator's delta)
            self.stats.tail_bytes += tail_bytes
            self.stats.tail_s += time.perf_counter() - t0
            self.obs.inc("sched.tail_bytes", tail_bytes)
        return ChunkResult(req.tag, req.data, bounds, fps, lengths)
