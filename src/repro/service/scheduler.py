"""Batched chunking scheduler: length-bucketed continuous batching for SeqCDC.

The serving problem: dedup traffic is a stream of *variable-length* byte
objects, but the TPU pipeline (``boundaries_batch`` — the vmapped two-phase
SeqCDC — plus vmapped ``chunk_fingerprints``) wants fixed ``(B, S)`` device
batches so one compiled XLA program stays hot.  This module bridges the two
with the same slot discipline as ``serve/engine.py``: requests queue per
*length bucket* (padded length from the half-octave grid {1, 1.5}x2^k —
two buckets per octave, capping row padding at 50%), a bucket dispatches
the moment its ``slots`` rows fill, and ``drain`` flushes partial buckets
padded with zero rows.  Distinct device shapes stay logarithmic (2 per
octave) in the stream-length range, so the jit cache is tiny and every
dispatch after warmup is a replay.

Exactness under padding (the part that is not just batching): chunking a
stream padded to bucket size S is *not* the same as chunking the stream —
the max-size/file-end cut consults the stream end.  But SeqCDC is memoryless
at chunk starts, so the decision for a chunk starting at ``s`` depends only
on bytes ``[s, s + max_size]``; while ``s + max_size <= n`` (true length),
the padded run and the exact run see identical windows and emit identical
boundaries.  The scheduler therefore keeps padded boundaries up to the last
chunk start with a full in-bounds window and re-chunks only the final
``< max_size`` tail with the event-driven host oracle (bit-identical to the
device pipeline by the tier-1 equivalence suite).  Result: boundaries (and
fingerprints) bit-identical to per-stream ``boundaries_two_phase``, at
device-batch throughput.

Both device stages have selectable backends (docs/KERNELS.md):
``mask_impl`` for the phase-1 bitmaps and ``fp_impl`` for chunk hashing
(the fused Pallas fingerprint kernel vs the gather/segment_sum reference),
each guarded by a first-dispatch bit-identity cross-check
(``cross_check_masks`` / ``cross_check_fps``).  Above both sits
``pipeline_impl``: ``"split"`` runs the stages as separate dispatches,
``"fused"`` collapses mask + boundary scan + fingerprints into the single
``kernels/fused_pipeline.py`` dispatch (one byte read instead of three),
guarded by its own first-dispatch cross-check against the composed split
path (``cross_check_pipeline`` / ``PipelineDivergenceError``).  The
default comes from ``REPRO_PIPELINE_IMPL`` (else ``"split"``), which is
how CI runs the whole tier-1 suite through the fused path.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Dict, List, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import oracle
from repro.obs import MetricsRegistry, labeled, span
from repro.core.automaton import max_chunks_for
from repro.core.params import SeqCDCParams
from repro.core.seqcdc import MaskImpl, StepImpl, boundaries_batch
from repro.dedup.fingerprint import (
    MAX_CHUNK,
    FpImpl,
    chunk_fingerprints,
    fingerprints_numpy,
)

#: mirrors kernels/fused_pipeline.py's PipelineImpl — declared locally so
#: importing the service does not pull the Pallas toolchain in eagerly
#: (the kernel module is imported lazily, like every other kernel here)
PipelineImpl = Literal["split", "fused"]

PIPELINE_IMPLS = ("split", "fused")


def _default_pipeline_impl() -> str:
    """``REPRO_PIPELINE_IMPL`` (CI's fused tier-1 leg sets it), else split."""
    return os.environ.get("REPRO_PIPELINE_IMPL", "split")


def _run_fused(x, p, mc):
    """The fused single-dispatch pipeline (module-level so the divergence
    tests can interpose a corrupted kernel, like ``chunk_fingerprints``)."""
    from repro.kernels import ops as kernel_ops

    return kernel_ops.fused_pipeline(x, p, max_chunks=mc)


def _run_split(x, p, mc, mask_impl, step_impl, fp_impl):
    """The composed three-dispatch pipeline (the fused kernel's oracle)."""
    bounds, counts = boundaries_batch(
        x, p, mask_impl=mask_impl, step_impl=step_impl, max_chunks=mc
    )
    fps, lens = jax.vmap(
        lambda d, b, c: chunk_fingerprints(d, b, c, max_chunks=mc,
                                           fp_impl=fp_impl)
    )(x, bounds, counts)
    return bounds, counts, fps, lens


@functools.partial(
    jax.jit,
    static_argnames=("p", "mc", "mask_impl", "step_impl", "with_fp", "fp_impl",
                     "pipeline_impl"),
)
def _device_chunk(x, *, p, mc, mask_impl, step_impl, with_fp, fp_impl,
                  pipeline_impl="split"):
    """(B, S) uint8 -> (bounds, counts[, fps, lens]).  One module-level jit
    (not a per-scheduler closure) so the compile cache is shared: a device
    shape compiles once per process, not once per service instance.

    ``pipeline_impl="fused"`` runs the whole thing — masks, boundary scan,
    fingerprints — as the one ``kernels/fused_pipeline.py`` dispatch
    (``mask_impl``/``fp_impl`` then select only the cross-check replays);
    a fingerprint-less batch has nothing to fuse and takes the split path.
    """
    if pipeline_impl == "fused" and with_fp:
        return _run_fused(x, p, mc)
    if not with_fp:
        bounds, counts = boundaries_batch(
            x, p, mask_impl=mask_impl, step_impl=step_impl, max_chunks=mc
        )
        return bounds, counts, None, None
    return _run_split(x, p, mc, mask_impl, step_impl, fp_impl)


class MaskDivergenceError(AssertionError):
    """The Pallas and lax mask kernels disagreed on a dispatched batch."""


class FingerprintDivergenceError(AssertionError):
    """The Pallas and reference fingerprint paths disagreed on a batch."""


class PipelineDivergenceError(AssertionError):
    """The fused and split pipelines disagreed on a dispatched batch.

    ``stage`` names what diverged first: ``"boundaries"`` (the mask/scan
    lanes emitted different chunking) or ``"fingerprints"`` (same chunks,
    different hashes) — the first question a kernel regression asks.
    """

    def __init__(self, message: str, stage: str):
        super().__init__(message)
        self.stage = stage


@dataclasses.dataclass
class ChunkRequest:
    seq: int  # submission order (results are returned in this order)
    tag: Any
    data: np.ndarray  # (n,) uint8


@dataclasses.dataclass
class ChunkResult:
    """Exact chunking of one stream: what the store/restore path consumes."""

    tag: Any
    data: np.ndarray  # the original stream (uint8)
    bounds: np.ndarray  # (C,) int64 exclusive chunk ends, bounds[-1] == size
    fps: np.ndarray  # (C, 2) uint32 accelerator fingerprints
    lengths: np.ndarray  # (C,) int64 chunk lengths

    @property
    def size(self) -> int:
        return int(self.data.size)


@dataclasses.dataclass
class SchedulerStats:
    dispatches: int = 0
    padded_rows: int = 0  # zero rows used to square off partial batches
    device_bytes: int = 0  # bytes shipped to the device (incl. padding)
    stream_bytes: int = 0  # real payload bytes
    tail_bytes: int = 0  # bytes re-chunked host-side (exactness fixup)

    @property
    def occupancy(self) -> float:
        """Real payload fraction of device traffic (batching efficiency)."""
        return self.stream_bytes / self.device_bytes if self.device_bytes else 0.0


class ChunkScheduler:
    """Length-bucketed continuous batching over the vmapped SeqCDC pipeline."""

    def __init__(
        self,
        params: SeqCDCParams | None = None,
        *,
        slots: int = 8,
        min_bucket: int = 1 << 14,
        max_batch_bytes: int = 8 << 20,
        mask_impl: MaskImpl = "jnp",
        step_impl: StepImpl = "wide",
        fp_impl: FpImpl = "reference",
        pipeline_impl: PipelineImpl | None = None,
        with_fingerprints: bool = True,
        cross_check_masks: bool = False,
        cross_check_fps: bool = False,
        cross_check_pipeline: bool = False,
        registry: MetricsRegistry | None = None,
    ):
        from repro.core.params import derived_params

        self.params = params or derived_params(8192)
        if with_fingerprints and self.params.max_size > MAX_CHUNK:
            raise ValueError(
                f"max_size {self.params.max_size} exceeds the fingerprint "
                f"limit {MAX_CHUNK}; pass with_fingerprints=False"
            )
        self.slots = slots
        self.max_batch_bytes = max_batch_bytes
        self.min_bucket = max(min_bucket, self.params.max_size)
        self.mask_impl = mask_impl
        self.step_impl = step_impl
        self.fp_impl = fp_impl
        if pipeline_impl is None:
            pipeline_impl = _default_pipeline_impl()
        if pipeline_impl not in PIPELINE_IMPLS:
            raise ValueError(
                f"pipeline_impl must be one of {PIPELINE_IMPLS}, "
                f"got {pipeline_impl!r}"
            )
        self.pipeline_impl = pipeline_impl
        self.with_fingerprints = with_fingerprints
        # bit-identity guard for the Pallas hot path: the first dispatch of
        # every device shape is replayed through the other mask backend and
        # compared — a cheap one-time check per compiled program that turns a
        # kernel regression into a loud MaskDivergenceError instead of silent
        # chunk-boundary drift (which dedup would quietly absorb as a worse
        # ratio, the nastiest possible failure mode).
        self.cross_check_masks = cross_check_masks
        self._checked_buckets: set[int] = set()
        # the fingerprint twin: first dispatch per bucket replays the other
        # fp_impl and compares bit-for-bit (FingerprintDivergenceError) — a
        # silently wrong fingerprint would mis-route chunks across shards
        # and poison the estimator index, so it gets the same guard
        self.cross_check_fps = cross_check_fps
        self._fp_checked_buckets: set[int] = set()
        # and the pipeline-level guard: the first dispatch of every bucket
        # is replayed through the *other* pipeline (fused <-> composed
        # split) and compared bit-for-bit across bounds, counts, fps and
        # lengths — PipelineDivergenceError names the stage that diverged
        self.cross_check_pipeline = cross_check_pipeline
        self._pipeline_checked_buckets: set[int] = set()
        self.stats = SchedulerStats()
        # always-on metrics (docs/OBSERVABILITY.md): the owning service
        # passes its registry so scheduler metrics land in its snapshot;
        # a bare scheduler gets its own
        self.obs = registry if registry is not None else MetricsRegistry()
        # the dispatch-latency histogram is labeled by the static pipeline
        # configuration, so a BENCH trajectory can attribute a latency
        # shift to an impl flip; rendered once, not per dispatch
        self._dispatch_hist = labeled(
            "sched.dispatch_s", pipeline=self.pipeline_impl,
            mask=self.mask_impl, fp=self.fp_impl,
        )
        self._bucket_metric_names: Dict[int, tuple[str, str, str]] = {}
        self._pending: Dict[int, List[ChunkRequest]] = {}
        self._ready: List[tuple[int, ChunkResult]] = []
        self._jit_cache: Dict[int, Any] = {}
        self._next_seq = 0

    # -- public -----------------------------------------------------------------
    def submit(self, data, tag: Any = None) -> int:
        """Queue one stream for chunking; dispatches when its bucket fills.

        ``data``: raw bytes-like (bytes/bytearray/memoryview) or anything
        ``np.ascontiguousarray`` turns into a uint8 vector.
        """
        if isinstance(data, (bytes, bytearray, memoryview)):
            arr = np.frombuffer(data, dtype=np.uint8)
        else:
            arr = np.ascontiguousarray(data, dtype=np.uint8).reshape(-1)
        seq = self._next_seq
        self._next_seq += 1
        self.stats.stream_bytes += arr.size
        if arr.size == 0:  # no chunks; never touches the device
            empty = np.zeros(0, dtype=np.int64)
            self._ready.append(
                (seq, ChunkResult(tag, arr, empty,
                                  np.zeros((0, 2), dtype=np.uint32), empty))
            )
            return seq
        bucket = self._bucket_for(arr.size)
        q = self._pending.setdefault(bucket, [])
        q.append(ChunkRequest(seq, tag, arr))
        if len(q) >= self._slots_for(bucket):
            self._dispatch(bucket)
        return seq

    def drain(self) -> List[ChunkResult]:
        """Flush every partial bucket and return all results, FIFO order."""
        for bucket in sorted(self._pending):
            if self._pending[bucket]:
                self._dispatch(bucket)
        self._ready.sort(key=lambda t: t[0])
        out = [r for _, r in self._ready]
        self._ready.clear()
        return out

    # -- internals ----------------------------------------------------------------
    def _bucket_for(self, n: int) -> int:
        # two buckets per octave ({1, 1.5} x 2^k): caps row padding at 50%
        # while keeping the set of compiled device shapes logarithmic
        b = self.min_bucket
        while b < n:
            if n <= b + (b >> 1):
                return b + (b >> 1)
            b <<= 1
        return b

    def _slots_for(self, bucket: int) -> int:
        """Rows per device batch: ``slots``, capped so a batch stays within
        ``max_batch_bytes`` (big streams dispatch in small, even solo, rows
        rather than waiting to fill a huge batch)."""
        return max(1, min(self.slots, self.max_batch_bytes // bucket))

    def _device_fn(self, bucket: int):
        fn = self._jit_cache.get(bucket)
        if fn is None:
            fn = functools.partial(
                _device_chunk,
                p=self.params,
                mc=max_chunks_for(bucket, self.params),
                mask_impl=self.mask_impl,
                step_impl=self.step_impl,
                with_fp=self.with_fingerprints,
                fp_impl=self.fp_impl,
                pipeline_impl=self.pipeline_impl,
            )
            self._jit_cache[bucket] = fn
        return fn

    def _bucket_names(self, bucket: int) -> tuple[str, str, str]:
        """(occupancy, pad_waste, batch_rows) gauge names for one bucket,
        rendered once per bucket rather than once per dispatch."""
        names = self._bucket_metric_names.get(bucket)
        if names is None:
            names = (
                labeled("sched.occupancy", bucket=bucket),
                labeled("sched.pad_waste", bucket=bucket),
                labeled("sched.batch_rows", bucket=bucket),
            )
            self._bucket_metric_names[bucket] = names
        return names

    def _dispatch(self, bucket: int):
        rows = self._slots_for(bucket)
        reqs = self._pending[bucket]
        self._pending[bucket] = []
        payload = sum(r.data.size for r in reqs)
        batch = np.zeros((rows, bucket), dtype=np.uint8)
        for row, r in enumerate(reqs):
            batch[row, : r.data.size] = r.data
        with span("sched.dispatch", bucket=bucket, rows=len(reqs),
                  payload_bytes=payload, device_bytes=batch.size):
            t0 = time.perf_counter()
            bounds, counts, fps, lens = self._device_fn(bucket)(
                jnp.asarray(batch)
            )
            # np.asarray forces device completion, so the elapsed time is
            # the real dispatch latency, not the async-enqueue cost
            bounds = np.asarray(bounds)
            counts = np.asarray(counts)
            if fps is not None:
                fps, lens = np.asarray(fps), np.asarray(lens)
            dispatch_s = time.perf_counter() - t0
        # cross-check replays are excluded from the latency histogram: they
        # are a one-time-per-bucket guard, not steady-state dispatch cost
        if self.cross_check_masks and bucket not in self._checked_buckets:
            self._checked_buckets.add(bucket)
            self.obs.inc(labeled("sched.cross_checks", kind="masks"))
            self._cross_check(bucket, batch, bounds, counts)
        if fps is not None:
            if self.cross_check_fps and bucket not in self._fp_checked_buckets:
                self._fp_checked_buckets.add(bucket)
                self.obs.inc(labeled("sched.cross_checks", kind="fps"))
                self._cross_check_fp(bucket, batch, bounds, counts, fps, lens)
            if (self.cross_check_pipeline
                    and bucket not in self._pipeline_checked_buckets):
                self._pipeline_checked_buckets.add(bucket)
                self.obs.inc(labeled("sched.cross_checks", kind="pipeline"))
                self._cross_check_pipeline(bucket, batch, bounds, counts,
                                           fps, lens)
        self.stats.dispatches += 1
        self.stats.device_bytes += batch.size
        self.stats.padded_rows += rows - len(reqs)
        self.obs.inc("sched.dispatches")
        self.obs.inc("sched.device_bytes", batch.size)
        self.obs.inc("sched.payload_bytes", payload)
        self.obs.inc("sched.padded_rows", rows - len(reqs))
        self.obs.observe(self._dispatch_hist, dispatch_s)
        occ_name, waste_name, rows_name = self._bucket_names(bucket)
        occ = payload / batch.size if batch.size else 0.0
        self.obs.set_gauge(occ_name, occ)
        self.obs.set_gauge(waste_name, 1.0 - occ)
        self.obs.set_gauge(rows_name, len(reqs))
        for row, r in enumerate(reqs):
            self._ready.append((r.seq, self._exactify(
                r, bounds[row, : counts[row]],
                fps[row] if fps is not None else None,
            )))

    def _cross_check(self, bucket: int, batch: np.ndarray,
                     bounds: np.ndarray, counts: np.ndarray):
        """Replay one batch through the other mask backend; raise on any bit."""
        from repro.core.seqcdc import boundaries_batch

        other = "jnp" if self.mask_impl == "pallas" else "pallas"
        b2, c2 = boundaries_batch(
            jnp.asarray(batch), self.params, mask_impl=other,
            step_impl=self.step_impl,
            max_chunks=max_chunks_for(bucket, self.params),
        )
        b2, c2 = np.asarray(b2), np.asarray(c2)
        if not (np.array_equal(counts, c2) and np.array_equal(bounds, b2)):
            rows = np.nonzero(
                (counts != c2) | (bounds != b2).any(axis=-1)
            )[0].tolist()
            raise MaskDivergenceError(
                f"mask_impl={self.mask_impl!r} and {other!r} diverged on "
                f"bucket {bucket} (rows {rows}): the Pallas phase-1 kernel "
                f"no longer matches the lax reference bit-for-bit"
            )

    def _cross_check_fp(self, bucket: int, batch: np.ndarray,
                        bounds: np.ndarray, counts: np.ndarray,
                        fps: np.ndarray, lens: np.ndarray):
        """Replay one batch's fingerprints through the other fp backend;
        raise on any differing bit (the ``_cross_check`` twin for fps)."""
        other = "reference" if self.fp_impl == "pallas" else "pallas"
        mc = max_chunks_for(bucket, self.params)
        f2, l2 = jax.vmap(
            lambda d, b, c: chunk_fingerprints(d, b, c, max_chunks=mc,
                                               fp_impl=other)
        )(jnp.asarray(batch), jnp.asarray(bounds), jnp.asarray(counts))
        f2, l2 = np.asarray(f2), np.asarray(l2)
        if not (np.array_equal(fps, f2) and np.array_equal(lens, l2)):
            rows = np.nonzero(
                (fps != f2).any(axis=(-2, -1)) | (lens != l2).any(axis=-1)
            )[0].tolist()
            raise FingerprintDivergenceError(
                f"fp_impl={self.fp_impl!r} and {other!r} diverged on bucket "
                f"{bucket} (rows {rows}): the Pallas fingerprint kernel no "
                f"longer matches the gather-chain reference bit-for-bit"
            )

    def _cross_check_pipeline(self, bucket: int, batch: np.ndarray,
                              bounds: np.ndarray, counts: np.ndarray,
                              fps: np.ndarray, lens: np.ndarray):
        """Replay one batch through the *other* pipeline (fused <-> composed
        split) and compare everything bit-for-bit; the raised error names
        the first stage that diverged — a wrong boundary and a wrong hash
        point at different kernel lanes."""
        mc = max_chunks_for(bucket, self.params)
        x = jnp.asarray(batch)
        if self.pipeline_impl == "fused":
            other = "split"
            b2, c2, f2, l2 = _run_split(x, self.params, mc, self.mask_impl,
                                        self.step_impl, self.fp_impl)
        else:
            other = "fused"
            b2, c2, f2, l2 = _run_fused(x, self.params, mc)
        b2, c2 = np.asarray(b2), np.asarray(c2)
        f2, l2 = np.asarray(f2), np.asarray(l2)
        if not (np.array_equal(counts, c2) and np.array_equal(bounds, b2)):
            rows = np.nonzero(
                (counts != c2) | (bounds != b2).any(axis=-1)
            )[0].tolist()
            raise PipelineDivergenceError(
                f"pipeline_impl={self.pipeline_impl!r} and {other!r} "
                f"diverged on bucket {bucket} (rows {rows}) in the "
                f"boundary stage: the fused kernel's mask/scan lanes no "
                f"longer match the split path bit-for-bit",
                stage="boundaries",
            )
        if not (np.array_equal(fps, f2) and np.array_equal(lens, l2)):
            rows = np.nonzero(
                (fps != f2).any(axis=(-2, -1)) | (lens != l2).any(axis=-1)
            )[0].tolist()
            raise PipelineDivergenceError(
                f"pipeline_impl={self.pipeline_impl!r} and {other!r} "
                f"diverged on bucket {bucket} (rows {rows}) in the "
                f"fingerprint stage: identical chunk boundaries but the "
                f"fused kernel's hash limb path no longer matches",
                stage="fingerprints",
            )

    def _exactify(self, req: ChunkRequest, padded: np.ndarray,
                  padded_fps: np.ndarray | None) -> ChunkResult:
        """Trim a padded-run boundary list to the exact per-stream result."""
        n = req.data.size
        p = self.params
        kept = 0
        s = 0
        for b in padded:
            if s + p.max_size > n:
                break
            kept += 1
            s = int(b)
        if s == n:  # stream length hit a boundary exactly: nothing to redo
            bounds = padded[:kept].astype(np.int64)
            tail_rel = np.zeros(0, dtype=np.int64)
        else:
            tail_rel = oracle.boundaries_numpy(req.data[s:], p)
            self.stats.tail_bytes += n - s
            self.obs.inc("sched.tail_bytes", n - s)
            bounds = np.concatenate([padded[:kept].astype(np.int64), tail_rel + s])
        lengths = np.diff(np.concatenate([[0], bounds]))
        if padded_fps is None:
            fps = np.zeros((0, 2), dtype=np.uint32)
        elif tail_rel.size:
            fps = np.concatenate([
                padded_fps[:kept],
                fingerprints_numpy(req.data[s:], tail_rel),
            ])
        else:
            fps = padded_fps[:kept].copy()
        return ChunkResult(req.tag, req.data, bounds, fps, lengths)
