"""PEP-562 lazy package exports, shared by the ``service``/``dedup`` inits.

Both packages mix numpy+stdlib modules (store, objects, transport, depot)
with jax-heavy ones (scheduler, dist_index), and the spawned shard-server
processes must be able to import the former without paying for the latter.
One helper owns the resolution/caching/``__dir__`` behavior so the two
package inits cannot drift.
"""
from __future__ import annotations

import importlib
import sys
from typing import Dict, Sequence, Tuple


def install(module_name: str, exports: Dict[str, str],
            submodules: Sequence[str]) -> Tuple:
    """Build ``(__getattr__, __dir__)`` for a lazy package ``__init__``.

    ``exports`` maps public name -> defining submodule (relative, ``.api``
    style); ``submodules`` lists names resolvable as plain submodules.
    Resolved exports are cached on the package module, so the second access
    skips ``__getattr__`` entirely.
    """

    def __getattr__(name: str):
        if name in exports:
            value = getattr(
                importlib.import_module(exports[name], module_name), name
            )
            setattr(sys.modules[module_name], name, value)
            return value
        if name in submodules:
            return importlib.import_module("." + name, module_name)
        raise AttributeError(
            f"module {module_name!r} has no attribute {name!r}"
        )

    def __dir__():
        return sorted(
            set(vars(sys.modules[module_name])) | set(exports) | set(submodules)
        )

    return __getattr__, __dir__
