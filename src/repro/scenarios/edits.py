"""Seeded edit programs: insert/delete/update/append over structured data.

The engine behind the versioned-corpus scenarios (docs/SCENARIOS.md): a
*program* is an explicit list of :class:`EditOp`, sampled from a seeded
rng and applied sequentially, so each dataset revision is a deterministic
function of (base bytes, seed) and the edited-byte totals are known by
construction — the generator can state the corpus's duplicate fraction
instead of guessing it.  Inserts and deletes shift every byte after them,
which is exactly the workload CDC exists for (fixed-size chunking loses
all alignment; content-defined boundaries resynchronize).

Structured base data (:func:`structured_rows`) mimics record-oriented
files — pipe-delimited rows with ids, categorical words, and numeric
fields — so updates/inserts look like dataset edits, not noise splices.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence, Tuple

import numpy as np

#: op kinds, in the order the sampler's kind-draw indexes them
KINDS = ("insert", "delete", "update", "append")

_WORDS = (
    "alpha bravo charlie delta echo foxtrot golf hotel india juliet kilo "
    "lima mike november oscar papa quebec romeo sierra tango uniform "
    "victor whiskey xray yankee zulu"
).split()


@dataclasses.dataclass(frozen=True)
class EditOp:
    """One edit: where, how many bytes leave, and what bytes arrive.

    ``offset`` indexes the revision *as it stands when the op runs* (ops
    apply sequentially); ``length`` is the span removed (delete/update —
    zero for insert/append); ``payload`` is the bytes added (empty for
    delete).  ``append`` ignores ``offset``/``length``.
    """

    kind: str
    offset: int
    length: int
    payload: bytes = b""


def apply_op(data: np.ndarray, op: EditOp) -> np.ndarray:
    """Apply one op; offsets/lengths are clamped, never out-of-range."""
    n = int(data.size)
    pay = np.frombuffer(op.payload, dtype=np.uint8)
    if op.kind == "append":
        return np.concatenate([data, pay])
    off = min(max(0, op.offset), n)
    if op.kind == "insert":
        return np.concatenate([data[:off], pay, data[off:]])
    end = min(n, off + max(0, op.length))
    if op.kind == "delete":
        return np.concatenate([data[:off], data[end:]])
    if op.kind == "update":
        return np.concatenate([data[:off], pay, data[end:]])
    raise ValueError(f"unknown edit kind {op.kind!r}")


def apply_program(data: np.ndarray, ops: Sequence[EditOp]) -> np.ndarray:
    out = np.ascontiguousarray(data, dtype=np.uint8)
    for op in ops:
        out = apply_op(out, op)
    return out


def fresh_bytes(ops: Sequence[EditOp]) -> int:
    """Bytes a program adds that did not exist before — the payload side
    of the construction-level duplicate accounting."""
    return sum(len(op.payload) for op in ops)


def sample_program(
    rng: np.random.Generator,
    size: int,
    n_ops: int,
    *,
    kinds: Sequence[str] = KINDS,
    max_edit: int = 256,
    payload: "callable | None" = None,
) -> List[EditOp]:
    """Draw a seeded program of ``n_ops`` edits against a ``size``-byte
    revision.  ``payload(rng, length) -> bytes`` supplies inserted bytes
    (default: uniform random), so structured scenarios can insert
    structured records.  Offsets track the running length, so every op is
    in-range when applied sequentially."""
    if payload is None:
        payload = lambda r, ln: r.integers(0, 256, ln, dtype=np.uint8).tobytes()
    ops: List[EditOp] = []
    cur = int(size)
    for _ in range(max(0, int(n_ops))):
        kind = kinds[int(rng.integers(0, len(kinds)))]
        ln = int(rng.integers(1, max_edit + 1))
        off = int(rng.integers(0, max(1, cur)))
        if kind == "insert":
            ops.append(EditOp("insert", off, 0, payload(rng, ln)))
            cur += ln
        elif kind == "delete":
            ln = min(ln, max(0, cur - 1))  # never delete the whole object
            ops.append(EditOp("delete", off, ln))
            cur = max(1, cur - ln)
        elif kind == "update":
            ops.append(EditOp("update", off, ln, payload(rng, ln)))
            cur = max(cur, off)  # length-preserving up to the clamp
        else:  # append
            ops.append(EditOp("append", 0, 0, payload(rng, ln)))
            cur += ln
    return ops


def revision_history(
    base: np.ndarray,
    revisions: int,
    ops_per_rev: int,
    rng: np.random.Generator,
    **sample_kw,
) -> Iterator[Tuple[np.ndarray, List[EditOp]]]:
    """Yield ``revisions`` successive (bytes, program) states; the first
    is the base itself with an empty program."""
    cur = np.ascontiguousarray(base, dtype=np.uint8)
    yield cur, []
    for _ in range(max(0, int(revisions) - 1)):
        ops = sample_program(rng, int(cur.size), ops_per_rev, **sample_kw)
        cur = apply_program(cur, ops)
        yield cur, ops


# -- structured base data ----------------------------------------------------

def structured_rows(rng: np.random.Generator, nbytes: int,
                    start_id: int = 0) -> np.ndarray:
    """Record-oriented base data: pipe-delimited rows with a sequential
    id, categorical words, and a numeric field — dataset-shaped bytes, so
    edit programs read as row updates/inserts rather than noise."""
    rows: List[bytes] = []
    total, rid = 0, int(start_id)
    while total < nbytes:
        w = [_WORDS[int(i)] for i in rng.integers(0, len(_WORDS), 3)]
        row = (f"{rid:08d}|{w[0]}|{w[1]}-{w[2]}|"
               f"{rng.random():.6f}|{int(rng.integers(0, 2))}\n").encode()
        rows.append(row)
        total += len(row)
        rid += 1
    return np.frombuffer(b"".join(rows), dtype=np.uint8)[:nbytes].copy()


def row_payload(rng: np.random.Generator, length: int) -> bytes:
    """Structured insert payload: whole rows, trimmed to ``length``."""
    return structured_rows(rng, length, start_id=int(rng.integers(10**7))
                           ).tobytes()
