"""Scenario engine: seeded versioned-corpus workloads with known structure.

Public surface (docs/SCENARIOS.md):

* :data:`SCENARIOS` / :func:`generate` — the workload catalog; each entry
  deterministically builds a list of named objects plus an
  :class:`ExpectedStructure` descriptor (constructed duplicate fraction,
  expected dedup-ratio band).
* :func:`corpus_digest` — canonical fingerprint of the determinism
  contract (same seed -> same digest, cross-process).
* :func:`bench_params` — the chunking params the ratio bands contract
  against, per budget.
* :func:`lm_training_corpus` — flat LM byte stream for the training
  example (``examples/train_dedup_lm.py``).

numpy + stdlib only: importing this package never imports jax.
"""
from .base import (  # noqa: F401
    BUDGETS,
    ExpectedStructure,
    Scenario,
    ScenarioCorpus,
    corpus_digest,
)
from .generators import (  # noqa: F401
    SCENARIOS,
    bench_params,
    generate,
    lm_training_corpus,
)
from . import edits  # noqa: F401

__all__ = [
    "BUDGETS", "ExpectedStructure", "Scenario", "ScenarioCorpus",
    "SCENARIOS", "bench_params", "corpus_digest", "edits", "generate",
    "lm_training_corpus",
]
