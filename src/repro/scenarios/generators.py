"""The scenario catalog: four versioned-corpus workload generators.

Each builder returns a :class:`~repro.scenarios.base.ScenarioCorpus` whose
redundancy is known by construction (``fresh`` bytes are tracked as they
are emitted), and whose expected dedup-ratio band is declared per budget
for the canonical bench configuration (:func:`bench_params`).  The bands
were measured on the seed corpora and widened for chunking slack; they
are a *contract*, not a measurement — see docs/SCENARIOS.md before
touching them.

Catalog (seeds are part of the corpus identity — changing one changes
every golden pin):

* ``dataset_revisions`` — edit-program revision history over structured
  row data (the HF parquet-dedupe-estimator workload shape).
* ``backup_snapshots``  — daily backups of a mixed-entropy "disk": small
  in-place mutations + log growth over a large unchanged base.
* ``lm_text``           — LM-training text shards with controlled exact
  and near duplication (the corpus side of examples/train_dedup_lm.py).
* ``container_images``  — tar-like concatenated-file images re-assembled
  per release with a few files changed (offset-shifting layer rebuilds).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from .base import ExpectedStructure, Scenario, ScenarioCorpus, scaled
from . import edits

MiB = 1 << 20
KiB = 1 << 10


def bench_params(scenario: str, budget: str = "small"):
    """The canonical chunking params the expected-ratio bands contract
    against — *per scenario*, because chunker quality is
    workload-dependent (the CDC survey's point, and this subsystem's):
    byte-shifted binary corpora use production 8 KiB average chunks, but
    LM text needs a finer grain — duplicate docs are only a few 8 KiB
    chunks long, and SeqCDC's boundary walk needs many chunks to
    resynchronize after entering a duplicate at a new phase, so coarse
    chunks dedup text to ~nothing.  The tiny (test-matrix) budget drops
    everything to 1 KiB so tens-of-KiB objects still have meaningful
    chunk counts."""
    from repro.core.params import derived_params

    if budget == "tiny":
        return derived_params(1024)
    return derived_params(SCENARIOS[scenario].avg_chunk)


# -- 1. dataset revisions (edit programs over structured rows) ---------------

#: budget -> (base_bytes, revisions, ops_per_rev, band)
_REVISIONS = {
    "tiny":  (24 * KiB, 3, 6),
    "quick": (640 * KiB, 4, 8),
    "small": (2 * MiB, 5, 12),
    "full":  (6 * MiB, 8, 20),
}
_REVISION_BANDS = {
    "tiny":  (1.35, 1.95),
    "quick": (2.2, 3.3),
    "small": (2.5, 3.7),
    "full":  (4.2, 6.3),
}


def _dataset_revisions(budget: str, seed: int) -> ScenarioCorpus:
    base_bytes, revisions, ops = scaled(_REVISIONS, budget)
    rng = np.random.default_rng(seed)
    base = edits.structured_rows(rng, base_bytes)
    objects: List[Tuple[str, np.ndarray]] = []
    fresh = 0
    for i, (rev, prog) in enumerate(edits.revision_history(
            base, revisions, ops, rng, payload=edits.row_payload)):
        objects.append((f"rev-{i:03d}", rev))
        fresh += int(base.size) if i == 0 else edits.fresh_bytes(prog)
    logical = sum(int(d.size) for _, d in objects)
    lo, hi = scaled(_REVISION_BANDS, budget)
    return ScenarioCorpus(
        scenario="dataset_revisions", budget=budget, seed=seed,
        objects=objects,
        expected=ExpectedStructure(1.0 - fresh / logical, lo, hi))


# -- 2. backup-style daily snapshots -----------------------------------------

#: budget -> (base_bytes, days, ops_per_day)
_BACKUP = {
    "tiny":  (32 * KiB, 3, 4),
    "quick": (1 * MiB, 4, 6),
    "small": (3 * MiB, 6, 10),
    "full":  (8 * MiB, 10, 16),
}
_BACKUP_BANDS = {
    "tiny":  (3.0, 4.5),
    "quick": (2.3, 3.5),
    "small": (3.2, 4.8),
    "full":  (5.4, 8.2),
}


def _disk_base(rng: np.random.Generator, nbytes: int) -> np.ndarray:
    """Mixed-entropy 'disk image': zero runs, text pages, binary blobs,
    and a repeated metadata page — the backup-source byte mix."""
    meta = rng.integers(0, 256, 512, dtype=np.uint8)
    parts: List[np.ndarray] = []
    total = 0
    while total < nbytes:
        kind = int(rng.integers(0, 10))
        if kind < 3:
            part = np.zeros(int(rng.integers(4 * KiB, 32 * KiB)),
                            dtype=np.uint8)
        elif kind < 6:
            part = edits.structured_rows(
                rng, int(rng.integers(4 * KiB, 24 * KiB)),
                start_id=int(rng.integers(10**6)))
        elif kind < 9:
            part = rng.integers(0, 256, int(rng.integers(8 * KiB, 48 * KiB)),
                                dtype=np.uint8)
        else:
            part = meta.copy()
        parts.append(part)
        total += int(part.size)
    return np.concatenate(parts)[:nbytes]


def _backup_snapshots(budget: str, seed: int) -> ScenarioCorpus:
    base_bytes, days, ops = scaled(_BACKUP, budget)
    rng = np.random.default_rng(seed)
    cur = _disk_base(rng, base_bytes)
    objects = [("day-000", cur.copy())]
    fresh = int(cur.size)
    # backups skew to in-place updates plus log-style appends; a rare
    # insert keeps the byte-shifting pressure CDC is supposed to absorb
    kinds = ("update", "update", "update", "append", "insert")
    for d in range(1, days):
        prog = edits.sample_program(rng, int(cur.size), ops, kinds=kinds,
                                    max_edit=2048)
        cur = edits.apply_program(cur, prog)
        objects.append((f"day-{d:03d}", cur.copy()))
        fresh += edits.fresh_bytes(prog)
    logical = sum(int(d.size) for _, d in objects)
    lo, hi = scaled(_BACKUP_BANDS, budget)
    return ScenarioCorpus(
        scenario="backup_snapshots", budget=budget, seed=seed,
        objects=objects,
        expected=ExpectedStructure(1.0 - fresh / logical, lo, hi))


# -- 3. LM-training text with controlled near-duplication --------------------

#: budget -> (shards, shard_bytes, doc_words_lo, doc_words_hi).  Docs must
#: span many average chunks (words*~7B >> avg_chunk) or CDC has no
#: interior chunks to resynchronize on and duplicate docs dedup to ~zero.
_LM = {
    "tiny":  (3, 64 * KiB, 2000, 4000),
    "quick": (4, 320 * KiB, 10000, 20000),
    "small": (4, 1 * MiB, 10000, 20000),
    "full":  (6, 2 * MiB, 10000, 20000),
}
_LM_BANDS = {
    "tiny":  (1.0, 1.25),
    "quick": (1.35, 1.95),
    "small": (1.5, 2.25),
    "full":  (1.4, 2.1),
}
#: doc-level duplication mix: fresh / exact-duplicate / near-duplicate
_LM_P_EXACT, _LM_P_NEAR = 0.25, 0.25
_LM_NEAR_EDITS = 8  # word substitutions per near-duplicate


def _vocab(rng: np.random.Generator, size: int = 2000) -> List[bytes]:
    letters = np.frombuffer(b"abcdefghijklmnopqrstuvwxyz", dtype=np.uint8)
    out = []
    for _ in range(size):
        n = int(rng.integers(3, 10))
        out.append(letters[rng.integers(0, 26, n)].tobytes())
    return out


def _fresh_doc(rng: np.random.Generator, vocab: List[bytes],
               lo: int, hi: int) -> bytes:
    n = int(rng.integers(lo, hi))
    # Zipf-ish draw: natural-text token frequencies, clipped to the vocab
    idx = np.minimum(rng.zipf(1.3, n), len(vocab)) - 1
    return b" ".join(vocab[int(i)] for i in idx) + b"\n\n"


def _lm_text(budget: str, seed: int) -> ScenarioCorpus:
    shards, shard_bytes, lo_w, hi_w = scaled(_LM, budget)
    rng = np.random.default_rng(seed)
    vocab = _vocab(rng)
    docs: List[bytes] = []
    fresh = 0
    objects: List[Tuple[str, np.ndarray]] = []
    for s in range(shards):
        parts: List[bytes] = []
        total = 0
        while total < shard_bytes:
            draw = rng.random()
            if docs and draw < _LM_P_EXACT:
                doc = docs[int(rng.integers(0, len(docs)))]
            elif docs and draw < _LM_P_EXACT + _LM_P_NEAR:
                words = docs[int(rng.integers(0, len(docs)))].split(b" ")
                for _ in range(_LM_NEAR_EDITS):
                    j = int(rng.integers(0, len(words)))
                    w = vocab[int(rng.integers(0, len(vocab)))]
                    fresh += len(w)
                    words[j] = w
                doc = b" ".join(words)
            else:
                doc = _fresh_doc(rng, vocab, lo_w, hi_w)
                fresh += len(doc)
                docs.append(doc)
            parts.append(doc)
            total += len(doc)
        objects.append((f"shard-{s:02d}", np.frombuffer(
            b"".join(parts), dtype=np.uint8)[:shard_bytes].copy()))
    logical = sum(int(d.size) for _, d in objects)
    lo, hi = scaled(_LM_BANDS, budget)
    return ScenarioCorpus(
        scenario="lm_text", budget=budget, seed=seed, objects=objects,
        expected=ExpectedStructure(
            max(0.0, 1.0 - fresh / logical), lo, hi))


def lm_training_corpus(mb: float, seed: int = 303) -> np.ndarray:
    """One flat LM-pretraining byte stream with the catalog's controlled
    duplication mix — the corpus side of ``examples/train_dedup_lm.py``
    (dedup-before-tokenization has real duplicates to remove)."""
    nbytes = int(mb * MiB)
    rng = np.random.default_rng(seed)
    vocab = _vocab(rng)
    docs: List[bytes] = []
    parts: List[bytes] = []
    total = 0
    while total < nbytes:
        draw = rng.random()
        if docs and draw < _LM_P_EXACT + _LM_P_NEAR:
            doc = docs[int(rng.integers(0, len(docs)))]
        else:
            doc = _fresh_doc(rng, vocab, 10000, 20000)
            docs.append(doc)
        parts.append(doc)
        total += len(doc)
    return np.frombuffer(b"".join(parts), dtype=np.uint8)[:nbytes].copy()


# -- 4. container/archive-style concatenated-file images ---------------------

#: budget -> (files, file_lo, file_hi, versions, updates, adds, deletes)
_CONTAINER = {
    "tiny":  (16, 512, 4 * KiB, 3, 2, 1, 1),
    "quick": (48, 2 * KiB, 40 * KiB, 4, 4, 2, 1),
    "small": (96, 2 * KiB, 64 * KiB, 5, 6, 3, 1),
    "full":  (128, 4 * KiB, 96 * KiB, 6, 8, 4, 2),
}
_CONTAINER_BANDS = {
    "tiny":  (1.55, 2.3),
    "quick": (1.8, 2.7),
    "small": (3.0, 4.6),
    "full":  (3.4, 5.1),
}
_BLOCK = 512  # tar-style header/content block granularity


def _file_content(rng: np.random.Generator, lo: int, hi: int) -> np.ndarray:
    n = int(rng.integers(lo, hi))
    kind = int(rng.integers(0, 3))
    if kind == 0:  # text-ish
        return edits.structured_rows(rng, n, start_id=int(rng.integers(10**6)))
    if kind == 1:  # binary
        return rng.integers(0, 256, n, dtype=np.uint8)
    return np.zeros(n, dtype=np.uint8)  # sparse


def _image(files: Dict[str, np.ndarray]) -> np.ndarray:
    """Serialize a file map as a tar-like stream: per file a 512-byte
    header (name + size, zero padded) then content padded to 512."""
    parts: List[np.ndarray] = []
    for name in sorted(files):
        data = files[name]
        hdr = np.zeros(_BLOCK, dtype=np.uint8)
        meta = f"{name}\x00{int(data.size):o}\x00ustar".encode()[:_BLOCK]
        hdr[: len(meta)] = np.frombuffer(meta, dtype=np.uint8)
        parts.append(hdr)
        pad = (-int(data.size)) % _BLOCK
        parts.append(data)
        if pad:
            parts.append(np.zeros(pad, dtype=np.uint8))
    return np.concatenate(parts) if parts else np.zeros(0, dtype=np.uint8)


def _container_images(budget: str, seed: int) -> ScenarioCorpus:
    n_files, lo, hi, versions, updates, adds, deletes = scaled(
        _CONTAINER, budget)
    rng = np.random.default_rng(seed)
    files: Dict[str, np.ndarray] = {}
    fresh = 0
    for i in range(n_files):
        files[f"usr/pkg-{i:04d}.bin"] = _file_content(rng, lo, hi)
    objects: List[Tuple[str, np.ndarray]] = []
    img = _image(files)
    objects.append(("image-v000", img))
    fresh += int(img.size)
    next_id = n_files
    for v in range(1, versions):
        names = sorted(files)
        for name in [names[int(i)] for i in
                     rng.choice(len(names), size=min(updates, len(names)),
                                replace=False)]:
            files[name] = _file_content(rng, lo, hi)
            fresh += int(files[name].size)
        for _ in range(adds):
            data = _file_content(rng, lo, hi)
            files[f"usr/pkg-{next_id:04d}.bin"] = data
            fresh += int(data.size) + _BLOCK  # new header is fresh too
            next_id += 1
        names = sorted(files)
        for name in [names[int(i)] for i in
                     rng.choice(len(names), size=min(deletes, len(names) - 1),
                                replace=False)]:
            del files[name]
        objects.append((f"image-v{v:03d}", _image(files)))
    logical = sum(int(d.size) for _, d in objects)
    blo, bhi = scaled(_CONTAINER_BANDS, budget)
    return ScenarioCorpus(
        scenario="container_images", budget=budget, seed=seed,
        objects=objects,
        expected=ExpectedStructure(
            max(0.0, 1.0 - fresh / logical), blo, bhi))


# -- registry ----------------------------------------------------------------

SCENARIOS: Dict[str, Scenario] = {
    s.name: s for s in (
        Scenario("dataset_revisions", 101,
                 "edit-program revision history over structured rows",
                 _dataset_revisions),
        Scenario("backup_snapshots", 202,
                 "daily snapshots: small mutations over a large base",
                 _backup_snapshots),
        Scenario("lm_text", 303,
                 "LM-training text shards with controlled near-duplication",
                 _lm_text, avg_chunk=1024),
        Scenario("container_images", 404,
                 "tar-like concatenated-file images, few files change per "
                 "release", _container_images),
    )
}


def generate(name: str, budget: str = "small",
             seed: int | None = None) -> ScenarioCorpus:
    """Build one scenario corpus; same (name, budget, seed) -> identical
    bytes in any process (the determinism contract)."""
    try:
        sc = SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; catalog: {sorted(SCENARIOS)}"
        ) from None
    return sc.generate(budget, seed)
