"""Scenario engine core types: corpora with a known duplicate structure.

A *scenario* is a seeded, deterministic generator of a versioned corpus —
a list of named objects (revisions, daily snapshots, corpus shards,
container images) whose redundancy is known *by construction* — plus an
:class:`ExpectedStructure` descriptor stating that construction-level
truth and the dedup-ratio band the service is contracted to deliver on
it.  Benchmarks (``benchmarks/bench_scenarios.py``) and tests
(``tests/test_scenarios.py``) consume the same objects, so a space-savings
regression on any workload shape fails CI exactly like a speed regression
(docs/SCENARIOS.md).

Determinism contract: ``generate(name, budget)`` is a pure function of
``(name, budget, seed)`` — same inputs produce byte-identical objects in
any process on any platform (numpy PCG64 streams only; no time, no
``hash()``, no filesystem reads).  :func:`corpus_digest` is the canonical
fingerprint of that contract.

This package is numpy + stdlib only — importable from shard servers,
tests, and examples without touching jax.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Tuple

import numpy as np

#: corpus-size tiers, smallest to largest; "tiny" exists for differential
#: tests (seconds-fast matrix cells), the rest mirror benchmarks/run.py
BUDGETS = ("tiny", "quick", "small", "full")


@dataclasses.dataclass(frozen=True)
class ExpectedStructure:
    """What the generator *built*, independent of any chunker.

    ``duplicate_fraction`` is the constructed redundancy: the fraction of
    logical bytes that are byte-identical to content emitted earlier in
    the corpus (payload accounting only — chunk-boundary spill means a
    real chunker recovers *at most* this much, so it upper-bounds
    achievable space savings).  ``min_dedup_ratio``/``max_dedup_ratio``
    is the contract band for the canonical service configuration
    (:func:`repro.scenarios.bench_params` at the corpus's budget): a
    measured ratio outside the band is a scenario regression.
    """

    duplicate_fraction: float
    min_dedup_ratio: float
    max_dedup_ratio: float

    def check_ratio(self, ratio: float) -> bool:
        return self.min_dedup_ratio <= ratio <= self.max_dedup_ratio


@dataclasses.dataclass
class ScenarioCorpus:
    """One generated workload: ordered named objects + expected structure."""

    scenario: str
    budget: str
    seed: int
    objects: List[Tuple[str, np.ndarray]]
    expected: ExpectedStructure

    @property
    def logical_bytes(self) -> int:
        return sum(int(d.size) for _, d in self.objects)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Registry entry: name, default seed, the budget-aware builder, and
    the scenario's canonical average chunk size (workloads dedup best at
    different grains — see :func:`repro.scenarios.bench_params`)."""

    name: str
    seed: int
    summary: str
    build: Callable[[str, int], ScenarioCorpus]
    avg_chunk: int = 8192

    def generate(self, budget: str = "small", seed: int | None = None
                 ) -> ScenarioCorpus:
        if budget not in BUDGETS:
            raise KeyError(
                f"unknown budget {budget!r}; expected one of {BUDGETS}")
        return self.build(budget, self.seed if seed is None else int(seed))


def corpus_digest(corpus: ScenarioCorpus) -> str:
    """SHA-256 over every object's name, length, and bytes, in order —
    the determinism contract's canonical fingerprint (same seed -> same
    digest, in any process)."""
    h = hashlib.sha256()
    for name, data in corpus.objects:
        h.update(name.encode())
        h.update(str(int(data.size)).encode())
        h.update(np.ascontiguousarray(data, dtype=np.uint8).tobytes())
    return h.hexdigest()


def scaled(table: Dict[str, tuple], budget: str) -> tuple:
    """Per-budget generator parameters with a loud failure for gaps."""
    try:
        return table[budget]
    except KeyError:
        raise KeyError(
            f"scenario has no parameters for budget {budget!r}; "
            f"declared: {sorted(table)}") from None
