"""Chunk fingerprinting in JAX (paper SSII "Chunk Hashing").

The deduplication pipeline needs a content-only fingerprint per chunk.  On
the accelerator we use a 62-bit fingerprint built from two independent
polynomial hashes mod p = 2^31 - 1:

    h_r(chunk) = sum_i  b_i * r^(len-1-i)   mod p

computed *fully in parallel* over all bytes of all chunks: each byte's
contribution is b * r^(offset-from-chunk-end), a per-byte table gather plus a
multiply realised as 8 conditional 31-bit rotations (x * 2^k mod 2^31-1 is a
k-rotation of the 31-bit word — no 64-bit arithmetic needed, DESIGN.md SS8),
followed by a segment sum in 16-bit limbs to avoid uint32 overflow.

Collision-resistant SHA-256 (host-side, hashlib) is used where the paper
requires it — the content-addressed block store — in dedup/store.py.

Constraint: chunk length < 65536 bytes (the power table and the limb-sum
overflow bound).  All chunking configs here have max_size <= 64 KiB.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

#: backend for :func:`chunk_fingerprints`: the jnp ``searchsorted``/gather/
#: ``segment_sum`` chain ("reference") or the fused Pallas kernel
#: (kernels/fingerprint.py) — bit-identical, guarded by the scheduler's
#: first-dispatch cross-check (docs/KERNELS.md)
FpImpl = Literal["reference", "pallas"]

P31 = np.uint32((1 << 31) - 1)
MAX_CHUNK = 1 << 16
#: two independent generators (fixed, arbitrary < p)
R1 = 1_103_515_245
R2 = 747_796_405


@functools.lru_cache(maxsize=None)
def _pow_table_np(r: int, size: int = MAX_CHUNK) -> np.ndarray:
    p = (1 << 31) - 1
    out = np.empty(size, dtype=np.uint32)
    acc = 1
    for e in range(size):
        out[e] = acc
        acc = (acc * r) % p
    return out


def _rot31(x, k: int):
    """x * 2^k mod (2^31 - 1) for x < p: a 31-bit rotation."""
    return ((x << k) | (x >> (31 - k))) & P31


def _mulmod(b, y, bits: int = 8):
    """b * y mod p for b < 2^bits, y < p — ``bits`` conditional rotations
    (x * 2^j mod p is a j-rotation of the 31-bit word).  bits=8 is the
    per-byte form; the Pallas kernel uses bits=31 for general factors."""
    acc = jnp.zeros_like(y)
    for j in range(bits):
        bit = (b >> j) & 1
        term = _rot31(y, j)
        acc = _addmod(acc, jnp.where(bit.astype(bool), term, 0))
    return acc


def _byte_mulmod(b, y):
    """b * y mod p for b in [0,256), y < p — 8 conditional rotations."""
    return _mulmod(b, y, 8)


def _addmod(a, b):
    s = a + b  # a,b < p  =>  s < 2p < 2^32: one conditional subtract
    return jnp.where(s >= P31, s - P31, s)


def _segment_fold(contrib, seg, num_segments: int):
    """Segment-sum of values < p with exact mod-p folding via 16-bit limbs."""
    lo = contrib & 0xFFFF
    hi = contrib >> 16
    lo_s = jax.ops.segment_sum(lo, seg, num_segments=num_segments)
    hi_s = jax.ops.segment_sum(hi, seg, num_segments=num_segments)
    # lo_s < 2^16 * 2^16 = 2^32 (max chunk 65536 bytes): fold mod p
    lo_m = _fold32(lo_s)
    hi_m = _fold32(hi_s)
    return _addmod(lo_m, _rotk(hi_m, 16))


def _fold32(x):
    """x (uint32) mod p via 2^31 === 1: x = (x & p) + (x >> 31), twice."""
    x = (x & P31) + (x >> 31)
    return jnp.where(x >= P31, x - P31, x)


def _rotk(x, k: int):
    return _rot31(x, k)


@functools.partial(jax.jit, static_argnames=("max_chunks", "fp_impl"))
def chunk_fingerprints(
    data: jax.Array,
    bounds: jax.Array,
    count: jax.Array,
    *,
    max_chunks: int,
    fp_impl: FpImpl = "reference",
) -> tuple[jax.Array, jax.Array]:
    """Per-chunk (fp (max_chunks, 2) uint32, lengths (max_chunks,) int32).

    ``bounds`` are exclusive chunk ends, sorted, sentinel-padded past
    ``count`` (the layout produced by core.seqcdc / core.chunker).
    Entries past ``count`` have fp = 0 and length = 0.

    ``fp_impl="pallas"`` dispatches to the fused kernel
    (kernels/fingerprint.py, interpret mode auto-selected on CPU) —
    bit-identical output, no per-byte gather/scatter.
    """
    if fp_impl == "pallas":
        from repro.kernels import ops  # lazy: no cycle (see ops docstring)

        return ops.chunk_fingerprints(data, bounds, count,
                                      max_chunks=max_chunks)
    if fp_impl != "reference":
        raise ValueError(f"unknown fp_impl {fp_impl!r}")
    n = data.shape[-1]
    d = data.astype(jnp.uint32)
    idx = jnp.arange(n, dtype=jnp.int32)
    # chunk id per byte: first j with bounds[j] > idx  (sentinel keeps it valid)
    seg = jnp.searchsorted(bounds, idx, side="right").astype(jnp.int32)
    seg = jnp.minimum(seg, max_chunks - 1)
    end = bounds[seg]
    e = jnp.clip(end - 1 - idx, 0, MAX_CHUNK - 1)  # offset from chunk end

    fps = []
    for r in (R1, R2):
        pow_r = jnp.asarray(_pow_table_np(r))
        contrib = _byte_mulmod(d, pow_r[e])
        fps.append(_segment_fold(contrib, seg, max_chunks))
    fp = jnp.stack(fps, axis=-1)

    starts = jnp.concatenate([jnp.zeros((1,), bounds.dtype), bounds[:-1]])
    lengths = (bounds - starts).astype(jnp.int32)
    valid = jnp.arange(max_chunks) < count
    fp = jnp.where(valid[:, None], fp, 0)
    lengths = jnp.where(valid, lengths, 0)
    return fp, lengths


def fingerprints_numpy(data: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """Host-side reference (tests): exact same 62-bit fingerprint."""
    p = (1 << 31) - 1
    out = np.zeros((len(bounds), 2), dtype=np.uint32)
    s = 0
    t1 = _pow_table_np(R1)
    t2 = _pow_table_np(R2)
    for j, e in enumerate(np.asarray(bounds, dtype=np.int64)):
        chunk = np.asarray(data[s:e], dtype=np.uint64)
        exp = np.arange(e - s - 1, -1, -1, dtype=np.int64)
        out[j, 0] = np.uint32((chunk * t1[exp].astype(np.uint64)).sum() % p)
        out[j, 1] = np.uint32((chunk * t2[exp].astype(np.uint64)).sum() % p)
        s = e
    return out
