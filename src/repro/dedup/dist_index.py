"""Distributed fingerprint index: partition-by-hash all_to_all (shard_map).

This maps the paper's *fingerprint comparison* stage (SSII) onto a TPU pod:
each data-parallel shard chunks its own slice of the corpus and produces a
local (fp, length) table; global dedup then requires comparing fingerprints
*across* shards.  Classic distributed-dedup systems (HYDRAstor, Extreme
Binning) partition the fingerprint space by hash; we express exactly that
with jax-native collectives:

  1. owner(fp) = fp.h1 mod num_shards     (consistent hash partitioning)
  2. route each entry to its owner with a capacity-padded ``all_to_all``
     (sort-by-owner + scatter into per-destination buckets)
  3. owners dedup locally (sort + first-occurrence mask) — correctness is
     local because equal fingerprints always land on the same owner
  4. ``psum`` the per-owner unique/dedup byte counts.

The routed tensor is (num_shards, capacity, 3): capacity-padding in place of
ragged all_to_all; overflow beyond capacity is *counted and reported*, never
silently dropped (overflow_total in the result).  Capacity/overflow semantics
and how a consumer (the sharded service) should react are documented in
docs/SHARDING.md.

:func:`owner_of` is the one normative partition rule; the host-side
:func:`route_host` and the in-JAX all_to_all path both derive from it, so a
chunk record always lands on the same owner whichever transport moved it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as PS


def owner_of(fp1, num_shards: int):
    """Shard owner of a fingerprint: ``fp.h1 mod num_shards``.

    The consistent-hash partition rule (HYDRAstor-style).  Works on python
    ints, numpy arrays, and jax arrays; every routing path in the repo —
    the shard_map ``all_to_all`` here and the sharded service's host/threaded
    fallback — must use this function so equal fingerprints always meet on
    the same owner (which is what makes owner-local dedup globally correct).
    """
    return fp1 % num_shards


def route_host(fps: np.ndarray, num_shards: int) -> np.ndarray:
    """Host fallback for the all_to_all path: per-record owner shard ids.

    ``fps``: (C, 2) uint32 fingerprint table (only ``h1`` routes).  Returns
    (C,) int32 owner ids in [0, num_shards).  No capacity limit — the host
    path is ragged-friendly, so it never overflows; it is the documented
    fallback when the mesh path reports ``overflow_total > 0``.
    """
    fps = np.asarray(fps)
    return owner_of(fps[:, 0].astype(np.int64), num_shards).astype(np.int32)


def suggested_capacity(rows_per_shard: int, num_shards: int,
                       capacity_factor: float = 1.5) -> int:
    """Per-destination bucket rows for the capacity-padded ``all_to_all``.

    Uniform routing sends ``rows_per_shard / num_shards`` rows to each owner;
    ``capacity_factor`` is the headroom multiplier over that expectation
    (+8 floor for tiny shards).  See docs/SHARDING.md for how to size it.
    """
    return int((rows_per_shard / num_shards) * capacity_factor) + 8


def _local_route(fp, lengths, num_shards: int, capacity: int):
    """Build the (num_shards, capacity, 3) routed buffer for one shard."""
    c = fp.shape[0]
    owner = owner_of(fp[:, 0], num_shards).astype(jnp.int32)
    valid = lengths > 0
    owner = jnp.where(valid, owner, num_shards)  # padding -> dropped
    # position within destination bucket: rank among same-owner entries
    order = jnp.argsort(owner, stable=True)
    owner_s = owner[order]
    ones = jnp.ones_like(owner_s)
    pos_in_owner = jnp.cumsum(ones) - 1
    # subtract start offset of each owner group
    starts = jnp.searchsorted(owner_s, jnp.arange(num_shards + 1))
    pos = pos_in_owner - starts[jnp.clip(owner_s, 0, num_shards)]
    buf = jnp.zeros((num_shards, capacity, 3), dtype=jnp.uint32)
    src = jnp.stack(
        [fp[order][:, 0], fp[order][:, 1], lengths[order].astype(jnp.uint32)],
        axis=-1,
    )
    ok = (owner_s < num_shards) & (pos < capacity)
    dst_o = jnp.where(ok, owner_s, num_shards)  # drop
    dst_p = jnp.where(ok, pos, 0)
    buf = buf.at[dst_o, dst_p].set(src, mode="drop")
    overflow = jnp.sum((owner_s < num_shards) & (pos >= capacity))
    return buf, overflow


def _owner_dedup(routed):
    """Dedup the entries this shard owns.  routed: (num_shards, capacity, 3)."""
    flat = routed.reshape(-1, 3)
    f1, f2, ln = flat[:, 0], flat[:, 1], flat[:, 2].astype(jnp.int32)
    valid = ln > 0
    pad = jnp.uint32(0xFFFFFFFF)
    f1 = jnp.where(valid, f1, pad)
    f2 = jnp.where(valid, f2, pad)
    k1, k2, ls, vs = jax.lax.sort((f1, f2, ln, valid.astype(jnp.int32)), num_keys=2)
    p1 = jnp.concatenate([jnp.full((1,), 0, k1.dtype), k1[:-1]])
    p2 = jnp.concatenate([jnp.full((1,), 0, k2.dtype), k2[:-1]])
    is_first = ((k1 != p1) | (k2 != p2)) & (vs > 0)
    # first element edge: valid and always first
    is_first = is_first.at[0].set(vs[0] > 0)
    return (
        jnp.sum(ls * vs),
        jnp.sum(jnp.where(is_first, ls, 0)),
        jnp.sum(is_first.astype(jnp.int32)),
        jnp.sum(vs),
    )


def routed_fp_tables(mesh: Mesh, axis: str = "data", *, capacity_factor=1.5):
    """The transport half of :func:`distributed_dedup`, exposed on its own.

    Returns a jitted fn: (fp (S*C, 2), lengths (S*C,)) sharded over ``axis``
    -> ``(tables, overflow_total)`` where ``tables`` is
    ``(S, S, capacity, 3)`` uint32: ``tables[owner, src]`` holds the records
    shard ``src`` routed to ``owner`` (``[:, :, :, 2] == 0`` marks padding).
    This is what an owner node consumes — the sharded service feeds each
    owner's slab to that shard's fingerprint index.

    ``overflow_total`` counts records dropped from the padded buckets; a
    consumer must treat any nonzero overflow as "this batch did not all
    arrive" and re-route via :func:`route_host` (see docs/SHARDING.md).
    """
    ns = mesh.shape[axis]

    def fn(fp, lengths):
        c = fp.shape[0]  # per-shard rows (shard_map body sees local shapes)
        capacity = suggested_capacity(c, ns, capacity_factor)
        buf, overflow = _local_route(fp, lengths, ns, capacity)
        routed = jax.lax.all_to_all(
            buf, axis, split_axis=0, concat_axis=0, tiled=True
        )
        return routed.reshape(ns, capacity, 3), jax.lax.psum(overflow, axis)

    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(PS(axis), PS(axis)),
        out_specs=(PS(axis), PS()),
        check_rep=False,
    )

    def call(fp, lengths):
        tables, overflow = mapped(fp, lengths)
        # stacked per-owner slabs: (S * S, capacity, 3) -> (S, S, capacity, 3)
        return tables.reshape(ns, ns, tables.shape[-2], 3), overflow

    return jax.jit(call)


def distributed_dedup(mesh: Mesh, axis: str = "data", *, capacity_factor=1.5):
    """Returns a jitted fn: (fp (S*C, 2), lengths (S*C,)) sharded over ``axis``
    -> replicated global stats dict.  S = mesh axis size."""
    ns = mesh.shape[axis]

    def fn(fp, lengths):
        c = fp.shape[0]  # per-shard rows (shard_map body sees local shapes)
        capacity = suggested_capacity(c, ns, capacity_factor)

        buf, overflow = _local_route(fp, lengths, ns, capacity)
        routed = jax.lax.all_to_all(
            buf, axis, split_axis=0, concat_axis=0, tiled=True
        )
        orig, dedup, uniq, total = _owner_dedup(routed.reshape(ns, capacity, 3))
        return {
            "original_bytes": jax.lax.psum(orig, axis),
            "dedup_bytes": jax.lax.psum(dedup, axis),
            "unique_chunks": jax.lax.psum(uniq, axis),
            "total_chunks": jax.lax.psum(total, axis),
            "overflow_total": jax.lax.psum(overflow, axis),
        }

    spec_in = PS(axis)
    spec_out = PS()
    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(spec_in, spec_in),
        out_specs={
            "original_bytes": spec_out,
            "dedup_bytes": spec_out,
            "unique_chunks": spec_out,
            "total_chunks": spec_out,
            "overflow_total": spec_out,
        },
        check_rep=False,
    )
    return jax.jit(mapped)
