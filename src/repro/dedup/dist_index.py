"""Distributed fingerprint index: partition-by-hash all_to_all (shard_map).

This maps the paper's *fingerprint comparison* stage (SSII) onto a TPU pod:
each data-parallel shard chunks its own slice of the corpus and produces a
local (fp, length) table; global dedup then requires comparing fingerprints
*across* shards.  Classic distributed-dedup systems (HYDRAstor, Extreme
Binning) partition the fingerprint space by hash; we express exactly that
with jax-native collectives:

  1. owner(fp) = fp.h1 mod num_shards     (consistent hash partitioning)
  2. route each entry to its owner with a capacity-padded ``all_to_all``
     (sort-by-owner + scatter into per-destination buckets)
  3. owners dedup locally (sort + first-occurrence mask) — correctness is
     local because equal fingerprints always land on the same owner
  4. ``psum`` the per-owner unique/dedup byte counts.

The routed tensor is (num_shards, capacity, 3): capacity-padding in place of
ragged all_to_all; overflow beyond capacity is *counted and reported*, never
silently dropped (overflow_total in the result).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as PS


def _local_route(fp, lengths, num_shards: int, capacity: int):
    """Build the (num_shards, capacity, 3) routed buffer for one shard."""
    c = fp.shape[0]
    owner = (fp[:, 0] % num_shards).astype(jnp.int32)
    valid = lengths > 0
    owner = jnp.where(valid, owner, num_shards)  # padding -> dropped
    # position within destination bucket: rank among same-owner entries
    order = jnp.argsort(owner, stable=True)
    owner_s = owner[order]
    ones = jnp.ones_like(owner_s)
    pos_in_owner = jnp.cumsum(ones) - 1
    # subtract start offset of each owner group
    starts = jnp.searchsorted(owner_s, jnp.arange(num_shards + 1))
    pos = pos_in_owner - starts[jnp.clip(owner_s, 0, num_shards)]
    buf = jnp.zeros((num_shards, capacity, 3), dtype=jnp.uint32)
    src = jnp.stack(
        [fp[order][:, 0], fp[order][:, 1], lengths[order].astype(jnp.uint32)],
        axis=-1,
    )
    ok = (owner_s < num_shards) & (pos < capacity)
    dst_o = jnp.where(ok, owner_s, num_shards)  # drop
    dst_p = jnp.where(ok, pos, 0)
    buf = buf.at[dst_o, dst_p].set(src, mode="drop")
    overflow = jnp.sum((owner_s < num_shards) & (pos >= capacity))
    return buf, overflow


def _owner_dedup(routed):
    """Dedup the entries this shard owns.  routed: (num_shards, capacity, 3)."""
    flat = routed.reshape(-1, 3)
    f1, f2, ln = flat[:, 0], flat[:, 1], flat[:, 2].astype(jnp.int32)
    valid = ln > 0
    pad = jnp.uint32(0xFFFFFFFF)
    f1 = jnp.where(valid, f1, pad)
    f2 = jnp.where(valid, f2, pad)
    k1, k2, ls, vs = jax.lax.sort((f1, f2, ln, valid.astype(jnp.int32)), num_keys=2)
    p1 = jnp.concatenate([jnp.full((1,), 0, k1.dtype), k1[:-1]])
    p2 = jnp.concatenate([jnp.full((1,), 0, k2.dtype), k2[:-1]])
    is_first = ((k1 != p1) | (k2 != p2)) & (vs > 0)
    # first element edge: valid and always first
    is_first = is_first.at[0].set(vs[0] > 0)
    return (
        jnp.sum(ls * vs),
        jnp.sum(jnp.where(is_first, ls, 0)),
        jnp.sum(is_first.astype(jnp.int32)),
        jnp.sum(vs),
    )


def distributed_dedup(mesh: Mesh, axis: str = "data", *, capacity_factor=1.5):
    """Returns a jitted fn: (fp (S*C, 2), lengths (S*C,)) sharded over ``axis``
    -> replicated global stats dict.  S = mesh axis size."""
    ns = mesh.shape[axis]

    def fn(fp, lengths):
        c = fp.shape[0]  # per-shard rows (shard_map body sees local shapes)
        capacity = int((c / ns) * capacity_factor) + 8

        buf, overflow = _local_route(fp, lengths, ns, capacity)
        routed = jax.lax.all_to_all(
            buf, axis, split_axis=0, concat_axis=0, tiled=True
        )
        orig, dedup, uniq, total = _owner_dedup(routed.reshape(ns, capacity, 3))
        return {
            "original_bytes": jax.lax.psum(orig, axis),
            "dedup_bytes": jax.lax.psum(dedup, axis),
            "unique_chunks": jax.lax.psum(uniq, axis),
            "total_chunks": jax.lax.psum(total, axis),
            "overflow_total": jax.lax.psum(overflow, axis),
        }

    spec_in = PS(axis)
    spec_out = PS()
    mapped = shard_map(
        fn,
        mesh=mesh,
        in_specs=(spec_in, spec_in),
        out_specs={
            "original_bytes": spec_out,
            "dedup_bytes": spec_out,
            "unique_chunks": spec_out,
            "total_chunks": spec_out,
            "overflow_total": spec_out,
        },
        check_rep=False,
    )
    return jax.jit(mapped)
