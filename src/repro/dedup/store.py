"""Content-addressed chunk store (paper SSII "Data Storage").

Chunks are keyed by SHA-256 (collision-resistant, as the paper prescribes for
the storage layer).  Backends: in-memory dict or a directory of block files
with a refcount manifest — enough to run the end-to-end dedup pipeline and
the CDC incremental checkpoint store on top of it.

Compression (the exemplar estimators' model: every chunk compressed, the
*compressed* dedup ratio reported):

* ``codec="none"|"zlib"|"lz4"`` selects the **write codec** — how new
  blocks are encoded.  zlib is stdlib and always available; lz4 is used
  when the optional ``lz4`` package is installed and refused loudly
  otherwise.  ``codec=None`` resolves the :data:`CODEC_ENV` environment
  default (which is how the ``codec-on`` CI job flips the whole suite).
* Storage is **per-key self-describing**: each block remembers the codec
  it was stored under, so a depot freely mixes raw and compressed blocks —
  reopening a compressed depot with ``codec="none"`` (or a codec-less v1
  depot with ``codec="zlib"``) reads every old block correctly and merely
  changes how *new* blocks are written.  A block that compression does not
  shrink is stored raw (``compressed_bytes <= stored_bytes`` always).
* Accounting is **raw-first**: ``stored_bytes`` stays the sum of unique
  *raw* bytes — the dedup ratio is unchanged by the codec — while the new
  live total ``compressed_bytes`` is the payload bytes actually held.
  ``stat()`` reports both plus ``compressed_ratio``
  (= stored/compressed, the store's compression factor).  GC byte
  accounting (``sweep``/``drop``/``repair_ref``) is in raw bytes.

Cold tiering (``DirBlockStore(hot_bytes=N)``): newly put blocks land *raw*
(hot — restores pay no decompress), and once the hot tier exceeds
``hot_bytes`` the least-recently-used blocks are demoted — recompressed in
place with the write codec, raw file removed after the compressed file is
atomically in place.  A crash anywhere in that window leaves both forms
(equal content; the raw file is authoritative and the compressed copy is
swept) or only the compressed form with a stale manifest (self-healed on
the next read); ``gc``/``sweep`` stay correct across tiers.

The wire path (``service/transport``): chunks can also arrive
*pre-compressed* via :meth:`put_compressed_blocks` — the shard writer
thread compressed them once, they travelled compressed over the RPC, and
the store files the payload as-is under the client-computed key.

Observability: :meth:`attach_obs` points the store at the owning service's
``MetricsRegistry``; encode time lands in ``store.compress_s`` and
compressed payload bytes in ``store.compressed_bytes{shard=}``
(docs/OBSERVABILITY.md).
"""
from __future__ import annotations

import hashlib
import json
import os
import time
import zlib
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

try:  # optional: the related estimators' per-chunk codec
    import lz4.frame as _lz4
except ImportError:  # pragma: no cover - depends on environment
    _lz4 = None

#: every codec name the store knows (availability of lz4 is environmental)
CODECS = ("none", "zlib", "lz4")

#: environment default for ``codec=None`` (the codec-on CI job sets it)
CODEC_ENV = "REPRO_STORE_CODEC"

#: zlib level 1: the writer hot path wants lz4-like speed; on the repo's
#: structured corpora level 1 already gets most of the ratio of level 6
ZLIB_LEVEL = 1


class BlockCorruptionError(RuntimeError):
    """A stored block's payload failed to decode to its recorded raw form.

    The store-layer analogue of the service's ``IntegrityError`` (which
    subsumes it at restore time): the bytes on disk are not the bytes the
    accounting says were stored.
    """


def available_codecs() -> Tuple[str, ...]:
    """Codecs usable in this process (lz4 only when the package exists)."""
    return tuple(c for c in CODECS if c != "lz4" or _lz4 is not None)


def resolve_codec(codec: Optional[str]) -> str:
    """Validate a codec name; ``None`` resolves the :data:`CODEC_ENV`
    default.  Unknown names and an unavailable lz4 raise ``ValueError``
    (loud, never a silent fallback — negotiation is the wire's job)."""
    if codec is None:
        codec = os.environ.get(CODEC_ENV) or "none"
    if codec not in CODECS:
        raise ValueError(f"unknown codec {codec!r} (one of {CODECS})")
    if codec == "lz4" and _lz4 is None:
        raise ValueError(
            "codec 'lz4' requested but the lz4 package is not installed "
            f"(available: {available_codecs()})"
        )
    return codec


def negotiate_codec(preferred: str, offered: Sequence[str]) -> str:
    """The one codec-negotiation rule (client preference vs peer support):
    the preference if the peer offers it, else the best mutually-available
    compressor (lz4 degrades to zlib, which is stdlib), else ``none``."""
    if preferred in offered:
        return preferred
    if preferred == "lz4" and "zlib" in offered:
        return "zlib"
    return "none"


def encode_block(codec: str, raw: bytes) -> Tuple[str, bytes]:
    """Compress one block -> ``(effective_codec, payload)``.

    Falls back to ``("none", raw)`` when compression does not shrink the
    block (already-compressed or high-entropy data), so stored payloads
    are never larger than the raw bytes.
    """
    if codec == "none":
        return "none", raw
    if codec == "zlib":
        payload = zlib.compress(raw, ZLIB_LEVEL)
    else:
        payload = _lz4.compress(raw)
    if len(payload) >= len(raw):
        return "none", raw
    return codec, payload


def decode_block(codec: str, payload: bytes,
                 raw_size: Optional[int] = None) -> bytes:
    """Decompress one block; :class:`BlockCorruptionError` on a payload
    that fails to decode or decodes to the wrong length."""
    if codec == "none":
        raw = payload
    else:
        try:
            if codec == "zlib":
                raw = zlib.decompress(payload)
            elif codec == "lz4" and _lz4 is not None:
                raw = _lz4.decompress(payload)
            else:
                raise ValueError(f"codec {codec!r} unavailable")
        except Exception as e:
            raise BlockCorruptionError(
                f"{codec} payload failed to decode: {e}"
            ) from e
    if raw_size is not None and len(raw) != raw_size:
        raise BlockCorruptionError(
            f"decoded {len(raw)}B, accounting says {raw_size}B raw"
        )
    return raw


def sha256_key(chunk: bytes) -> str:
    return hashlib.sha256(chunk).hexdigest()


class BlockStore:
    """In-memory content-addressed store with dedup + compression accounting."""

    def __init__(self, codec: Optional[str] = None):
        self.codec = resolve_codec(codec)
        self.blocks: dict[str, bytes] = {}  # key -> stored payload
        self.refs: dict[str, int] = {}
        self.sizes: dict[str, int] = {}  # key -> raw size
        self.csizes: dict[str, int] = {}  # key -> stored payload size
        #: per-key codec; keys stored raw are simply absent (the common
        #: case for codec="none" depots, keeping manifests compact)
        self.key_codec: dict[str, str] = {}
        # all are *live* totals: puts grow them, releases/drops shrink them
        # (freeing everything returns them to zero — see release())
        self.logical_bytes = 0  # live bytes referenced by clients
        self.stored_bytes = 0  # unique *raw* bytes currently stored
        self.compressed_bytes = 0  # unique *payload* bytes currently stored
        #: owning service's MetricsRegistry (attach_obs); None = uncounted
        self.obs = None
        self.obs_shard = 0

    def attach_obs(self, registry, shard: int = 0):
        """Report compression telemetry into ``registry`` (labeled by
        ``shard``): ``store.compress_s`` encode latency and
        ``store.compressed_bytes{shard=}`` payload bytes written."""
        self.obs = registry
        self.obs_shard = int(shard)

    # -- encode/decode (shared by both backends) --------------------------------
    def _encode(self, raw: bytes) -> Tuple[str, bytes]:
        if self.codec == "none":
            return "none", raw
        t0 = time.perf_counter()
        codec, payload = encode_block(self.codec, raw)
        if self.obs is not None:
            from repro.obs import labeled

            self.obs.observe("store.compress_s", time.perf_counter() - t0)
            if codec != "none":
                self.obs.inc(
                    labeled("store.compressed_bytes", shard=self.obs_shard),
                    len(payload),
                )
        return codec, payload

    def _decode(self, key: str, payload: bytes) -> bytes:
        codec = self.key_codec.get(key, "none")
        try:
            return decode_block(codec, payload, self.sizes.get(key))
        except BlockCorruptionError as e:
            raise BlockCorruptionError(f"block {key}: {e}") from None

    def _record_meta(self, key: str, raw_size: int, codec: str, csize: int):
        self.sizes[key] = raw_size
        self.csizes[key] = csize
        if codec != "none":
            self.key_codec[key] = codec
        else:
            self.key_codec.pop(key, None)

    def _forget_meta(self, key: str):
        self.sizes.pop(key, None)
        self.csizes.pop(key, None)
        self.key_codec.pop(key, None)

    def _stored_size(self, key: str) -> int:
        """Payload bytes held for ``key`` (raw size when stored raw)."""
        if key in self.csizes:
            return self.csizes[key]
        return self.chunk_size(key)

    # -- put --------------------------------------------------------------------
    def _write_block(self, key: str, raw: bytes) -> int:
        """Store ``raw`` under ``key`` -> payload bytes actually held."""
        codec, payload = self._encode(raw)
        self.blocks[key] = payload
        self._record_meta(key, len(raw), codec, len(payload))
        return len(payload)

    def _write_block_pre(self, key: str, raw_size: int, codec: str,
                         payload: bytes) -> int:
        """Store an already-compressed payload as-is -> payload bytes held."""
        self.blocks[key] = payload
        self._record_meta(key, raw_size, codec, len(payload))
        return len(payload)

    def put(self, chunk: bytes) -> str:
        chunk = bytes(chunk)
        key = sha256_key(chunk)
        self.logical_bytes += len(chunk)
        if key not in self.refs:
            csize = self._write_block(key, chunk)
            self.stored_bytes += len(chunk)
            self.compressed_bytes += csize
            self.refs[key] = 0
        self.refs[key] += 1
        return key

    def put_blocks(self, chunks: Iterable[bytes]) -> list[str]:
        """Batched put, the writer hot-path surface: in-process stores just
        loop, while a remote store (``service/transport/client.py``)
        overrides this into one RPC per batch — which is why the sharded
        flush coalesces each shard's chunks instead of calling ``put``
        per chunk."""
        return [self.put(c) for c in chunks]

    def put_compressed_blocks(self, keys: Sequence[str],
                              raw_sizes: Sequence[int], codec,
                              payloads: Sequence[bytes]) -> list[str]:
        """Batched put of pre-compressed payloads (the protocol v4 wire
        form): ``keys`` are SHA-256 of the *raw* bytes, computed by the
        writer that also compressed them, so the bytes compress once (off
        the ingest thread) and travel compressed.  Payloads are filed
        as-is — a duplicate key costs a refcount bump, no decompress.
        Whole-object restore verification still catches any corruption
        end to end.

        ``codec`` is one name for the whole batch or a per-key sequence
        (the writer's encode falls back to raw on incompressible chunks,
        so mixed batches are the norm under a compressing codec).
        """
        codecs = ([codec] * len(keys) if isinstance(codec, str)
                  else [str(c) for c in codec])
        for c in set(codecs):
            if c != "none":
                resolve_codec(c)  # loud on a codec this process can't read
        out = []
        for key, raw_size, c, payload in zip(keys, raw_sizes, codecs,
                                             payloads):
            raw_size = int(raw_size)
            self.logical_bytes += raw_size
            if key not in self.refs:
                csize = self._write_block_pre(key, raw_size, c, payload)
                self.stored_bytes += raw_size
                self.compressed_bytes += csize
                self.refs[key] = 0
            self.refs[key] += 1
            out.append(key)
        return out

    def put_stream(self, data, bounds: Iterable[int]) -> list[str]:
        """Chunk-and-store a byte stream given exclusive boundary offsets.

        ``bounds`` must be strictly increasing and cover the whole stream
        (last bound == ``len(data)``); anything else raises ``ValueError``
        — a short or non-monotonic bounds list used to silently drop the
        trailing bytes, which a later restore could not detect.  The whole
        list is validated *before* any chunk is stored, so a rejected call
        never leaves a partial ingest behind.
        """
        data = np.asarray(data, dtype=np.uint8)
        ends = [int(e) for e in bounds]
        s = 0
        for e in ends:
            if e <= s:
                raise ValueError(
                    f"bounds must be strictly increasing: {e} after {s}"
                )
            if e > data.size:
                raise ValueError(
                    f"bound {e} beyond stream end {data.size}"
                )
            s = e
        if s != data.size:
            raise ValueError(
                f"bounds cover {s} of {data.size} bytes "
                "(last bound must equal len(data))"
            )
        keys = []
        s = 0
        for e in ends:
            keys.append(self.put(data[s:e].tobytes()))
            s = e
        return keys

    # -- get --------------------------------------------------------------------
    def get(self, key: str) -> bytes:
        return self._decode(key, self.blocks[key])

    def get_blocks(self, keys: Iterable[str]) -> list[bytes]:
        """Batched get, one block per key.  The base form is a loop; the
        remote store proxy overrides it with a single RPC, which is what
        the sharded restore path batches per shard."""
        return [self.get(k) for k in keys]

    def get_stream(self, keys: Iterable[str]) -> bytes:
        return b"".join(self.get(k) for k in keys)

    def __contains__(self, key: str) -> bool:
        return key in self.refs

    def chunk_size(self, key: str) -> int:
        """Raw (uncompressed) size of a block — the unit every byte
        accounting uses, whatever codec the payload sits under."""
        if key in self.sizes:
            return self.sizes[key]
        return len(self.get(key))

    def _remove_block(self, key: str):
        del self.blocks[key]

    def scan_keys(self) -> list[str]:
        """Every key the store physically holds (GC sweep domain).

        For file-backed stores this includes blocks present on disk but
        missing from the refcount manifest (a crash between block write and
        manifest sync), which refcount iteration alone would never see.
        """
        return list(self.refs)

    def repair_ref(self, key: str, refs: int):
        """Set a key's refcount to the recomputed truth, fixing accounting.

        Re-adopts blocks that exist but fell out of the manifest (crash
        between block write and manifest sync): their bytes re-enter
        ``stored_bytes``/``logical_bytes``/``compressed_bytes`` so the live
        totals match refs.  All byte math is in *raw* sizes except the
        payload-sized ``compressed_bytes`` — consistent with ``put``.
        """
        size = self.chunk_size(key)
        have = self.refs.get(key)
        if have is None:
            self.stored_bytes += size
            self.compressed_bytes += self._stored_size(key)
            self.logical_bytes += refs * size
        else:
            self.logical_bytes += (refs - have) * size
        self.refs[key] = refs

    def release(self, key: str) -> bool:
        """Drop one reference; free the block on the last one.

        Safe on unknown keys (returns False, no accounting change) so callers
        replaying a partially-applied delete never crash.  ``logical_bytes``
        shrinks by one reference's worth per release and
        ``stored_bytes``/``compressed_bytes`` by the block's raw/payload
        size when it is freed, so all remain *live* totals after deletes
        (freeing everything returns them to zero).
        """
        if key not in self.refs:
            return False
        size = self.chunk_size(key)
        csize = self._stored_size(key)
        self.logical_bytes -= size
        self.refs[key] -= 1
        if self.refs[key] > 0:
            return False
        del self.refs[key]
        self._remove_block(key)
        self._forget_meta(key)
        self.stored_bytes -= size
        self.compressed_bytes -= csize
        return True

    def delete(self, key: str) -> bool:
        """Alias for :meth:`release` (service-facing name)."""
        return self.release(key)

    def release_many(self, keys: Iterable[str]) -> list[bool]:
        """Batched :meth:`release`, one freed-flag per key.  The base form
        is a loop; the remote store proxy overrides it with a single RPC,
        which is what the sharded delete path batches per shard."""
        return [self.release(k) for k in keys]

    def drop(self, key: str) -> int:
        """GC sweep: remove a block unconditionally, whatever its refcount.

        Used by mark-and-sweep when recomputed liveness says the block has no
        referents (e.g. refcount drift after a crash).  Returns the *raw*
        stored bytes reclaimed (0 for unknown keys) — GC accounting is in
        raw sizes on every tier.
        """
        if key not in self.refs:
            return 0
        size = self.chunk_size(key)
        csize = self._stored_size(key)
        refs = self.refs.pop(key)
        self._remove_block(key)
        self._forget_meta(key)
        self.stored_bytes -= size
        self.compressed_bytes -= csize
        self.logical_bytes -= refs * size
        return size

    def sweep(self, live: Dict[str, int]) -> Tuple[int, int, int]:
        """One mark-and-sweep pass against recomputed liveness.

        ``live`` is the truth (key -> reference count from the recipe
        roots).  Sweeps :meth:`scan_keys` — which for file-backed stores
        includes block files the refcount manifest never recorded —
        dropping unreferenced blocks and repairing refcount drift.  Returns
        ``(freed_blocks, freed_bytes, repaired_refs)``.

        Lives on the store (not the service) because it only touches store
        state — which is what lets a remote store run the whole pass next
        to its data in one RPC (``transport/client.py`` overrides this).
        """
        freed_blocks = freed_bytes = repaired = 0
        for key in self.scan_keys():
            want = live.get(key, 0)
            if want == 0:
                freed_bytes += self.drop(key)
                freed_blocks += 1
            elif self.refs.get(key) != want:
                self.repair_ref(key, want)
                repaired += 1
        return freed_blocks, freed_bytes, repaired

    def sync(self):
        """Make accounting durable (no-op for the in-memory backend).

        Uniform entry point so multi-store owners (the sharded service's
        per-shard flush) need not type-switch on the backend.
        """

    @property
    def unique_chunks(self) -> int:
        """Number of unique blocks held (part of the stats surface shared
        with the remote store proxy, which cannot expose a refs dict)."""
        return len(self.refs)

    def stat(self) -> Dict[str, float]:
        """The accounting quad in one call — the shape consumers should
        prefer over reading the properties separately, because on the
        remote store proxy each property is a full RPC and ``stat()`` is
        exactly one.  ``compressed_ratio`` is stored/compressed — the
        store's compression factor on its unique bytes (1.0 for codec-less
        depots); the *end-to-end* ratio (dedup x compression) is the
        service's ``ServiceStats.compressed_ratio``.
        """
        return {
            "stored_bytes": self.stored_bytes,
            "logical_bytes": self.logical_bytes,
            "compressed_bytes": self.compressed_bytes,
            "compressed_ratio": (
                self.stored_bytes / self.compressed_bytes
                if self.compressed_bytes else 1.0
            ),
            "unique_chunks": self.unique_chunks,
        }

    @property
    def savings(self) -> float:
        if not self.logical_bytes:
            return 0.0
        return (self.logical_bytes - self.stored_bytes) / self.logical_bytes


#: block-file suffix per codec: compressed forms are self-describing on
#: disk, so crash recovery can identify a block's codec with no manifest
_CODEC_SUFFIX = {"none": "", "zlib": ".z", "lz4": ".lz4"}
_SUFFIX_CODEC = {".z": "zlib", ".lz4": "lz4"}

#: manifest schema version: 2 adds codec/csizes/key_codecs/compressed_bytes;
#: a version-less manifest is v1 (codec-less depot, every block raw)
MANIFEST_VERSION = 2


class DirBlockStore(BlockStore):
    """File-backed store: one file per unique block + a json manifest.

    Writes are atomic (tmp + rename) so a crashed writer never corrupts the
    store — required by the fault-tolerant checkpoint manager built on top.

    The manifest also records block *sizes*: a crash between a block unlink
    and the manifest sync leaves manifest entries whose files are gone, and
    recovery (``release`` replay, ``gc``) must be able to correct the byte
    accounting for a block it can no longer stat.  v2 manifests add the
    per-key codec and payload-size maps; a v1 manifest loads as an all-raw
    depot (back-compat both ways — see the module docstring).

    ``codec=None`` resolves, in order: the manifest's recorded write codec
    (a compressed depot keeps compressing when reopened by codec-unaware
    tooling), the :data:`CODEC_ENV` environment default, then ``"none"``.
    An explicit ``codec=`` always wins — that is how a depot is reopened
    with a *different* codec preference (old blocks keep their recorded
    codec; only new writes change).

    ``hot_bytes > 0`` enables cold tiering (requires a compressing codec):
    puts land raw (hot), and LRU blocks beyond the budget are demoted —
    recompressed in place on the putting thread.  Reads of hot blocks
    refresh recency; cold reads decompress without promoting.
    """

    def __init__(self, root: str, codec: Optional[str] = None,
                 hot_bytes: int = 0):
        manifest_codec = None
        self.root = root
        os.makedirs(os.path.join(root, "blocks"), exist_ok=True)
        self._manifest_path = os.path.join(root, "manifest.json")
        m = None
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                m = json.load(f)
            manifest_codec = m.get("codec")
        if codec is None and manifest_codec is not None:
            codec = manifest_codec
        super().__init__(codec)
        self.hot_bytes = int(hot_bytes)
        if self.hot_bytes > 0 and self.codec == "none":
            raise ValueError(
                "hot_bytes tiering needs a compressing codec "
                "(demotion recompresses in place); got codec='none'"
            )
        #: LRU of hot (raw-on-disk) keys -> raw size; tiering only
        self._hot: "OrderedDict[str, int]" = OrderedDict()
        self._hot_total = 0
        if m is not None:
            self.refs = {k: int(v) for k, v in m["refs"].items()}
            self.sizes = {k: int(v) for k, v in m.get("sizes", {}).items()}
            if int(m.get("version", 1)) >= 2:
                self.csizes = {k: int(v)
                               for k, v in m.get("csizes", {}).items()}
                self.key_codec = {k: str(v)
                                  for k, v in m.get("key_codecs", {}).items()}
                self.compressed_bytes = int(
                    m.get("compressed_bytes", m["stored_bytes"])
                )
            else:
                # v1 (codec-less) manifest: every block is raw, payload
                # bytes == raw bytes
                self.csizes = dict(self.sizes)
                self.compressed_bytes = int(m["stored_bytes"])
            self.logical_bytes = int(m["logical_bytes"])
            self.stored_bytes = int(m["stored_bytes"])
            if self.hot_bytes > 0:
                # raw blocks are the hot set; manifest order is the best
                # recency estimate a restart has (true LRU resumes as reads
                # and puts refresh it)
                for k in self.refs:
                    if self.key_codec.get(k, "none") == "none":
                        self._hot[k] = self.sizes.get(k, 0)
                        self._hot_total += self._hot[k]

    def _path(self, key: str, codec: str = "none") -> str:
        return os.path.join(self.root, "blocks", key + _CODEC_SUFFIX[codec])

    def _find_block(self, key: str) -> Tuple[Optional[str], Optional[str]]:
        """Locate ``key`` on disk -> ``(path, codec)`` or ``(None, None)``.

        Probes the recorded codec's path first, then every other form —
        a crash between a demotion's rename and the manifest sync leaves
        the disk ahead of the manifest, and reads must self-heal.
        """
        recorded = self.key_codec.get(key, "none")
        for codec in (recorded, *(c for c in CODECS if c != recorded)):
            p = self._path(key, codec)
            if os.path.exists(p):
                return p, codec
        return None, None

    def _load_block(self, key: str) -> Tuple[bytes, str, int]:
        """Read + decode ``key`` from disk -> ``(raw, codec, payload_size)``;
        ``KeyError`` when no form of the block exists (every backend's
        missing-block contract).  Heals stale per-key codec records: a
        demotion that crashed after its rename is adopted into the
        accounting here."""
        path, codec = self._find_block(key)
        if path is None:
            raise KeyError(key)
        try:
            with open(path, "rb") as f:
                payload = f.read()
        except FileNotFoundError:
            raise KeyError(key) from None  # raced a concurrent sweep
        try:
            raw = decode_block(codec, payload, self.sizes.get(key))
        except BlockCorruptionError as e:
            raise BlockCorruptionError(f"block {key}: {e}") from None
        if key in self.refs and codec != self.key_codec.get(key, "none"):
            # disk moved ahead of the manifest (crashed demotion): adopt
            # the on-disk form so payload accounting matches reality
            self.compressed_bytes += len(payload) - self._stored_size(key)
            self._record_meta(key, len(raw), codec, len(payload))
        return raw, codec, len(payload)

    def _atomic_write(self, path: str, payload: bytes):
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)

    # -- put / tiering -----------------------------------------------------------
    def _write_block(self, key: str, raw: bytes) -> int:
        # write keyed on *file presence*, not on the refcount: a stale
        # manifest (crash between unlink and manifest sync) may list a key
        # whose file is gone, and a committed recipe must never name bytes
        # that are not on disk
        path, codec = self._find_block(key)
        if path is not None:
            csize = os.path.getsize(path)
            self._record_meta(key, len(raw), codec, csize)
            return csize
        if self.hot_bytes > 0:
            # tiered put: land raw (hot), demote LRU cold blocks after
            self._atomic_write(self._path(key), raw)
            self._record_meta(key, len(raw), "none", len(raw))
            self._hot[key] = len(raw)
            self._hot_total += len(raw)
            self._evict_cold()
            return len(raw)
        codec, payload = self._encode(raw)
        self._atomic_write(self._path(key, codec), payload)
        self._record_meta(key, len(raw), codec, len(payload))
        return len(payload)

    def _write_block_pre(self, key: str, raw_size: int, codec: str,
                         payload: bytes) -> int:
        path, found = self._find_block(key)
        if path is not None:
            csize = os.path.getsize(path)
            self._record_meta(key, raw_size, found, csize)
            return csize
        # pre-compressed arrivals are cold by definition (the writer
        # already paid the encode); they bypass the hot tier
        self._atomic_write(self._path(key, codec), payload)
        self._record_meta(key, raw_size, codec, len(payload))
        return len(payload)

    def _evict_cold(self):
        """Demote LRU hot blocks until the hot tier fits ``hot_bytes``."""
        while self._hot_total > self.hot_bytes and self._hot:
            key, size = self._hot.popitem(last=False)
            self._hot_total -= size
            self._demote(key)

    def _demote(self, key: str):
        """Recompress one hot block in place: compressed file atomically
        renamed first, raw file removed after — a crash in between leaves
        both (equal content; scan sweeps the derived copy)."""
        raw_path = self._path(key)
        try:
            with open(raw_path, "rb") as f:
                raw = f.read()
        except FileNotFoundError:
            return  # raced a drop/sweep: nothing to demote
        codec, payload = self._encode(raw)
        if codec == "none":
            return  # incompressible: stays raw, just no longer LRU-tracked
        self._atomic_write(self._path(key, codec), payload)
        if key in self.refs:
            self.compressed_bytes += len(payload) - self._stored_size(key)
        self._record_meta(key, len(raw), codec, len(payload))
        try:
            os.remove(raw_path)
        except FileNotFoundError:
            pass
        if self.obs is not None:
            from repro.obs import labeled

            self.obs.inc(labeled("store.tier_demotions",
                                 shard=self.obs_shard))

    def put(self, chunk: bytes) -> str:
        # the refcount fast path must still consult *file presence*: a
        # stale manifest (crash between a delete's unlink and its manifest
        # sync) may list a key whose file is gone, and a committed recipe
        # must never name bytes that are not on disk — re-puts of such a
        # key rewrite the file
        chunk = bytes(chunk)
        key = sha256_key(chunk)
        if key in self.refs and self._find_block(key)[0] is None:
            old = self.csizes.get(key, self.sizes.get(key, 0))
            csize = self._write_block(key, chunk)
            self.compressed_bytes += csize - old
        return super().put(chunk)

    def put_compressed_blocks(self, keys: Sequence[str],
                              raw_sizes: Sequence[int], codec,
                              payloads: Sequence[bytes]) -> list[str]:
        # same stale-manifest rewrite window as put(), pre-compressed form
        codecs = ([codec] * len(keys) if isinstance(codec, str)
                  else [str(c) for c in codec])
        for key, raw_size, c, payload in zip(keys, raw_sizes, codecs,
                                             payloads):
            if key in self.refs and self._find_block(key)[0] is None:
                old = self.csizes.get(key, self.sizes.get(key, 0))
                csize = self._write_block_pre(key, int(raw_size), c, payload)
                self.compressed_bytes += csize - old
        return super().put_compressed_blocks(keys, raw_sizes, codecs,
                                             payloads)

    def _touch_hot(self, key: str):
        if self._hot and key in self._hot:
            self._hot.move_to_end(key)

    def _untrack_hot(self, key: str):
        if self._hot and key in self._hot:
            self._hot_total -= self._hot.pop(key)

    # -- get / meta --------------------------------------------------------------
    def get(self, key: str) -> bytes:
        raw, _, _ = self._load_block(key)
        self._touch_hot(key)
        return raw

    def get_stream(self, keys: Iterable[str]) -> bytes:
        return b"".join(self.get(k) for k in keys)

    def chunk_size(self, key: str) -> int:
        # manifest size first: must work for manifest-listed keys whose
        # block file a crashed delete already unlinked
        if key in self.sizes:
            return self.sizes[key]
        raw, codec, csize = self._load_block(key)  # orphan: learn + cache
        self._record_meta(key, len(raw), codec, csize)
        return len(raw)

    def _stored_size(self, key: str) -> int:
        if key in self.csizes:
            return self.csizes[key]
        self.chunk_size(key)  # loads + caches csizes too
        return self.csizes.get(key, self.sizes.get(key, 0))

    def _remove_block(self, key: str):
        self._untrack_hot(key)
        self._forget_meta(key)
        for codec in CODECS:  # every on-disk form, whichever tier it was in
            try:
                os.remove(self._path(key, codec))
            except FileNotFoundError:
                pass  # replay of a partially-applied delete: already gone

    def scan_keys(self) -> list[str]:
        """Manifest keys plus any block files on disk the manifest missed.

        Stale ``.tmp`` files are torn writes by construction (commits go
        through atomic rename) and are unlinked during the scan, as is the
        compressed copy of a block whose raw form still exists (a demotion
        that crashed between its rename and the raw unlink — the raw file
        is authoritative, the compressed one is derived).
        """
        keys = set(self.refs)
        blocks_dir = os.path.join(self.root, "blocks")
        on_disk: dict[str, set] = {}
        for fn in os.listdir(blocks_dir):
            if fn.endswith(".tmp"):
                try:
                    os.remove(os.path.join(blocks_dir, fn))
                except FileNotFoundError:
                    pass
                continue
            base, ext = os.path.splitext(fn)
            if ext in _SUFFIX_CODEC:
                on_disk.setdefault(base, set()).add(_SUFFIX_CODEC[ext])
            else:
                on_disk.setdefault(fn, set()).add("none")
        for key, forms in on_disk.items():
            if "none" in forms:
                for codec in forms - {"none"}:  # crashed demotion leftover
                    try:
                        os.remove(self._path(key, codec))
                    except FileNotFoundError:
                        pass
            keys.add(key)
        return sorted(keys)

    def repair_ref(self, key: str, refs: int):
        self.chunk_size(key)  # ensure sizes/csizes known (loads orphans)
        super().repair_ref(key, refs)

    def drop(self, key: str) -> int:
        if key in self.refs:
            return super().drop(key)
        # on-disk orphan: never entered the accounting.  One try/except
        # path per form — an exists/getsize/remove sequence would race a
        # concurrent sweep unlinking the same file (TOCTOU) and crash on
        # a block that is simply already gone.
        self._forget_meta(key)
        for codec in CODECS:
            path = self._path(key, codec)
            try:
                with open(path, "rb") as f:
                    payload = f.read()
                os.remove(path)
            except FileNotFoundError:
                continue
            try:  # report *raw* bytes reclaimed, consistent across tiers
                return len(decode_block(codec, payload))
            except BlockCorruptionError:
                return len(payload)  # torn orphan: disk bytes are all we know
        return 0

    def sync(self):
        self.sync_manifest()

    def sync_manifest(self):
        tmp = self._manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {
                    "version": MANIFEST_VERSION,
                    "codec": self.codec,
                    "refs": self.refs,
                    "sizes": {k: self.sizes[k] for k in self.refs
                              if k in self.sizes},
                    "csizes": {k: self.csizes[k] for k in self.refs
                               if k in self.csizes},
                    "key_codecs": {k: c for k, c in self.key_codec.items()
                                   if k in self.refs},
                    "logical_bytes": self.logical_bytes,
                    "stored_bytes": self.stored_bytes,
                    "compressed_bytes": self.compressed_bytes,
                },
                f,
            )
        os.replace(tmp, self._manifest_path)
