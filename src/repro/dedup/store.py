"""Content-addressed chunk store (paper SSII "Data Storage").

Chunks are keyed by SHA-256 (collision-resistant, as the paper prescribes for
the storage layer).  Backends: in-memory dict or a directory of block files
with a refcount manifest — enough to run the end-to-end dedup pipeline and
the CDC incremental checkpoint store on top of it.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Dict, Iterable, Tuple

import numpy as np


def sha256_key(chunk: bytes) -> str:
    return hashlib.sha256(chunk).hexdigest()


class BlockStore:
    """In-memory content-addressed store with dedup accounting."""

    def __init__(self):
        self.blocks: dict[str, bytes] = {}
        self.refs: dict[str, int] = {}
        # both are *live* totals: puts grow them, releases/drops shrink them
        # (freeing everything returns both to zero — see release())
        self.logical_bytes = 0  # live bytes referenced by clients
        self.stored_bytes = 0  # unique bytes currently stored

    def put(self, chunk: bytes) -> str:
        key = sha256_key(chunk)
        self.logical_bytes += len(chunk)
        if key not in self.refs:
            self.blocks[key] = bytes(chunk)
            self.stored_bytes += len(chunk)
            self.refs[key] = 0
        self.refs[key] += 1
        return key

    def get(self, key: str) -> bytes:
        return self.blocks[key]

    def get_blocks(self, keys: Iterable[str]) -> list[bytes]:
        """Batched get, one block per key.  The base form is a loop; the
        remote store proxy overrides it with a single RPC, which is what
        the sharded restore path batches per shard."""
        return [self.get(k) for k in keys]

    def __contains__(self, key: str) -> bool:
        return key in self.refs

    def chunk_size(self, key: str) -> int:
        return len(self.blocks[key])

    def _remove_block(self, key: str):
        del self.blocks[key]

    def scan_keys(self) -> list[str]:
        """Every key the store physically holds (GC sweep domain).

        For file-backed stores this includes blocks present on disk but
        missing from the refcount manifest (a crash between block write and
        manifest sync), which refcount iteration alone would never see.
        """
        return list(self.refs)

    def repair_ref(self, key: str, refs: int):
        """Set a key's refcount to the recomputed truth, fixing accounting.

        Re-adopts blocks that exist but fell out of the manifest (crash
        between block write and manifest sync): their bytes re-enter
        ``stored_bytes``/``logical_bytes`` so the live totals match refs.
        """
        size = self.chunk_size(key)
        have = self.refs.get(key)
        if have is None:
            self.stored_bytes += size
            self.logical_bytes += refs * size
        else:
            self.logical_bytes += (refs - have) * size
        self.refs[key] = refs

    def put_blocks(self, chunks: Iterable[bytes]) -> list[str]:
        """Batched put, the writer hot-path surface: in-process stores just
        loop, while a remote store (``service/transport/client.py``)
        overrides this into one RPC per batch — which is why the sharded
        flush coalesces each shard's chunks instead of calling ``put``
        per chunk."""
        return [self.put(c) for c in chunks]

    def put_stream(self, data, bounds: Iterable[int]) -> list[str]:
        """Chunk-and-store a byte stream given exclusive boundary offsets."""
        data = np.asarray(data, dtype=np.uint8)
        keys = []
        s = 0
        for e in bounds:
            keys.append(self.put(data[s:e].tobytes()))
            s = int(e)
        return keys

    def get_stream(self, keys: Iterable[str]) -> bytes:
        return b"".join(self.blocks[k] for k in keys)

    def release(self, key: str) -> bool:
        """Drop one reference; free the block on the last one.

        Safe on unknown keys (returns False, no accounting change) so callers
        replaying a partially-applied delete never crash.  ``logical_bytes``
        shrinks by one reference's worth per release and ``stored_bytes`` by
        the block size when it is freed, so both remain *live* totals after
        deletes (freeing everything returns both to zero).
        """
        if key not in self.refs:
            return False
        size = self.chunk_size(key)
        self.logical_bytes -= size
        self.refs[key] -= 1
        if self.refs[key] > 0:
            return False
        del self.refs[key]
        self._remove_block(key)
        self.stored_bytes -= size
        return True

    def delete(self, key: str) -> bool:
        """Alias for :meth:`release` (service-facing name)."""
        return self.release(key)

    def release_many(self, keys: Iterable[str]) -> list[bool]:
        """Batched :meth:`release`, one freed-flag per key.  The base form
        is a loop; the remote store proxy overrides it with a single RPC,
        which is what the sharded delete path batches per shard."""
        return [self.release(k) for k in keys]

    def drop(self, key: str) -> int:
        """GC sweep: remove a block unconditionally, whatever its refcount.

        Used by mark-and-sweep when recomputed liveness says the block has no
        referents (e.g. refcount drift after a crash).  Returns the stored
        bytes reclaimed (0 for unknown keys).
        """
        if key not in self.refs:
            return 0
        size = self.chunk_size(key)
        refs = self.refs.pop(key)
        self._remove_block(key)
        self.stored_bytes -= size
        self.logical_bytes -= refs * size
        return size

    def sweep(self, live: Dict[str, int]) -> Tuple[int, int, int]:
        """One mark-and-sweep pass against recomputed liveness.

        ``live`` is the truth (key -> reference count from the recipe
        roots).  Sweeps :meth:`scan_keys` — which for file-backed stores
        includes block files the refcount manifest never recorded —
        dropping unreferenced blocks and repairing refcount drift.  Returns
        ``(freed_blocks, freed_bytes, repaired_refs)``.

        Lives on the store (not the service) because it only touches store
        state — which is what lets a remote store run the whole pass next
        to its data in one RPC (``transport/client.py`` overrides this).
        """
        freed_blocks = freed_bytes = repaired = 0
        for key in self.scan_keys():
            want = live.get(key, 0)
            if want == 0:
                freed_bytes += self.drop(key)
                freed_blocks += 1
            elif self.refs.get(key) != want:
                self.repair_ref(key, want)
                repaired += 1
        return freed_blocks, freed_bytes, repaired

    def sync(self):
        """Make accounting durable (no-op for the in-memory backend).

        Uniform entry point so multi-store owners (the sharded service's
        per-shard flush) need not type-switch on the backend.
        """

    @property
    def unique_chunks(self) -> int:
        """Number of unique blocks held (part of the stats surface shared
        with the remote store proxy, which cannot expose a refs dict)."""
        return len(self.refs)

    def stat(self) -> Dict[str, int]:
        """The accounting triple in one call — the shape consumers should
        prefer over reading the three properties separately, because on the
        remote store proxy each property is a full RPC and ``stat()`` is
        exactly one."""
        return {
            "stored_bytes": self.stored_bytes,
            "logical_bytes": self.logical_bytes,
            "unique_chunks": self.unique_chunks,
        }

    @property
    def savings(self) -> float:
        if not self.logical_bytes:
            return 0.0
        return (self.logical_bytes - self.stored_bytes) / self.logical_bytes


class DirBlockStore(BlockStore):
    """File-backed store: one file per unique block + a json manifest.

    Writes are atomic (tmp + rename) so a crashed writer never corrupts the
    store — required by the fault-tolerant checkpoint manager built on top.

    The manifest also records block *sizes*: a crash between a block unlink
    and the manifest sync leaves manifest entries whose files are gone, and
    recovery (``release`` replay, ``gc``) must be able to correct the byte
    accounting for a block it can no longer stat.
    """

    def __init__(self, root: str):
        super().__init__()
        self.root = root
        self.sizes: dict[str, int] = {}
        os.makedirs(os.path.join(root, "blocks"), exist_ok=True)
        self._manifest_path = os.path.join(root, "manifest.json")
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                m = json.load(f)
            self.refs = {k: int(v) for k, v in m["refs"].items()}
            self.sizes = {k: int(v) for k, v in m.get("sizes", {}).items()}
            self.logical_bytes = m["logical_bytes"]
            self.stored_bytes = m["stored_bytes"]

    def _path(self, key: str) -> str:
        return os.path.join(self.root, "blocks", key)

    def put(self, chunk: bytes) -> str:
        key = sha256_key(chunk)
        self.logical_bytes += len(chunk)
        path = self._path(key)
        # write keyed on *file presence*, not on the refcount: a stale
        # manifest (crash between unlink and manifest sync) may list a key
        # whose file is gone, and a committed recipe must never name bytes
        # that are not on disk
        if not os.path.exists(path):
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(chunk)
            os.replace(tmp, path)
        if key not in self.refs:
            self.stored_bytes += len(chunk)
            self.refs[key] = 0
        self.refs[key] += 1
        self.sizes[key] = len(chunk)
        return key

    def get(self, key: str) -> bytes:
        try:
            with open(self._path(key), "rb") as f:
                return f.read()
        except FileNotFoundError:
            # missing blocks surface as KeyError on every backend (the
            # in-memory store, this one, and the remote proxy), so callers
            # and transports agree on the exception type
            raise KeyError(key) from None

    def get_stream(self, keys: Iterable[str]) -> bytes:
        return b"".join(self.get(k) for k in keys)

    def chunk_size(self, key: str) -> int:
        # manifest size first: must work for manifest-listed keys whose
        # block file a crashed delete already unlinked
        if key in self.sizes:
            return self.sizes[key]
        return os.path.getsize(self._path(key))

    def _remove_block(self, key: str):
        self.sizes.pop(key, None)
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass  # replay of a partially-applied delete: already unlinked

    def scan_keys(self) -> list[str]:
        """Manifest keys plus any block files on disk the manifest missed.

        Stale ``.tmp`` files are torn writes by construction (commits go
        through atomic rename) and are unlinked during the scan.
        """
        keys = set(self.refs)
        blocks_dir = os.path.join(self.root, "blocks")
        for fn in os.listdir(blocks_dir):
            if fn.endswith(".tmp"):
                os.remove(os.path.join(blocks_dir, fn))
            else:
                keys.add(fn)
        return sorted(keys)

    def repair_ref(self, key: str, refs: int):
        self.sizes.setdefault(key, self.chunk_size(key))
        super().repair_ref(key, refs)

    def drop(self, key: str) -> int:
        if key in self.refs:
            return super().drop(key)
        path = self._path(key)  # on-disk orphan: never entered the accounting
        if not os.path.exists(path):
            return 0
        size = os.path.getsize(path)
        os.remove(path)
        return size

    def sync(self):
        self.sync_manifest()

    def sync_manifest(self):
        tmp = self._manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {
                    "refs": self.refs,
                    "sizes": {k: self.sizes[k] for k in self.refs
                              if k in self.sizes},
                    "logical_bytes": self.logical_bytes,
                    "stored_bytes": self.stored_bytes,
                },
                f,
            )
        os.replace(tmp, self._manifest_path)
