"""Content-addressed chunk store (paper SSII "Data Storage").

Chunks are keyed by SHA-256 (collision-resistant, as the paper prescribes for
the storage layer).  Backends: in-memory dict or a directory of block files
with a refcount manifest — enough to run the end-to-end dedup pipeline and
the CDC incremental checkpoint store on top of it.
"""
from __future__ import annotations

import hashlib
import json
import os
from typing import Iterable

import numpy as np


def sha256_key(chunk: bytes) -> str:
    return hashlib.sha256(chunk).hexdigest()


class BlockStore:
    """In-memory content-addressed store with dedup accounting."""

    def __init__(self):
        self.blocks: dict[str, bytes] = {}
        self.refs: dict[str, int] = {}
        self.logical_bytes = 0  # bytes written by clients
        self.stored_bytes = 0  # unique bytes actually stored

    def put(self, chunk: bytes) -> str:
        key = sha256_key(chunk)
        self.logical_bytes += len(chunk)
        if key not in self.blocks:
            self.blocks[key] = bytes(chunk)
            self.stored_bytes += len(chunk)
            self.refs[key] = 0
        self.refs[key] += 1
        return key

    def get(self, key: str) -> bytes:
        return self.blocks[key]

    def put_stream(self, data, bounds: Iterable[int]) -> list[str]:
        """Chunk-and-store a byte stream given exclusive boundary offsets."""
        data = np.asarray(data, dtype=np.uint8)
        keys = []
        s = 0
        for e in bounds:
            keys.append(self.put(data[s:e].tobytes()))
            s = int(e)
        return keys

    def get_stream(self, keys: Iterable[str]) -> bytes:
        return b"".join(self.blocks[k] for k in keys)

    def release(self, key: str):
        self.refs[key] -= 1
        if self.refs[key] == 0:
            blk = self.blocks.pop(key)
            self.stored_bytes -= len(blk)
            del self.refs[key]

    @property
    def savings(self) -> float:
        if not self.logical_bytes:
            return 0.0
        return (self.logical_bytes - self.stored_bytes) / self.logical_bytes


class DirBlockStore(BlockStore):
    """File-backed store: one file per unique block + a json manifest.

    Writes are atomic (tmp + rename) so a crashed writer never corrupts the
    store — required by the fault-tolerant checkpoint manager built on top.
    """

    def __init__(self, root: str):
        super().__init__()
        self.root = root
        os.makedirs(os.path.join(root, "blocks"), exist_ok=True)
        self._manifest_path = os.path.join(root, "manifest.json")
        if os.path.exists(self._manifest_path):
            with open(self._manifest_path) as f:
                m = json.load(f)
            self.refs = {k: int(v) for k, v in m["refs"].items()}
            self.logical_bytes = m["logical_bytes"]
            self.stored_bytes = m["stored_bytes"]

    def _path(self, key: str) -> str:
        return os.path.join(self.root, "blocks", key)

    def put(self, chunk: bytes) -> str:
        key = sha256_key(chunk)
        self.logical_bytes += len(chunk)
        path = self._path(key)
        if key not in self.refs:
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(chunk)
            os.replace(tmp, path)
            self.stored_bytes += len(chunk)
            self.refs[key] = 0
        self.refs[key] += 1
        return key

    def get(self, key: str) -> bytes:
        with open(self._path(key), "rb") as f:
            return f.read()

    def get_stream(self, keys: Iterable[str]) -> bytes:
        return b"".join(self.get(k) for k in keys)

    def release(self, key: str):
        self.refs[key] -= 1
        if self.refs[key] == 0:
            blk_path = self._path(key)
            self.stored_bytes -= os.path.getsize(blk_path)
            os.remove(blk_path)
            del self.refs[key]

    def sync_manifest(self):
        tmp = self._manifest_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(
                {
                    "refs": self.refs,
                    "logical_bytes": self.logical_bytes,
                    "stored_bytes": self.stored_bytes,
                },
                f,
            )
        os.replace(tmp, self._manifest_path)
