"""Fingerprint comparison and space-savings accounting (paper SSII, Eq. 1).

Two layers:

* :func:`dedup_stats` — in-JAX: sort the (fp, length) table, mark first
  occurrences, reduce.  Used by the accelerator-resident pipeline and the
  benchmarks (space savings = 1 - deduplicated/original, Eq. 1).
* :class:`FingerprintIndex` — host-side incremental index (dict) used by the
  streaming ingest pipeline and the CDC checkpoint store, where chunks arrive
  over time and persistence matters.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def dedup_stats(fp: jax.Array, lengths: jax.Array):
    """Global dedup over a batch of chunk tables.

    fp: (..., C, 2) uint32; lengths: (..., C) int32 (0 = padding).
    Returns dict with original_bytes, dedup_bytes, unique_chunks, total_chunks.
    """
    f1 = fp[..., 0].reshape(-1)
    f2 = fp[..., 1].reshape(-1)
    ln = lengths.reshape(-1)
    valid = ln > 0
    # push padding to the end with an impossible key (real fps are < 2^31)
    pad_key = jnp.uint32(0xFFFFFFFF)
    f1 = jnp.where(valid, f1, pad_key)
    f2 = jnp.where(valid, f2, pad_key)
    key1_s, key2_s, lens_s, valid_s = jax.lax.sort(
        (f1, f2, ln, valid.astype(jnp.int32)), num_keys=2
    )
    prev1 = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, key1_s.dtype), key1_s[:-1]])
    prev2 = jnp.concatenate([jnp.full((1,), 0xFFFFFFFF, key2_s.dtype), key2_s[:-1]])
    first = ((key1_s != prev1) | (key2_s != prev2)) & (valid_s > 0)
    original = jnp.sum(lens_s * valid_s)
    dedup = jnp.sum(jnp.where(first, lens_s, 0))
    return {
        "original_bytes": original,
        "dedup_bytes": dedup,
        "unique_chunks": jnp.sum(first.astype(jnp.int32)),
        "total_chunks": jnp.sum(valid_s),
    }


def space_savings(stats) -> float:
    o = float(stats["original_bytes"])
    d = float(stats["dedup_bytes"])
    return (o - d) / o if o else 0.0


@dataclasses.dataclass
class FingerprintIndex:
    """Host-side incremental fingerprint database (paper SSII step 3)."""

    seen: dict = dataclasses.field(default_factory=dict)
    original_bytes: int = 0
    dedup_bytes: int = 0

    def add(self, fp: tuple, length: int) -> bool:
        """Returns True if the chunk is new (must be stored)."""
        self.original_bytes += int(length)
        if fp in self.seen:
            return False
        self.seen[fp] = int(length)
        self.dedup_bytes += int(length)
        return True

    def add_batch(self, fps: np.ndarray, lengths: np.ndarray) -> np.ndarray:
        """Vectorized-ish batch add; returns bool array (new per chunk)."""
        out = np.zeros(len(lengths), dtype=bool)
        for i, (f, l) in enumerate(zip(map(tuple, np.asarray(fps)), lengths)):
            if l > 0:
                out[i] = self.add(f, int(l))
        return out

    @property
    def savings(self) -> float:
        if not self.original_bytes:
            return 0.0
        return (self.original_bytes - self.dedup_bytes) / self.original_bytes
