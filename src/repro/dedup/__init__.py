"""repro.dedup — fingerprints, dedup index, distributed index, block store.

Exports resolve lazily (``repro._lazy``): ``store`` is numpy+stdlib while
``fingerprint``/``index``/``dist_index`` pull in jax, and a spawned shard
server (``service/transport/shard_server.py``) needs only the former —
lazy resolution keeps those processes accelerator-runtime-free.
"""
from repro._lazy import install as _install

_EXPORTS = {
    "owner_of": ".dist_index",
    "route_host": ".dist_index",
    "chunk_fingerprints": ".fingerprint",
    "fingerprints_numpy": ".fingerprint",
    "FingerprintIndex": ".index",
    "dedup_stats": ".index",
    "space_savings": ".index",
    "BlockStore": ".store",
    "DirBlockStore": ".store",
    "sha256_key": ".store",
    "BlockCorruptionError": ".store",
    "available_codecs": ".store",
    "resolve_codec": ".store",
    "negotiate_codec": ".store",
}

_SUBMODULES = ("dist_index", "fingerprint", "index", "store")

__all__ = sorted(_EXPORTS) + list(_SUBMODULES)

__getattr__, __dir__ = _install(__name__, _EXPORTS, _SUBMODULES)
