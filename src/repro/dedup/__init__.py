"""repro.dedup — fingerprints, dedup index, distributed index, block store."""
from .dist_index import owner_of, route_host  # noqa: F401
from .fingerprint import chunk_fingerprints, fingerprints_numpy  # noqa: F401
from .index import FingerprintIndex, dedup_stats, space_savings  # noqa: F401
from .store import BlockStore, DirBlockStore, sha256_key  # noqa: F401
