"""Granite-8B (code): 36L dense llama-arch, GQA kv=8.

[arXiv:2405.04324] — d_model 4096, 32 heads (head_dim 128), FFN 14336,
vocab 49152.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10_000_000.0,
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="data",
    microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        microbatch=0,
        fsdp="none",
        attn_q_block=64,
    )
