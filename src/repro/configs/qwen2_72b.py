"""Qwen2-72B: 80L dense, GQA kv=8, QKV bias.

[arXiv:2407.10671] — d_model 8192, 64 heads (head_dim 128), FFN 29568,
vocab 152064, rope theta 1e6.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="pod_data",
    microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        fsdp="none",
        microbatch=0,
        attn_q_block=64,
    )
