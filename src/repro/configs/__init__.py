"""Architecture registry: the 10 assigned configs + the paper's own pipeline.

``get_config(name)`` returns the exact public config; ``get_reduced(name)``
returns the family-preserving smoke variant used by CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict

from .base import (  # noqa: F401
    SHAPES,
    SUBQUADRATIC,
    ModelConfig,
    ShapeConfig,
    param_count,
    shape_applicable,
)

_MODULES: Dict[str, str] = {
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama3.2-1b": "llama32_1b",
    "qwen2-72b": "qwen2_72b",
    "granite-8b": "granite_8b",
    "musicgen-large": "musicgen_large",
    "llava-next-34b": "llava_next_34b",
    "xlstm-125m": "xlstm_125m",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

ARCHS = tuple(_MODULES)


def _module(name: str):
    try:
        mod = _MODULES[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; available: {list(_MODULES)}") from None
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def cells(include_inapplicable: bool = False):
    """All (arch, shape) dry-run cells — 40 assigned, minus long_500k skips."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if include_inapplicable or shape_applicable(cfg, shape):
                out.append((arch, shape.name))
    return out
