"""Llama-3.2-1B: 16L dense, GQA kv=8, tied embeddings.

[hf:meta-llama/Llama-3.2-1B] — d_model 2048, 32 heads (head_dim 64),
FFN 8192, vocab 128256, rope theta 500000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500_000.0,
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="data",
    microbatch=4,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        microbatch=0,
        fsdp="none",
        attn_q_block=64,
    )
