"""Phi-3-medium 14B: 40L dense, GQA kv=10, SwiGLU 17920.

[arXiv:2404.14219] — d_model 5120, 40 heads (head_dim 128), vocab 100352.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=10_000.0,
    tp_head_pad=48,
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="data",
    microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=256,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        microbatch=0,
        fsdp="none",
        attn_q_block=64,
    )
