"""DeepSeek-V3 671B: 61L, MLA attention, 1 shared + 256 routed experts top-8.

[arXiv:2412.19437] — d_model 7168, 128 heads, MLA (q_lora 1536, kv_lora 512,
qk_nope 128 + qk_rope 64, v_head 128), first 3 layers dense (FFN 18432),
expert FFN 2048, vocab 129280.  The MTP head (multi-token prediction) is an
optional flag, off for the dry-run cells (DESIGN.md SS8).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,  # qk_nope + qk_rope (used only for analytics; MLA has own dims)
    d_ff=18432,  # the 3 leading dense layers
    vocab_size=129280,
    rope_theta=10_000.0,
    n_experts=256,
    moe_top_k=8,
    d_ff_expert=2048,
    n_shared_experts=1,
    n_dense_layers=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="pod_data",
    microbatch=16,
)


def reduced() -> ModelConfig:
    """Smoke config: tiny MLA + shared/routed MoE with 3-dense prefix -> 1."""
    return CONFIG.replace(
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=24,
        d_ff=128,
        vocab_size=256,
        n_experts=8,
        moe_top_k=2,
        d_ff_expert=32,
        n_dense_layers=1,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        fsdp="none",
        microbatch=0,
        attn_q_block=64,
    )
