"""xLSTM-125M: 12 blocks of mLSTM with interleaved sLSTM.

[arXiv:2405.04517; unverified] — d_model 768, 4 heads, vocab 50304 (GPT-2
rounded), d_ff 0 (the mLSTM up-projection replaces the FFN).  We use an
xLSTM[5:1]-style ratio: every 6th block is sLSTM (2 of 12).  Constant-size
recurrent state -> runs the long_500k cell (DESIGN.md SS5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    tie_embeddings=True,
    slstm_every=6,
    mlstm_proj_factor=2.0,
    mlstm_chunk=256,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="dots",
    fsdp="none",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        head_dim=32,
        vocab_size=256,
        slstm_every=3,
        mlstm_chunk=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
    )
