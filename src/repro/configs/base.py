"""Model/shape configuration schema for the assigned architectures.

One frozen dataclass covers all four families (dense / moe / ssm / hybrid);
each architecture file in this package instantiates it with the exact public
numbers, plus a family-preserving ``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"  # silu -> SwiGLU, gelu -> GeGLU
    input_mode: str = "tokens"  # tokens | embeddings | mixed
    img_tokens: int = 0  # mixed mode: precomputed patch embeddings per sample

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0  # leading dense layers (DeepSeek-V3: 3)
    capacity_factor: float = 1.25
    moe_aux_coef: float = 0.01  # load-balance loss coefficient

    # MLA (DeepSeek)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (xLSTM)
    slstm_every: int = 0  # every k-th block is sLSTM (0 = none)
    mlstm_proj_factor: float = 2.0
    mlstm_chunk: int = 256

    # hybrid (RecurrentGemma)
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rglru", "rglru", "attn")
    lru_width: int = 0
    window_size: int = 0  # local attention window
    conv_width: int = 4
    logits_soft_cap: float = 0.0

    # distribution
    tp_head_pad: int = 0  # pad attention-activation heads to this for TP
                          # (params keep the exact public head count)

    # numerics / lowering
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "none"  # none | full | dots
    fsdp: str = "none"  # none | data | pod_data
    attn_q_block: int = 1024  # query-block size for chunked attention
    attn_kv_block: int = 0  # kv-block size for online-softmax (flash-style)
                            # attention; 0 = materialize (qb, S) score tiles
    microbatch: int = 0  # grad-accumulation microbatches (0 = off)

    # -- derived ------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def group_size(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def block_kind(self, layer: int) -> str:
        """Block type for a layer index (handles hybrid/ssm/moe patterns)."""
        if self.family == "hybrid" and self.block_pattern:
            return self.block_pattern[layer % len(self.block_pattern)]
        if self.family == "ssm":
            if self.slstm_every and (layer + 1) % self.slstm_every == 0:
                return "slstm"
            return "mlstm"
        if self.family == "moe" and layer >= self.n_dense_layers:
            return "moe"
        return "dense"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

#: archs allowed to run long_500k (sub-quadratic state); all others skip it
SUBQUADRATIC = ("xlstm-125m", "recurrentgemma-2b")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.name in SUBQUADRATIC
    return True


def param_count(cfg: ModelConfig) -> tuple[int, int]:
    """(total_params, active_params_per_token) — analytic, for rooflines."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    active = emb
    for layer in range(cfg.n_layers):
        kind = cfg.block_kind(layer)
        if cfg.use_mla:
            attn = (
                d * cfg.q_lora_rank
                + cfg.q_lora_rank * h * (cfg.qk_nope_dim + cfg.qk_rope_dim)
                + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
                + cfg.kv_lora_rank * h * (cfg.qk_nope_dim + cfg.v_head_dim)
                + h * cfg.v_head_dim * d
            )
        elif kind in ("mlstm", "slstm"):
            attn = 0
        else:
            attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = 3 * d * cfg.d_ff
        if kind == "moe":
            ff_e = cfg.d_ff_expert
            router = d * cfg.n_experts
            total += attn + 3 * d * ff_e * (cfg.n_experts + cfg.n_shared_experts) + router
            active += attn + 3 * d * ff_e * (cfg.moe_top_k + cfg.n_shared_experts) + router
        elif kind == "mlstm":
            du = int(d * cfg.mlstm_proj_factor)
            blk = 2 * d * du + 3 * du * du + du * d  # up(x2), qkv, down
            total += blk
            active += blk
        elif kind == "slstm":
            blk = 8 * d * d  # 4 gates x (input + recurrent)
            total += blk
            active += blk
        elif kind == "rglru":
            w = cfg.lru_width
            blk = 2 * d * w + w * cfg.conv_width + 2 * w * w + w * d + mlp
            total += blk
            active += blk
        else:  # dense / hybrid-attn blocks: attention + own MLP
            total += attn + mlp
            active += attn + mlp
    return total, active
