"""MusicGen-large backbone: 48L decoder-only over EnCodec tokens, MHA.

[arXiv:2306.05284] — d_model 2048, 32 heads (kv=32, i.e. full MHA),
FFN 8192, vocab 2048 (codec codebook).  The EnCodec frontend is a STUB:
``input_specs()`` supplies precomputed frame embeddings (input_mode
"embeddings"); cross-attention text conditioning is out of scope
(DESIGN.md SS8).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    input_mode="embeddings",
    act="gelu",
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="data",
    microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=64,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        microbatch=0,
        fsdp="none",
        attn_q_block=64,
    )
