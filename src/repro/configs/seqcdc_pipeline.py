"""The paper's own configuration: SeqCDC dedup-pipeline settings.

Not an LM architecture — this is the configuration surface of the paper's
contribution itself (chunking + fingerprinting + dedup), consumed by the
data pipeline, the checkpoint store, and the benchmarks.  Table I parameters
live in core/params.py; this file is the framework-level config record.
"""
from __future__ import annotations

import dataclasses

from repro.core.params import SeqCDCParams, paper_params


@dataclasses.dataclass(frozen=True)
class DedupConfig:
    """Framework-level dedup settings (paper SSIII + SSVI)."""

    algorithm: str = "seqcdc"  # any name in core.chunker registry
    avg_chunk: int = 8192  # paper's headline configuration
    mode: str = "increasing"
    mask_impl: str = "jnp"  # jnp | pallas (phase-1 bitmap backend)
    step_impl: str = "gather"  # wide | gather (phase-2 automaton step)
    segment_bytes: int = 1 << 20
    batch_segments: int = 8
    distributed_index: bool = True  # partition-by-hash all_to_all on a mesh

    def params(self) -> SeqCDCParams:
        return paper_params(self.avg_chunk, self.mode)


CONFIG = DedupConfig()
