"""RecurrentGemma-2B: RG-LRU + local sliding-window MQA, pattern (R,R,A).

[arXiv:2402.19427] — 26 layers, d_model 2560, 10 heads (MQA kv=1,
head_dim 256), GeGLU FFN 7680 (paper: expansion 3), lru_width 2560,
window 2048, logits soft cap 30.  Constant decode state (lru h + conv tail +
2048-window cache) -> runs the long_500k cell (DESIGN.md SS5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    act="gelu",
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    window_size=2048,
    conv_width=4,
    logits_soft_cap=30.0,
    rope_theta=10_000.0,
    tp_head_pad=16,
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="data",
    microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=256,
        lru_width=64,
        window_size=32,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        microbatch=0,
        fsdp="none",
        attn_q_block=64,
    )
