"""Qwen3-30B-A3B: 48L MoE, 128 experts top-8, GQA kv=4, q/k-norm.

[hf:Qwen/Qwen3-30B-A3B] — d_model 2048, 32 heads (head_dim 128, decoupled
from d_model/heads = 64), expert FFN 768, vocab 151936, no shared experts,
every layer MoE.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=6144,  # unused: all layers are MoE
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    moe_top_k=8,
    d_ff_expert=768,
    n_shared_experts=0,
    n_dense_layers=0,
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="data",
    microbatch=8,  # peak activation HBM measured 60 GiB/dev without accumulation
)


def reduced() -> ModelConfig:
    """Family-preserving smoke config: tiny MoE with q/k-norm + GQA."""
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        n_experts=8,
        moe_top_k=2,
        d_ff_expert=32,
        microbatch=0,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        fsdp="none",
        attn_q_block=64,
    )
