"""LLaVA-NeXT-34B backbone: 60L dense, 56 heads, anyres tiling stub.

[hf:llava-hf/llava-v1.6; unverified] — d_model 7168, 56 heads (GQA kv=8,
head_dim 128), FFN 20480, vocab 64000.  The ViT/anyres frontend is a STUB:
``input_specs()`` supplies 2880 precomputed patch embeddings (5 tiles x 576)
per sample, prepended to the text tokens (input_mode "mixed").

56 heads do not divide the 16-way model axis: the sharding layer replicates
what cannot shard or lets GSPMD pad (12.5% waste at 16-way) — recorded in the
roofline notes.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    input_mode="mixed",
    img_tokens=2880,  # 5 anyres tiles x 576 patches
    rope_theta=5_000_000.0,
    tp_head_pad=64,
    attn_kv_block=1024,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    remat="full",
    fsdp="pod_data",
    microbatch=8,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        img_tokens=16,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        fsdp="none",
        microbatch=0,
        attn_q_block=64,
    )
