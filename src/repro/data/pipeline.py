"""Dedup ingest pipeline: the paper's technique as a training-data stage.

Per-host flow (each data-parallel host runs this on its own corpus shard —
chunking is embarrassingly parallel across shards, which is how the paper's
single-node algorithm scales to a pod):

    corpus shard -> [SeqCDC chunk] -> [fingerprint] -> [dedup filter]
                 -> unique-chunk byte stream -> token batches

Dedup before tokenization removes redundant training bytes (duplicate
documents/backup copies), a real pretraining-pipeline concern.  The chunking
and fingerprinting run batched on the accelerator (vmapped two-phase SeqCDC);
the index is either host-local (:class:`FingerprintIndex`) or the distributed
partition-by-hash index (dedup/dist_index.py) when a mesh is available.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core import SeqCDCParams, derived_params
from repro.core.automaton import max_chunks_for
from repro.core.seqcdc import boundaries_batch
from repro.dedup import FingerprintIndex, chunk_fingerprints


@dataclasses.dataclass
class PipelineConfig:
    avg_chunk: int = 8192
    segment_bytes: int = 1 << 20  # accelerator batch granularity
    batch_segments: int = 8  # segments chunked per device dispatch
    vocab_size: int = 256  # byte-level tokens by default
    seq_len: int = 1024
    batch_size: int = 8
    drop_duplicates: bool = True


class DedupIngest:
    """Streaming dedup of a host corpus shard, accelerator-batched."""

    def __init__(self, cfg: PipelineConfig, params: SeqCDCParams | None = None):
        self.cfg = cfg
        self.params = params or derived_params(cfg.avg_chunk)
        self.index = FingerprintIndex()
        self._jit_cache = {}

    def _chunk_batch(self, segs: np.ndarray):
        """segs: (B, S) uint8 -> (bounds, counts, fps, lens) numpy."""
        import jax
        import jax.numpy as jnp

        B, S = segs.shape
        key = (B, S)
        fn = self._jit_cache.get(key)
        if fn is None:
            mc = max_chunks_for(S, self.params)

            @jax.jit
            def fn(x):
                bounds, counts = boundaries_batch(x, self.params)
                fps, lens = jax.vmap(
                    lambda d, b, c: chunk_fingerprints(d, b, c, max_chunks=mc)
                )(x, bounds, counts)
                return bounds, counts, fps, lens

            self._jit_cache[key] = fn
        bounds, counts, fps, lens = fn(jnp.asarray(segs))
        return (np.asarray(bounds), np.asarray(counts), np.asarray(fps),
                np.asarray(lens))

    def unique_bytes(self, corpus: np.ndarray) -> Iterator[np.ndarray]:
        """Yield unique-chunk byte arrays from the corpus shard, in order."""
        S = self.cfg.segment_bytes
        B = self.cfg.batch_segments
        n_seg = len(corpus) // S
        tail = corpus[n_seg * S :]
        for i in range(0, n_seg, B):
            block = corpus[i * S : min((i + B) * S, n_seg * S)]
            nb = len(block) // S
            segs = block.reshape(nb, S)
            bounds, counts, fps, lens = self._chunk_batch(segs)
            for b in range(nb):
                cnt = int(counts[b])
                new = self.index.add_batch(fps[b, :cnt], lens[b, :cnt])
                s = 0
                for j in range(cnt):
                    e = int(bounds[b, j])
                    if new[j] or not self.cfg.drop_duplicates:
                        yield segs[b, s:e]
                    s = e
        if tail.size:
            if self.index.add((int(tail.sum()), len(tail)), len(tail)):
                yield tail

    def token_batches(self, corpus: np.ndarray) -> Iterator[np.ndarray]:
        """Pack unique bytes into (batch, seq_len+1) uint8 LM batches."""
        cfg = self.cfg
        need = cfg.batch_size * (cfg.seq_len + 1)
        buf = np.zeros(0, dtype=np.uint8)
        for chunk in self.unique_bytes(corpus):
            buf = np.concatenate([buf, chunk])
            while len(buf) >= need:
                batch = buf[:need].reshape(cfg.batch_size, cfg.seq_len + 1)
                yield batch
                buf = buf[need:]

    @property
    def savings(self) -> float:
        return self.index.savings
