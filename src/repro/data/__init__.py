"""repro.data — corpus synthesis, dedup ingest pipeline, batch loader."""
from .corpus import container_corpus, load_dataset, snapshot_series, vm_image_like  # noqa: F401
from .loader import LoaderConfig, TokenLoader  # noqa: F401
from .pipeline import DedupIngest, PipelineConfig  # noqa: F401
