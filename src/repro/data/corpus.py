"""Corpus construction: real container files + synthetic snapshot series.

The paper evaluates on 40-230 GB proprietary corpora (VM images, build-server
backups, kernel trees, Redis/MySQL snapshots).  We reproduce the *phenomena*
at container scale (DESIGN.md SS8) with:

* :func:`container_corpus` — real bytes harvested from this machine's
  filesystem (source trees, shared objects, text): the "LNX-like" corpus.
* :func:`snapshot_series` — K successive "backups" of a mutating store:
  each snapshot applies insert/delete/overwrite edits to the previous one
  (byte-shifting!) — the "DEV/RDS/TPCC-like" corpora.  Edit rates control
  the achievable dedup.
* :func:`vm_image_like` — mixed-entropy image: zero runs, text blocks,
  binary blobs, repeated filesystem metadata — the "DEB-like" corpus.
"""
from __future__ import annotations

import os
from typing import Iterator

import numpy as np

_DEFAULT_ROOTS = ("/usr/lib/python3", "/usr/include", "/etc", "/opt")


def container_corpus(
    max_bytes: int = 64 << 20, roots=_DEFAULT_ROOTS, max_file: int = 4 << 20
) -> np.ndarray:
    """Concatenate real files from the container filesystem (deterministic walk)."""
    bufs, total = [], 0
    for root in roots:
        if total >= max_bytes or not os.path.isdir(root):
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames.sort()
            for fn in sorted(filenames):
                path = os.path.join(dirpath, fn)
                try:
                    size = os.path.getsize(path)
                    if size == 0 or size > max_file or os.path.islink(path):
                        continue
                    with open(path, "rb") as f:
                        bufs.append(np.frombuffer(f.read(), dtype=np.uint8))
                    total += size
                except OSError:
                    continue
                if total >= max_bytes:
                    break
            if total >= max_bytes:
                break
    if not bufs:  # fallback: deterministic pseudo-text
        return vm_image_like(max_bytes, seed=13)
    out = np.concatenate(bufs)
    return out[:max_bytes]


def snapshot_series(
    base_bytes: int = 8 << 20,
    snapshots: int = 8,
    edit_rate: float = 2e-5,
    seed: int = 0,
    low_entropy: bool = False,
) -> Iterator[np.ndarray]:
    """Yield K snapshots; each applies ~edit_rate*len edits to the previous.

    Edits are insert (1-64 B), delete (1-64 B), or overwrite (1-256 B) at
    random offsets — the byte-shifting workload of paper SSIV.
    """
    rng = np.random.default_rng(seed)
    if low_entropy:
        cur = rng.integers(0, 16, base_bytes, dtype=np.uint8) * 16
    else:
        cur = rng.integers(0, 256, base_bytes, dtype=np.uint8)
    yield cur.copy()
    for _ in range(snapshots - 1):
        n_edits = max(1, int(len(cur) * edit_rate))
        parts = []
        prev = 0
        offs = np.sort(rng.integers(0, len(cur), n_edits))
        for off in offs:
            off = int(off)
            if off < prev:
                continue
            parts.append(cur[prev:off])
            kind = rng.integers(0, 3)
            if kind == 0:  # insert
                parts.append(rng.integers(0, 256, int(rng.integers(1, 65)), dtype=np.uint8))
                prev = off
            elif kind == 1:  # delete
                prev = min(len(cur), off + int(rng.integers(1, 65)))
            else:  # overwrite
                ln = int(rng.integers(1, 257))
                parts.append(rng.integers(0, 256, ln, dtype=np.uint8))
                prev = min(len(cur), off + ln)
        parts.append(cur[prev:])
        cur = np.concatenate(parts)
        yield cur.copy()


def vm_image_like(total: int = 32 << 20, seed: int = 0) -> np.ndarray:
    """Mixed-entropy 'VM image': zero pages, ASCII text, binary, metadata."""
    rng = np.random.default_rng(seed)
    words = np.array(
        [w.encode() for w in (
            "the quick brown fox jumps over lazy dog kernel module "
            "config system daemon service mount device driver linux "
        ).split()], dtype=object,
    )
    parts, size = [], 0
    meta = rng.integers(0, 256, 4096, dtype=np.uint8)  # repeated fs metadata
    while size < total:
        kind = rng.integers(0, 10)
        if kind < 3:  # zero run
            ln = int(rng.integers(4096, 65536))
            parts.append(np.zeros(ln, dtype=np.uint8))
        elif kind < 6:  # text
            txt = b" ".join(rng.choice(words, 2048).tolist())
            parts.append(np.frombuffer(txt, dtype=np.uint8))
        elif kind < 9:  # binary blob
            ln = int(rng.integers(8192, 131072))
            parts.append(rng.integers(0, 256, ln, dtype=np.uint8))
        else:  # repeated metadata page
            parts.append(meta.copy())
        size += len(parts[-1])
    return np.concatenate(parts)[:total]


DATASETS = {
    "LNX": lambda mb=48: container_corpus(mb << 20),
    "DEB": lambda mb=48: vm_image_like(mb << 20, seed=1),
    "DEV": lambda mb=48: np.concatenate(
        list(snapshot_series(base_bytes=max(mb // 8, 1) << 20, snapshots=8, edit_rate=1e-5, seed=2))
    ),
    "RDS": lambda mb=48: np.concatenate(
        list(snapshot_series(base_bytes=max(mb // 8, 1) << 20, snapshots=8, edit_rate=1e-4, seed=3, low_entropy=True))
    ),
    "TPCC": lambda mb=48: np.concatenate(
        list(snapshot_series(base_bytes=max(mb // 6, 1) << 20, snapshots=6, edit_rate=5e-5, seed=4))
    ),
}


def load_dataset(name: str, mb: int = 48) -> np.ndarray:
    return DATASETS[name](mb)
