"""Deterministic sharded batch loader with restart cursor.

Feeds the train loop: infinite stream of (tokens, labels) batches derived
from a (deduplicated) corpus, sharded by host so each data-parallel host
reads only its slice, with a step cursor that makes restart-after-failure
bit-deterministic (train/loop.py restores the cursor from the checkpoint).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LoaderConfig:
    batch_size: int = 8  # global batch
    seq_len: int = 256
    host_index: int = 0
    host_count: int = 1
    seed: int = 17


class TokenLoader:
    """Byte-level LM batches from a corpus array, deterministic per step."""

    def __init__(self, corpus: np.ndarray, cfg: LoaderConfig):
        assert cfg.batch_size % cfg.host_count == 0
        self.cfg = cfg
        self.corpus = np.ascontiguousarray(corpus, dtype=np.uint8)
        self.n = len(self.corpus) - (cfg.seq_len + 1)
        assert self.n > 0, "corpus smaller than one sequence"
        self.local_batch = cfg.batch_size // cfg.host_count

    def batch_at(self, step: int) -> tuple[np.ndarray, np.ndarray]:
        """(tokens (local_B, S), labels (local_B, S)) for a global step.

        Offsets are a pure function of (seed, step, host, row): restart at
        step k reproduces exactly the batches a non-failed run would see.
        """
        cfg = self.cfg
        with np.errstate(over="ignore"):  # splitmix64: wraparound intended
            rows = np.arange(self.local_batch, dtype=np.uint64)
            gidx = (
                np.uint64(step) * np.uint64(cfg.batch_size)
                + np.uint64(cfg.host_index) * np.uint64(self.local_batch)
                + rows
            )
            x = gidx + np.uint64(cfg.seed) * np.uint64(0x9E3779B97F4A7C15)
            x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
            x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
            x = x ^ (x >> np.uint64(31))
            offs = (x % np.uint64(self.n)).astype(np.int64)
        idx = offs[:, None] + np.arange(self.cfg.seq_len + 1)[None, :]
        window = self.corpus[idx]
        return window[:, :-1].astype(np.int32), window[:, 1:].astype(np.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
