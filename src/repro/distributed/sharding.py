"""Logical-axis sharding rules -> PartitionSpecs (MaxText-style, mesh-aware).

Models declare *logical* axes on every parameter (via layers.PT) and on key
activations (via :func:`constrain`); this module maps them onto the physical
mesh:

  batch           -> (pod, data)     heads/kv_heads/mlp/experts/vocab/lru -> model
  embed           -> fsdp axes       (ZeRO-3-style parameter sharding over
                                      data, and over pod too for >=30B archs)
  q_lora/kv_lora  -> model (low priority: yields to heads when both occur)

Greedy assignment with priorities guarantees a mesh axis is used at most once
per spec.  Divisibility fallback: a dim smaller than its mesh-axes product is
replicated (e.g. kv_heads=4 on model=16 — replicating tiny KV projections
beats GSPMD's 4x padding); a dim that is larger but not divisible is sharded
unevenly (GSPMD pads; e.g. llava's 56 heads on 16 — 12.5% pad waste, recorded
in the roofline notes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS


def _template_map(fn, template):
    """Lazy import of models.layers.template_map (models imports `constrain`
    from this module at load time — keep the dependency one-way at import)."""
    from repro.models.layers import template_map

    return template_map(fn, template)

#: lower = assigned first
_PRIORITY = {
    "batch": 0,
    "vocab": 0,
    "heads": 0,
    "mlp": 0,
    "experts": 0,
    "lru": 0,
    "kv_heads": 1,
    "expert_mlp": 2,
    "mlp2": 2,
    "embed2": 2,
    "lru2": 2,
    "embed": 3,  # fsdp
    "q_lora": 4,
    "kv_lora": 4,
}


def default_rules(mesh: Mesh, fsdp: str = "none") -> Dict[str, Tuple[str, ...]]:
    names = mesh.axis_names
    multi_pod = "pod" in names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    fsdp_map = {
        "none": (),
        "data": ("data",),
        "pod_data": ("pod", "data") if multi_pod else ("data",),
    }
    return {
        "batch": batch_axes,
        "seq": (),
        "seq_kv": ("model",),  # decode KV caches: shard the sequence dim
        "heads_act": ("model",),  # attention activations (possibly padded)
        "embed": fsdp_map[fsdp],
        "embed2": ("model",),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": (),
        "head_dim2": (),
        "mlp": ("model",),
        "mlp2": ("model",),
        "experts": ("model",),
        "expert_mlp": (),
        "q_lora": ("model",),
        "kv_lora": ("model",),
        "lru": ("model",),
        "lru2": (),
        "conv": (),
        "stack": (),
    }


def rules_for_config(mesh: Mesh, cfg) -> Dict[str, Tuple[str, ...]]:
    """Arch-aware rules: one consistent tensor-parallel strategy per config.

    * heads divide the model axis -> standard head TP (kv replicated when the
      kv count doesn't divide — replicating tiny KV projections beats padding);
    * heads do NOT divide (phi3's 40, llava's 56 on a 16-way axis) ->
      head_dim TP: Q/K/V/O and the KV cache shard the 128-wide head_dim, and
      attention contractions over head_dim all-reduce.  One decision for the
      whole model keeps every attention tensor's sharding compatible.
    """
    rules = default_rules(mesh, getattr(cfg, "fsdp", "none"))
    m = mesh.shape.get("model", 1)
    h_eff = max(getattr(cfg, "tp_head_pad", 0), cfg.n_heads)
    if cfg.n_heads % m != 0:
        # padded-activation head TP: weights keep the exact head count and
        # replicate over model (FSDP shards their embed dim); activations pad
        # to h_eff and shard heads_act.  head_dim TP was tried and rejected:
        # the sharded-contraction all-reduce on (B,H,S,S) scores measured
        # 24 TiB/device at 32k prefill (EXPERIMENTS.md SSPerf).
        rules["heads"] = ()
        rules["kv_heads"] = ()
        rules["head_dim"] = ()
        if h_eff % m != 0:
            rules["heads_act"] = ()  # no padding configured: replicate
    elif cfg.n_kv_heads % m != 0:
        rules["kv_heads"] = ()
    return rules


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    rules: Dict[str, Tuple[str, ...]]

    def spec_for(self, axes: Tuple, shape: Tuple | None = None) -> PS:
        """PartitionSpec for logical axes (greedy, priority-ordered).

        pjit rejects non-divisible shardings at argument boundaries, so every
        assignment is divisibility-checked (shorter prefixes tried first).
        Arch-level fallbacks (e.g. head_dim TP when the head count doesn't
        divide the model axis) are decided once per config in
        :func:`rules_for_config`, never per tensor — per-tensor fallbacks
        produce *inconsistent* attention sharding (Q by heads, K/V by
        head_dim) and a collective storm.
        """
        order = sorted(
            range(len(axes)),
            key=lambda i: _PRIORITY.get(axes[i] or "", 9),
        )
        assigned: list = [None] * len(axes)
        used: set = set()
        for i in order:
            name = axes[i]
            if name is None:
                continue
            mesh_axes = tuple(
                a for a in self.rules.get(name, ()) if a not in used
            )
            if not mesh_axes:
                continue
            if shape is not None:
                # longest divisible prefix (e.g. batch on (pod, data))
                while mesh_axes:
                    prod = int(np.prod([self.mesh.shape[a] for a in mesh_axes]))
                    if shape[i] >= prod and shape[i] % prod == 0:
                        break
                    mesh_axes = mesh_axes[:-1]
                if not mesh_axes:
                    continue
            assigned[i] = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
            used.update(mesh_axes)
        return PS(*assigned)

    def pspec_tree(self, template):
        return _template_map(lambda t: self.spec_for(t.axes, t.shape), template)

    def sharding_tree(self, template):
        return _template_map(
            lambda t: NamedSharding(self.mesh, self.spec_for(t.axes, t.shape)),
            template,
        )


_ctx = threading.local()


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield
    finally:
        _ctx.rules = prev


def active_rules() -> ShardingRules | None:
    return getattr(_ctx, "rules", None)


def constrain(x, *axes):
    """with_sharding_constraint by logical axes; no-op outside use_rules()."""
    rules = active_rules()
    if rules is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec_for(axes, x.shape))
    )
