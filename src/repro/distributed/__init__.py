"""repro.distributed — logical-axis sharding rules and collective helpers."""
from .sharding import (  # noqa: F401
    ShardingRules,
    active_rules,
    constrain,
    default_rules,
    rules_for_config,
    use_rules,
)
