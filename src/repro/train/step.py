"""Train step builder: loss -> grads (optionally microbatched) -> AdamW.

Gradient accumulation runs as a ``lax.scan`` over microbatches with f32
accumulators; per-microbatch grads are in the model's compute dtype (bf16 on
the large archs), which also halves the gradient all-reduce bytes that cross
the data/pod axes — the "gradient compression" lever recorded in DESIGN.md
SS6.  The returned function is pure and jit/pjit-friendly; launch/dryrun.py
lowers it with sharded ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import lm
from . import optim


def loss_fn(cfg, params, batch):
    loss, metrics = lm.loss_and_metrics(cfg, params, batch)
    return loss, metrics


def _split_micro(batch: Dict[str, jax.Array], m: int):
    """(B, ...) -> (m, B/m, ...) for every array in the batch dict."""

    def r(x):
        b = x.shape[0]
        assert b % m == 0, (b, m)
        return x.reshape(m, b // m, *x.shape[1:])

    return jax.tree.map(r, batch)


def grads_and_metrics(cfg, params, batch):
    """Value-and-grad with optional lax.scan microbatching (f32 accumulators)."""
    m = cfg.microbatch
    gfun = jax.value_and_grad(functools.partial(loss_fn, cfg), has_aux=True)
    if not m or m <= 1:
        (loss, metrics), grads = gfun(params, batch)
        return grads, {**metrics, "loss": loss}

    micro = _split_micro(batch, m)

    def body(acc, mb):
        g_acc, l_acc = acc
        (loss, _), grads = gfun(params, mb)
        g_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / m, g_acc, grads
        )
        return (g_acc, l_acc + loss / m), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), _ = jax.lax.scan(body, (g0, jnp.zeros((), jnp.float32)), micro)
    grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
    return grads, {"loss": loss}


def make_train_step(cfg, opt_cfg: optim.OptConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        grads, metrics = grads_and_metrics(cfg, params, batch)
        params, opt_state, opt_metrics = optim.update(
            opt_cfg, grads, opt_state, params
        )
        m = {
            "loss": metrics["loss"],
            "grad_norm": opt_metrics["grad_norm"],
            "lr": opt_metrics["lr"],
        }
        return params, opt_state, m

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, metrics = loss_fn(cfg, params, batch)
        return {"loss": loss, "ce": metrics["ce"]}

    return eval_step
