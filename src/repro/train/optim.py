"""AdamW from scratch + warmup-cosine schedule.

Decoupled weight decay (no decay on norms/biases/1-D params), global-norm
gradient clipping, f32 moments by default with an ``opt_dtype`` knob
(bfloat16 moments halve optimizer HBM for the >=70 B archs — recorded in the
dry-run memory table).  Optimizer state mirrors the parameter tree leaf for
leaf, so the sharding layer shards it with the same PartitionSpecs as the
parameters (ZeRO-style when fsdp is enabled).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    opt_dtype: str = "float32"  # moment dtype: float32 | bfloat16


class OptState(NamedTuple):
    mu: Any  # first moment  (tree like params)
    nu: Any  # second moment (tree like params)
    count: jax.Array  # step counter (scalar int32)


def schedule(cfg: OptConfig, step):
    """Warmup-linear then cosine to ``min_lr_frac * lr``."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(cfg: OptConfig, params) -> OptState:
    dt = jnp.dtype(cfg.opt_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(zeros, jax.tree.map(jnp.copy, zeros), jnp.zeros((), jnp.int32))


def _decay_mask(params):
    """True where weight decay applies: >=2-D parameter matrices only."""
    return jax.tree.map(lambda p: p.ndim >= 2, params)


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def update(cfg: OptConfig, grads, state: OptState, params):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1, b2 = cfg.betas
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    dt = jnp.dtype(cfg.opt_dtype)
    masks = _decay_mask(params)

    def leaf(p, g, mu, nu, decay):
        g32 = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + g32 * (1.0 - b1)
        nu32 = nu.astype(jnp.float32) * b2 + jnp.square(g32) * (1.0 - b2)
        step = (mu32 / c1) / (jnp.sqrt(nu32 / c2) + cfg.eps)
        if decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, mu32.astype(dt), nu32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    flat_m = treedef.flatten_up_to(masks)
    out = [leaf(*args) for args in zip(flat_p, flat_g, flat_mu, flat_nu, flat_m)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(new_mu, new_nu, count), {"grad_norm": gnorm, "lr": lr}
