"""repro.train — optimizer, train step, fault-tolerant loop."""
from .optim import OptConfig, OptState, init as opt_init, update as opt_update  # noqa: F401
from .step import grads_and_metrics, make_eval_step, make_train_step  # noqa: F401
from .loop import LoopConfig, StragglerMonitor, Trainer  # noqa: F401
