"""Fault-tolerant training loop.

* checkpoint/restart through the CDC store (incremental, cheap — adjacent
  checkpoints dedup against each other), atomic manifests;
* deterministic restart: the data loader is a pure function of (seed, step),
  so resume at step k reproduces exactly the batches of an unfailed run
  (bit-determinism is tested in tests/test_train.py);
* straggler monitor: EWMA step time, slow steps logged and surfaced to a
  policy hook (on real pods the hook triggers re-scheduling / hot-spare
  swap; here it records events for inspection);
* elastic: restore maps checkpoints onto whatever mesh/sharding the new job
  runs with (checkpoint/store.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from . import optim, step as step_mod


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    ckpt_async: bool = False
    log_every: int = 10
    straggler_factor: float = 3.0  # step slower than factor x EWMA -> event
    ewma_alpha: float = 0.1


class StragglerMonitor:
    """EWMA step-time tracker with a pluggable slow-step policy hook."""

    def __init__(self, factor: float, alpha: float, policy: Callable | None = None):
        self.factor = factor
        self.alpha = alpha
        self.policy = policy
        self.ewma: float | None = None
        self.events: List[Dict] = []

    def observe(self, step: int, dt: float):
        if self.ewma is None:
            self.ewma = dt
            return
        if dt > self.factor * self.ewma:
            ev = {"step": step, "dt": dt, "ewma": self.ewma}
            self.events.append(ev)
            if self.policy is not None:
                self.policy(ev)
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt


class Trainer:
    def __init__(
        self,
        cfg,
        opt_cfg: optim.OptConfig,
        loop_cfg: LoopConfig,
        loader,
        ckpt: CheckpointManager | None = None,
        *,
        straggler_policy: Callable | None = None,
        jit: bool = True,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.loop_cfg = loop_cfg
        self.loader = loader
        self.ckpt = ckpt
        self.monitor = StragglerMonitor(
            loop_cfg.straggler_factor, loop_cfg.ewma_alpha, straggler_policy
        )
        fn = step_mod.make_train_step(cfg, opt_cfg)
        self.train_step = jax.jit(fn) if jit else fn
        self.history: List[Dict] = []

    # -- state ----------------------------------------------------------------
    def init_state(self, key):
        from repro.models import lm

        params = lm.init_params(self.cfg, key)
        return params, optim.init(self.opt_cfg, params)

    def try_restore(self, params, opt_state):
        """Resume from the newest committed checkpoint if one exists."""
        if self.ckpt is None:
            return 0, params, opt_state
        step, state, extra = self.ckpt.restore(
            tree_like={"params": params, "opt": opt_state}
        )
        if step is None:
            return 0, params, opt_state
        p = jax.tree.map(
            lambda a, b: jax.numpy.asarray(a, b.dtype), state["params"], params
        )
        o = jax.tree.map(
            lambda a, b: jax.numpy.asarray(a, b.dtype), state["opt"], opt_state
        )
        return int(extra.get("next_step", step + 1)), p, o

    # -- loop -----------------------------------------------------------------
    def run(self, key, steps: int | None = None):
        steps = steps or self.loop_cfg.total_steps
        params, opt_state = self.init_state(key)
        start, params, opt_state = self.try_restore(params, opt_state)

        for step in range(start, steps):
            tokens, labels = self.loader.batch_at(step)
            batch = {"tokens": tokens, "labels": labels}
            t0 = time.perf_counter()
            params, opt_state, metrics = self.train_step(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.observe(step, dt)

            rec = {
                "step": step,
                "loss": float(metrics["loss"]),
                "grad_norm": float(metrics["grad_norm"]),
                "lr": float(metrics["lr"]),
                "dt": dt,
            }
            self.history.append(rec)
            if self.loop_cfg.log_every and step % self.loop_cfg.log_every == 0:
                print(
                    f"step {step:5d} loss {rec['loss']:.4f} "
                    f"gnorm {rec['grad_norm']:.3f} lr {rec['lr']:.2e} {dt*1e3:.0f}ms"
                )

            if self.ckpt and (step + 1) % self.loop_cfg.ckpt_every == 0:
                state = {"params": params, "opt": opt_state}
                extra = {"next_step": step + 1}
                if self.loop_cfg.ckpt_async:
                    self.ckpt.save_async(step, state, extra)
                else:
                    self.ckpt.save(step, state, extra)

        if self.ckpt:
            self.ckpt.wait()
        return params, opt_state
