"""SeqCDC parameters (paper Table I) and chunking-size policy.

The paper's Table I gives (SeqLength, SkipTrigger, SkipSize) per average chunk
size, with min/max chunk sizes of 0.5x/2x the average (min 1 KB at 4 KB avg,
SS VI "Alternatives").  SS VI-B additionally notes that at 4 KB the SkipTrigger is
raised by 10% to constrain skipping; Table I's 55 (vs 50) already reflects it.
"""
from __future__ import annotations

import dataclasses
import math

KiB = 1024

#: Paper Table I: avg_size -> (SeqLength, SkipTrigger, SkipSize)
_TABLE_I = {
    4 * KiB: (5, 55, 256),
    8 * KiB: (5, 50, 256),
    16 * KiB: (5, 50, 512),
}

INCREASING = "increasing"
DECREASING = "decreasing"


@dataclasses.dataclass(frozen=True)
class SeqCDCParams:
    """Normative parameter set for one SeqCDC configuration.

    Attributes mirror SSIII of the paper.  ``min_size``/``max_size`` follow the
    evaluation setup (0.5x / 2x the target average; 1 KB floor at 4 KB).
    """

    avg_size: int = 8 * KiB
    seq_length: int = 5
    skip_trigger: int = 50
    skip_size: int = 256
    min_size: int = 4 * KiB
    max_size: int = 16 * KiB
    mode: str = INCREASING

    def __post_init__(self):
        if self.seq_length < 2:
            raise ValueError("seq_length must be >= 2")
        if self.mode not in (INCREASING, DECREASING):
            raise ValueError(f"mode must be increasing|decreasing, got {self.mode}")
        if self.min_size < self.seq_length:
            raise ValueError("min_size must be >= seq_length")
        if self.max_size < self.min_size:
            raise ValueError("max_size must be >= min_size")
        if self.skip_size < 1 or self.skip_trigger < 1:
            raise ValueError("skip_size and skip_trigger must be positive")

    @property
    def sub_min_skip(self) -> int:
        """Bytes ignored at the start of each chunk (SSIII-B)."""
        return self.min_size - self.seq_length

    @property
    def block_width(self) -> int:
        """Largest power-of-two W with W <= min(skip_size, min_size - seq_length).

        The block automaton (core/automaton.py) relies on: any event inside a
        W-block advances the scan position by at least min(skip_size,
        sub_min_skip) >= W, i.e. beyond the block, so at most one event fires
        per block and the in-block scan is a closed-form vector expression.
        See DESIGN.md SS4.
        """
        lim = min(self.skip_size, self.min_size - self.seq_length)
        w = 1 << int(math.floor(math.log2(lim)))
        return min(w, 1024)


def paper_params(avg_size: int = 8 * KiB, mode: str = INCREASING) -> SeqCDCParams:
    """Parameters for one of the paper's three evaluated average sizes."""
    if avg_size not in _TABLE_I:
        raise KeyError(f"paper Table I has no entry for avg_size={avg_size}")
    L, T, K = _TABLE_I[avg_size]
    min_size = max(KiB, avg_size // 2)
    return SeqCDCParams(
        avg_size=avg_size,
        seq_length=L,
        skip_trigger=T,
        skip_size=K,
        min_size=min_size,
        max_size=2 * avg_size,
        mode=mode,
    )


def derived_params(avg_size: int, mode: str = INCREASING) -> SeqCDCParams:
    """Parameters for arbitrary average sizes (beyond Table I).

    Calibration (benchmarks/bench_calibrate.py) shows that with SeqLength L the
    boundary probability per byte on random data is ~1/L! for strictly monotone
    runs; L=5 gives ~1/120 per position *before* min-size suppression, and the
    effective average is then dominated by min_size + geometric tail.  We keep
    L=5 for 2-32 KB (paper's range), and scale SkipSize with avg_size as the
    paper does (256 B below 16 KB, 512 B at 16 KB, +256 B per doubling after,
    capped at 4 KB).
    """
    if avg_size in _TABLE_I:
        return paper_params(avg_size, mode)
    L = 5
    T = 50
    if avg_size < 8 * KiB:
        T = 55
    doublings = max(0, int(math.log2(max(avg_size, 16 * KiB) / (16 * KiB))))
    K = min(512 * (1 << doublings), 4 * KiB)
    if avg_size < 16 * KiB:
        K = 256
    return SeqCDCParams(
        avg_size=avg_size,
        seq_length=L,
        skip_trigger=T,
        skip_size=K,
        min_size=max(KiB, avg_size // 2),
        max_size=2 * avg_size,
        mode=mode,
    )
