"""Phase 2 of SeqCDC-TPU: the W-block boundary-selection automaton.

Consumes the candidate/opposing bitmaps from phase 1 (core/masks.py or the
Pallas kernel) and resolves chunk boundaries with a ``lax.scan`` over W-byte
blocks.  Correctness rests on the invariant proved in DESIGN.md SS4: with
``W <= min(SkipSize, min_size - SeqLength)`` every event (candidate boundary,
skip trigger, max-size/file-end cut) advances the scan position past the
current block, so at most one event fires per block and the in-block scan
reduces to::

    first candidate >= offset       -> masked argmin      (the paper's ffs)
    first pair where carry+cumsum(opposing) > SkipTrigger  (the paper's
                                     popcnt/pdep/tzcnt)   -> masked argmin
    max-size / file-end cut position -> scalar arithmetic

Two step implementations are provided:

* ``wide``  — O(W) vector work per block (baseline; direct transcription).
* ``gather`` — O(1) gathers per block against tables precomputed in parallel
  over all blocks (cumsum / next-candidate / m-th-opposing-position).  This is
  the beyond-paper optimization logged in EXPERIMENTS.md SSPerf: the serial
  phase does constant work per block, pushing the whole pipeline to the
  bandwidth of phase 1.

Both are bit-identical to the oracle (tests/test_seqcdc_equivalence.py).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .params import SeqCDCParams

# np scalar, not jnp: it traces as a jaxpr literal, which lets the fused
# Pallas pipeline kernel (kernels/fused_pipeline.py) reuse _resolve in its
# kernel body without capturing a device constant
_BIG = np.int32(1 << 30)


def max_chunks_for(n: int, p: SeqCDCParams) -> int:
    """Upper bound on the number of chunks for an n-byte stream (+1 fixup slot)."""
    return max(1, n // p.min_size + 2)


def _padded_blocks(cand: jax.Array, opp: jax.Array, n: int, p: SeqCDCParams):
    """Pad bitmaps past n so every event fires inside the scan (DESIGN.md SS4).

    Scan positions never exceed ``cut_k + SkipSize`` for any chunk, and the
    final cut fires at position < n + SkipSize; padding by SkipSize + W rounded
    to a W multiple captures every event.
    """
    W = p.block_width
    n_pad = ((n + p.skip_size + W) + W - 1) // W * W
    pad = n_pad - n
    cand = jnp.pad(cand, (0, pad))
    opp = jnp.pad(opp, (0, pad))
    return cand.reshape(-1, W), opp.reshape(-1, W)


def _resolve(k, c, s, kc, kt, bend, in_block, n, p: SeqCDCParams):
    """Shared event-resolution logic given first-candidate kc / trigger kt.

    A trigger whose skip landing reaches the cut position is itself a cut:
    the scalar algorithm checks ``k + L > s + max_size`` *before* reading a
    window, so a skip from ``kt`` to ``kt + SkipSize >= cut_k`` cuts at
    ``cut_b`` without consulting any byte in between.  Resolving that here
    (rather than letting the landing position carry into a later block)
    keeps the scan position <= cut_k, which is what guarantees every event
    advances past its block: a deferred cut would rescan from
    ``cut_b + sub_min_skip``, *behind* blocks the scan already consumed,
    whenever SkipSize exceeds min_size (legal parameters, outside Table I).
    """
    L = p.seq_length
    cut_b = jnp.minimum(s + p.max_size, n)
    cut_k = cut_b - (L - 1)  # first scan position that cuts
    e_cut = jnp.maximum(cut_k, k)
    fire_cut = in_block & (e_cut < bend) & (e_cut <= jnp.minimum(kc, kt))
    fire_cand = in_block & ~fire_cut & (kc < kt)
    fire_trig = in_block & ~fire_cut & ~fire_cand & (kt < _BIG)
    trig_cuts = fire_trig & (kt + p.skip_size >= cut_k)  # overshooting skip
    emit_cut = fire_cut | trig_cuts
    bound_cand = kc + L
    new_s = jnp.where(emit_cut, cut_b, jnp.where(fire_cand, bound_cand, s))
    new_k = jnp.where(
        emit_cut,
        cut_b + p.sub_min_skip,
        jnp.where(
            fire_cand,
            bound_cand + p.sub_min_skip,
            jnp.where(fire_trig, kt + p.skip_size, jnp.where(in_block, bend, k)),
        ),
    )
    emit = emit_cut | fire_cand
    bound = jnp.where(emit_cut, cut_b, bound_cand)
    any_event = fire_cut | fire_cand | fire_trig
    return new_k, new_s, emit, bound, any_event


def _scan_wide(candb, oppb, n, p: SeqCDCParams):
    """Baseline step: O(W) vector ops per block."""
    W = p.block_width
    nb = candb.shape[0]
    iota = jnp.arange(W, dtype=jnp.int32)
    T = jnp.int32(p.skip_trigger)

    def step(state, xs):
        k, c, s = state
        cb, ob, bstart = xs
        bend = bstart + W
        in_block = (k < bend) & (s < n)
        o = jnp.maximum(k - bstart, 0)
        active = iota >= o
        pos = bstart + iota
        kc = jnp.min(jnp.where(cb & active, pos, _BIG))
        cum = c + jnp.cumsum((ob & active).astype(jnp.int32))
        kt = jnp.min(jnp.where(ob & active & (cum > T), pos, _BIG))
        new_k, new_s, emit, bound, any_event = _resolve(
            k, c, s, kc, kt, bend, in_block, n, p
        )
        new_c = jnp.where(any_event, 0, jnp.where(in_block, cum[-1], c))
        return (new_k, new_c, new_s), (emit, bound)

    init = (jnp.int32(p.sub_min_skip), jnp.int32(0), jnp.int32(0))
    bstarts = jnp.arange(nb, dtype=jnp.int32) * W
    _, (emits, bounds) = jax.lax.scan(step, init, (candb, oppb, bstarts))
    return emits, bounds


def _scan_wide_packed(candb, oppb, ends, n_row, p: SeqCDCParams,
                      max_chunks: int):
    """Packed-row variant of ``_scan_wide``: many streams share one row.

    The scan state carries a fourth register ``se`` — the end offset of the
    segment the position currently walks — and every ``_resolve`` call sees
    ``se`` where the unpacked scan sees ``n``: the max-size/file-end cut of
    stream ``i`` consults stream ``i``'s end, nothing later.  When an emitted
    bound lands *on* the segment end, ``se`` advances to the next entry of
    ``ends`` and the registers the emit leaves behind (``s = bound``,
    ``k = bound + sub_min_skip``, ``c = 0``) are exactly the init state a
    per-stream run would start the next segment with — the segment reset is
    the emit itself, no extra state.

    Cross-segment leakage cannot happen even though the bitmaps are one
    row-wide vector: the caller clips candidate bits to ``pos <= end - L``
    and opposing bits to ``pos < end - 1`` of *their own* segment, and any
    in-block event position belonging to a later segment sits at or past
    ``se`` while the current segment's cut position ``cut_k`` sits strictly
    before it — ``_resolve`` resolves ties cut-first, so the segment-end cut
    always fires before a later segment's bit can be consumed.  A no-event
    block never contains the live segment's end (the cut would have fired),
    so the opposing-counter carry ``c`` stays segment-pure too.

    Unlike the unpacked scan, one block can host *several* events: with a
    run of tiny segments, each segment-end cut resets the scan position
    just past its own end — arbitrarily many cuts inside one block.  The
    unpacked one-event-per-block invariant
    (``W <= min(skip_size, min_size - L)``) only holds when every reset
    jumps a full ``sub_min_skip``, so each block re-resolves until the scan
    position clears it (every pass either emits a strictly larger bound or
    stops: the inner loop terminates).  Emitted bounds are scattered into
    the carried output directly, as block-level emit slots no longer
    suffice.

    The post-emit scan position is *clamped* to the next pending cut
    position ``se' - (L-1)``: the min-size skip assumes at least
    ``min_size`` bytes remain in the segment, which a tiny next segment
    violates — unclamped, the scan position can overleap several segments
    (or the padded block range entirely, silently dropping their end
    cuts).  The clamp keeps ``k <= cut_k`` everywhere, so every cut fires
    in the block holding its cut position, never behind the scan.

    ``ends``: (G,) int32 nondecreasing exclusive segment ends, padded past
    the last real segment with ``n_row`` (= the row's payload end).  Empty
    segments are duplicate entries and are skipped naturally — the advance
    looks for the *next strictly greater* end.
    """
    W = p.block_width
    nb = candb.shape[0]
    iota = jnp.arange(W, dtype=jnp.int32)
    T = jnp.int32(p.skip_trigger)

    def next_end(x):
        return jnp.min(jnp.where(ends > x, ends, _BIG))

    def step(state, xs):
        cb, ob, bstart = xs
        bend = bstart + W

        def resolve_once(st):
            k, c, s, se, cnt, out, go = st
            in_block = (k < bend) & (s < n_row)
            o = jnp.maximum(k - bstart, 0)
            active = iota >= o
            pos = bstart + iota
            kc = jnp.min(jnp.where(cb & active, pos, _BIG))
            cum = c + jnp.cumsum((ob & active).astype(jnp.int32))
            kt = jnp.min(jnp.where(ob & active & (cum > T), pos, _BIG))
            new_k, new_s, emit, bound, any_event = _resolve(
                k, c, s, kc, kt, bend, in_block, se, p
            )
            new_c = jnp.where(any_event, 0, jnp.where(in_block, cum[-1], c))
            new_se = jnp.where(emit & (bound >= se), next_end(bound), se)
            # clamp the post-emit scan position to the next pending cut
            # (min-size skip may overleap a whole run of tiny segments —
            # and the padded block range entirely; positions before
            # ``new_se - (L-1)`` hold no legal event: in-segment candidate
            # bits are clipped to ``pos <= end - L`` and a skip can never
            # preempt the cut, which wins position ties)
            new_k = jnp.where(
                emit, jnp.minimum(new_k, new_se - (p.seq_length - 1)), new_k
            )
            out = out.at[jnp.where(emit, cnt, max_chunks)].set(
                bound.astype(jnp.int32), mode="drop"
            )
            cnt = cnt + emit.astype(jnp.int32)
            # a late segment-end cut resets the scan *inside* this block:
            # go around again (non-emit events always clear it — a skip
            # lands >= bstart + skip_size >= bend, a no-event pass at bend)
            go = emit & (new_k < bend) & (new_s < n_row)
            return (new_k, new_c, new_s, new_se, cnt, out, go)

        st = jax.lax.while_loop(
            lambda st: st[-1], resolve_once, state + (jnp.bool_(True),)
        )
        return st[:-1], None

    out0 = jnp.full((max_chunks,), _BIG, dtype=jnp.int32)
    se0 = next_end(jnp.int32(0))
    # the same clamp at init: the first segment may be shorter than min_size
    k0 = jnp.minimum(jnp.int32(p.sub_min_skip), se0 - (p.seq_length - 1))
    init = (k0, jnp.int32(0), jnp.int32(0), se0, jnp.int32(0), out0)
    bstarts = jnp.arange(nb, dtype=jnp.int32) * W
    (_, _, _, _, count, out), _ = jax.lax.scan(
        step, init, (candb, oppb, bstarts)
    )
    return out, count


def select_boundaries_packed(
    cand: jax.Array,
    opp: jax.Array,
    ends: jax.Array,
    p: SeqCDCParams,
    *,
    max_chunks: int,
) -> tuple[jax.Array, jax.Array]:
    """Resolve chunk boundaries for a packed row of concatenated streams.

    ``cand``/``opp`` are (S,) row-wide bitmaps already clipped per segment
    (see ``seqcdc.boundaries_packed``); ``ends`` is the (G,) segment-end
    table.  Returns ``(bounds, count)`` in *row* coordinates: ascending
    exclusive ends with every segment end present exactly once, so
    consecutive differences are exact chunk lengths and a host demux can
    slice per-stream results back out with two searchsorteds.  Only the
    ``wide`` step is provided for packed rows (it is the one the fused
    kernel mirrors block-for-block).
    """
    S = cand.shape[-1]
    n_row = jnp.max(ends)  # dynamic: the row's real payload end
    candb, oppb = _padded_blocks(cand, opp, S, p)
    out, count = _scan_wide_packed(candb, oppb, ends, n_row, p, max_chunks)
    # fix-up: guarantee the final boundary n_row (dynamic here, unlike the
    # unpacked select_boundaries where n is static)
    last = jnp.where(count > 0, out[jnp.maximum(count - 1, 0)], 0)
    need = (last < n_row) & (n_row > 0)
    out = out.at[jnp.where(need, count, max_chunks)].set(n_row, mode="drop")
    count = count + need.astype(jnp.int32)
    return out, count


def _scan_gather(candb, oppb, n, p: SeqCDCParams):
    """Optimized step: O(1) gathers per block.

    Parallel precompute (vectorized over all blocks, runs on the VPU):
      * ``opp_pref``  (nb, W) inclusive prefix sums of the opposing bitmap;
      * ``next_cand`` (nb, W) position of the first candidate at index >= j
        (reverse cumulative min of masked iota);
      * ``mth_opp``   (nb, W) position of the m-th (1-indexed) opposing pair.
    The scan step then resolves events with 4 dynamic gathers.
    """
    W = p.block_width
    nb = candb.shape[0]
    iota = jnp.arange(W, dtype=jnp.int32)
    T = jnp.int32(p.skip_trigger)

    # -- parallel tables ---------------------------------------------------
    opp_i32 = oppb.astype(jnp.int32)
    opp_pref = jnp.cumsum(opp_i32, axis=-1)  # (nb, W) inclusive
    opp_total = opp_pref[:, -1]  # (nb,)

    masked = jnp.where(candb, iota, _BIG)
    # reverse cummin -> first candidate index >= j
    next_cand = jnp.flip(
        jax.lax.associative_scan(jnp.minimum, jnp.flip(masked, axis=-1), axis=-1),
        axis=-1,
    )  # (nb, W), value in [0, W) or _BIG

    # mth_opp[b, m-1] = index of the m-th opposing pair in block b (or _BIG)
    ranks = jnp.where(oppb, opp_pref - 1, _BIG)  # 0-indexed rank at each set bit
    mth_opp = jnp.full((nb, W), _BIG, dtype=jnp.int32)
    mth_opp = mth_opp.at[jnp.arange(nb)[:, None], jnp.clip(ranks, 0, W - 1)].min(
        jnp.where(oppb, iota, _BIG), mode="drop"
    )

    def step(state, xs):
        k, c, s = state
        next_cand_b, opp_pref_b, mth_opp_b, opp_total_b, bstart = xs
        bend = bstart + W
        in_block = (k < bend) & (s < n)
        o = jnp.clip(k - bstart, 0, W - 1)
        # first candidate >= o
        kc_rel = next_cand_b[o]
        kc = jnp.where(kc_rel < _BIG, bstart + kc_rel, _BIG)
        # trigger: first pair with carry + (pref[j] - pref_before_o) > T
        pref_before = jnp.where(o > 0, opp_pref_b[o - 1], 0)
        m = (T - c) + pref_before  # 0-indexed rank of the exceeding pair
        m_clipped = jnp.clip(m, 0, W - 1)
        kt_rel = jnp.where(m < W, mth_opp_b[m_clipped], _BIG)
        kt = jnp.where((kt_rel < _BIG) & (kt_rel >= o), bstart + kt_rel, _BIG)
        new_k, new_s, emit, bound, any_event = _resolve(
            k, c, s, kc, kt, bend, in_block, n, p
        )
        c_pass = c + opp_total_b - pref_before
        new_c = jnp.where(any_event, 0, jnp.where(in_block, c_pass, c))
        return (new_k, new_c, new_s), (emit, bound)

    init = (jnp.int32(p.sub_min_skip), jnp.int32(0), jnp.int32(0))
    bstarts = jnp.arange(nb, dtype=jnp.int32) * W
    _, (emits, bounds) = jax.lax.scan(
        step, init, (next_cand, opp_pref, mth_opp, opp_total, bstarts)
    )
    return emits, bounds


def _scan_event(cand, opp, n, p: SeqCDCParams, max_chunks: int):
    """Event-driven step: O(#chunks + #skips) sequential iterations.

    Beyond-paper optimization (EXPERIMENTS.md SSPerf): instead of scanning
    W-byte blocks (n/W sequential steps), precompute two inclusive prefix
    sums — candidates and opposing pairs — and let a ``lax.while_loop`` jump
    straight from event to event:

      next candidate >= k   = searchsorted(cand_pref, cand_pref[k-1] + 1)
      trigger position      = searchsorted(opp_pref,  opp_pref[k-1] + T - c + 1)

    Sequential steps drop from n/W (e.g. 16384 for 8 MiB at W=512) to the
    event count (~2.5 k for 8 MiB at 8 KiB chunks) and each step is O(log n)
    — the same semantics as the paper's scalar loop, with all O(n) work in
    the two parallel prefix sums.  Bit-identical to the oracle (tested).
    """
    L = p.seq_length
    T = jnp.int32(p.skip_trigger)
    # exclusive prefix sums, length n+1: pref[k] = count in positions < k
    cand_pref = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(cand.astype(jnp.int32))]
    )
    opp_pref = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(opp.astype(jnp.int32))]
    )
    total_cand = cand_pref[-1]
    total_opp = opp_pref[-1]

    def cond(st):
        k, c, s, cnt, out = st
        return (s < n) & (cnt < max_chunks)

    def body(st):
        k, c, s, cnt, out = st
        kk = jnp.clip(k, 0, n)
        cut_b = jnp.minimum(s + p.max_size, n)
        cut_k = cut_b - (L - 1)
        # first candidate at position >= k
        rank_c = cand_pref[kk]
        kc = jnp.where(
            rank_c < total_cand,
            jnp.searchsorted(cand_pref, rank_c + 1, side="left") - 1,
            _BIG,
        )
        # first opposing pair (at >= k) whose running count exceeds T
        rank_o = opp_pref[kk]
        want = rank_o + (T - c) + 1
        kt = jnp.where(
            want <= total_opp,
            jnp.searchsorted(opp_pref, want, side="left") - 1,
            _BIG,
        )
        e_cut = jnp.maximum(cut_k, k)
        fire_cut = (e_cut <= jnp.minimum(kc, kt))
        fire_cand = ~fire_cut & (kc < kt)
        bound = jnp.where(fire_cut, cut_b, kc + L)
        emit = fire_cut | fire_cand
        out = out.at[jnp.where(emit, cnt, max_chunks)].set(bound, mode="drop")
        cnt = cnt + emit.astype(jnp.int32)
        new_s = jnp.where(emit, bound, s)
        new_k = jnp.where(emit, bound + p.sub_min_skip, kt + p.skip_size)
        new_c = jnp.int32(0)  # every event resets the counter
        return (new_k, new_c, new_s, cnt, out)

    out0 = jnp.full((max_chunks,), _BIG, dtype=jnp.int32)
    init = (jnp.int32(p.sub_min_skip), jnp.int32(0), jnp.int32(0), jnp.int32(0), out0)
    _, _, _, cnt, out = jax.lax.while_loop(cond, body, init)
    return out, cnt


def select_boundaries(
    cand: jax.Array,
    opp: jax.Array,
    n: int,
    p: SeqCDCParams,
    *,
    step_impl: Literal["wide", "gather", "event"] = "wide",
    max_chunks: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Resolve chunk boundaries from bitmaps.

    Returns ``(bounds, count)``: ``bounds`` is ``(max_chunks,)`` int32 of
    exclusive end offsets (sentinel ``1<<30`` past ``count``), sorted
    ascending, last real entry == n.
    """
    if max_chunks is None:
        max_chunks = max_chunks_for(n, p)
    if step_impl == "event":
        out, count = _scan_event(cand, opp, n, p, max_chunks)
        # fix-up: guarantee the final boundary n (while_loop emits it via the
        # cut path, but an n == 0 stream emits nothing)
        last = jnp.where(count > 0, out[jnp.maximum(count - 1, 0)], 0)
        need = (last < n) & (n > 0)
        out = out.at[jnp.where(need, count, max_chunks)].set(n, mode="drop")
        return out, count + need.astype(jnp.int32)
    candb, oppb = _padded_blocks(cand, opp, n, p)
    if step_impl == "wide":
        emits, bounds = _scan_wide(candb, oppb, n, p)
    elif step_impl == "gather":
        emits, bounds = _scan_gather(candb, oppb, n, p)
    else:
        raise ValueError(step_impl)
    count = jnp.sum(emits.astype(jnp.int32))
    idx = jnp.cumsum(emits.astype(jnp.int32)) - 1
    out = jnp.full((max_chunks,), _BIG, dtype=jnp.int32)
    out = out.at[jnp.where(emits, idx, max_chunks)].set(
        bounds.astype(jnp.int32), mode="drop"
    )
    # fix-up: guarantee the final boundary n (no-op when already emitted)
    last = jnp.where(count > 0, out[jnp.maximum(count - 1, 0)], 0)
    need = (last < n) & (n > 0)
    out = out.at[jnp.where(need, count, max_chunks)].set(n, mode="drop")
    count = count + need.astype(jnp.int32)
    return out, count
