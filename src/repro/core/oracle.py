"""Normative SeqCDC oracles (host-side, numpy).

Two exact-equivalent implementations of the semantics in DESIGN.md SS4:

* :func:`boundaries_slow` — a direct byte-at-a-time transcription of the
  sequential algorithm (SSIII of the paper).  This is the ground truth every
  other implementation (numpy event-driven, lax.scan block automaton,
  lax.while_loop, Pallas-backed two-phase) is property-tested against.
* :func:`boundaries_numpy` — an event-driven vectorized version used for
  host-side ingest at corpus scale: precomputes the candidate/opposing bitmaps
  once, then jumps from event to event with prefix sums instead of scanning
  byte by byte.  O(#chunks + #skips) python iterations instead of O(bytes).

Boundary convention: *exclusive* end offsets; chunk i is
``data[bounds[i-1]:bounds[i]]`` with ``bounds[-1] == len(data)``.
"""
from __future__ import annotations

import numpy as np

from .params import DECREASING, INCREASING, SeqCDCParams


def _as_u8(data) -> np.ndarray:
    arr = np.asarray(data)
    if arr.dtype != np.uint8:
        arr = arr.astype(np.uint8)
    return arr.reshape(-1)


def pair_flags(data: np.ndarray, mode: str) -> tuple[np.ndarray, np.ndarray]:
    """(forward, opposing) pair bitmaps, each of length ``len(data)`` .

    ``forward[k]`` is True iff pair (b[k], b[k+1]) is ordered in the target
    direction, ``opposing[k]`` iff ordered against it.  Index ``n-1`` is
    padded False (no pair starts there).
    """
    d = _as_u8(data)
    n = d.shape[0]
    fwd = np.zeros(n, dtype=bool)
    opp = np.zeros(n, dtype=bool)
    if n >= 2:
        gt = d[1:] > d[:-1]
        lt = d[1:] < d[:-1]
        if mode == INCREASING:
            fwd[: n - 1], opp[: n - 1] = gt, lt
        elif mode == DECREASING:
            fwd[: n - 1], opp[: n - 1] = lt, gt
        else:
            raise ValueError(mode)
    return fwd, opp


def candidate_flags(data: np.ndarray, seq_length: int, mode: str) -> np.ndarray:
    """cand[k] = 1 iff bytes k..k+L-1 are strictly monotone (run *starts* at k)."""
    d = _as_u8(data)
    n = d.shape[0]
    fwd, _ = pair_flags(d, mode)
    cand = np.zeros(n, dtype=bool)
    if n >= seq_length:
        m = n - seq_length + 1
        acc = fwd[:m].copy()
        for j in range(1, seq_length - 1):
            acc &= fwd[j : j + m]
        cand[:m] = acc
    return cand


def boundaries_slow(data, p: SeqCDCParams) -> list[int]:
    """Byte-at-a-time normative oracle.  Small inputs only (tests)."""
    d = _as_u8(data)
    n = d.shape[0]
    if n == 0:
        return []
    L = p.seq_length
    inc_mode = p.mode == INCREASING
    bounds: list[int] = []
    s = 0
    while s < n:
        k = s + p.sub_min_skip
        c = 0
        boundary = None
        while boundary is None:
            if k + L > s + p.max_size:  # max-size cut (checked first)
                boundary = min(s + p.max_size, n)
                break
            if k + L > n:  # file end
                boundary = n
                break
            win = d[k : k + L]
            if inc_mode:
                is_cand = bool(np.all(win[1:] > win[:-1]))
                is_opp = d[k + 1] < d[k]
            else:
                is_cand = bool(np.all(win[1:] < win[:-1]))
                is_opp = d[k + 1] > d[k]
            if is_cand:
                boundary = k + L
                break
            if is_opp:
                c += 1
                if c > p.skip_trigger:
                    k += p.skip_size
                    c = 0
                    continue
            k += 1
        bounds.append(boundary)
        s = boundary
    return bounds


def boundaries_numpy(data, p: SeqCDCParams) -> np.ndarray:
    """Event-driven exact oracle: O(#events) python steps.

    Precomputes the candidate bitmap, the opposing-pair prefix sum, and a
    "position of the m-th opposing pair" table, then resolves each chunk by
    jumping between (candidate | trigger | cut) events with searchsorted-free
    gathers.  Bit-identical to :func:`boundaries_slow` (tested).
    """
    d = _as_u8(data)
    n = d.shape[0]
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    L = p.seq_length
    cand = candidate_flags(d, L, p.mode)
    _, opp = pair_flags(d, p.mode)

    cand_pos = np.flatnonzero(cand)  # sorted candidate start positions
    opp_pos = np.flatnonzero(opp)  # sorted opposing-pair positions
    # opp_pref[k] = number of opposing pairs at positions < k
    # (= np.searchsorted(opp_pos, k), done incrementally below)

    bounds: list[int] = []
    s = 0
    T = p.skip_trigger
    while s < n:
        k = s + p.sub_min_skip
        c = 0
        while True:
            cut_k = min(s + p.max_size, n) - L + 1  # first scan pos that cuts
            cut_b = min(s + p.max_size, n)
            if k >= cut_k:
                bounds.append(cut_b)
                s = cut_b
                break
            # next candidate at position >= k
            ci = np.searchsorted(cand_pos, k)
            kc = int(cand_pos[ci]) if ci < cand_pos.size else n + p.max_size
            # position of the (T - c + 1)-th opposing pair at position >= k
            oi = np.searchsorted(opp_pos, k)
            ti = oi + (T - c)  # 0-indexed position of the pair that *exceeds* T
            kt = int(opp_pos[ti]) if ti < opp_pos.size else n + p.max_size
            event = min(kc, kt, cut_k)
            if event == cut_k and cut_k <= min(kc, kt):
                bounds.append(cut_b)
                s = cut_b
                break
            if kc < kt:  # boundary
                bounds.append(kc + L)
                s = kc + L
                break
            # trigger: skip
            k = kt + p.skip_size
            c = 0
        # loop continues with next chunk
    return np.asarray(bounds, dtype=np.int64)


def chunk_lengths(bounds, n: int | None = None) -> np.ndarray:
    b = np.asarray(bounds, dtype=np.int64).reshape(-1)
    if b.size == 0:
        return np.zeros(0, dtype=np.int64)
    return np.diff(np.concatenate([[0], b]))
