"""Hashless CDC baselines: AE (Asymmetric Extremum) and RAM.

Native variants are one-pass per-byte ``lax.scan`` automatons; vectorized
variants use the VectorCDC decomposition (DESIGN.md SS2): strict prefix maxima
give the extreme-point sequence directly, so

  * AE boundary  = first strict prefix-maximum p whose *next* strict maximum
    is more than ``w`` bytes away (no byte in (p, p+w] exceeds it);
  * RAM boundary = first byte >= max(first-w-byte window) past the window,

both of which are bulk array operations.  The Pallas ``block_max`` kernel
(kernels/extremum.py) provides the per-block maxima used to skip cold blocks
in the JAX path.

min-size handling (the paper applies min/max to all algorithms, SSVI): a
boundary whose end would fall before ``s + min_size`` is *deferred* — it fires
at ``s + min_size`` unless a new extreme (AE) supersedes it first.  Both
substrates implement this identically (tested bit-equal).
"""
from __future__ import annotations

import math

import numpy as np

from ..chunker import Chunker, register

_E_FACTOR = math.e / (math.e - 1.0)  # AE: E[chunk] ~ w * e/(e-1) on random data


def _ae_window(avg_size: int) -> int:
    return max(64, int(round(avg_size / _E_FACTOR)))


def _ram_window(avg_size: int) -> int:
    # RAM: E[chunk] ~ w + E[geom] ~ w + 256 for large windows on random data
    return max(64, avg_size - 256)


class _HashlessBase(Chunker):
    def __init__(self, avg_size=8192, window: int | None = None, **_):
        super().__init__(avg_size)
        self.window = window or self._default_window(avg_size)

    @staticmethod
    def _default_window(avg_size: int) -> int:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# AE
# ---------------------------------------------------------------------------


@register("ae")
class AEChunker(_HashlessBase):
    """AE, vectorized via strict prefix maxima (VectorCDC extreme-search)."""

    name = "ae"
    _default_window = staticmethod(_ae_window)

    def _boundaries(self, data):
        n = int(data.size)
        w = self.window
        bounds = []
        s = 0
        while s < n:
            cut = min(s + self.max_size, n)
            seg = data[s:cut].astype(np.int32)
            pm = np.maximum.accumulate(seg)
            prev = np.concatenate([[-1], pm[:-1]])
            ext = np.flatnonzero(seg > prev)  # strict prefix maxima positions
            nxt = np.concatenate([ext[1:], [1 << 30]])
            t = np.maximum(ext + w, self.min_size - 1)  # deferred fire time
            ok = (nxt > t) & (t + 1 <= cut - s)
            hit = np.flatnonzero(ok)
            if hit.size:
                bounds.append(s + int(t[hit[0]]) + 1)
            else:
                bounds.append(cut)
            s = bounds[-1]
        return np.asarray(bounds, dtype=np.int64)


@register("ae_seq")
class AESeqChunker(AEChunker):
    """AE, native one-pass per-byte scan."""

    name = "ae_seq"

    def _boundaries(self, data):
        import jax
        import jax.numpy as jnp

        n = int(data.size)
        w = self.window
        mn, mx = self.min_size, self.max_size
        cache = self.__dict__.setdefault("_scan_cache", {})
        run = cache.get(n)
        if run is None:

            @jax.jit
            def run(d8):
                d32 = d8.astype(jnp.int32)

                def step(st, b):
                    rel, ev, ep = st
                    rel = rel + 1
                    is_ext = b > ev
                    ev = jnp.where(is_ext, b, ev)
                    ep = jnp.where(is_ext, rel, ep)
                    fire = (rel - ep >= w) & (rel + 1 >= mn)
                    end = fire | (rel + 1 >= mx)
                    rel = jnp.where(end, -1, rel)
                    ev = jnp.where(end, -1, ev)
                    ep = jnp.where(end, 0, ep)
                    return (rel, ev, ep), end

                init = (jnp.int32(-1), jnp.int32(-1), jnp.int32(0))
                _, ends = jax.lax.scan(step, init, d32)
                return ends

            cache[n] = run
        ends = np.asarray(run(np.asarray(data, dtype=np.uint8)))
        bounds = (np.flatnonzero(ends) + 1).astype(np.int64)
        if bounds.size == 0 or bounds[-1] != n:
            bounds = np.concatenate([bounds, [n]])
        return bounds


# ---------------------------------------------------------------------------
# RAM
# ---------------------------------------------------------------------------


@register("ram")
class RAMChunker(_HashlessBase):
    """RAM, vectorized: window max + first-exceed search (VectorCDC range scan)."""

    name = "ram"
    _default_window = staticmethod(_ram_window)

    def _boundaries(self, data):
        n = int(data.size)
        w = self.window
        bounds = []
        s = 0
        while s < n:
            cut = min(s + self.max_size, n)
            wend = min(s + w, cut)
            m = int(data[s:wend].max()) if wend > s else 0
            start = s + max(w, self.min_size - 1)
            if start < cut:
                seg = data[start:cut]
                hits = np.flatnonzero(seg >= m)
                if hits.size:
                    bounds.append(start + int(hits[0]) + 1)
                    s = bounds[-1]
                    continue
            bounds.append(cut)
            s = cut
        return np.asarray(bounds, dtype=np.int64)


@register("ram_seq")
class RAMSeqChunker(RAMChunker):
    """RAM, native one-pass per-byte scan."""

    name = "ram_seq"

    def _boundaries(self, data):
        import jax
        import jax.numpy as jnp

        n = int(data.size)
        w = self.window
        mn, mx = self.min_size, self.max_size
        cache = self.__dict__.setdefault("_scan_cache", {})
        run = cache.get(n)
        if run is None:

            @jax.jit
            def run(d8):
                d32 = d8.astype(jnp.int32)

                def step(st, b):
                    rel, m = st
                    rel = rel + 1
                    in_win = rel < w
                    m = jnp.where(in_win, jnp.maximum(m, b), m)
                    fire = (~in_win) & (b >= m) & (rel + 1 >= mn)
                    end = fire | (rel + 1 >= mx)
                    rel = jnp.where(end, -1, rel)
                    m = jnp.where(end, 0, m)
                    return (rel, m), end

                init = (jnp.int32(-1), jnp.int32(0))
                _, ends = jax.lax.scan(step, init, d32)
                return ends

            cache[n] = run
        ends = np.asarray(run(np.asarray(data, dtype=np.uint8)))
        bounds = (np.flatnonzero(ends) + 1).astype(np.int64)
        if bounds.size == 0 or bounds[-1] != n:
            bounds = np.concatenate([bounds, [n]])
        return bounds
