"""CDC baseline algorithms (paper SSVI "Alternatives").

Importing this package registers every baseline with core.chunker's registry:
fixed (XC), gear[_seq], crc[_seq], rabin[_seq], fastcdc[_seq], tttd,
ae[_seq], ram[_seq] — plus seqcdc variants registered by core.chunker itself.
"""
from . import hash_based, hashless  # noqa: F401
from . import linear_hash, selectors  # noqa: F401
