"""Boundary selection from candidate bitmaps (shared by hash-based baselines).

Hash-based CDC (Rabin/CRC/Gear) reduces, after the two-phase split, to:
given a *position-independent* boundary bitmap (``h & mask == 0``), select
boundaries sequentially subject to min/max chunk sizes.  That is exactly the
SeqCDC block automaton with no skip trigger and run length 1, so we reuse
``core.automaton`` via a light parameter shim instead of a second scan.

Conventions: a set bit at position k means "chunk may end at k+1" (the hash
window ends at byte k).  min/max semantics: first admissible end is
``s + min_size``; if no match fires before ``s + max_size``, cut there.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .. import automaton


@dataclasses.dataclass(frozen=True)
class SelectorParams:
    """Duck-typed stand-in for SeqCDCParams accepted by core.automaton."""

    min_size: int
    max_size: int
    seq_length: int = 1  # boundary = bit position + 1
    skip_trigger: int = 1 << 30  # never triggers
    skip_size: int = 1 << 20

    @property
    def sub_min_skip(self) -> int:
        return self.min_size - self.seq_length

    @property
    def block_width(self) -> int:
        import math

        lim = min(self.skip_size, self.min_size - self.seq_length)
        return min(1 << int(math.floor(math.log2(lim))), 1024)


def select_jax(bitmap, n: int, min_size: int, max_size: int, step_impl="wide"):
    """(bounds, count) from a jnp bool bitmap (bit k => boundary k+1)."""
    import jax.numpy as jnp

    p = SelectorParams(min_size=min_size, max_size=max_size)
    opp = jnp.zeros_like(bitmap)
    return automaton.select_boundaries(bitmap, opp, n, p, step_impl=step_impl)


def select_numpy(match_pos: np.ndarray, n: int, min_size: int, max_size: int):
    """Event-driven selection from sorted match positions (bit k => end k+1)."""
    bounds = []
    s = 0
    while s < n:
        cut = min(s + max_size, n)
        lo = np.searchsorted(match_pos, s + min_size - 1)  # k >= s+min-1
        k = int(match_pos[lo]) if lo < match_pos.size else n + max_size
        if k + 1 <= cut and k + 1 >= s + min_size:
            bounds.append(k + 1)
            s = k + 1
        else:
            bounds.append(cut)
            s = cut
    return np.asarray(bounds, dtype=np.int64)


def select_two_region_numpy(
    small_pos: np.ndarray,
    large_pos: np.ndarray,
    n: int,
    min_size: int,
    avg_size: int,
    max_size: int,
):
    """FastCDC-style normalized selection (NC levels): small mask (harder)
    in [s+min, s+avg), large mask (easier) in [s+avg, s+max)."""
    bounds = []
    s = 0
    while s < n:
        cut = min(s + max_size, n)
        # region 1: end in [s+min, s+avg)  <=> k in [s+min-1, s+avg-1)
        lo = np.searchsorted(small_pos, s + min_size - 1)
        k1 = int(small_pos[lo]) if lo < small_pos.size else n + max_size
        if k1 + 1 < s + avg_size and k1 + 1 <= cut:
            bounds.append(k1 + 1)
            s = k1 + 1
            continue
        # region 2: end in [s+avg, s+max)
        lo = np.searchsorted(large_pos, s + avg_size - 1)
        k2 = int(large_pos[lo]) if lo < large_pos.size else n + max_size
        if k2 + 1 <= cut:
            bounds.append(k2 + 1)
            s = k2 + 1
            continue
        bounds.append(cut)
        s = cut
    return np.asarray(bounds, dtype=np.int64)


def select_tttd_numpy(
    primary_pos: np.ndarray,
    backup_pos: np.ndarray,
    n: int,
    min_size: int,
    max_size: int,
):
    """TTTD: primary divisor boundary if found in [min, max); else the *last*
    backup-divisor match in the range; else cut at max."""
    bounds = []
    s = 0
    while s < n:
        cut = min(s + max_size, n)
        lo = np.searchsorted(primary_pos, s + min_size - 1)
        k = int(primary_pos[lo]) if lo < primary_pos.size else n + max_size
        if k + 1 <= cut:
            bounds.append(k + 1)
            s = k + 1
            continue
        # last backup match with end in [s+min, cut]
        lo = np.searchsorted(backup_pos, s + min_size - 1)
        hi = np.searchsorted(backup_pos, cut - 1, side="right")
        if hi > lo:
            kb = int(backup_pos[hi - 1])
            bounds.append(kb + 1)
            s = kb + 1
            continue
        bounds.append(cut)
        s = cut
    return np.asarray(bounds, dtype=np.int64)
