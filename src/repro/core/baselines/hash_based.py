"""Hash-based CDC baselines: GEAR, CRC, RC (Rabin), FastCDC, TTTD.

Each algorithm ships in two substrates (paper SSVI "Alternatives"):

* ``<name>_seq`` — *native*: one ``lax.scan`` step per byte carrying the
  rolling register, the paper's unaccelerated scalar loop.
* ``<name>``     — *vectorized*: position-independent hash bitmap computed in
  bulk (per-offset-table window sum / Pallas Gear kernel), boundaries selected
  by the shared automaton — the SS-CDC two-stage design adapted to TPU.

Both substrates share one hash definition (continuous over the stream, no
per-chunk reset; identical to reset semantics once the window washes out,
which min_size >= window guarantees) so they are bit-identical — tested.
"""
from __future__ import annotations

import functools
import math

import numpy as np

from ..chunker import Chunker, register
from . import linear_hash as lh
from . import selectors


def _bits_for(avg_size: int) -> int:
    return int(round(math.log2(avg_size)))


# ---------------------------------------------------------------------------
# native per-byte scan (shared)
# ---------------------------------------------------------------------------


def _scan_native(
    data_np, window: int, update, match, min_size: int, max_size: int, cache=None
):
    """Generic per-byte lax.scan chunker.  update(h,b_in,b_out); match(h,rel).

    ``cache`` (a dict owned by the chunker instance) memoizes the jitted scan
    per input length so repeated calls hit the jit cache.
    """
    import jax
    import jax.numpy as jnp

    n = int(data_np.size)
    run = cache.get(n) if cache is not None else None
    if run is None:

        @jax.jit
        def run(d8):
            d32 = d8.astype(jnp.int32)
            idx = jnp.arange(n)
            b_out = jnp.where(idx >= window, jnp.roll(d32, window), 0)

            def step(st, xs):
                h, rel = st
                bi, bo = xs
                h = update(h, bi, bo)
                rel = rel + 1
                end = (match(h, rel) & (rel >= min_size)) | (rel >= max_size)
                rel = jnp.where(end, 0, rel)
                return (h, rel), end

            (_, _), ends = jax.lax.scan(
                step, (jnp.uint32(0), jnp.int32(0)), (d32, b_out)
            )
            return ends

        if cache is not None:
            cache[n] = run

    ends = np.asarray(run(jnp.asarray(data_np)))
    bounds = (np.flatnonzero(ends) + 1).astype(np.int64)
    if bounds.size == 0 or bounds[-1] != n:
        bounds = np.concatenate([bounds, [n]])
    return bounds


# ---------------------------------------------------------------------------
# GEAR
# ---------------------------------------------------------------------------


@register("gear")
class GearChunker(Chunker):
    """Gear chunking, vectorized (window-32 parallel hash + automaton)."""

    name = "gear"

    def __init__(self, avg_size=8192, use_pallas: bool = False,
                 mask_bits: int | None = None, **_):
        super().__init__(avg_size)
        bits = mask_bits or _bits_for(avg_size)
        self.mask_bits = bits
        self.mask = np.uint32(((1 << bits) - 1) << (32 - bits))  # high bits
        self.use_pallas = use_pallas

    def _bitmap(self, data):
        import jax.numpy as jnp

        if self.use_pallas:
            from repro.kernels import ops

            h = ops.gear_hash(jnp.asarray(data))
        else:
            from repro.kernels import ref

            h = ref.gear_hash_parallel(jnp.asarray(data))
        return (h & jnp.uint32(self.mask)) == 0

    def _boundaries(self, data):
        import jax.numpy as jnp

        bitmap = self._bitmap(data)
        bounds, count = selectors.select_jax(
            bitmap, int(data.size), self.min_size, self.max_size
        )
        return np.asarray(bounds)[: int(count)]


@register("gear_seq")
class GearSeqChunker(GearChunker):
    """Gear chunking, native per-byte scan."""

    name = "gear_seq"

    def _boundaries(self, data):
        import jax.numpy as jnp
        from repro.kernels.ref import gear_table

        table = gear_table()
        mask = jnp.uint32(self.mask)

        def update(h, bi, bo):
            return (h << 1) + table[bi]

        def match(h, rel):
            return (h & mask) == 0

        return _scan_native(data, 0, update, match, self.min_size, self.max_size,
                             self.__dict__.setdefault('_scan_cache', {}))


# ---------------------------------------------------------------------------
# CRC and Rabin (windowed linear hashes)
# ---------------------------------------------------------------------------


class _WindowedChunker(Chunker):
    WINDOW = 32

    def __init__(self, avg_size=8192, backend: str = "numpy",
                 mask_bits: int | None = None, **_):
        super().__init__(avg_size)
        bits = mask_bits or _bits_for(avg_size)
        self.mask_bits = bits
        self.mask = np.uint32((1 << bits) - 1)  # low bits (paper SSII-A)
        self.backend = backend

    def _tables(self) -> np.ndarray:
        raise NotImplementedError

    def _boundaries(self, data):
        if self.backend == "numpy":
            h = lh.windowed_hash_np(data, self._tables())
            pos = np.flatnonzero((h & self.mask) == 0)
            return selectors.select_numpy(
                pos, int(data.size), self.min_size, self.max_size
            )
        import jax.numpy as jnp

        h = lh.windowed_hash_jnp(jnp.asarray(data), self._tables())
        bitmap = (h & jnp.uint32(self.mask)) == 0
        bounds, count = selectors.select_jax(
            bitmap, int(data.size), self.min_size, self.max_size
        )
        return np.asarray(bounds)[: int(count)]


@register("crc")
class CRCChunker(_WindowedChunker):
    name = "crc"
    WINDOW = lh.CRC_WINDOW

    def _tables(self):
        return lh.crc_tables(self.WINDOW)


@register("rabin")
class RabinChunker(_WindowedChunker):
    name = "rabin"
    WINDOW = lh.RABIN_WINDOW

    def _tables(self):
        return lh.rabin_tables(self.WINDOW)


@register("crc_seq")
class CRCSeqChunker(CRCChunker):
    """CRC chunking, native rolling scan (byte-step + windowed removal)."""

    name = "crc_seq"

    def _boundaries(self, data):
        import jax.numpy as jnp

        tables = self._tables()
        base = jnp.asarray(lh.crc_byte_table())
        t0 = jnp.asarray(tables[0])
        # removal table: contribution of the byte at offset WINDOW after the
        # x^8 step == zero-extend tables[-1] one more byte.
        last = tables[-1]
        t_out = jnp.asarray(
            ((last << 8) & 0xFFFFFFFF) ^ lh.crc_byte_table()[(last >> 24) & 0xFF]
        )
        mask = jnp.uint32(self.mask)

        def update(h, bi, bo):
            h = ((h << 8) & jnp.uint32(0xFFFFFFFF)) ^ base[(h >> 24) & 0xFF]
            return h ^ t0[bi] ^ t_out[bo]

        def match(h, rel):
            return (h & mask) == 0

        return _scan_native(
            data, self.WINDOW, update, match, self.min_size, self.max_size,
            self.__dict__.setdefault('_scan_cache', {}),
        )


@register("rabin_seq")
class RabinSeqChunker(RabinChunker):
    """Rabin chunking, native rolling scan (x^8 multiply + removal)."""

    name = "rabin_seq"

    def _boundaries(self, data):
        import jax.numpy as jnp

        tables = self._tables()
        red8 = jnp.asarray(lh.rabin_red8())
        t0 = jnp.asarray(tables[0])
        last = tables[-1]
        # removal: (v * x^(8*WINDOW)) mod P = x^8-step of tables[-1]
        t_out_np = np.zeros(256, dtype=np.uint32)
        for v in range(256):
            t_out_np[v] = lh._gf2_mod(int(last[v]) << 8, lh.RABIN_POLY, 31)
        t_out = jnp.asarray(t_out_np)
        mask = jnp.uint32(self.mask)

        def update(h, bi, bo):
            h31 = ((h << 8) & jnp.uint32(0x7FFFFFFF)) ^ red8[(h >> 23) & 0xFF]
            return h31 ^ t0[bi] ^ t_out[bo]

        def match(h, rel):
            return (h & mask) == 0

        return _scan_native(
            data, self.WINDOW, update, match, self.min_size, self.max_size,
            self.__dict__.setdefault('_scan_cache', {}),
        )


# ---------------------------------------------------------------------------
# FastCDC (gear + sub-minimum skipping + 2-level normalization)
# ---------------------------------------------------------------------------


@register("fastcdc")
class FastCDCChunker(Chunker):
    """FastCDC NC=2, vectorized: gear bitmap x 2 masks + two-region select."""

    name = "fastcdc"

    def __init__(self, avg_size=8192, use_pallas: bool = False,
                 mask_bits: int | None = None, **_):
        super().__init__(avg_size)
        bits = mask_bits or _bits_for(avg_size)
        self.mask_bits = bits
        self.mask_s = np.uint32(lh.spread_mask(bits + 2, seed=7))
        self.mask_l = np.uint32(lh.spread_mask(max(bits - 2, 1), seed=11))
        self.use_pallas = use_pallas

    def _hash(self, data):
        import jax.numpy as jnp

        if self.use_pallas:
            from repro.kernels import ops

            return ops.gear_hash(jnp.asarray(data))
        from repro.kernels import ref

        return ref.gear_hash_parallel(jnp.asarray(data))

    def _boundaries(self, data):
        h = np.asarray(self._hash(data))
        small = np.flatnonzero((h & self.mask_s) == 0)
        large = np.flatnonzero((h & self.mask_l) == 0)
        return selectors.select_two_region_numpy(
            small, large, int(data.size), self.min_size, self.avg_size, self.max_size
        )


@register("fastcdc_seq")
class FastCDCSeqChunker(FastCDCChunker):
    """FastCDC, native per-byte scan (hash continuous; skips noted in docs)."""

    name = "fastcdc_seq"

    def _boundaries(self, data):
        import jax.numpy as jnp
        from repro.kernels.ref import gear_table

        table = gear_table()
        ms = jnp.uint32(self.mask_s)
        ml = jnp.uint32(self.mask_l)
        avg = self.avg_size

        def update(h, bi, bo):
            return (h << 1) + table[bi]

        def match(h, rel):
            small = ((h & ms) == 0) & (rel < avg)
            large = ((h & ml) == 0) & (rel >= avg)
            return small | large

        return _scan_native(data, 0, update, match, self.min_size, self.max_size,
                             self.__dict__.setdefault('_scan_cache', {}))


# ---------------------------------------------------------------------------
# TTTD (Rabin + backup divisor)
# ---------------------------------------------------------------------------


@register("tttd")
class TTTDChunker(_WindowedChunker):
    """TTTD, vectorized (primary + backup rabin divisors, backtracking select).

    The backup-divisor backtrack re-scans bytes after a max-size cut, so TTTD
    has no one-pass native scan; we ship the two-phase form only (native cost
    ~= Rabin + one extra compare, see benchmarks notes).
    """

    name = "tttd"
    WINDOW = lh.RABIN_WINDOW

    def __init__(self, avg_size=8192, mask_bits: int | None = None, **kw):
        super().__init__(avg_size, mask_bits=mask_bits, **kw)
        bits = mask_bits or _bits_for(avg_size)
        self.mask_bits = bits
        self.mask = np.uint32((1 << bits) - 1)
        self.mask_backup = np.uint32((1 << max(bits - 1, 1)) - 1)

    def _tables(self):
        return lh.rabin_tables(self.WINDOW)

    def _boundaries(self, data):
        h = lh.windowed_hash_np(data, self._tables())
        primary = np.flatnonzero((h & self.mask) == 0)
        backup = np.flatnonzero((h & self.mask_backup) == 0)
        return selectors.select_tttd_numpy(
            primary, backup, int(data.size), self.min_size, self.max_size
        )
