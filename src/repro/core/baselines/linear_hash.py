"""Windowed linear rolling hashes (Rabin, CRC, Gear) and their parallel form.

Rolling hashes look inherently sequential, but every one used by CDC is
*linear* in its window bytes:

  * Rabin:  h_i = sum_d  b_{i-d} * x^{8d}  mod P      (GF(2) polynomial)
  * CRC:    h_i = xor_d  T_d[b_{i-d}]                  (GF(2), affine-free
            with init=0)
  * Gear:   h_i = sum_d  G[b_{i-d}] << d   (mod 2^32)  (register truncation
            bounds the window to 32 bytes)

so the hash at *every* position is an independent window sum over per-offset
tables: the parallel decomposition used by the vectorized baselines (the TPU
answer to SS-CDC's multi-head AVX rolling, DESIGN.md SS2).  This module builds
the per-offset tables host-side (python ints: exact wraparound, no numpy
overflow traps) and provides numpy/jnp evaluators.

32-bit registers throughout (jnp has no uint64 without x64); chunking quality
depends on mask bit-count, not register width — noted in DESIGN.md SS8.
"""
from __future__ import annotations

import functools

import numpy as np

RABIN_WINDOW = 48
CRC_WINDOW = 32
GEAR_WINDOW = 32

#: x^31 + x^3 + 1 — primitive trinomial over GF(2), degree 31 (fits uint32).
RABIN_POLY = (1 << 31) | (1 << 3) | 1
#: CRC-32 (IEEE 802.3) polynomial, non-reflected form, init=0 for linearity.
CRC_POLY = 0x04C11DB7


def _gf2_mod(val: int, poly: int, deg: int) -> int:
    while val.bit_length() > deg:
        val ^= poly << (val.bit_length() - 1 - deg)
    return val


@functools.lru_cache(maxsize=None)
def rabin_tables(window: int = RABIN_WINDOW) -> np.ndarray:
    """T[d][v] = (v * x^(8d)) mod P  -> (window, 256) uint32."""
    out = np.zeros((window, 256), dtype=np.uint32)
    for d in range(window):
        for v in range(256):
            out[d, v] = _gf2_mod(v << (8 * d), RABIN_POLY, 31)
    return out


@functools.lru_cache(maxsize=None)
def rabin_red8() -> np.ndarray:
    """RED[t] = (t << 31) mod P: reduction of the 8 bits (h>>23) that overflow
    degree 31 after the native x^8-multiply step."""
    return np.asarray(
        [_gf2_mod(t << 31, RABIN_POLY, 31) for t in range(256)], dtype=np.uint32
    )


@functools.lru_cache(maxsize=None)
def crc_byte_table() -> np.ndarray:
    """Non-reflected CRC-32 byte-step table (init 0, no final xor)."""
    out = np.zeros(256, dtype=np.uint32)
    for v in range(256):
        r = v << 24
        for _ in range(8):
            r = ((r << 1) ^ CRC_POLY) & 0xFFFFFFFF if r & 0x80000000 else (r << 1) & 0xFFFFFFFF
        out[v] = r
    return out


@functools.lru_cache(maxsize=None)
def crc_tables(window: int = CRC_WINDOW) -> np.ndarray:
    """T[d][v] = CRC register after byte v followed by d zero bytes."""
    base = crc_byte_table()
    out = np.zeros((window, 256), dtype=np.uint32)
    out[0] = base
    for d in range(1, window):
        prev = out[d - 1]
        out[d] = ((prev << 8) & 0xFFFFFFFF) ^ base[(prev >> 24) & 0xFF]
    return out


def windowed_hash_np(data: np.ndarray, tables: np.ndarray) -> np.ndarray:
    """h[i] = xor_d T[d][b[i-d]] (missing terms at stream head omitted)."""
    d8 = np.asarray(data, dtype=np.uint8)
    n = d8.shape[0]
    w = tables.shape[0]
    h = np.zeros(n, dtype=np.uint32)
    for d in range(min(w, n)):
        contrib = tables[d][d8[: n - d]]
        h[d:] ^= contrib
    return h


def windowed_hash_jnp(data, tables_np: np.ndarray):
    """jnp version of :func:`windowed_hash_np` (vectorized baselines)."""
    import jax.numpy as jnp

    d = data.astype(jnp.int32)
    n = d.shape[0]
    w = tables_np.shape[0]
    tables = jnp.asarray(tables_np)
    idx = jnp.arange(n)
    h = jnp.zeros(n, dtype=jnp.uint32)
    for j in range(min(w, n)):
        contrib = tables[j][jnp.roll(d, j)]
        h = h ^ jnp.where(idx >= j, contrib, 0)
    return h


def spread_mask(bits: int, seed: int, width: int = 32) -> int:
    """FastCDC-style mask with ``bits`` set positions spread over the word."""
    rng = np.random.default_rng(seed)
    pos = rng.choice(width, size=bits, replace=False)
    m = 0
    for p in pos:
        m |= 1 << int(p)
    return m
