"""Unified chunking API and algorithm registry.

Every CDC algorithm in the framework (SeqCDC and the seven baselines, plus
their vectorized variants) is exposed as a :class:`Chunker` with a common
interface, so the dedup pipeline, the checkpoint store, and the benchmark
harness are algorithm-agnostic — mirroring DedupBench's role in the paper.

``Chunker.chunk(data)`` accepts host bytes/ndarray of any length and returns a
numpy int64 array of exclusive boundary offsets (last == len(data)).
JAX-backed chunkers jit per (length-bucket, params); host chunkers run numpy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from . import automaton, oracle, seqcdc
from .params import SeqCDCParams, derived_params

_REGISTRY: Dict[str, Callable[..., "Chunker"]] = {}


def register(name: str):
    def deco(factory):
        _REGISTRY[name] = factory
        return factory

    return deco


def available() -> list[str]:
    return sorted(_REGISTRY)


def make_chunker(name: str, avg_size: int = 8192, **kw) -> "Chunker":
    """Factory: e.g. make_chunker("seqcdc", 8192), make_chunker("fastcdc", ...)."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown chunker {name!r}; available: {available()}") from None
    return factory(avg_size=avg_size, **kw)


class Chunker:
    """Base: host-facing boundary computation with padding to length buckets."""

    name = "abstract"
    #: rounded-up length buckets to bound jit recompilation for host calls
    BUCKET = 1 << 20

    def __init__(self, avg_size: int):
        self.avg_size = int(avg_size)
        self.min_size = max(1024, self.avg_size // 2)
        self.max_size = 2 * self.avg_size

    # -- subclass hook -----------------------------------------------------
    def _boundaries(self, data: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    # -- public ------------------------------------------------------------
    def chunk(self, data) -> np.ndarray:
        """Exclusive boundary offsets (int64), last == len(data)."""
        arr = np.frombuffer(data, dtype=np.uint8) if isinstance(
            data, (bytes, bytearray, memoryview)
        ) else np.asarray(data, dtype=np.uint8).reshape(-1)
        if arr.size == 0:
            return np.zeros(0, dtype=np.int64)
        out = np.asarray(self._boundaries(arr), dtype=np.int64)
        assert out.size and out[-1] == arr.size, (self.name, out[-5:], arr.size)
        return out

    def chunk_lengths(self, data) -> np.ndarray:
        b = self.chunk(data)
        return np.diff(np.concatenate([[0], b]))


class _SeqCDCBase(Chunker):
    def __init__(self, avg_size: int = 8192, mode: str = "increasing", params=None):
        super().__init__(avg_size)
        self.params: SeqCDCParams = params or derived_params(avg_size, mode)
        self.min_size = self.params.min_size
        self.max_size = self.params.max_size


@register("seqcdc")
class SeqCDCChunker(_SeqCDCBase):
    """Vectorized two-phase SeqCDC (paper's VSEQ analogue)."""

    name = "seqcdc"

    def __init__(self, *a, mask_impl="jnp", step_impl="wide", **kw):
        super().__init__(*a, **kw)
        self.mask_impl = mask_impl
        self.step_impl = step_impl

    def _boundaries(self, data: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        n = data.size
        n_pad = (n + self.BUCKET - 1) // self.BUCKET * self.BUCKET
        padded = np.zeros(n_pad, dtype=np.uint8)
        padded[:n] = data
        # chunk the padded buffer but cap boundaries at n: we pass true n via
        # re-running select on the real length bucketed jit — simplest exact
        # approach: jit keyed on (n_pad,) with n as static arg equal to true n.
        bounds, count = seqcdc.boundaries_two_phase(
            jnp.asarray(padded[:n]),
            self.params,
            mask_impl=self.mask_impl,
            step_impl=self.step_impl,
        )
        return np.asarray(bounds)[: int(count)]


@register("seqcdc_seq")
class SeqCDCSequentialChunker(_SeqCDCBase):
    """Scalar while_loop SeqCDC (paper's unaccelerated SEQ analogue)."""

    name = "seqcdc_seq"

    def _boundaries(self, data: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp

        bounds, count = seqcdc.boundaries_sequential(jnp.asarray(data), self.params)
        return np.asarray(bounds)[: int(count)]


@register("seqcdc_numpy")
class SeqCDCNumpyChunker(_SeqCDCBase):
    """Event-driven numpy oracle (host ingest path, no JAX)."""

    name = "seqcdc_numpy"

    def _boundaries(self, data: np.ndarray) -> np.ndarray:
        return oracle.boundaries_numpy(data, self.params)


@register("fixed")
class FixedChunker(Chunker):
    """Fixed-size chunking (XC in the paper): the space-savings floor."""

    name = "fixed"

    def _boundaries(self, data: np.ndarray) -> np.ndarray:
        n = data.size
        return np.arange(self.avg_size, n + self.avg_size, self.avg_size).clip(
            max=n
        ).astype(np.int64)
