"""Monte-Carlo parameter calibration (paper SSV "Obtaining parameter values").

The paper tunes (SeqLength, SkipTrigger, SkipSize) by simulating on randomized
data until the achieved average chunk size matches the target, then validates
on one real dataset.  We reproduce that methodology for SeqCDC *and* extend it
to every baseline (mask bits / window sizes), so all algorithms are compared
at comparable achieved averages.  ``benchmarks/bench_calibrate.py`` re-runs
the search and prints the table; the frozen results live in
``CALIBRATED`` below and are selected via ``make_chunker(..., calibrated=True)``
equivalents in the benchmark harness.
"""
from __future__ import annotations

import numpy as np

from .chunker import make_chunker
from .params import SeqCDCParams

_SIM_BYTES = 4 << 20

#: Frozen output of the Monte-Carlo search below (4 MiB uniform random, seed 0,
#: regenerate with ``python -m benchmarks.bench_calibrate``).  The search picks
#: SeqLength=6 on random data: strict 6-byte monotone runs occur ~1/720 per
#: byte, which with min_size = avg/2 and skip amplification lands the mean on
#: target, whereas Table I's L=5 (tuned on the paper's real datasets, where
#: monotone runs are rarer than uniform) undershoots on synthetic streams.
#: ``paper_params`` remains available for fidelity runs; benchmarks report the
#: achieved mean for both.
CALIBRATED = {
    4096: {
        "seqcdc": dict(seq_length=6, skip_trigger=40, skip_size=128),
        "gear": dict(mask_bits=11), "crc": dict(mask_bits=11),
        "rabin": dict(mask_bits=11), "fastcdc": dict(mask_bits=11),
        "tttd": dict(mask_bits=11),
        "ae": dict(window=4096), "ram": dict(window=3840),
    },
    8192: {
        "seqcdc": dict(seq_length=6, skip_trigger=55, skip_size=512),
        "gear": dict(mask_bits=12), "crc": dict(mask_bits=12),
        "rabin": dict(mask_bits=12), "fastcdc": dict(mask_bits=12),
        "tttd": dict(mask_bits=12),
        "ae": dict(window=8192), "ram": dict(window=7936),
    },
    16384: {
        "seqcdc": dict(seq_length=6, skip_trigger=40, skip_size=768),
        "gear": dict(mask_bits=13), "crc": dict(mask_bits=13),
        "rabin": dict(mask_bits=13), "fastcdc": dict(mask_bits=13),
        "tttd": dict(mask_bits=13),
        "ae": dict(window=16384), "ram": dict(window=16128),
    },
    32768: {
        "seqcdc": dict(seq_length=6, skip_trigger=50, skip_size=1024),
        "gear": dict(mask_bits=14), "crc": dict(mask_bits=14),
        "rabin": dict(mask_bits=14), "fastcdc": dict(mask_bits=14),
        "tttd": dict(mask_bits=14),
        "ae": dict(window=32768), "ram": dict(window=32640),
    },
}


def calibrated_kwargs(name: str, avg_size: int) -> dict:
    """Frozen calibrated knobs for a chunker family at a standard avg size."""
    fam = name.replace("_seq", "").replace("_numpy", "")
    table = CALIBRATED.get(avg_size, {})
    kw = dict(table.get(fam, {}))
    if fam == "seqcdc" and kw:
        from .params import SeqCDCParams

        p = SeqCDCParams(
            avg_size=avg_size,
            min_size=max(1024, avg_size // 2),
            max_size=2 * avg_size,
            **kw,
        )
        return {"params": p}
    return kw


def calibrated_chunker(name: str, avg_size: int, **extra):
    """make_chunker with the frozen calibrated knobs applied."""
    kw = calibrated_kwargs(name, avg_size)
    kw.update(extra)
    return make_chunker(name, avg_size, **kw)


def _mean_size(chunker, data) -> float:
    lens = chunker.chunk_lengths(data)
    return float(lens.mean()) if lens.size else float("nan")


def _sim_data(seed: int = 0, n: int = _SIM_BYTES) -> np.ndarray:
    return np.random.default_rng(seed).integers(0, 256, n, dtype=np.uint8)


def calibrate_seqcdc(avg_size: int, data: np.ndarray | None = None):
    """Grid search near Table I values; returns the best SeqCDCParams."""
    data = _sim_data() if data is None else data
    best, best_err = None, float("inf")
    min_size = max(1024, avg_size // 2)
    for L in (4, 5, 6):
        for T in (40, 45, 50, 55, 60):
            for K in (128, 256, 384, 512, 768, 1024):
                p = SeqCDCParams(
                    avg_size=avg_size,
                    seq_length=L,
                    skip_trigger=T,
                    skip_size=K,
                    min_size=min_size,
                    max_size=2 * avg_size,
                )
                c = make_chunker("seqcdc_numpy", avg_size, params=p)
                err = abs(_mean_size(c, data) - avg_size)
                if err < best_err:
                    best, best_err = p, err
    return best


def calibrate_scalar(name: str, avg_size: int, key: str, grid, data=None):
    """1-D search over a single knob (mask bits / window) for a baseline."""
    data = _sim_data() if data is None else data
    best, best_err = None, float("inf")
    for v in grid:
        c = make_chunker(name, avg_size, **{key: v})
        err = abs(_mean_size(c, data) - avg_size)
        if err < best_err:
            best, best_err = v, err
    return best
