"""SeqCDC public API: chunk boundary computation in JAX.

Backends (all bit-identical, property-tested against the numpy oracle):

* ``two_phase``  — the TPU-native vectorized pipeline (DESIGN.md SS2):
  phase 1 candidate/opposing bitmaps (jnp reference or Pallas kernel),
  phase 2 W-block ``lax.scan`` automaton (``wide`` or ``gather`` step).
  This is the analogue of the paper's VSEQ.
* ``sequential`` — a ``lax.while_loop`` transcription of the scalar algorithm
  with true data-dependent skipping.  This is the analogue of the paper's
  unaccelerated SEQ and the baseline for the vector-speedup experiments.

Batched use: streams of equal length chunk independently; ``vmap`` over the
leading axis (used by the dedup ingest pipeline to keep the TPU busy).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from . import automaton, masks
from .params import SeqCDCParams

_BIG = jnp.int32(1 << 30)

MaskImpl = Literal["jnp", "pallas"]
StepImpl = Literal["wide", "gather", "event"]


def _compute_masks(data: jax.Array, p: SeqCDCParams, mask_impl: MaskImpl):
    if mask_impl == "jnp":
        return masks.seqcdc_masks(data, p.seq_length, p.mode)
    if mask_impl == "pallas":
        from repro.kernels import ops as kernel_ops

        return kernel_ops.seqcdc_masks(data, p.seq_length, p.mode)
    raise ValueError(mask_impl)


@functools.partial(
    jax.jit, static_argnames=("p", "mask_impl", "step_impl", "max_chunks")
)
def boundaries_two_phase(
    data: jax.Array,
    p: SeqCDCParams,
    *,
    mask_impl: MaskImpl = "jnp",
    step_impl: StepImpl = "wide",
    max_chunks: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Vectorized SeqCDC.  ``data``: (n,) uint8.  Returns (bounds, count)."""
    n = data.shape[-1]
    if n == 0:  # static: an empty stream has no chunks
        mc = max_chunks or automaton.max_chunks_for(n, p)
        return jnp.full((mc,), _BIG, dtype=jnp.int32), jnp.int32(0)
    cand, opp = _compute_masks(data, p, mask_impl)
    return automaton.select_boundaries(
        cand, opp, n, p, step_impl=step_impl, max_chunks=max_chunks
    )


@functools.partial(
    jax.jit, static_argnames=("p", "mask_impl", "max_chunks")
)
def boundaries_packed(
    data: jax.Array,
    seg_end_pos: jax.Array,
    ends: jax.Array,
    p: SeqCDCParams,
    *,
    mask_impl: MaskImpl = "jnp",
    max_chunks: int,
) -> tuple[jax.Array, jax.Array]:
    """Chunk a packed row of concatenated streams, bit-identical per segment.

    ``data``: (S,) uint8 — several streams laid out back to back, zero
    padding after the last.  ``seg_end_pos``: (S,) int32 — for every byte
    position, the exclusive end of the segment it belongs to (the row
    payload end for padding positions).  ``ends``: (G,) int32 nondecreasing
    segment ends, padded with the payload end.

    The row-wide phase-1 bitmaps see cross-segment byte pairs (stream i's
    last byte against stream i+1's first), which a per-stream run never
    compares; clipping candidate bits to ``pos <= end - L`` and opposing
    bits to ``pos < end - 1`` of their own segment removes exactly those,
    leaving every surviving bit equal to the bit the segment's solo run
    would compute.  Phase 2 is the packed automaton
    (``automaton.select_boundaries_packed``), which resets at segment ends.
    Returned bounds are in row coordinates with every segment end present
    exactly once (``wide``-step semantics; packed rows have no ``step_impl``
    selector).
    """
    S = data.shape[-1]
    if S == 0:  # static: an empty row has no chunks
        return jnp.full((max_chunks,), _BIG, dtype=jnp.int32), jnp.int32(0)
    cand, opp = _compute_masks(data, p, mask_impl)
    pos = jnp.arange(S, dtype=jnp.int32)
    cand = cand & (pos <= seg_end_pos - p.seq_length)
    opp = opp & (pos < seg_end_pos - 1)
    return automaton.select_boundaries_packed(
        cand, opp, ends, p, max_chunks=max_chunks
    )


def boundaries_packed_batch(
    data: jax.Array,
    seg_end_pos: jax.Array,
    ends: jax.Array,
    p: SeqCDCParams,
    *,
    mask_impl: MaskImpl = "jnp",
    max_chunks: int,
) -> tuple[jax.Array, jax.Array]:
    """Batched :func:`boundaries_packed` over (B, S) rows / (B, G) ends."""
    fn = functools.partial(
        boundaries_packed, p=p, mask_impl=mask_impl, max_chunks=max_chunks
    )
    return jax.vmap(lambda d, sep, e: fn(d, sep, e))(data, seg_end_pos, ends)


@functools.partial(jax.jit, static_argnames=("p", "max_chunks"))
def boundaries_sequential(
    data: jax.Array, p: SeqCDCParams, *, max_chunks: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Scalar SeqCDC via ``lax.while_loop`` (true data-dependent skipping).

    One loop iteration per *scanned* position: sub-minimum regions and
    content-defined skips advance the position without touching the data —
    exactly the paper's unaccelerated algorithm.
    """
    n = data.shape[-1]
    if max_chunks is None:
        max_chunks = automaton.max_chunks_for(n, p)
    L = p.seq_length
    T = jnp.int32(p.skip_trigger)
    inc = p.mode == "increasing"
    d = data.astype(jnp.uint8)
    lidx = jnp.arange(L - 1)

    def cond(st):
        k, c, s, cnt, out = st
        return s < n

    def body(st):
        k, c, s, cnt, out = st
        cut_b = jnp.minimum(s + p.max_size, n)
        cut_k = cut_b - (L - 1)
        hit_cut = k >= cut_k
        # candidate check: L bytes at k (safe: only used when k + L <= n)
        safe_k = jnp.minimum(k, jnp.int32(max(n - L, 0)))
        win = jax.lax.dynamic_slice(d, (safe_k,), (L,))
        mono = jnp.all(win[1:] > win[:-1]) if inc else jnp.all(win[1:] < win[:-1])
        is_cand = ~hit_cut & mono
        a = d[jnp.minimum(safe_k, n - 2)]
        b = d[jnp.minimum(safe_k + 1, n - 1)]
        is_opp = ~hit_cut & ~is_cand & ((b < a) if inc else (b > a))
        trig = is_opp & (c + 1 > T)

        emit = hit_cut | is_cand
        bound = jnp.where(hit_cut, cut_b, k + L)
        out = out.at[jnp.where(emit, cnt, max_chunks)].set(bound, mode="drop")
        cnt = cnt + emit.astype(jnp.int32)

        new_s = jnp.where(emit, bound, s)
        new_k = jnp.where(
            emit,
            bound + p.sub_min_skip,
            jnp.where(trig, k + p.skip_size, k + 1),
        )
        new_c = jnp.where(emit | trig, 0, c + is_opp.astype(jnp.int32))
        return (new_k, new_c, new_s, cnt, out)

    out0 = jnp.full((max_chunks,), _BIG, dtype=jnp.int32)
    init = (jnp.int32(p.sub_min_skip), jnp.int32(0), jnp.int32(0), jnp.int32(0), out0)
    if n == 0:
        return out0, jnp.int32(0)
    if n < max(L, 2):  # too short for any pair/run: single chunk (static)
        return out0.at[0].set(n), jnp.int32(1)
    _, _, _, cnt, out = jax.lax.while_loop(cond, body, init)
    return out, cnt


def boundaries_batch(
    data: jax.Array,
    p: SeqCDCParams,
    *,
    mask_impl: MaskImpl = "jnp",
    step_impl: StepImpl = "wide",
    max_chunks: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Batched two-phase SeqCDC over (B, n) streams -> ((B, max_chunks), (B,))."""
    fn = functools.partial(
        boundaries_two_phase,
        p=p,
        mask_impl=mask_impl,
        step_impl=step_impl,
        max_chunks=max_chunks or automaton.max_chunks_for(data.shape[-1], p),
    )
    return jax.vmap(fn)(data)


def bounds_to_numpy(bounds, count) -> "list":
    """Strip sentinel padding host-side -> python list(s) of int boundaries.

    Accepts either a single stream's ``(max_chunks,) + scalar count`` (returns
    a flat list) or the batched layout from :func:`boundaries_batch`,
    ``(B, max_chunks) + (B,)`` (returns a list of B lists) — the host-side
    exit point for both the single-stream and batch entry points.
    """
    import numpy as np

    b = np.asarray(bounds)
    c = np.asarray(count)
    if b.ndim == 1:
        return b[: int(c)].astype(np.int64).tolist()
    if b.ndim != 2 or c.shape != b.shape[:1]:
        raise ValueError(f"bad bounds/count shapes: {b.shape} / {c.shape}")
    return [row[: int(k)].astype(np.int64).tolist() for row, k in zip(b, c)]
