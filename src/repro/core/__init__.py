"""repro.core — SeqCDC (the paper's contribution) and the CDC algorithm zoo."""
from .params import (  # noqa: F401
    DECREASING,
    INCREASING,
    SeqCDCParams,
    derived_params,
    paper_params,
)
from .chunker import Chunker, available, make_chunker, register  # noqa: F401
from .seqcdc import (  # noqa: F401
    boundaries_batch,
    boundaries_sequential,
    boundaries_two_phase,
)

# Import baselines for registry side effects.
from . import baselines as _baselines  # noqa: F401,E402
