"""Candidate / opposing bitmap computation in JAX (phase 1 of SeqCDC-TPU).

This is the data-parallel half of the paper's AVX-512 kernel (SSIII-D, Fig. 3),
re-expressed for bulk execution: pairwise shifted compares -> masks M_1..M_{L-1}
-> AND-reduction -> candidate bitmap; one opposite compare -> opposing bitmap.

The canonical jnp implementation lives here; ``kernels/seqcdc_masks.py`` is the
Pallas VMEM-tiled version and ``kernels/ref.py`` re-exports these functions as
its oracle.  Shapes: input ``(..., n)`` uint8, outputs ``(..., n)`` bool.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import DECREASING, INCREASING


def pair_masks(data: jax.Array, mode: str = INCREASING) -> tuple[jax.Array, jax.Array]:
    """(forward, opposing) pair bitmaps of shape ``data.shape``.

    ``forward[..., k]`` == pair (b[k], b[k+1]) ordered with the mode,
    ``opposing[..., k]`` == ordered against it; index n-1 padded False.
    """
    if data.dtype != jnp.uint8:
        data = data.astype(jnp.uint8)
    cur = data[..., :-1]
    nxt = data[..., 1:]
    gt = nxt > cur
    lt = nxt < cur
    pad = [(0, 0)] * (data.ndim - 1) + [(0, 1)]
    gt = jnp.pad(gt, pad)
    lt = jnp.pad(lt, pad)
    if mode == INCREASING:
        return gt, lt
    if mode == DECREASING:
        return lt, gt
    raise ValueError(mode)


def candidate_mask(fwd: jax.Array, seq_length: int) -> jax.Array:
    """AND of ``seq_length - 1`` consecutive forward-pair bits.

    cand[..., k] == run of `seq_length` monotone bytes starts at k.  Equivalent
    to the paper's M_1 & M_2 & ... mask combination; bit k indexes the run
    *start* (paper Fig. 3).  Positions k > n - seq_length are False because
    ``fwd`` is already False-padded at n-1 and we shift False in.
    """
    n = fwd.shape[-1]
    acc = fwd
    for j in range(1, seq_length - 1):
        shifted = jnp.roll(fwd, -j, axis=-1)
        # roll wraps; mask the wrapped tail to False
        idx = jnp.arange(n)
        shifted = jnp.where(idx < n - j, shifted, False)
        acc = acc & shifted
    return acc


def seqcdc_masks(
    data: jax.Array, seq_length: int, mode: str = INCREASING
) -> tuple[jax.Array, jax.Array]:
    """(candidate, opposing) bitmaps for SeqCDC.  Pure-jnp reference."""
    fwd, opp = pair_masks(data, mode)
    return candidate_mask(fwd, seq_length), opp
