"""Fault-tolerant CDC-deduplicated checkpoint store.

The paper's chunking algorithm applied to the framework's own state: every
parameter/optimizer leaf is serialized, chunked with SeqCDC, and stored in a
content-addressed block store.  Between adjacent checkpoints most chunks are
identical (slow-moving weights, byte-shift-resistant boundaries), so step k+1
costs only the *changed* chunks — incremental checkpointing for free, with
dedup factors reported by the store.  This is DESIGN.md SS3's ``checkpoint/``
layer and the paper-representative cell of the roofline/perf study.

Durability contract:
* every block write is atomic (tmp + rename, DirBlockStore);
* a checkpoint becomes visible only when its manifest rename commits;
* ``latest`` is a pointer file updated by atomic rename — a crash at any
  point leaves the newest *committed* checkpoint readable (tested).

Elasticity: manifests record logical leaf paths + shapes + dtypes, never mesh
layout, so a checkpoint saved from one mesh restores onto any other
(``restore_sharded`` device_puts each leaf with the target NamedSharding).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict

import jax
import numpy as np

from repro.core.chunker import make_chunker
from repro.dedup.store import DirBlockStore


def _flatten(tree) -> Dict[str, Any]:
    """Tree -> {path string: leaf} with deterministic, reversible paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = leaf
    return out


def _unflatten(tree_like, flat: Dict[str, Any]):
    """Inverse of _flatten given a structural template tree."""
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = [flat[jax.tree_util.keystr(p)] for p, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(
        self,
        root: str,
        *,
        algorithm: str = "seqcdc",
        avg_chunk: int = 64 * 1024,
        keep: int = 3,
    ):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.store = DirBlockStore(os.path.join(root, "store"))
        self.chunker = make_chunker(algorithm, avg_chunk)
        self.keep = keep
        self._lock = threading.Lock()
        self._async_thread: threading.Thread | None = None

    # -- paths ---------------------------------------------------------------
    def _manifest_path(self, step: int) -> str:
        return os.path.join(self.root, f"manifest-{step:08d}.json")

    @property
    def _latest_path(self) -> str:
        return os.path.join(self.root, "latest")

    def steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.root):
            if fn.startswith("manifest-") and fn.endswith(".json"):
                out.append(int(fn[len("manifest-") : -len(".json")]))
        return sorted(out)

    def latest_step(self) -> int | None:
        try:
            with open(self._latest_path) as f:
                step = int(f.read().strip())
        except (OSError, ValueError):
            return None
        return step if os.path.exists(self._manifest_path(step)) else None

    # -- save ----------------------------------------------------------------
    def _put_leaf(self, arr: np.ndarray) -> Dict[str, Any]:
        raw = np.ascontiguousarray(arr)
        data = raw.tobytes()
        view = np.frombuffer(data, dtype=np.uint8)
        bounds = self.chunker.chunk(view) if view.size else np.zeros(0, np.int64)
        keys = self.store.put_stream(view, bounds) if view.size else []
        return {"shape": list(arr.shape), "dtype": str(arr.dtype), "keys": keys}

    def save(self, step: int, state: Dict[str, Any], extra: Dict | None = None):
        """Synchronous checkpoint.  ``state`` is a dict of pytrees."""
        with self._lock:
            host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
            manifest = {"step": step, "extra": extra or {}, "trees": {}}
            for name, tree in host.items():
                leaves = {}
                for path, leaf in _flatten(tree).items():
                    leaves[path] = self._put_leaf(np.asarray(leaf))
                manifest["trees"][name] = leaves
            self.store.sync_manifest()
            tmp = self._manifest_path(step) + ".tmp"
            with open(tmp, "w") as f:
                json.dump(manifest, f)
            os.replace(tmp, self._manifest_path(step))  # commit point
            tmp = self._latest_path + ".tmp"
            with open(tmp, "w") as f:
                f.write(str(step))
            os.replace(tmp, self._latest_path)
            self._retain()

    def save_async(self, step: int, state, extra=None):
        """Device-get synchronously, write in a background thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._async_thread = threading.Thread(
            target=self.save, args=(step, host, extra), daemon=True
        )
        self._async_thread.start()

    def wait(self):
        if self._async_thread is not None:
            self._async_thread.join()
            self._async_thread = None

    def _retain(self):
        steps = self.steps()
        for step in steps[: -self.keep] if self.keep else []:
            path = self._manifest_path(step)
            with open(path) as f:
                manifest = json.load(f)
            for tree in manifest["trees"].values():
                for meta in tree.values():
                    for key in meta["keys"]:
                        self.store.release(key)
            os.remove(path)
        self.store.sync_manifest()

    # -- restore ---------------------------------------------------------------
    def _get_leaf(self, meta: Dict[str, Any]) -> np.ndarray:
        data = self.store.get_stream(meta["keys"])
        arr = np.frombuffer(data, dtype=np.dtype(meta["dtype"]))
        return arr.reshape(meta["shape"]).copy()

    def restore(self, step: int | None = None, tree_like: Dict | None = None):
        """Returns (step, {name: tree-or-flat-dict}, extra).

        With ``tree_like`` (a dict of structural templates, e.g. abstract
        params), leaves are unflattened into real pytrees; otherwise flat
        ``{path: ndarray}`` dicts are returned.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None, None
        with open(self._manifest_path(step)) as f:
            manifest = json.load(f)
        out = {}
        for name, leaves in manifest["trees"].items():
            flat = {p: self._get_leaf(m) for p, m in leaves.items()}
            if tree_like is not None and name in tree_like:
                out[name] = _unflatten(tree_like[name], flat)
            else:
                out[name] = flat
        return step, out, manifest["extra"]

    def restore_sharded(self, tree_like, shardings, step: int | None = None):
        """Elastic restore: device_put every leaf with the target sharding.

        ``shardings`` mirrors ``tree_like`` (NamedSharding per leaf) for a
        mesh that may differ from the one that saved the checkpoint.
        """
        step, out, extra = self.restore(step, tree_like)
        if step is None:
            return None, None, None
        placed = {}
        for name, tree in out.items():
            sh = shardings[name]
            placed[name] = jax.tree.map(
                lambda leaf, s: jax.device_put(leaf, s), tree, sh
            )
        return step, placed, extra

    # -- accounting ------------------------------------------------------------
    @property
    def dedup_savings(self) -> float:
        return self.store.savings
