"""repro.checkpoint — CDC-deduplicated fault-tolerant checkpointing."""
from .store import CheckpointManager  # noqa: F401
