"""Thread-safe metrics registry: counters, gauges, log-bucketed histograms.

The always-on measurement substrate for the dedup service (the catalog of
every instrumented name lives in docs/OBSERVABILITY.md).  Three metric
kinds, all behind one lock per registry:

* **counters** — monotonically increasing totals (ints or float seconds);
* **gauges**   — last-written values (queue depth, per-bucket occupancy);
* **histograms** — log-bucketed distributions (latencies, sizes) exporting
  count/sum/min/max and p50/p95/p99 without retaining samples.

Histogram buckets are geometric with :data:`BUCKETS_PER_OCTAVE` buckets per
factor of two (ratio ``2**(1/4) ~ 1.19``), so a bucket index is
``ceil(log(v) / log(ratio))`` and a quantile is resolved to the geometric
midpoint of its bucket — at most ~9% relative error, constant memory,
O(1) per observation.  Non-positive observations land in a dedicated
underflow bucket and report as 0.0.

Label convention: a *labeled* metric name is rendered by :func:`labeled`
as ``name{k=v,...}`` with keys sorted, so the same (name, labels) pair is
always the same string and snapshots diff cleanly across runs.  Labeled
series are *capped per family* (the part before the ``{``): once a family
holds ``max_labeled_series`` distinct label combinations, further new
combinations are dropped and counted in ``obs.series_dropped{family=}``
instead of growing the registry without bound (the ``{bucket=,packed=}``
gauge families grow per observed shape, and a hostile or buggy label
value — say a raw stream name — must not OOM a long-lived server).
Unlabeled series and existing labeled series are never dropped.

Snapshots are plain JSON-serializable dicts; :func:`merge_snapshots` folds
many of them (the per-shard-server snapshots gathered over the wire by
``ShardedDedupService.metrics()``) into one aggregate: counters and
histogram buckets sum, gauges sum too (documented — a summed queue depth
is the fleet's total backlog; per-shard values remain in the unmerged
snapshots).

Everything here is stdlib-only: the numpy-only shard server processes
import this module, so it must never pull in jax or numpy.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple

#: geometric histogram resolution: 4 buckets per factor of two
BUCKETS_PER_OCTAVE = 4

_RATIO = 2.0 ** (1.0 / BUCKETS_PER_OCTAVE)
_LOG_RATIO = math.log(_RATIO)

#: bucket index for non-positive observations (sorts below every real one)
_UNDERFLOW = -(10**9)


def bucket_index(value: float) -> int:
    """Index of the geometric bucket ``(ratio**(i-1), ratio**i]`` holding
    ``value``; non-positive values go to the underflow bucket."""
    if value <= 0.0:
        return _UNDERFLOW
    # ceil with a tolerance so exact powers of the ratio stay in their own
    # bucket instead of flipping on float noise
    return math.ceil(math.log(value) / _LOG_RATIO - 1e-9)


def bucket_value(index: int) -> float:
    """Representative value (geometric midpoint) of a bucket index."""
    if index == _UNDERFLOW:
        return 0.0
    return _RATIO ** (index - 0.5)


class _Histogram:
    __slots__ = ("count", "total", "vmin", "vmax", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets: Dict[int, int] = {}

    def observe(self, value: float):
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        i = bucket_index(value)
        self.buckets[i] = self.buckets.get(i, 0) + 1


def _quantiles(buckets: Dict[int, int], count: int,
               qs: Iterable[float]) -> List[float]:
    """Quantiles resolved to bucket midpoints from a bucket->count map."""
    if not count:
        return [0.0 for _ in qs]
    order = sorted(buckets)
    out = []
    for q in qs:
        rank = q * count
        cum = 0.0
        val = bucket_value(order[-1])
        for i in order:
            cum += buckets[i]
            if cum >= rank:
                val = bucket_value(i)
                break
        out.append(val)
    return out


def _hist_export(count: int, total: float, vmin: float, vmax: float,
                 buckets: Dict[int, int]) -> dict:
    p50, p95, p99 = _quantiles(buckets, count, (0.50, 0.95, 0.99))
    return {
        "count": count,
        "sum": total,
        "min": vmin if count else 0.0,
        "max": vmax if count else 0.0,
        "mean": total / count if count else 0.0,
        "p50": p50,
        "p95": p95,
        "p99": p99,
        # JSON object keys must be strings; kept sorted for stable diffs
        "buckets": {str(i): buckets[i] for i in sorted(buckets)},
    }


def labeled(name: str, **labels) -> str:
    """Render ``name{k=v,...}`` with sorted keys — the one label syntax."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class _Timer:
    """``with registry.time("x.latency_s"):`` — observes elapsed seconds."""

    __slots__ = ("_reg", "_name", "_t0")

    def __init__(self, reg: "MetricsRegistry", name: str):
        self._reg = reg
        self._name = name

    def __enter__(self) -> "_Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._reg.observe(self._name, time.perf_counter() - self._t0)
        return False


class MetricsRegistry:
    """One process-visible bag of counters/gauges/histograms (thread-safe).

    Each service instance owns a registry (so tests don't cross-pollute);
    each shard server process owns one, exported over the wire by the
    ``metrics`` op.  All mutators are O(1) under one lock — cheap enough
    for the per-dispatch / per-RPC / per-writer-task granularity the
    service instruments at (the overhead contract in
    docs/OBSERVABILITY.md), but not for per-byte loops.
    """

    #: default per-family cap on distinct labeled series (far above the
    #: widest legitimate family — ~40 length buckets x 2 packed states)
    DEFAULT_MAX_LABELED_SERIES = 256

    def __init__(self, max_labeled_series: int = DEFAULT_MAX_LABELED_SERIES):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Histogram] = {}
        self._max_labeled_series = max_labeled_series
        # per-kind family -> count of distinct labeled series admitted
        self._families: Dict[str, Dict[str, int]] = {
            "counter": {}, "gauge": {}, "hist": {},
        }

    def _admit(self, kind: str, store: dict, name: str) -> bool:
        """Whether a write to ``name`` may proceed (caller holds the lock).

        Existing series and unlabeled names (a fixed, code-enumerated set)
        always pass; a *new* labeled series passes only while its family is
        under the cap, else it is dropped and tallied in
        ``obs.series_dropped{family=}`` (written directly to the counter
        store — the overflow counter itself is exempt from the guard).
        """
        if name in store:
            return True
        brace = name.find("{")
        if brace < 0:
            return True
        family = name[:brace]
        fams = self._families[kind]
        n = fams.get(family, 0)
        if n >= self._max_labeled_series:
            dropped = labeled("obs.series_dropped", family=family)
            self._counters[dropped] = self._counters.get(dropped, 0) + 1
            return False
        fams[family] = n + 1
        return True

    # -- mutators ---------------------------------------------------------------
    def inc(self, name: str, value: float = 1):
        """Add ``value`` (default 1) to a monotonic counter."""
        with self._lock:
            if self._admit("counter", self._counters, name):
                self._counters[name] = self._counters.get(name, 0) + value

    def set_gauge(self, name: str, value: float):
        """Record the current value of a gauge (last write wins)."""
        with self._lock:
            if self._admit("gauge", self._gauges, name):
                self._gauges[name] = value

    def observe(self, name: str, value: float):
        """Add one observation to a log-bucketed histogram."""
        with self._lock:
            if not self._admit("hist", self._hists, name):
                return
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Histogram()
            h.observe(value)

    def time(self, name: str) -> _Timer:
        """Context manager observing elapsed wall seconds into ``name``."""
        return _Timer(self, name)

    # -- export -----------------------------------------------------------------
    def counter(self, name: str) -> float:
        with self._lock:
            return self._counters.get(name, 0)

    def gauge(self, name: str, default: float = 0.0) -> float:
        with self._lock:
            return self._gauges.get(name, default)

    def snapshot(self) -> dict:
        """JSON-serializable copy of everything (percentiles precomputed)."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {
                    n: _hist_export(h.count, h.total, h.vmin, h.vmax,
                                    h.buckets)
                    for n, h in sorted(self._hists.items())
                },
            }

    def clear(self):
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            for fams in self._families.values():
                fams.clear()


class _Phase:
    """Context manager arm of :meth:`PhaseClock.phase`."""

    __slots__ = ("_clock", "_name")

    def __init__(self, clock: "PhaseClock", name: str):
        self._clock = clock
        self._name = name

    def __enter__(self) -> "_Phase":
        self._clock._push(self._name)
        return self

    def __exit__(self, *exc):
        self._clock._pop()
        return False


class PhaseClock:
    """Partition one request's wall time into named phases, exactly.

    The clock starts at construction with an implicit bottom phase
    (``"other"``); ``with clock.phase("fp"):`` accrues the enclosed wall
    time to ``fp`` (phases nest — the inner phase owns the time while it
    is open).  :meth:`move` reattributes seconds measured elsewhere (the
    scheduler's host tail redo happens *inside* the dispatch call, so the
    service moves its reported seconds from ``chunk-dispatch`` to
    ``tail`` after the fact).  :meth:`stop` closes the clock and returns
    ``(total, phases)`` where ``sum(phases.values()) == total`` *by
    construction* — every elapsed instant belongs to exactly one phase —
    which is what lets the ``req.latency_s{op=,phase=}`` histograms
    reconcile against the request root span's wall time.

    Single-threaded by design: one clock lives on one request's calling
    thread (work done on writer threads is observed from the calling
    thread as queue-wait/barrier phases, not by sharing the clock).
    """

    OTHER = "other"

    def __init__(self):
        self._t0 = time.perf_counter()
        self._last = self._t0
        self._stack: List[str] = [self.OTHER]
        self._phases: Dict[str, float] = {}
        self._total: Optional[float] = None

    def _accrue(self):
        now = time.perf_counter()
        top = self._stack[-1]
        self._phases[top] = self._phases.get(top, 0.0) + (now - self._last)
        self._last = now

    def _push(self, name: str):
        self._accrue()
        self._stack.append(name)

    def _pop(self):
        self._accrue()
        self._stack.pop()

    def phase(self, name: str) -> _Phase:
        """Accrue the wall time of the ``with`` body to phase ``name``."""
        return _Phase(self, name)

    def move(self, src: str, dst: str, seconds: float):
        """Reattribute up to ``seconds`` already accrued to ``src`` onto
        ``dst`` (clamped so no phase goes negative and the sum is
        preserved)."""
        seconds = max(0.0, min(seconds, self._phases.get(src, 0.0)))
        if seconds <= 0.0:
            return
        self._phases[src] -= seconds
        self._phases[dst] = self._phases.get(dst, 0.0) + seconds

    def stop(self) -> Tuple[float, Dict[str, float]]:
        """Close the clock: returns ``(total_s, {phase: seconds})`` with
        the phases summing to the total exactly.  Idempotent."""
        if self._total is None:
            while len(self._stack) > 1:  # abandoned phases (error paths)
                self._pop()
            self._accrue()
            self._total = self._last - self._t0
        return self._total, dict(self._phases)


def merge_snapshots(snaps: Iterable[Optional[dict]]) -> dict:
    """Fold many :meth:`MetricsRegistry.snapshot` dicts into one aggregate.

    Counters sum; gauges sum (a summed queue depth is the fleet backlog —
    per-shard values stay in the unmerged snapshots); histograms merge
    bucket-wise and re-derive their percentiles, so the aggregate p99 is
    the true p99 of the union, not an average of per-shard p99s.
    ``None`` entries (an unreachable shard) are skipped.
    """
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    hists: Dict[str, dict] = {}  # name -> {count,sum,min,max,buckets{int:n}}
    for s in snaps:
        if not s:
            continue
        for k, v in s.get("counters", {}).items():
            counters[k] = counters.get(k, 0) + v
        for k, v in s.get("gauges", {}).items():
            gauges[k] = gauges.get(k, 0) + v
        for name, h in s.get("histograms", {}).items():
            acc = hists.setdefault(
                name,
                {"count": 0, "sum": 0.0, "min": math.inf, "max": -math.inf,
                 "buckets": {}},
            )
            acc["count"] += h["count"]
            acc["sum"] += h["sum"]
            if h["count"]:
                acc["min"] = min(acc["min"], h["min"])
                acc["max"] = max(acc["max"], h["max"])
            for i, n in h.get("buckets", {}).items():
                i = int(i)
                acc["buckets"][i] = acc["buckets"].get(i, 0) + n
    return {
        "counters": counters,
        "gauges": gauges,
        "histograms": {
            n: _hist_export(a["count"], a["sum"], a["min"], a["max"],
                            a["buckets"])
            for n, a in sorted(hists.items())
        },
    }
