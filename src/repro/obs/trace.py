"""Span-based pipeline tracing, emitted as JSONL when ``REPRO_TRACE`` is set.

A *span* wraps one unit of pipeline work — a scheduler dispatch, a flush, a
writer task, an RPC — and records wall time, thread-CPU time, and whatever
attributes the call site attaches (byte counts, bucket sizes, op names):

    with span("sched.dispatch", bucket=bucket) as sp:
        ...
        sp["rows"] = rows          # attrs can be added mid-span

One JSON object per line (the schema in docs/OBSERVABILITY.md):

    {"ts": <epoch s at span end>, "name": "...", "wall_s": ..., "cpu_s": ...,
     "pid": ..., "thread": "...", ...attrs}

``REPRO_TRACE`` selects the sink: a path appends JSONL there (parents
created); ``1``/``stderr`` writes to stderr.  Unset (the default) makes
:func:`span` return a shared no-op whose enter/exit is two attribute
lookups — tracing must cost nothing when it is off, and must never change
results when it is on (CI runs the whole tier-1 suite with it enabled).

The environment variable is re-read on every span start, so tests and
long-lived services can toggle tracing without restarting; the output file
handle is cached per path and writes are serialized under one lock
(spans from writer threads and RPC handlers interleave).

Stdlib-only, like the rest of ``repro.obs`` — shard servers trace too.
"""
from __future__ import annotations

import json
import os
import sys
import threading
import time
from typing import Optional, TextIO

#: the switch: unset/empty = off; "1"/"stderr" = stderr; else = JSONL path
TRACE_ENV = "REPRO_TRACE"

_lock = threading.Lock()
_sink_path: Optional[str] = None
_sink_file: Optional[TextIO] = None


def enabled() -> bool:
    """True when ``REPRO_TRACE`` selects a sink (re-read every call)."""
    return bool(os.environ.get(TRACE_ENV))


def _sink() -> TextIO:
    """The current sink stream (caller holds ``_lock``)."""
    global _sink_path, _sink_file
    target = os.environ.get(TRACE_ENV, "")
    if target in ("1", "stderr"):
        return sys.stderr
    if target != _sink_path:
        if _sink_file is not None:
            try:
                _sink_file.close()
            except OSError:
                pass
        parent = os.path.dirname(target)
        if parent:
            os.makedirs(parent, exist_ok=True)
        _sink_file = open(target, "a", encoding="utf-8")
        _sink_path = target
    return _sink_file  # type: ignore[return-value]


def _emit(record: dict):
    line = json.dumps(record, separators=(",", ":"), default=str)
    with _lock:
        try:
            out = _sink()
            out.write(line + "\n")
            out.flush()
        except OSError:
            pass  # a torn sink must never take the pipeline down


class _NullSpan:
    """Shared do-nothing span for the tracing-off path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def __setitem__(self, key, value):
        pass


_NULL = _NullSpan()


class Span:
    """One traced unit of work (use via :func:`span`, not directly)."""

    __slots__ = ("name", "attrs", "_t0", "_c0")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        self._c0 = time.thread_time()
        return self

    def __exit__(self, etype, exc, tb) -> bool:
        record = {
            "ts": time.time(),
            "name": self.name,
            "wall_s": time.perf_counter() - self._t0,
            "cpu_s": time.thread_time() - self._c0,
            "pid": os.getpid(),
            "thread": threading.current_thread().name,
        }
        if etype is not None:
            record["error"] = etype.__name__
        record.update(self.attrs)
        _emit(record)
        return False  # exceptions always propagate

    def __setitem__(self, key, value):
        self.attrs[key] = value


def span(name: str, **attrs):
    """Start a span named ``name`` with initial attributes ``attrs``.

    Returns the shared no-op when tracing is off, so call sites need no
    ``if`` of their own.
    """
    if not os.environ.get(TRACE_ENV):
        return _NULL
    return Span(name, attrs)
