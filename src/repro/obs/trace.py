"""Causal span tracing, emitted as JSONL when ``REPRO_TRACE`` is set.

A *span* wraps one unit of pipeline work — a scheduler dispatch, a flush, a
writer task, an RPC — and records wall time, thread-CPU time, and whatever
attributes the call site attaches (byte counts, bucket sizes, op names):

    with span("sched.dispatch", bucket=bucket) as sp:
        ...
        sp["rows"] = rows          # attrs can be added mid-span

Spans are *causal*: every span carries a ``trace_id`` (shared by all work
descending from one request), its own ``span_id``, and the ``parent_id``
of the span it ran under.  Parentage is tracked through a thread-local
context stack — a span started while another span is open on the same
thread becomes its child automatically.  Two explicit hand-offs cover the
places the thread-local cannot reach:

* :func:`current_context` captures the active ``(trace_id, span_id)`` —
  cheap, and ``None`` when tracing is off or no span is open;
* :func:`scope` re-installs a captured context on another thread (the
  writer-thread seam: a queued task adopts the flush that enqueued it, so
  queue-wait and store-write time attribute to the request that paid it)
  or from a deserialized wire frame (``shard_server.py`` adopts the
  client's ``rpc.client`` span as the parent of its ``rpc.server`` span —
  the ``trace`` meta entry of protocol VERSION 3).

One JSON object per line (the v2 schema in docs/OBSERVABILITY.md):

    {"ts": <epoch s at span end>, "name": "...", "trace_id": "...",
     "span_id": "...", "parent_id": "..."|absent, "wall_s": ...,
     "cpu_s": ..., "pid": ..., "thread": "...", ...attrs}

``REPRO_TRACE`` selects the sink: a path appends JSONL there (parents
created); ``1``/``stderr`` writes to stderr.  Unset (the default) makes
:func:`span` return a shared no-op whose enter/exit is two attribute
lookups — tracing must cost nothing when it is off, and must never change
results when it is on (CI runs the whole tier-1 suite with it enabled).

The environment variable is re-read on every span start, so tests and
long-lived services can toggle tracing without restarting; the output file
handle is cached per path and writes are serialized under one lock
(spans from writer threads and RPC handlers interleave).  Every record is
flushed line-by-line and the cached handle is closed at interpreter exit
(``atexit``), so a shard server stopped via ``shutdown`` never truncates
its tail spans.

Stdlib-only, like the rest of ``repro.obs`` — shard servers trace too.
"""
from __future__ import annotations

import atexit
import json
import os
import sys
import threading
import time
from typing import Optional, TextIO, Tuple

#: the switch: unset/empty = off; "1"/"stderr" = stderr; else = JSONL path
TRACE_ENV = "REPRO_TRACE"

_lock = threading.Lock()
_sink_path: Optional[str] = None
_sink_file: Optional[TextIO] = None

#: per-thread context stack of (trace_id, span_id) — the causal chain
_tls = threading.local()


def enabled() -> bool:
    """True when ``REPRO_TRACE`` selects a sink (re-read every call)."""
    return bool(os.environ.get(TRACE_ENV))


def _close_sink():
    """Close the cached sink handle (idempotent; registered with atexit so
    a process that exits mid-trace flushes and closes its tail lines)."""
    global _sink_path, _sink_file
    with _lock:
        if _sink_file is not None:
            try:
                _sink_file.close()
            except OSError:
                pass
            _sink_file = None
            _sink_path = None


atexit.register(_close_sink)


def _sink() -> TextIO:
    """The current sink stream (caller holds ``_lock``)."""
    global _sink_path, _sink_file
    target = os.environ.get(TRACE_ENV, "")
    if target in ("1", "stderr"):
        return sys.stderr
    if target != _sink_path or _sink_file is None:
        if _sink_file is not None:
            try:
                _sink_file.close()
            except OSError:
                pass
            _sink_file = None
        parent = os.path.dirname(target)
        if parent:
            os.makedirs(parent, exist_ok=True)
        _sink_file = open(target, "a", encoding="utf-8")
        _sink_path = target
    return _sink_file  # type: ignore[return-value]


def _emit(record: dict):
    line = json.dumps(record, separators=(",", ":"), default=str)
    with _lock:
        try:
            out = _sink()
            # one write + flush per record: concurrent appenders (shard
            # server processes share the path) emit whole lines, and a
            # killed process loses at most the span it was writing
            out.write(line + "\n")
            out.flush()
        except OSError:
            pass  # a torn sink must never take the pipeline down


# -- causal context --------------------------------------------------------------
def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _new_id(nbytes: int = 8) -> str:
    return os.urandom(nbytes).hex()


def current_context() -> Optional[dict]:
    """The active ``{"trace_id", "span_id"}``, or ``None``.

    ``None`` both when tracing is off and when no span is open on this
    thread — callers capture it unconditionally (one attr lookup when
    off) and hand it to :func:`scope` on the far side of a thread or
    process seam.
    """
    st = getattr(_tls, "stack", None)
    if not st:
        return None
    trace_id, span_id = st[-1]
    return {"trace_id": trace_id, "span_id": span_id}


class _Scope:
    """Context manager installing a foreign parent context (see :func:`scope`)."""

    __slots__ = ("_ctx", "_pushed")

    def __init__(self, ctx: Optional[dict]):
        self._ctx = ctx
        self._pushed = False

    def __enter__(self) -> "_Scope":
        ctx = self._ctx
        if ctx and ctx.get("trace_id") and ctx.get("span_id"):
            _stack().append((str(ctx["trace_id"]), str(ctx["span_id"])))
            self._pushed = True
        return self

    def __exit__(self, *exc) -> bool:
        if self._pushed:
            st = _stack()
            if st:
                st.pop()
        return False


def scope(ctx: Optional[dict]) -> _Scope:
    """Adopt a context captured elsewhere as this thread's span parent.

    ``ctx`` is what :func:`current_context` returned on the originating
    thread (or arrived in a wire frame's ``trace`` meta entry); spans
    started inside the ``with`` become its children.  ``None`` or a
    malformed dict is a no-op, so call sites need no ``if`` of their own.
    """
    return _Scope(ctx)


class _NullSpan:
    """Shared do-nothing span for the tracing-off path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def __setitem__(self, key, value):
        pass


_NULL = _NullSpan()


class Span:
    """One traced unit of work (use via :func:`span`, not directly)."""

    __slots__ = ("name", "attrs", "_t0", "_c0", "_ids")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs

    def __enter__(self) -> "Span":
        st = _stack()
        if st:
            trace_id, parent_id = st[-1]
        else:
            trace_id, parent_id = _new_id(), None
        span_id = _new_id()
        self._ids: Tuple[str, str, Optional[str]] = (
            trace_id, span_id, parent_id
        )
        st.append((trace_id, span_id))
        self._t0 = time.perf_counter()
        self._c0 = time.thread_time()
        return self

    def __exit__(self, etype, exc, tb) -> bool:
        wall = time.perf_counter() - self._t0
        cpu = time.thread_time() - self._c0
        st = _stack()
        if st:  # pop our own frame (LIFO: spans nest on one thread)
            st.pop()
        trace_id, span_id, parent_id = self._ids
        record = {
            "ts": time.time(),
            "name": self.name,
            "trace_id": trace_id,
            "span_id": span_id,
            "wall_s": wall,
            "cpu_s": cpu,
            "pid": os.getpid(),
            "thread": threading.current_thread().name,
        }
        if parent_id is not None:
            record["parent_id"] = parent_id
        if etype is not None:
            record["error"] = etype.__name__
        record.update(self.attrs)
        _emit(record)
        return False  # exceptions always propagate

    def __setitem__(self, key, value):
        self.attrs[key] = value


def span(name: str, **attrs):
    """Start a span named ``name`` with initial attributes ``attrs``.

    Returns the shared no-op when tracing is off, so call sites need no
    ``if`` of their own.
    """
    if not os.environ.get(TRACE_ENV):
        return _NULL
    return Span(name, attrs)
