"""repro.obs — stdlib-only observability: metrics registry + span tracing.

The measurement substrate every service layer reports through
(docs/OBSERVABILITY.md is the catalog):

* :class:`MetricsRegistry` — thread-safe counters, gauges, and log-bucketed
  histograms with p50/p95/p99 export; :func:`merge_snapshots` aggregates
  many snapshots (e.g. the per-shard-server ones fetched over the wire by
  ``ShardedDedupService.metrics()``) into one.
* :func:`span` — causal tracing context manager emitting JSONL records
  (trace/span/parent IDs + wall/CPU time + byte counts) when
  ``REPRO_TRACE`` is set; a shared no-op otherwise.  :func:`current_context`
  and :func:`scope` carry the causal chain across thread and process seams
  (writer queue, shard RPC).
* :class:`PhaseClock` — exact wall-time partitioner behind the
  ``req.latency_s{op=,phase=}`` request histograms: phases tile the
  request's wall time by construction, so per-phase sums reconcile with
  the root span.

Deliberately *not* lazy and deliberately dependency-free: the numpy-only
shard server processes import this package, so it must stay importable
without jax, numpy, or anything outside the standard library.
"""
from .metrics import (
    BUCKETS_PER_OCTAVE,
    MetricsRegistry,
    PhaseClock,
    bucket_index,
    bucket_value,
    labeled,
    merge_snapshots,
)
from .trace import TRACE_ENV, Span, current_context, enabled, scope, span

__all__ = [
    "BUCKETS_PER_OCTAVE",
    "MetricsRegistry",
    "PhaseClock",
    "Span",
    "TRACE_ENV",
    "bucket_index",
    "bucket_value",
    "current_context",
    "enabled",
    "labeled",
    "merge_snapshots",
    "scope",
    "span",
]
