"""Jitted public wrappers for the Pallas kernels.

``interpret`` mode is selected automatically: on CPU (this container) the
kernel bodies execute via the Pallas interpreter for bit-exact validation
against ref.py; on TPU they compile to Mosaic.  Override with
REPRO_PALLAS_INTERPRET=0/1.
"""
from __future__ import annotations

import os

import jax

from . import extremum as _extremum
from . import gear_hash as _gear_hash
from . import seqcdc_masks as _seqcdc_masks


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def seqcdc_masks(data, seq_length: int, mode: str = "increasing"):
    """(candidate, opposing) bitmaps via the Pallas phase-1 kernel."""
    return _seqcdc_masks.seqcdc_masks_pallas(
        data, seq_length, mode, interpret=_interpret()
    )


def gear_hash(data, table=None):
    """Per-position uint32 Gear hash via the parallel window-32 kernel."""
    return _gear_hash.gear_hash_pallas(data, table, interpret=_interpret())


def block_max(data, block: int = 128):
    """Per-block byte maxima via the range-scan kernel."""
    return _extremum.block_max_pallas(data, block=block, interpret=_interpret())


def flash_attention(q, k, v, **kw):
    """Causal flash attention via the Pallas kernel (VMEM score tiles)."""
    from . import flash_attn as _fa

    return _fa.flash_attention_pallas(q, k, v, interpret=_interpret(), **kw)


def chunk_fingerprints(data, bounds, count, *, max_chunks: int):
    """Fused per-chunk 62-bit fingerprints via the Pallas kernel.

    (Imported lazily: kernels/fingerprint.py pulls constants from
    repro.dedup.fingerprint, which in turn dispatches back here only
    inside function bodies — no import cycle.)
    """
    from . import fingerprint as _fp

    return _fp.fingerprint_pallas(
        data, bounds, count, max_chunks=max_chunks, interpret=_interpret()
    )


def fused_pipeline(data, p, *, max_chunks: int):
    """Single-dispatch chunk+fingerprint pipeline via the fused kernel.

    ``data``: ``(S,)`` or ``(B, S)`` uint8.  Returns
    ``(bounds, count(s), fps, lengths)`` bit-identical to the composed
    split path (``boundaries_batch`` + ``chunk_fingerprints``); the
    service scheduler selects it with ``pipeline_impl="fused"``.
    (Lazy import for the same no-cycle reason as ``chunk_fingerprints``.)
    """
    from . import fused_pipeline as _fpipe

    if data.ndim == 1:
        return _fpipe.fused_pipeline(
            data, p, max_chunks=max_chunks, interpret=_interpret()
        )
    return _fpipe.fused_pipeline_batch(
        data, p, max_chunks=max_chunks, interpret=_interpret()
    )


def packed_pipeline(data, seg_end_pos, ends, p, *, max_chunks: int):
    """Segment-packed fused pipeline: many streams per device row.

    ``data``: ``(B, S)`` uint8 rows of concatenated streams;
    ``seg_end_pos``: ``(B, S)`` int32 per-position segment ends;
    ``ends``: ``(B, G)`` int32 nondecreasing segment ends padded with the
    row payload end.  Returns ``(bounds, counts, fps, lengths)`` in row
    coordinates, bit-identical per segment to chunking each stream alone
    (``ref.packed_pipeline`` is the host oracle; the packed split path is
    ``seqcdc.boundaries_packed_batch`` + ``chunk_fingerprints``).
    """
    from . import fused_pipeline as _fpipe

    return _fpipe.packed_pipeline_batch(
        data, seg_end_pos, ends, p, max_chunks=max_chunks,
        interpret=_interpret(),
    )
