"""Pallas TPU kernel: causal flash attention (forward).

The §Perf cell-A analysis (EXPERIMENTS.md) showed the XLA-level online-softmax
attention still round-trips (qb, kvb) score tiles through HBM (~1.9 TiB/device
loop-weighted at 32 K prefill); this kernel keeps the tiles in VMEM — per
layer the HBM traffic drops to the q/k/v/out streams, which is the estimated
memory-term floor (13.5 s -> ~3.5 s for phi3 prefill_32k).

Grid: (B*H, n_q_blocks, n_kv_blocks), kv innermost.  The (m, l, acc) running
state lives in VMEM scratch that persists across the kv iterations of one
(bh, qi) cell and is re-initialized at kv==0; the output block is written at
the last kv step (the standard Pallas flash structure).  Causality is an
additive bias from block position iotas; fully-masked tiles (kv block
entirely after the q block) are skipped with ``pl.when``.

Validated bit-close against the pure-jnp oracle in interpret mode across a
shape sweep (tests/test_kernels.py::test_flash_kernel).  GQA: callers repeat
K/V to H (the framework's repeat-KV layout) before the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, qb: int, kvb: int, nkv: int, scale: float, causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _tile():
        q = q_ref[0].astype(jnp.float32)  # (qb, hd)
        k = k_ref[0].astype(jnp.float32)  # (kvb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.dot(q, k.T) * scale  # (qb, kvb)
        if causal:
            qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 0)
            kpos = kj * kvb + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(p, v)

    if causal:
        # skip tiles entirely above the diagonal
        pl.when(kj * kvb <= qi * qb + (qb - 1))(_tile)
    else:
        _tile()

    @pl.when(kj == nkv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "q_block", "kv_block", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    causal: bool = True,
    q_block: int = 256,
    kv_block: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """Causal flash attention.  q/k/v: (B, S, H, hd) with equal H (repeat-KV
    upstream for GQA).  Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd), (q.shape, k.shape)
    if scale is None:
        scale = 1.0 / (hd**0.5)
    qb = min(q_block, S)
    kvb = min(kv_block, S)
    assert S % qb == 0 and S % kvb == 0, (S, qb, kvb)
    nq, nkv = S // qb, S // kvb

    # (B, S, H, hd) -> (B*H, S, hd): one grid row per (batch, head)
    def _bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    qf, kf, vf = _bh(q), _bh(k), _bh(v)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, qb=qb, kvb=kvb, nkv=nkv, scale=scale, causal=causal
        ),
        grid=(B * H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, qb, hd), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, kvb, hd), lambda bh, i, j: (bh, j, 0)),
            pl.BlockSpec((1, kvb, hd), lambda bh, i, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, hd), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            _scratch((qb,), jnp.float32),  # running max m
            _scratch((qb,), jnp.float32),  # running denominator l
            _scratch((qb, hd), jnp.float32),  # running numerator acc
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


def _scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)
