"""Pallas TPU kernel: fused 62-bit chunk fingerprints (chunk-hashing hot path).

The reference pipeline (``dedup/fingerprint.py``, ``fp_impl="reference"``)
is gather-bound: per byte it pays a ``searchsorted`` over the chunk bounds,
a random gather from the 64 Ki-entry power table, and two ``segment_sum``
scatter-adds.  This kernel removes every per-byte gather/scatter with an
algebraic refactor of the polynomial hash

    h_r(chunk) = sum_i b_i * r^(len-1-i)   mod p,   p = 2^31 - 1.

For a byte at stream index ``i = t0 + q`` (tile start ``t0``, lane ``q``)
in a chunk with exclusive end ``e``, the needed power splits as

    r^(e-1-i) = r^(TILE-1-q) * r^(e - t0 - TILE)

so per tile the kernel computes, for both generators in one pass:

1. ``w[q] = b[q] * r^(TILE-1-q)`` — the 8-conditional-rotation byte mulmod
   against a *fixed per-lane weight vector* (the same VMEM block every grid
   step: no per-byte table gather);
2. an in-kernel segmented mod-p reduction: 16-bit-limb cumulative sums of
   ``w`` (exact for TILE <= 65536) read back at the tile-clipped chunk
   starts/ends — two tiny per-chunk gathers instead of an n-element
   scatter-add;
3. the per-chunk rescale by ``r^(e - t0 - TILE)`` via a precomputed factor
   table ``ftab[k] = r^(k-TILE)`` (negative exponents through the Fermat
   inverse — p is prime), a 31-rotation general mulmod on a
   ``(max_chunks,)`` vector.

Per-tile partials are combined across the grid by the same limb-fold (the
only work left outside the kernel, ``O(num_tiles * max_chunks)``).  Output
is bit-identical to ``chunk_fingerprints(..., fp_impl="reference")`` and to
``fingerprints_numpy`` — tests/test_fingerprint_kernel.py and the
scheduler's first-dispatch cross-check (docs/KERNELS.md) enforce it.

Constraints: TILE must be a multiple of 1024 (whole (8,128) VPU tiles) and
<= 65536 (the limb-sum overflow bound); chunk lengths <= MAX_CHUNK = 65536
(the power/factor-table bound, same as the reference); streams < 2 GiB —
int32 byte positions, the same cap as the reference path (the cross-tile
limb bound of TILE * 65536 tiles is looser and never binds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.dedup.fingerprint import (
    MAX_CHUNK,
    R1,
    R2,
    _addmod,
    _byte_mulmod,
    _fold32,
    _mulmod,
    _pow_table_np,
    _rot31,
)

DEFAULT_TILE = 64 * 1024  # == MAX_CHUNK: the largest exact-limb tile


@functools.lru_cache(maxsize=None)
def _weight_table_np(r: int, tile: int) -> np.ndarray:
    """w[q] = r^(tile-1-q) mod p — the fixed per-lane weight vector."""
    assert tile <= MAX_CHUNK, tile
    return np.ascontiguousarray(_pow_table_np(r)[:tile][::-1])


@functools.lru_cache(maxsize=None)
def _factor_table_np(r: int, tile: int) -> np.ndarray:
    """ftab[k] = r^(k - tile) mod p for k in [0, tile + MAX_CHUNK).

    Indexed by ``end - t0`` clipped into range: a chunk intersecting the
    tile has ``t0 < end <= start + MAX_CHUNK < t0 + tile + MAX_CHUNK``.
    Negative exponents go through the Fermat inverse (p = 2^31 - 1 is
    prime, so r^-1 = r^(p-2)).
    """
    p = (1 << 31) - 1
    out = np.empty(tile + MAX_CHUNK, dtype=np.uint32)
    out[tile:] = _pow_table_np(r)
    inv = pow(r, p - 2, p)
    acc = 1
    for d in range(1, tile + 1):
        acc = (acc * inv) % p
        out[tile - d] = acc
    return out


def _fp_kernel(t0_ref, x_ref, bounds_ref, starts_ref, wpow_ref, ftab_ref,
               out_ref, *, tile: int):
    t0 = t0_ref[0, 0]  # tile start offset in the stream
    x = x_ref[...].astype(jnp.uint32)  # (tile,) bytes
    bounds = bounds_ref[...]  # (mc,) int32 exclusive ends, sentinel-padded
    starts = starts_ref[...]  # (mc,) int32 chunk starts
    # tile-local byte ranges [s, e) of each chunk (empty when disjoint)
    e = jnp.clip(bounds - t0, 0, tile)
    s = jnp.minimum(jnp.clip(starts - t0, 0, tile), e)
    fidx = jnp.clip(bounds - t0, 0, ftab_ref.shape[-1] - 1).astype(jnp.int32)

    def prefix(c, k):  # sum of the first k elements of an inclusive cumsum
        return jnp.where(k > 0, c[jnp.maximum(k - 1, 0)], 0)

    cols = []
    for g in range(2):
        w = _byte_mulmod(x, wpow_ref[g])  # (tile,) < p, no per-byte gather
        lo = jnp.cumsum(w & 0xFFFF, dtype=jnp.uint32)  # exact: tile <= 2^16
        hi = jnp.cumsum(w >> 16, dtype=jnp.uint32)
        lo_m = _fold32(prefix(lo, e) - prefix(lo, s))
        hi_m = _fold32(prefix(hi, e) - prefix(hi, s))
        partial = _addmod(lo_m, _rot31(hi_m, 16))  # segmented sum mod p
        cols.append(_mulmod(ftab_ref[g, fidx], partial, 31))
    out_ref[...] = jnp.stack(cols, axis=-1)[None]


@functools.partial(
    jax.jit, static_argnames=("max_chunks", "tile", "interpret")
)
def fingerprint_pallas(
    data: jax.Array,
    bounds: jax.Array,
    count: jax.Array,
    *,
    max_chunks: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Per-chunk (fp (max_chunks, 2) uint32, lengths (max_chunks,) int32).

    Drop-in for ``chunk_fingerprints`` (same bounds layout: exclusive ends,
    sorted, sentinel-padded past ``count``; entries past ``count`` zeroed).
    """
    assert data.ndim == 1, data.shape
    n = data.shape[-1]
    if n == 0:
        return (jnp.zeros((max_chunks, 2), jnp.uint32),
                jnp.zeros((max_chunks,), jnp.int32))
    tile = min(tile, max(1024, ((n + 1023) // 1024) * 1024))
    assert tile % 1024 == 0 and tile <= MAX_CHUNK, tile
    n_pad = (n + tile - 1) // tile * tile
    nt = n_pad // tile
    assert nt <= (1 << 16), (n, tile)  # cross-tile limb-sum exactness
    x = jnp.pad(data.astype(jnp.uint8), (0, n_pad - n))
    b32 = bounds.astype(jnp.int32)
    starts32 = jnp.concatenate([jnp.zeros((1,), jnp.int32), b32[:-1]])
    t0s = (jnp.arange(nt, dtype=jnp.int32) * tile).reshape(nt, 1)
    wpow = jnp.stack(
        [jnp.asarray(_weight_table_np(r, tile)) for r in (R1, R2)]
    )
    ftab = jnp.stack(
        [jnp.asarray(_factor_table_np(r, tile)) for r in (R1, R2)]
    )

    parts = pl.pallas_call(
        functools.partial(_fp_kernel, tile=tile),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),  # t0 (not program_id:
            # stays correct when the whole call is vmapped over a batch)
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((max_chunks,), lambda i: (0,)),
            pl.BlockSpec((max_chunks,), lambda i: (0,)),
            pl.BlockSpec((2, tile), lambda i: (0, 0)),
            pl.BlockSpec((2, tile + MAX_CHUNK), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, max_chunks, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, max_chunks, 2), jnp.uint32),
        interpret=interpret,
    )(t0s, x, b32, starts32, wpow, ftab)

    # cross-tile combine: per-tile partials < p, limb sums exact for nt <= 2^16
    lo = jnp.sum(parts & 0xFFFF, axis=0, dtype=jnp.uint32)
    hi = jnp.sum(parts >> 16, axis=0, dtype=jnp.uint32)
    fp = _addmod(_fold32(lo), _rot31(_fold32(hi), 16))

    lengths = b32 - starts32  # same masked tail as the reference path
    valid = jnp.arange(max_chunks) < count
    fp = jnp.where(valid[:, None], fp, 0)
    lengths = jnp.where(valid, lengths, 0)
    return fp, lengths
