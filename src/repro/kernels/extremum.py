"""Pallas TPU kernel: per-block byte maxima (VectorCDC range-scan substrate).

VectorCDC accelerates RAM/AE by vectorizing their two phases, *extreme byte
search* and *range scan*.  On TPU the range scan maps to per-block maxima
computed at HBM bandwidth; the hashless automatons (the AE/RAM chunkers in
core/baselines/hashless.py) then skip whole blocks whose max cannot beat
the running extreme and only descend into candidate blocks — the same
wide-compare/first-hit pattern as VectorCDC's movemask+ffs, expressed as
block max + masked argmin.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128
DEFAULT_TILE_BLOCKS = 512  # 512 blocks x 128 B = 64 KiB per grid step


def _block_max_kernel(x_ref, out_ref, *, block: int):
    x = x_ref[...]  # (TB * block,)
    tb = x.shape[0] // block
    out_ref[...] = jnp.max(x.reshape(tb, block), axis=-1)


@functools.partial(
    jax.jit, static_argnames=("block", "tile_blocks", "interpret")
)
def block_max_pallas(
    data: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    tile_blocks: int = DEFAULT_TILE_BLOCKS,
    interpret: bool = True,
) -> jax.Array:
    """Per-block maxima of a 1-D uint8 stream; pads tail with 0 (neutral)."""
    assert data.ndim == 1
    n = data.shape[0]
    if n == 0:
        return jnp.zeros((0,), dtype=jnp.uint8)
    nb = (n + block - 1) // block
    tb = min(tile_blocks, nb)
    nb_pad = (nb + tb - 1) // tb * tb
    x = jnp.pad(data.astype(jnp.uint8), (0, nb_pad * block - n))
    nt = nb_pad // tb

    out = pl.pallas_call(
        functools.partial(_block_max_kernel, block=block),
        grid=(nt,),
        in_specs=[pl.BlockSpec((tb * block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb_pad,), jnp.uint8),
        interpret=interpret,
    )(x)
    return out[:nb]
