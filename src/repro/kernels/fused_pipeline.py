"""Pallas TPU kernel: fused single-dispatch SeqCDC chunk+fingerprint pipeline.

The split pipeline the scheduler composes (``pipeline_impl="split"``) runs
three dispatches per padded bucket: the phase-1 extremum-mask kernel, the
phase-2 boundary-selection scan, and the fingerprint kernel — the last of
which re-reads every byte the mask pass already touched.  SeqCDC's
throughput argument (and the follow-up AVX vector-chunking paper) is that
boundary detection and hashing should share one pass over the data; this
kernel is that fusion: per (row, tile) grid step the TILE-byte VMEM block
is read **once** and feeds

1. the mask comparison lanes — shifted pairwise compares over the tile plus
   an (L-1)-byte halo, AND-reduced into the candidate bitmap, one opposite
   compare for the opposing bitmap (identical decisions to
   ``core/masks.py`` / ``kernels/seqcdc_masks.py``);
2. the limb-accumulating hash state — per-byte weights against a *fixed*
   per-lane ``r^-q`` vector (8 conditional 31-bit rotations, no per-byte
   gather), 16-bit-limb cumulative sums exact for ``tile + halo <= 65536``;
3. the boundary automaton — a ``fori_loop`` over the tile's W-byte blocks
   running the exact ``_scan_wide`` step (it calls
   ``core/automaton._resolve`` itself), with the scan state carried across
   tiles in VMEM scratch (the grid iterates row-major, tiles innermost,
   like the flash-attention kernel's kv state).

Boundary decisions are consumed *in-kernel* to segment the hash reduction:
the moment a block emits a chunk end ``e``, the fingerprint of ``[s, e)``
is read off the running prefix state —

    h_r(chunk) = (P_r(e) - P_r(s)) * r^(e-1)  mod p,
    P_r(i)     = sum_{j<i} b_j * r^-j          (prefix of position-weighted
                                                bytes; negative exponents via
                                                the Fermat inverse, p prime)

— two scalar prefix reads, one factor gather, three 31-rotation mulmods.
``P_r(s)`` was latched when the previous boundary was emitted, and the
cross-tile carry ``P_r(t0)`` lives in scratch, so chunks spanning any
number of tiles cost the same as local ones.  The final file-end boundary
fixup of ``select_boundaries`` is replicated in-kernel at the last tile
(``r^(n-1)`` arrives as a host-precomputed operand).

Output is bit-identical to the composed split path — bounds/count from
``boundaries_batch(step_impl="wide")`` and fps/lengths from
``chunk_fingerprints`` — which tests/test_fused_pipeline.py, the
differential matrix harness (tests/test_pipeline_matrix.py), and the
scheduler's first-dispatch ``PipelineDivergenceError`` cross-check
(docs/KERNELS.md) all enforce.

Constraints: TILE a multiple of 1024 (whole (8,128) VPU tiles) with
``TILE + halo <= 65536`` where ``halo = skip_size + seq_length - 1``
(the limb-sum exactness bound; the halo is that wide because an
overshooting skip resolved as a cut can emit a bound ``skip_size + L - 1``
bytes past its block — hence the 32 KiB default, half the fingerprint
kernel's); chunk lengths <= ``MAX_CHUNK`` = 65536 (the power-table bound,
as everywhere); streams < 2 GiB (int32 positions).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.automaton import _BIG, _resolve
from repro.core.params import SeqCDCParams
from repro.dedup.fingerprint import (
    MAX_CHUNK,
    P31,
    R1,
    R2,
    _addmod,
    _byte_mulmod,
    _fold32,
    _mulmod,
    _pow_table_np,
    _rot31,
)

#: selects the scheduler's device pipeline: three dispatches ("split" —
#: masks, boundary scan, fingerprints) or this kernel ("fused")
PipelineImpl = Literal["split", "fused"]

DEFAULT_TILE = 32 * 1024  # + halo stays under the 65536 limb-exactness bound


@functools.lru_cache(maxsize=None)
def _negpow_table_np(r: int, size: int) -> np.ndarray:
    """w[q] = r^-q mod p — the fixed per-lane prefix weight vector."""
    p = (1 << 31) - 1
    inv = pow(r, p - 2, p)  # Fermat: p is prime
    out = np.empty(size, dtype=np.uint32)
    acc = 1
    for q in range(size):
        out[q] = acc
        acc = (acc * inv) % p
    return out


def _mulmod31(a, y):
    """a * y mod p for a, y < p — 31 conditional rotations (scalar use)."""
    return _mulmod(a, y, 31)


def _pipeline_kernel(
    t0_ref, x_ref, halo_ref, rneg_ref, rpos_ref, wneg_ref, postab_ref,
    rnm1_ref, bounds_ref, counts_ref, fps_ref, lens_ref, sti_ref, sth_ref,
    *, p: SeqCDCParams, n: int, mc: int, tile: int, halo: int,
    nb_split: int, last_t0: int,
):
    t0 = t0_ref[0, 0]  # tile start offset in the (padded) stream
    L = p.seq_length
    W = p.block_width
    nb = tile // W
    T = jnp.int32(p.skip_trigger)
    ext_len = tile + halo

    @pl.when(t0 == 0)  # first tile of a row: reset state and outputs
    def _init():
        sti_ref[...] = jnp.zeros_like(sti_ref)  # k, c, s, cnt
        sti_ref[0] = np.int32(p.sub_min_skip)
        sth_ref[...] = jnp.zeros_like(sth_ref)  # P(t0) carry, P(s) latch
        bounds_ref[...] = jnp.full_like(bounds_ref, _BIG)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        fps_ref[...] = jnp.zeros_like(fps_ref)
        lens_ref[...] = jnp.zeros_like(lens_ref)

    # -- the one byte read: tile + (L-1)-byte halo from the next tile -------
    x = x_ref[0]  # (tile,) uint8
    ext = jnp.concatenate([x, halo_ref[0, 0]])  # (tile + halo,)

    # -- mask lanes (phase 1, same decisions as core/masks.py) --------------
    a = ext[:-1]
    b = ext[1:]
    gt = b > a  # (tile + halo - 1,) pair bits
    lt = b < a
    inc = p.mode == "increasing"
    fwd = gt if inc else lt
    acc = fwd[:tile]
    for j in range(1, L - 1):  # AND of L-1 shifted pair masks
        acc = jnp.logical_and(acc, fwd[j:j + tile])
    pos = t0 + jnp.arange(tile, dtype=jnp.int32)
    cand = acc & (pos <= n - L)  # the reference wrapper's tail masking
    opp = (lt if inc else gt)[:tile] & (pos < n - 1)

    # -- hash lanes: position-weighted limb prefix sums ---------------------
    xw = ext.astype(jnp.uint32)
    lo, hi = [], []
    for g in range(2):
        w = _byte_mulmod(xw, wneg_ref[g])  # b_q * r^-q, fixed weight vector
        lo.append(jnp.cumsum(w & 0xFFFF, dtype=jnp.uint32))  # exact:
        hi.append(jnp.cumsum(w >> 16, dtype=jnp.uint32))  # ext_len <= 2^16
    rneg = rneg_ref[0]  # (2,) r^-t0
    rpos = rpos_ref[0]  # (2,) r^t0
    carry0 = sth_ref[0, 0]  # P(t0) per generator
    carry1 = sth_ref[0, 1]

    def tile_prefix(g, m):
        """P within this tile: sum of the first ``m`` ext weights, mod p."""
        i = jnp.maximum(m - 1, 0)
        part = _addmod(_fold32(lo[g][i]), _rot31(_fold32(hi[g][i]), 16))
        return jnp.where(m > 0, part, jnp.uint32(0))

    def prefix_at(g, carry_g, e):
        """P(e) for a stream position ``e`` inside [t0, t0 + ext_len]."""
        m = jnp.clip(e - t0, 0, ext_len)
        return _addmod(carry_g, _mulmod31(rneg[g], tile_prefix(g, m)))

    def chunk_fp(g, carry_g, ps_g, e):
        """(P(e) - P(s)) * r^(e-1): the fingerprint of the closing chunk."""
        pe = prefix_at(g, carry_g, e)
        diff = _addmod(pe, P31 - ps_g)  # canonical: both operands < p
        fi = jnp.clip(e - 1 - t0, 0, ext_len - 1)
        rfac = _mulmod31(rpos[g], postab_ref[g, fi])
        # a bound behind this tile is only ever the file-end cut (the scan
        # position can overshoot cut_k = n - L + 1 when the tail is shorter
        # than a skip landing); its factor r^(n-1) is the host operand —
        # prefix_at is already exact there, P(t0) == P(n) past the data
        rfac = jnp.where(e - 1 - t0 < 0, rnm1_ref[0, g], rfac)
        return pe, _mulmod31(diff, rfac)

    # -- boundary automaton: the exact _scan_wide step per W-block ----------
    iota = jnp.arange(W, dtype=jnp.int32)
    k0, c0, s0, cnt0 = sti_ref[0], sti_ref[1], sti_ref[2], sti_ref[3]
    ps0 = sth_ref[1, 0], sth_ref[1, 1]

    def body(j, st):
        k, c, s, cnt, ps_0, ps_1 = st
        bstart = t0 + j * W
        bend = bstart + W
        # blocks past the split path's padded bitmap simply don't exist
        # there; masking in_block reproduces that exactly
        in_block = (k < bend) & (s < n) & (t0 // W + j < nb_split)
        cb = jax.lax.dynamic_slice(cand, (j * W,), (W,))
        ob = jax.lax.dynamic_slice(opp, (j * W,), (W,))
        o = jnp.maximum(k - bstart, 0)
        active = iota >= o
        posw = bstart + iota
        kc = jnp.min(jnp.where(cb & active, posw, _BIG))
        cum = c + jnp.cumsum((ob & active).astype(jnp.int32))
        kt = jnp.min(jnp.where(ob & active & (cum > T), posw, _BIG))
        new_k, new_s, emit, bound, any_event = _resolve(
            k, c, s, kc, kt, bend, in_block, n, p
        )
        new_c = jnp.where(any_event, 0, jnp.where(in_block, cum[-1], c))
        # boundary decision consumed in-kernel: segment the hash reduction
        pe0, fp0 = chunk_fp(0, carry0, ps_0, bound)
        pe1, fp1 = chunk_fp(1, carry1, ps_1, bound)
        idx = jnp.minimum(cnt, mc - 1)
        keep = emit & (cnt < mc)  # the split path's mode="drop" scatter
        bounds_ref[0, idx] = jnp.where(keep, bound, bounds_ref[0, idx])
        lens_ref[0, idx] = jnp.where(keep, bound - s, lens_ref[0, idx])
        fps_ref[0, idx, 0] = jnp.where(keep, fp0, fps_ref[0, idx, 0])
        fps_ref[0, idx, 1] = jnp.where(keep, fp1, fps_ref[0, idx, 1])
        return (new_k, new_c, new_s, cnt + emit.astype(jnp.int32),
                jnp.where(emit, pe0, ps_0), jnp.where(emit, pe1, ps_1))

    k, c, s, cnt, ps_0, ps_1 = jax.lax.fori_loop(
        0, nb, body, (k0, c0, s0, cnt0, *ps0)
    )

    # -- final-boundary fixup (select_boundaries' post-scan guarantee) ------
    last = jnp.where(
        cnt > 0, bounds_ref[0, jnp.clip(cnt - 1, 0, mc - 1)], 0)
    need = (t0 == last_t0) & (last < n)  # n > 0: static in this kernel
    pe0 = prefix_at(0, carry0, jnp.int32(n))  # r^(n-1) is a host operand:
    fp0 = _mulmod31(_addmod(pe0, P31 - ps_0), rnm1_ref[0, 0])  # n - 1 may
    pe1 = prefix_at(1, carry1, jnp.int32(n))  # fall outside this tile's
    fp1 = _mulmod31(_addmod(pe1, P31 - ps_1), rnm1_ref[0, 1])  # factor table
    idx = jnp.minimum(cnt, mc - 1)
    keep = need & (cnt < mc)
    bounds_ref[0, idx] = jnp.where(keep, jnp.int32(n), bounds_ref[0, idx])
    lens_ref[0, idx] = jnp.where(keep, jnp.int32(n) - s, lens_ref[0, idx])
    fps_ref[0, idx, 0] = jnp.where(keep, fp0, fps_ref[0, idx, 0])
    fps_ref[0, idx, 1] = jnp.where(keep, fp1, fps_ref[0, idx, 1])
    cnt = cnt + need.astype(jnp.int32)

    # -- persist state for the next tile ------------------------------------
    counts_ref[0, 0] = cnt
    sti_ref[...] = jnp.stack([k, c, s, cnt])
    sth_ref[0, 0] = _addmod(carry0, _mulmod31(rneg[0], tile_prefix(0, tile)))
    sth_ref[0, 1] = _addmod(carry1, _mulmod31(rneg[1], tile_prefix(1, tile)))
    sth_ref[1, 0] = ps_0
    sth_ref[1, 1] = ps_1


@functools.partial(
    jax.jit, static_argnames=("p", "max_chunks", "tile", "interpret")
)
def fused_pipeline_batch(
    data: jax.Array,
    p: SeqCDCParams,
    *,
    max_chunks: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Chunk + fingerprint a ``(B, S)`` uint8 batch in one dispatch.

    Returns ``(bounds (B, mc) int32, counts (B,) int32, fps (B, mc, 2)
    uint32, lengths (B, mc) int32)`` — bit-identical to
    ``boundaries_batch(..., step_impl="wide")`` composed with the vmapped
    ``chunk_fingerprints`` (any ``mask_impl``/``fp_impl``: all are
    bit-identical to each other).

    Precondition: ``max_chunks`` must be a true upper bound on the chunk
    count (``core.automaton.max_chunks_for`` — what the scheduler always
    passes).  With an undersized ``max_chunks`` the reference path folds
    all overflow bytes into the clamped last fp slot while this kernel
    drops overflow chunks whole, so the two fp tails differ (bounds,
    counts and lengths still agree).
    """
    assert data.ndim == 2, data.shape
    B, n = data.shape
    mc = max_chunks
    if n == 0:  # static: no chunks, matching the split path's empty case
        return (jnp.full((B, mc), _BIG, jnp.int32),
                jnp.zeros((B,), jnp.int32),
                jnp.zeros((B, mc, 2), jnp.uint32),
                jnp.zeros((B, mc), jnp.int32))
    if p.max_size > MAX_CHUNK:
        raise ValueError(
            f"max_size {p.max_size} exceeds the fingerprint power-table "
            f"bound {MAX_CHUNK}"
        )
    L = p.seq_length
    W = p.block_width
    # halo: the mask pair bits spill L-1 bytes past the tile, but emitted
    # bounds spill further — an overshooting skip resolved as a cut
    # (_resolve's trig_cuts) lands at cut_b < block_end + skip_size + L - 1,
    # and the in-kernel prefix/factor reads at that bound must still be
    # inside the extended byte window
    halo = p.skip_size + L - 1
    # the split automaton pads its bitmaps so every event fires in-scan
    # (core/automaton._padded_blocks); cover exactly those blocks
    nb_split = (n + p.skip_size + W + W - 1) // W
    cover = nb_split * W
    tile = min(tile, (cover + 1023) // 1024 * 1024)
    assert tile % 1024 == 0 and tile % W == 0, (tile, W)
    assert tile + halo <= MAX_CHUNK, (tile, halo)  # limb-sum exactness
    nt = (cover + tile - 1) // tile
    n_pad = nt * tile

    x = jnp.pad(data.astype(jnp.uint8), ((0, 0), (0, n_pad - n)))
    # halos[b, i] = x[b, (i+1)*tile : (i+1)*tile + halo], zero past the end
    # (halo may exceed tile when skip_size does, so slice rather than
    # reshape; nt is small and static)
    xh = jnp.pad(x, ((0, 0), (0, halo)))
    halos = jnp.stack(
        [xh[:, (i + 1) * tile:(i + 1) * tile + halo] for i in range(nt)],
        axis=1,
    )
    t0s = (jnp.arange(nt, dtype=jnp.int32) * tile).reshape(nt, 1)

    pm = (1 << 31) - 1
    wneg = jnp.stack(
        [jnp.asarray(_negpow_table_np(r, tile + halo)) for r in (R1, R2)]
    )
    postab = jnp.stack(
        [jnp.asarray(_pow_table_np(r)[: tile + halo]) for r in (R1, R2)]
    )
    rneg = jnp.asarray(np.array(
        [[pow(pow(r, pm - 2, pm), i * tile, pm) for r in (R1, R2)]
         for i in range(nt)], dtype=np.uint32))
    rpos = jnp.asarray(np.array(
        [[pow(r, i * tile, pm) for r in (R1, R2)] for i in range(nt)],
        dtype=np.uint32))
    rnm1 = jnp.asarray(np.array(
        [[pow(r, n - 1, pm) for r in (R1, R2)]], dtype=np.uint32))

    from jax.experimental.pallas import tpu as pltpu

    bounds, counts, fps, lens = pl.pallas_call(
        functools.partial(
            _pipeline_kernel, p=p, n=n, mc=mc, tile=tile, halo=halo,
            nb_split=nb_split, last_t0=(nt - 1) * tile,
        ),
        grid=(B, nt),  # row-major: each row's tiles run in order, so the
        # scratch scan/hash state threads through them (re-init at t0 == 0)
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (i, 0)),  # t0 (operand, not
            # program_id: the index map owns the grid->tile mapping)
            pl.BlockSpec((1, tile), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1, halo), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 2), lambda b, i: (i, 0)),  # r^-t0
            pl.BlockSpec((1, 2), lambda b, i: (i, 0)),  # r^t0
            pl.BlockSpec((2, tile + halo), lambda b, i: (0, 0)),
            pl.BlockSpec((2, tile + halo), lambda b, i: (0, 0)),
            pl.BlockSpec((1, 2), lambda b, i: (0, 0)),  # r^(n-1)
        ],
        out_specs=[
            pl.BlockSpec((1, mc), lambda b, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
            pl.BlockSpec((1, mc, 2), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, mc), lambda b, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, mc), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, mc, 2), jnp.uint32),
            jax.ShapeDtypeStruct((B, mc), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((4,), jnp.int32),  # automaton k, c, s, cnt
            pltpu.VMEM((2, 2), jnp.uint32),  # P(t0) carry, P(s) latch
        ],
        interpret=interpret,
    )(t0s, x, halos, rneg, rpos, wneg, postab, rnm1)
    return bounds, counts[:, 0], fps, lens


def fused_pipeline(
    data: jax.Array,
    p: SeqCDCParams,
    *,
    max_chunks: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Single-stream convenience: ``(n,)`` -> (bounds, count, fps, lengths)."""
    b, c, f, ln = fused_pipeline_batch(
        data[None], p, max_chunks=max_chunks, tile=tile, interpret=interpret
    )
    return b[0], c[0], f[0], ln[0]


# ---------------------------------------------------------------------------
# Segment-packed rows: many small streams share one device row.
# ---------------------------------------------------------------------------


def _packed_pipeline_kernel(
    t0_ref, x_ref, halo_ref, sep_ref, ends_ref, pend_ref, rend_ref,
    rneg_ref, rpos_ref, wneg_ref, postab_ref,
    bounds_ref, counts_ref, fps_ref, lens_ref, sti_ref, sth_ref, sps_ref,
    *, p: SeqCDCParams, mc: int, tile: int, halo: int,
    nb_split: int, last_t0: int,
):
    """``_pipeline_kernel`` with per-segment resets (docs/KERNELS.md).

    Four deltas against the unpacked kernel:

    * the automaton's file end is the *current segment's* end ``se`` (a
      fifth scratch register) instead of the static row width, and every
      emit landing on ``se`` advances it — the registers the emit leaves
      behind are exactly a fresh stream's init state, so the segment reset
      costs nothing beyond the extra register (the proof lives with
      ``automaton._scan_wide_packed``, which this mirrors block-for-block);
    * the mask lanes clip per *position* against the ``seg_end_pos``
      operand (cross-segment byte pairs must not form candidates), where
      the unpacked kernel clips against the static ``n``;
    * one W-block can emit several chunks: a segment-end cut resolving
      late resets the scan position *behind* or *inside* the block it
      fired in, so the per-block step is a ``while_loop`` that re-resolves
      until the position clears the block (mirroring
      ``_scan_wide_packed``'s inner loop), not the unpacked kernel's
      single ``_resolve``;
    * a bound behind the tile start needs its prefix from somewhere the
      running carry can't provide — the bytes between it and ``t0`` are
      *later* segments' real bytes, so ``P(t0) != P(bound)``, unlike the
      unpacked kernel's zero-pad argument.  Segment-end cuts (arbitrarily
      far behind) read host-shaped per-segment operands (``pend`` /
      ``rend``) looked up by end offset; max-size cuts land at most
      ``skip_size - L`` behind (a skip crossed the tile edge) and read the
      ``sps`` scratch — the previous tile's last ``skip_size + 1`` prefix
      values, stashed tile-to-tile — with ``r^(bound-1)`` reconstructed as
      ``r^t0 * r^-(t0-bound+1)`` from the resident negpow table.
    """
    t0 = t0_ref[0, 0]
    L = p.seq_length
    W = p.block_width
    nb = tile // W
    T = jnp.int32(p.skip_trigger)
    ext_len = tile + halo
    HL = p.skip_size  # left-stash depth: max behind-t0 reach of a max cut
    ends = ends_ref[0]  # (G,) segment ends, padded with the payload end
    n_row = jnp.max(ends)  # dynamic payload end (0 for an all-pad row)
    pend = pend_ref[0]  # (2, G) P(end) per generator
    rend = rend_ref[0]  # (2, G) r^(end-1) per generator

    def next_end(x):
        return jnp.min(jnp.where(ends > x, ends, _BIG))

    @pl.when(t0 == 0)  # first tile of a row: reset state and outputs
    def _init():
        sti_ref[...] = jnp.zeros_like(sti_ref)  # k, c, s, cnt, se
        first_end = next_end(jnp.int32(0))
        # same init clamp as _scan_wide_packed: the first segment may be
        # shorter than min_size
        sti_ref[0] = jnp.minimum(jnp.int32(p.sub_min_skip),
                                 first_end - (L - 1))
        sti_ref[4] = first_end
        sth_ref[...] = jnp.zeros_like(sth_ref)  # P(t0) carry, P(s) latch
        sps_ref[...] = jnp.zeros_like(sps_ref)  # P(t0 - q) left stash
        bounds_ref[...] = jnp.full_like(bounds_ref, _BIG)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        fps_ref[...] = jnp.zeros_like(fps_ref)
        lens_ref[...] = jnp.zeros_like(lens_ref)

    # -- the one byte read: tile + halo, same as the unpacked kernel --------
    x = x_ref[0]
    ext = jnp.concatenate([x, halo_ref[0, 0]])

    # -- mask lanes, clipped per segment -------------------------------------
    a = ext[:-1]
    b = ext[1:]
    gt = b > a
    lt = b < a
    inc = p.mode == "increasing"
    fwd = gt if inc else lt
    acc = fwd[:tile]
    for j in range(1, L - 1):
        acc = jnp.logical_and(acc, fwd[j:j + tile])
    pos = t0 + jnp.arange(tile, dtype=jnp.int32)
    sep = sep_ref[0]  # (tile,) exclusive end of each position's segment
    cand = acc & (pos <= sep - L)
    opp = (lt if inc else gt)[:tile] & (pos < sep - 1)

    # -- hash lanes: identical to the unpacked kernel ------------------------
    xw = ext.astype(jnp.uint32)
    lo, hi = [], []
    for g in range(2):
        w = _byte_mulmod(xw, wneg_ref[g])
        lo.append(jnp.cumsum(w & 0xFFFF, dtype=jnp.uint32))
        hi.append(jnp.cumsum(w >> 16, dtype=jnp.uint32))
    rneg = rneg_ref[0]
    rpos = rpos_ref[0]
    carry0 = sth_ref[0, 0]
    carry1 = sth_ref[0, 1]

    def tile_prefix(g, m):
        i = jnp.maximum(m - 1, 0)
        part = _addmod(_fold32(lo[g][i]), _rot31(_fold32(hi[g][i]), 16))
        return jnp.where(m > 0, part, jnp.uint32(0))

    def prefix_at(g, carry_g, e):
        m = jnp.clip(e - t0, 0, ext_len)
        return _addmod(carry_g, _mulmod31(rneg[g], tile_prefix(g, m)))

    def end_lookup(tab, g, e):
        """The (2, G) operand entry for the segment whose end == ``e``
        (duplicate ends from empty segments carry identical values)."""
        return jnp.max(jnp.where(ends == e, tab[g], jnp.uint32(0)))

    def chunk_fp(g, carry_g, ps_g, e):
        # a bound behind this tile is a cut: a segment end (pend/rend
        # operands, any depth) or a max-size cut a skip carried across the
        # tile edge (< skip_size behind: the sps left stash, with the
        # factor r^(e-1) = r^t0 * r^-(t0-e+1) off the negpow table)
        behind = e - 1 - t0 < 0
        is_end = jnp.any(ends == e)
        pe_b = jnp.where(is_end, end_lookup(pend, g, e),
                         sps_ref[g, jnp.clip(t0 - e, 0, HL)])
        pe = jnp.where(behind, pe_b, prefix_at(g, carry_g, e))
        diff = _addmod(pe, P31 - ps_g)
        fi = jnp.clip(e - 1 - t0, 0, ext_len - 1)
        rfac = _mulmod31(rpos[g], postab_ref[g, fi])
        rf_b = jnp.where(
            is_end, end_lookup(rend, g, e),
            _mulmod31(rpos[g], wneg_ref[g, jnp.clip(t0 - (e - 1), 0, HL + 1)]),
        )
        rfac = jnp.where(behind, rf_b, rfac)
        return pe, _mulmod31(diff, rfac)

    # -- packed boundary automaton: _scan_wide_packed's step per W-block -----
    iota = jnp.arange(W, dtype=jnp.int32)
    k0, c0, s0, cnt0, se0 = (sti_ref[0], sti_ref[1], sti_ref[2],
                             sti_ref[3], sti_ref[4])
    ps0 = sth_ref[1, 0], sth_ref[1, 1]

    def body(j, st):
        bstart = t0 + j * W
        bend = bstart + W
        cb = jax.lax.dynamic_slice(cand, (j * W,), (W,))
        ob = jax.lax.dynamic_slice(opp, (j * W,), (W,))

        def resolve_once(wst):
            k, c, s, cnt, se, ps_0, ps_1, go = wst
            in_block = (k < bend) & (s < n_row) & (t0 // W + j < nb_split)
            o = jnp.maximum(k - bstart, 0)
            active = iota >= o
            posw = bstart + iota
            kc = jnp.min(jnp.where(cb & active, posw, _BIG))
            cum = c + jnp.cumsum((ob & active).astype(jnp.int32))
            kt = jnp.min(jnp.where(ob & active & (cum > T), posw, _BIG))
            new_k, new_s, emit, bound, any_event = _resolve(
                k, c, s, kc, kt, bend, in_block, se, p
            )
            new_c = jnp.where(any_event, 0, jnp.where(in_block, cum[-1], c))
            pe0, fp0 = chunk_fp(0, carry0, ps_0, bound)
            pe1, fp1 = chunk_fp(1, carry1, ps_1, bound)
            idx = jnp.minimum(cnt, mc - 1)
            keep = emit & (cnt < mc)
            bounds_ref[0, idx] = jnp.where(keep, bound, bounds_ref[0, idx])
            lens_ref[0, idx] = jnp.where(keep, bound - s, lens_ref[0, idx])
            fps_ref[0, idx, 0] = jnp.where(keep, fp0, fps_ref[0, idx, 0])
            fps_ref[0, idx, 1] = jnp.where(keep, fp1, fps_ref[0, idx, 1])
            # a bound on the segment end advances to the next segment: the
            # emit's own register updates are the next stream's init state
            new_se = jnp.where(emit & (bound >= se), next_end(bound), se)
            # clamp the post-emit position to the next pending cut, exactly
            # as _scan_wide_packed does: the min-size skip may overleap a
            # run of tiny segments (and their end cuts) entirely
            new_k = jnp.where(
                emit, jnp.minimum(new_k, new_se - (L - 1)), new_k
            )
            # a late segment-end cut resets the scan inside this block:
            # re-resolve until the position clears it (_scan_wide_packed's
            # inner loop, block-for-block)
            go = emit & (new_k < bend) & (new_s < n_row)
            return (new_k, new_c, new_s, cnt + emit.astype(jnp.int32),
                    new_se, jnp.where(emit, pe0, ps_0),
                    jnp.where(emit, pe1, ps_1), go)

        wst = jax.lax.while_loop(
            lambda wst: wst[-1], resolve_once, st + (jnp.bool_(True),)
        )
        return wst[:-1]

    k, c, s, cnt, se, ps_0, ps_1 = jax.lax.fori_loop(
        0, nb, body, (k0, c0, s0, cnt0, se0, *ps0)
    )

    # -- final-boundary fixup: the row's payload end, dynamic here -----------
    last = jnp.where(
        cnt > 0, bounds_ref[0, jnp.clip(cnt - 1, 0, mc - 1)], 0)
    need = (t0 == last_t0) & (last < n_row) & (n_row > 0)
    pe0 = prefix_at(0, carry0, n_row)  # past-payload bytes are zero padding,
    pe1 = prefix_at(1, carry1, n_row)  # so the clipped read is exact even
    fp0 = _mulmod31(_addmod(pe0, P31 - ps_0),  # when n_row is behind t0
                    end_lookup(rend, 0, n_row))
    fp1 = _mulmod31(_addmod(pe1, P31 - ps_1),
                    end_lookup(rend, 1, n_row))
    idx = jnp.minimum(cnt, mc - 1)
    keep = need & (cnt < mc)
    bounds_ref[0, idx] = jnp.where(keep, n_row, bounds_ref[0, idx])
    lens_ref[0, idx] = jnp.where(keep, n_row - s, lens_ref[0, idx])
    fps_ref[0, idx, 0] = jnp.where(keep, fp0, fps_ref[0, idx, 0])
    fps_ref[0, idx, 1] = jnp.where(keep, fp1, fps_ref[0, idx, 1])
    cnt = cnt + need.astype(jnp.int32)

    # -- persist state for the next tile --------------------------------------
    counts_ref[0, 0] = cnt
    sti_ref[...] = jnp.stack([k, c, s, cnt, se])
    sth_ref[0, 0] = _addmod(carry0, _mulmod31(rneg[0], tile_prefix(0, tile)))
    sth_ref[0, 1] = _addmod(carry1, _mulmod31(rneg[1], tile_prefix(1, tile)))
    sth_ref[1, 0] = ps_0
    sth_ref[1, 1] = ps_1
    # left stash for the next tile: P(next_t0 - q), q in [0, HL] (tile > HL,
    # asserted by the wrapper, so every read lands inside this tile's limbs)
    li = tile - 1 - jnp.arange(HL + 1, dtype=jnp.int32)
    for g, carry_g in ((0, carry0), (1, carry1)):
        parts = _addmod(_fold32(lo[g][li]), _rot31(_fold32(hi[g][li]), 16))
        sps_ref[g] = _addmod(carry_g, _mulmod31(rneg[g], parts))


@functools.partial(
    jax.jit, static_argnames=("p", "max_chunks", "tile", "interpret")
)
def packed_pipeline_batch(
    data: jax.Array,
    seg_end_pos: jax.Array,
    ends: jax.Array,
    p: SeqCDCParams,
    *,
    max_chunks: int,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Chunk + fingerprint a segment-packed ``(B, S)`` batch in one dispatch.

    Each row holds several streams concatenated back to back (``ends``:
    (B, G) nondecreasing exclusive segment ends padded with the row's
    payload end; ``seg_end_pos``: (B, S) the segment end governing each
    byte position).  Returns the same ``(bounds, counts, fps, lengths)``
    layout as :func:`fused_pipeline_batch` but in row coordinates with
    every segment end present as a bound — bit-identical, per segment, to
    chunking each stream alone (``seqcdc.boundaries_packed`` composed with
    ``chunk_fingerprints`` is the split-path oracle; ``ref.packed_pipeline``
    is the per-stream host oracle).

    The 62-bit fingerprint is translation invariant (bytes are weighted by
    offset from the *chunk end*), so packed-row fps equal per-stream fps
    with no correction; only the prefix bookkeeping inside the kernel needs
    the per-segment ``P(end)``/``r^(end-1)`` operands, computed here from
    the row bytes with the same 16-bit-limb trick the kernel uses (exact
    because ``S <= 65536``, enforced below — one packed row is at most the
    fingerprint kernel's own byte bound).
    """
    assert data.ndim == 2, data.shape
    B, n = data.shape
    G = ends.shape[-1]
    mc = max_chunks
    if n == 0:  # static: no chunks
        return (jnp.full((B, mc), _BIG, jnp.int32),
                jnp.zeros((B,), jnp.int32),
                jnp.zeros((B, mc, 2), jnp.uint32),
                jnp.zeros((B, mc), jnp.int32))
    if p.max_size > MAX_CHUNK:
        raise ValueError(
            f"max_size {p.max_size} exceeds the fingerprint power-table "
            f"bound {MAX_CHUNK}"
        )
    if n > MAX_CHUNK:
        raise ValueError(
            f"packed row width {n} exceeds the limb-exactness bound "
            f"{MAX_CHUNK}; pack into narrower rows"
        )
    L = p.seq_length
    W = p.block_width
    halo = p.skip_size + L - 1
    nb_split = (n + p.skip_size + W + W - 1) // W
    cover = nb_split * W
    tile = min(tile, (cover + 1023) // 1024 * 1024)
    assert tile % 1024 == 0 and tile % W == 0, (tile, W)
    assert tile + halo <= MAX_CHUNK, (tile, halo)
    nt = (cover + tile - 1) // tile
    n_pad = nt * tile
    # the left-prefix stash reaches skip_size positions into the previous
    # tile; a skip wider than a tile would outrun it
    assert p.skip_size < tile, (p.skip_size, tile)

    x = jnp.pad(data.astype(jnp.uint8), ((0, 0), (0, n_pad - n)))
    # padding positions carry seg end 0: every clipped mask bit is false
    # there (pos >= n > 0 >= sep - L), matching the zero-pad bytes
    sep = jnp.pad(seg_end_pos.astype(jnp.int32), ((0, 0), (0, n_pad - n)))
    xh = jnp.pad(x, ((0, 0), (0, halo)))
    halos = jnp.stack(
        [xh[:, (i + 1) * tile:(i + 1) * tile + halo] for i in range(nt)],
        axis=1,
    )
    t0s = (jnp.arange(nt, dtype=jnp.int32) * tile).reshape(nt, 1)

    pm = (1 << 31) - 1
    wneg = jnp.stack(
        [jnp.asarray(_negpow_table_np(r, tile + halo)) for r in (R1, R2)]
    )
    postab = jnp.stack(
        [jnp.asarray(_pow_table_np(r)[: tile + halo]) for r in (R1, R2)]
    )
    rneg = jnp.asarray(np.array(
        [[pow(pow(r, pm - 2, pm), i * tile, pm) for r in (R1, R2)]
         for i in range(nt)], dtype=np.uint32))
    rpos = jnp.asarray(np.array(
        [[pow(r, i * tile, pm) for r in (R1, R2)] for i in range(nt)],
        dtype=np.uint32))

    # per-segment end operands: pend[b, g, i] = P_g(end_i) and
    # rend[b, g, i] = r_g^(end_i - 1) — row-wide limb prefix sums gathered
    # at the segment ends (uint32 cumsums of < 2^16 limbs over n <= 65536
    # entries: exact, the kernel's own argument)
    ends = ends.astype(jnp.int32)
    e_idx = jnp.clip(ends - 1, 0, n - 1)  # (B, G)
    full_pow = jnp.stack(
        [jnp.asarray(_pow_table_np(r)[:n]) for r in (R1, R2)]
    )  # (2, n): r^q for q < n; end - 1 < n always
    wneg_row = jnp.stack(
        [jnp.asarray(_negpow_table_np(r, n)) for r in (R1, R2)]
    )
    pr, rr = [], []
    for g in range(2):
        w = _byte_mulmod(data.astype(jnp.uint32), wneg_row[g])  # (B, n)
        lo = jnp.cumsum(w & 0xFFFF, axis=-1, dtype=jnp.uint32)
        hi = jnp.cumsum(w >> 16, axis=-1, dtype=jnp.uint32)
        pg = _addmod(
            _fold32(jnp.take_along_axis(lo, e_idx, axis=-1)),
            _rot31(_fold32(jnp.take_along_axis(hi, e_idx, axis=-1)), 16),
        )
        pr.append(jnp.where(ends > 0, pg, jnp.uint32(0)))
        rr.append(jnp.where(ends > 0, full_pow[g][e_idx], jnp.uint32(0)))
    pend = jnp.stack(pr, axis=1)  # (B, 2, G)
    rend = jnp.stack(rr, axis=1)  # (B, 2, G)

    from jax.experimental.pallas import tpu as pltpu

    bounds, counts, fps, lens = pl.pallas_call(
        functools.partial(
            _packed_pipeline_kernel, p=p, mc=mc, tile=tile, halo=halo,
            nb_split=nb_split, last_t0=(nt - 1) * tile,
        ),
        grid=(B, nt),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (i, 0)),  # t0
            pl.BlockSpec((1, tile), lambda b, i: (b, i)),
            pl.BlockSpec((1, 1, halo), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, tile), lambda b, i: (b, i)),  # seg_end_pos
            pl.BlockSpec((1, G), lambda b, i: (b, 0)),  # ends
            pl.BlockSpec((1, 2, G), lambda b, i: (b, 0, 0)),  # P(end)
            pl.BlockSpec((1, 2, G), lambda b, i: (b, 0, 0)),  # r^(end-1)
            pl.BlockSpec((1, 2), lambda b, i: (i, 0)),  # r^-t0
            pl.BlockSpec((1, 2), lambda b, i: (i, 0)),  # r^t0
            pl.BlockSpec((2, tile + halo), lambda b, i: (0, 0)),
            pl.BlockSpec((2, tile + halo), lambda b, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, mc), lambda b, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
            pl.BlockSpec((1, mc, 2), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, mc), lambda b, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, mc), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, mc, 2), jnp.uint32),
            jax.ShapeDtypeStruct((B, mc), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((5,), jnp.int32),  # automaton k, c, s, cnt, se
            pltpu.VMEM((2, 2), jnp.uint32),  # P(t0) carry, P(s) latch
            pltpu.VMEM((2, p.skip_size + 1), jnp.uint32),  # P(t0-q) stash
        ],
        interpret=interpret,
    )(t0s, x, halos, sep, ends, pend, rend, rneg, rpos, wneg, postab)
    return bounds, counts[:, 0], fps, lens
