"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel in ``kernels/`` must match its oracle bit-for-bit across the
shape/dtype sweep in tests/test_kernels.py (interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import masks as _core_masks

# ---------------------------------------------------------------------------
# SeqCDC candidate/opposing bitmaps (paper SSIII-D) — oracle is core.masks.
# ---------------------------------------------------------------------------


def seqcdc_masks(data: jax.Array, seq_length: int, mode: str = "increasing"):
    """(candidate, opposing) bool bitmaps, shape = data.shape."""
    return _core_masks.seqcdc_masks(data, seq_length, mode)


# ---------------------------------------------------------------------------
# Gear rolling hash (SS-CDC / FastCDC baseline substrate).
# ---------------------------------------------------------------------------


import functools


@functools.lru_cache(maxsize=None)
def _gear_table_np(seed: int):
    import numpy as np

    mask = (1 << 64) - 1  # python ints: no overflow warnings, exact wraparound
    x = seed
    out = []
    for _ in range(256):
        x = (x + 0x9E3779B97F4A7C15) & mask
        z = x
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & mask
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
        z = z ^ (z >> 31)
        out.append(z & 0xFFFFFFFF)
    return np.asarray(out, dtype=np.uint32)


def gear_table(seed: int = 0x9E3779B1) -> jax.Array:
    """Deterministic 256-entry Gear table (splitmix-style, uint32)."""
    return jnp.asarray(_gear_table_np(seed))


def gear_hash(data: jax.Array, table: jax.Array | None = None) -> jax.Array:
    """Sequential Gear: h[i] = (h[i-1] << 1) + G[b[i]]  (uint32 wraparound).

    The oracle for kernels/gear_hash.py.  Note the rolling window is
    effectively 32 bytes: contributions shift out of the 32-bit register.
    """
    if table is None:
        table = gear_table()
    d = data.astype(jnp.int32)
    g = table[d]  # (n,) uint32

    def step(h, gi):
        h = (h << 1) + gi
        return h, h

    _, hs = jax.lax.scan(step, jnp.uint32(0), g)
    return hs


def gear_hash_parallel(data: jax.Array, table: jax.Array | None = None) -> jax.Array:
    """Window-32 direct form: h[i] = sum_{j=0..31} G[b[i-j]] << j (uint32).

    Exactly equals :func:`gear_hash` for all i (positions i < 31 include only
    the existing terms).  This is the parallel decomposition the Pallas kernel
    implements (DESIGN.md SS2: redundant lookups traded for full parallelism).
    """
    if table is None:
        table = gear_table()
    g = table[data.astype(jnp.int32)]
    n = g.shape[-1]
    acc = jnp.zeros_like(g)
    for j in range(32):
        shifted = jnp.roll(g, j, axis=-1) << j
        idx = jnp.arange(n)
        shifted = jnp.where(idx >= j, shifted, 0)
        acc = acc + shifted
    return acc


# ---------------------------------------------------------------------------
# Flash attention (LM-substrate hot spot; EXPERIMENTS.md SSPerf cell A).
# ---------------------------------------------------------------------------


def flash_attention(q, k, v, scale: float | None = None, causal: bool = True):
    """Materialized-softmax oracle for kernels/flash_attn.py.

    q/k/v: (B, S, H, hd), equal head counts (repeat-KV upstream for GQA).
    """
    B, S, H, hd = q.shape
    if scale is None:
        scale = 1.0 / (hd**0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Chunk fingerprints (paper SSII chunk hashing; dedup hot path).
# ---------------------------------------------------------------------------


def chunk_fingerprints(data, bounds, count, *, max_chunks: int):
    """Oracle for kernels/fingerprint.py: the jnp searchsorted/gather/
    segment_sum chain in dedup/fingerprint.py (``fp_impl="reference"``).
    ``fingerprints_numpy`` there is the host-side ground truth for both.
    """
    from repro.dedup.fingerprint import chunk_fingerprints as _cf

    return _cf(data, bounds, count, max_chunks=max_chunks,
               fp_impl="reference")


# ---------------------------------------------------------------------------
# Fused chunk+fingerprint pipeline (single-dispatch service hot path).
# ---------------------------------------------------------------------------


def fused_pipeline(data, p, *, max_chunks: int):
    """Oracle for kernels/fused_pipeline.py: the composed split path —
    ``boundaries_batch(step_impl="wide")`` followed by the vmapped
    reference ``chunk_fingerprints`` — over a ``(B, S)`` batch.  This is
    the normative three-dispatch pipeline the fused kernel collapses.
    """
    from repro.core.seqcdc import boundaries_batch
    from repro.dedup.fingerprint import chunk_fingerprints as _cf

    bounds, counts = boundaries_batch(data, p, max_chunks=max_chunks)
    fps, lens = jax.vmap(
        lambda d, b, c: _cf(d, b, c, max_chunks=max_chunks,
                            fp_impl="reference")
    )(data, bounds, counts)
    return bounds, counts, fps, lens


def packed_pipeline(data, seg_lens, p, *, max_chunks: int):
    """Oracle for the segment-packed pipeline: chunk each stream *alone*.

    ``data``: (B, S) uint8 rows of concatenated streams; ``seg_lens``: per
    row, the list of stream lengths packed into it (zeros allowed — empty
    streams contribute no chunks).  Every segment runs through the host
    ground truth (``oracle.boundaries_numpy`` + ``fingerprints_numpy`` —
    the normative pair the whole equivalence suite anchors on, so this
    oracle cannot share a bug with either device path) and the results are
    re-offset into row coordinates.  Returns the packed layout
    ``(bounds (B, mc) int32 sentinel-padded, counts (B,), fps (B, mc, 2),
    lengths (B, mc))``.
    """
    import numpy as np

    from repro.core import oracle as _oracle
    from repro.core.automaton import _BIG
    from repro.dedup.fingerprint import fingerprints_numpy

    data = np.asarray(data, dtype=np.uint8)
    B = data.shape[0]
    mc = max_chunks
    bounds = np.full((B, mc), int(_BIG), dtype=np.int32)
    counts = np.zeros((B,), dtype=np.int32)
    fps = np.zeros((B, mc, 2), dtype=np.uint32)
    lens = np.zeros((B, mc), dtype=np.int32)
    for bi, lens_b in enumerate(seg_lens):
        off = 0
        j = 0
        for m in lens_b:
            seg = data[bi, off:off + m]
            bb = _oracle.boundaries_numpy(seg, p)
            ff = fingerprints_numpy(seg, bb)
            k = len(bb)
            bounds[bi, j:j + k] = np.asarray(bb, dtype=np.int32) + off
            fps[bi, j:j + k] = ff
            lens[bi, j:j + k] = np.diff(np.concatenate([[0], bb]))
            off += m
            j += k
        counts[bi] = j
    return bounds, counts, fps, lens


# ---------------------------------------------------------------------------
# Block maxima (VectorCDC / RAM-AE range-scan substrate).
# ---------------------------------------------------------------------------


def block_max(data: jax.Array, block: int = 128) -> jax.Array:
    """Per-block byte maxima; data length must be a multiple of ``block``."""
    n = data.shape[-1]
    assert n % block == 0, (n, block)
    return jnp.max(data.reshape(*data.shape[:-1], n // block, block), axis=-1)
