"""Pallas TPU kernel: parallel Gear rolling hash (SS-CDC substrate).

The Gear recurrence h[i] = (h[i-1] << 1) + G[b[i]] (uint32) looks sequential,
but the 32-bit register forgets contributions older than 32 bytes, so the
hash admits the closed window form

    h[i] = sum_{j=0..31} G[b[i-j]] << j      (uint32 wraparound)

— 32 independent table lookups + shifted adds per position.  This is the TPU
answer to SS-CDC's "roll with multiple heads" AVX-512 trick: instead of
scatter/gather across stream regions (expensive on TPU), we trade 32x
redundant VMEM table lookups for full data parallelism.  See DESIGN.md SS2.

Each grid step stages a TILE block with a 31-byte *left* halo of real
predecessor bytes; the first 31 positions of the stream (no predecessors) are
fixed up exactly in the wrapper.  The 256 x uint32 Gear table rides along in
VMEM (1 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gear_table

DEFAULT_TILE = 32 * 1024
_WIN = 32


def _gear_kernel(x_ref, head_ref, table_ref, out_ref):
    x = x_ref[...]  # (TILE,) uint8
    head = head_ref[0]  # (31,) uint8 : last 31 bytes of previous tile
    table = table_ref[...]  # (256,) uint32
    ext = jnp.concatenate([head, x])  # (TILE + 31,)
    g = table[ext.astype(jnp.int32)]  # VMEM gather
    tile = x.shape[0]
    acc = jnp.zeros((tile,), dtype=jnp.uint32)
    for j in range(_WIN):  # h[i] = sum_j G[b[i-j]] << j
        acc = acc + (g[_WIN - 1 - j : _WIN - 1 - j + tile] << j)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def gear_hash_pallas(
    data: jax.Array,
    table: jax.Array | None = None,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jax.Array:
    """Per-position uint32 Gear hash of a 1-D uint8 stream (any length)."""
    assert data.ndim == 1, data.shape
    n = data.shape[0]
    if table is None:
        table = gear_table()
    if n == 0:
        return jnp.zeros((0,), dtype=jnp.uint32)
    tile = min(tile, max(1024, ((n + 1023) // 1024) * 1024))
    n_pad = (n + tile - 1) // tile * tile
    x = jnp.pad(data.astype(jnp.uint8), (0, n_pad - n))
    nt = n_pad // tile
    # heads[i] = x[i*tile - 31 : i*tile]  (zeros for i == 0)
    heads = jnp.pad(x, (tile, 0)).reshape(nt + 1, tile)[:-1, -(_WIN - 1):]

    out = pl.pallas_call(
        _gear_kernel,
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1, _WIN - 1), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(x, heads, table)

    out = out[:n]
    # exact fix-up for the first 31 positions (zero-halo contributions differ)
    k = min(_WIN - 1, n)
    g0 = table[data[:k].astype(jnp.int32)]
    fix = jnp.zeros((k,), dtype=jnp.uint32)
    idx = jnp.arange(k)
    for j in range(_WIN):
        if j >= k:
            break
        sh = jnp.where(idx >= j, jnp.roll(g0, j) << j, 0)
        fix = fix + sh.astype(jnp.uint32)
    return out.at[:k].set(fix) if k else out
