"""Pallas TPU kernels for the chunking hot-spots the paper optimizes."""
from . import ops, ref  # noqa: F401
