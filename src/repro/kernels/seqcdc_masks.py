"""Pallas TPU kernel: SeqCDC candidate/opposing bitmaps (phase 1).

TPU adaptation of the paper's AVX-512 scan (SSIII-D, Fig. 3).  The AVX version
loads 64-byte registers at offsets 0..SeqLength-1 and combines pairwise
``cmpgt`` masks; here each grid step stages a TILE-byte VMEM block (plus an
(L-1)-byte halo from the next tile, passed as a second operand so BlockSpecs
stay non-overlapping) and performs the same shifted compares on 8x128 VPU
lanes.  Per byte of input the kernel does L-1 compares + L-2 ANDs + 1 compare
— arithmetic intensity ~L ops/byte, firmly HBM-bandwidth-bound, which is the
design point: phase 1 runs at memory speed and phase 2 (core/automaton.py)
touches only per-block summaries.

VMEM budget per grid step (TILE = 64 KiB): input 64 KiB + halo + 2x64 KiB
bool outputs + shifted temporaries ~ 0.4 MiB << 16 MiB VMEM.  TILE is a
multiple of 1024 so the flattened byte vector maps onto whole (8,128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 64 * 1024


def _masks_kernel(x_ref, tail_ref, cand_ref, opp_ref, *, L: int, inc: bool):
    x = x_ref[...]  # (TILE,) uint8
    t = tail_ref[0]  # (HALO,) uint8 : first HALO bytes of the next tile
    ext = jnp.concatenate([x, t])  # (TILE + L - 1,)
    a = ext[:-1]
    b = ext[1:]
    gt = b > a  # (TILE + L - 2,)
    lt = b < a
    fwd = gt if inc else lt
    opp = lt if inc else gt
    tile = x.shape[0]
    acc = fwd[:tile]
    for j in range(1, L - 1):  # AND of L-1 shifted pair masks (paper's M1&M2&..)
        acc = jnp.logical_and(acc, fwd[j : j + tile])
    cand_ref[...] = acc
    opp_ref[...] = opp[:tile]


@functools.partial(
    jax.jit, static_argnames=("seq_length", "mode", "tile", "interpret")
)
def seqcdc_masks_pallas(
    data: jax.Array,
    seq_length: int,
    mode: str = "increasing",
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(candidate, opposing) bitmaps for a 1-D uint8 stream of any length.

    Pads to a tile multiple, runs the grid, then masks the tail so that
    cand[k] is False for k > n - L and opp[n-1:] is False — bit-identical to
    kernels/ref.py::seqcdc_masks.
    """
    assert data.ndim == 1, data.shape
    n = data.shape[0]
    L = int(seq_length)
    halo = max(L - 1, 1)
    inc = mode == "increasing"
    if n == 0:
        z = jnp.zeros((0,), dtype=bool)
        return z, z
    tile = min(tile, max(1024, ((n + 1023) // 1024) * 1024))
    n_pad = (n + tile - 1) // tile * tile
    x = jnp.pad(data.astype(jnp.uint8), (0, n_pad - n))
    nt = n_pad // tile
    # tails[i] = x[(i+1)*tile : (i+1)*tile + halo], zero past the end
    tails = jnp.pad(x, (0, tile)).reshape(nt + 1, tile)[1:, :halo]

    cand, opp = pl.pallas_call(
        functools.partial(_masks_kernel, L=L, inc=inc),
        grid=(nt,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1, halo), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
            jax.ShapeDtypeStruct((n_pad,), jnp.bool_),
        ],
        interpret=interpret,
    )(x, tails)

    idx = jnp.arange(n)
    cand = jnp.where(idx <= n - L, cand[:n], False)
    opp = jnp.where(idx < n - 1, opp[:n], False)
    return cand, opp
