"""repro.launch — mesh construction, dry-run, train/serve CLIs.

NOTE: importing this package must not initialize jax devices; dryrun.py sets
XLA_FLAGS before any jax import and must stay the process entry point.
"""
