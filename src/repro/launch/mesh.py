"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the gradient all-reduce
crosses the pod axis (DCI) — the multi-pod dry-run proves that axis shards.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) > n:  # 512 placeholders present, single-pod mesh: use first 256
        devices = devices[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests / examples on CPU)."""
    devices = jax.devices()
    data = max(1, len(devices) // model)
    return jax.make_mesh((data, model), ("data", "model"), devices=devices[: data * model])
