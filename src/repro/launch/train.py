"""Training CLI: end-to-end driver over the public API.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \\
      --steps 200 --batch 8 --seq 256 --ckpt /tmp/ck

Runs the full stack on local devices: corpus -> SeqCDC dedup ingest ->
token loader -> sharded train step -> CDC incremental checkpoints with
restart support.  With --reduced (default on CPU) the family-preserving
smoke config is used; on a real pod the full config + production mesh apply.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--corpus-mb", type=int, default=8)
    ap.add_argument("--dedup", action="store_true", default=True)
    ap.add_argument("--no-dedup", dest="dedup", action="store_false")
    args = ap.parse_args()

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, get_reduced
    from repro.data import DedupIngest, LoaderConfig, PipelineConfig, TokenLoader
    from repro.data.corpus import load_dataset
    from repro.train import LoopConfig, OptConfig, Trainer

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} needs a modality frontend; train an LM arch")

    corpus = load_dataset("DEB", args.corpus_mb)
    if args.dedup:
        ing = DedupIngest(PipelineConfig(avg_chunk=8192, segment_bytes=1 << 20))
        corpus = np.concatenate(list(ing.unique_bytes(corpus)))
        print(f"dedup ingest: {ing.savings:.1%} duplicate bytes removed; "
              f"{corpus.nbytes >> 20} MiB remain")
    corpus = np.minimum(corpus, cfg.vocab_size - 1).astype(np.uint8)

    loader = TokenLoader(corpus, LoaderConfig(batch_size=args.batch, seq_len=args.seq))
    ckpt = CheckpointManager(args.ckpt) if args.ckpt else None
    trainer = Trainer(
        cfg,
        OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                  total_steps=args.steps),
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every),
        loader,
        ckpt,
    )
    params, _ = trainer.run(jax.random.PRNGKey(0))
    print(f"final loss {trainer.history[-1]['loss']:.4f} "
          f"({len(trainer.history)} steps run)")
    if ckpt:
        print(f"checkpoint store savings: {ckpt.dedup_savings:.1%}")
    if trainer.monitor.events:
        print(f"straggler events: {len(trainer.monitor.events)}")


if __name__ == "__main__":
    main()
