"""Serving CLI: batched generation with the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \\
      --requests 8 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    args = ap.parse_args()

    from repro.configs import get_reduced
    from repro.models import lm
    from repro.serve import Engine, ServeConfig

    cfg = get_reduced(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(
        max_slots=args.slots, cache_len=args.cache_len,
        max_new_tokens=args.max_new,
    ))
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 48))
        eng.submit(rng.integers(0, cfg.vocab_size, plen))
    out = eng.run()
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in out.values())
    print(f"served {len(out)} requests / {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on {args.slots} slots)")


if __name__ == "__main__":
    main()
