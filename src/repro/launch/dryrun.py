import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder devices host the production meshes, every cell's
step function is jit-lowered with sharded ShapeDtypeStructs and compiled by
the full SPMD pipeline, and the compiled artifact yields the memory and
roofline numbers recorded in EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs import SHAPES, ModelConfig, get_config, shape_applicable
from repro.distributed.sharding import ShardingRules, rules_for_config, use_rules
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.roofline import analyze
from repro.train import optim, step as step_mod


def shardings_for(template, rules: ShardingRules):
    return rules.sharding_tree(template)


def build_cell(cfg: ModelConfig, shape, mesh, *, opt_cfg=None):
    """Returns (jitted fn, abstract args tuple) for one cell."""
    rules = ShardingRules(mesh, rules_for_config(mesh, cfg))
    repl = NamedSharding(mesh, PS())

    p_tpl = S.params_template(cfg)
    p_sh = shardings_for(p_tpl, rules)
    p_abs = S.abstract_params(cfg)

    if shape.kind == "train":
        opt_cfg = opt_cfg or optim.OptConfig()
        o_sh = optim.OptState(p_sh, p_sh, repl)
        o_abs = S.abstract_opt(cfg, opt_cfg.opt_dtype)
        b_tpl = S.batch_template(cfg, shape)
        b_sh = rules.sharding_tree(b_tpl)
        b_abs = S.abstract_batch(cfg, shape)
        step = step_mod.make_train_step(cfg, opt_cfg)

        def fn(params, opt_state, batch):
            with use_rules(rules):  # activate constrain() at trace time
                return step(params, opt_state, batch)

        jfn = jax.jit(
            fn,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
        )
        return jfn, (p_abs, o_abs, b_abs)

    if shape.kind == "prefill":
        b_tpl = S.batch_template(cfg, shape)
        b_sh = rules.sharding_tree(b_tpl)
        b_abs = S.abstract_batch(cfg, shape)
        c_tpl = S.caches_template(cfg, shape)
        c_sh = [shardings_for(t, rules) for t in c_tpl]

        def fn(params, batch):
            with use_rules(rules):
                return lm.prefill_step(cfg, params, batch, cache_len=shape.seq_len)

        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=(None, c_sh))
        return jfn, (p_abs, b_abs)

    # decode: one new token against a seq_len-long cache
    c_tpl = S.caches_template(cfg, shape)
    c_sh = [shardings_for(t, rules) for t in c_tpl]
    c_abs = S.abstract_caches(cfg, shape)
    tokens, pos = S.decode_inputs(cfg, shape)
    tok_sh = rules.sharding_tree(
        {"t": S.PT((shape.global_batch, 1), ("batch", None), "zeros")}
    )["t"]

    def fn(params, caches, tokens, pos):
        with use_rules(rules):
            return lm.decode_step(cfg, params, caches, tokens, pos)

    jfn = jax.jit(
        fn,
        in_shardings=(p_sh, c_sh, tok_sh, repl),
        out_shardings=(None, c_sh),
    )
    return jfn, (p_abs, c_abs, tokens, pos)


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped (full-attention arch; see DESIGN.md SS5)"
        return rec
    try:
        t0 = time.time()
        jfn, args = build_cell(cfg, shape, mesh)
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        rl = analyze.from_compiled(
            arch, shape_name, mesh_name, mesh.size, compiled,
            cfg=cfg, shape_cfg=shape,
        )
        rec.update(rl.to_dict())
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            peak_bytes=(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        )
    except Exception as e:  # a failing cell is a bug: record it loudly
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import ARCHS

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh, mesh_name)
                records.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (
                        f" compile={rec['compile_s']}s"
                        f" flops/dev={rec['flops_per_device']:.3e}"
                        f" coll/dev={rec['collective_bytes_per_device']:.3e}B"
                        f" bottleneck={rec['bottleneck']}"
                    )
                print(f"[{mesh_name}] {arch} x {shape_name}: {status}{extra}", flush=True)
                if status == "FAILED":
                    print(rec["error"], flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    n_fail = sum(r["status"] == "FAILED" for r in records)
    print(f"cells: {len(records)}  failed: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
