"""Abstract input/state specs for every (architecture x input-shape) cell.

``input_specs()`` returns weak-type-correct ShapeDtypeStruct stand-ins for
every model input (no device allocation), plus the matching logical-axes
trees from which distributed.sharding derives NamedShardings.  This is what
launch/dryrun.py lowers and compiles, and what the roofline reads.

Cell kinds (configs.base.SHAPES):
  train    -> train_step(params, opt_state, batch)
  prefill  -> prefill_step(params, batch) -> (last logits, caches)
  decode   -> decode_step(params, caches, tokens, pos)  [one new token
              against a seq_len-long KV cache]
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeConfig
from repro.models import lm
from repro.models import transformer as tfm
from repro.models.layers import PT, template_map
from repro.train import optim


def abstract_tree(template, dtype):
    """PT tree -> ShapeDtypeStruct tree."""
    return template_map(lambda t: jax.ShapeDtypeStruct(t.shape, dtype), template)


def params_template(cfg: ModelConfig):
    return lm.lm_template(cfg)


def abstract_params(cfg: ModelConfig):
    return abstract_tree(params_template(cfg), jnp.dtype(cfg.param_dtype))


def opt_template(cfg: ModelConfig):
    """Optimizer state template mirroring the parameter tree (mu, nu, count)."""
    pt = params_template(cfg)
    return optim.OptState(pt, pt, PT((), (), "zeros"))


def abstract_opt(cfg: ModelConfig, opt_dtype: str = "float32"):
    pt = params_template(cfg)
    mu = abstract_tree(pt, jnp.dtype(opt_dtype))
    return optim.OptState(mu, mu, jax.ShapeDtypeStruct((), jnp.int32))


def batch_template(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, PT]:
    """Model input templates for a train/prefill cell (per input mode)."""
    B, S = shape.global_batch, shape.seq_len
    t: Dict[str, PT] = {}
    if cfg.input_mode == "tokens":
        t["tokens"] = PT((B, S), ("batch", "seq"), "zeros")
    elif cfg.input_mode == "embeddings":
        t["embeds"] = PT((B, S, cfg.d_model), ("batch", "seq", None), "zeros")
    else:  # mixed: anyres patch embeddings + text tokens
        s_txt = S - cfg.img_tokens
        assert s_txt > 0, (S, cfg.img_tokens)
        t["tokens"] = PT((B, s_txt), ("batch", "seq"), "zeros")
        t["embeds"] = PT((B, cfg.img_tokens, cfg.d_model), ("batch", "seq", None), "zeros")
    if shape.kind == "train":
        t["labels"] = PT((B, S), ("batch", "seq"), "zeros")
    return t


def abstract_batch(cfg: ModelConfig, shape: ShapeConfig):
    t = batch_template(cfg, shape)
    out = {}
    for k, pt in t.items():
        dt = jnp.dtype(cfg.compute_dtype) if k == "embeds" else jnp.int32
        out[k] = jax.ShapeDtypeStruct(pt.shape, dt)
    return out


def caches_template(cfg: ModelConfig, shape: ShapeConfig):
    return tfm.stack_cache_template(cfg, shape.global_batch, shape.seq_len)


def abstract_caches(cfg: ModelConfig, shape: ShapeConfig):
    return [
        abstract_tree(t, jnp.dtype(cfg.compute_dtype))
        for t in caches_template(cfg, shape)
    ]


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig):
    """(tokens, pos) abstract inputs for a decode cell."""
    B = shape.global_batch
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return tokens, pos


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Every abstract input of the cell, keyed by role (the dry-run contract)."""
    out: Dict[str, Any] = {"params": abstract_params(cfg)}
    if shape.kind == "train":
        out["opt_state"] = abstract_opt(cfg)
        out["batch"] = abstract_batch(cfg, shape)
    elif shape.kind == "prefill":
        out["batch"] = abstract_batch(cfg, shape)
    else:  # decode
        out["caches"] = abstract_caches(cfg, shape)
        tokens, pos = decode_inputs(cfg, shape)
        out["tokens"], out["pos"] = tokens, pos
    return out
