"""Batched serving example: continuous batching over mixed-length prompts.

  PYTHONPATH=src python examples/serve_batched.py

Spins up the slot-based engine on a reduced llama config, submits more
requests than slots, and verifies the greedy outputs equal the naive
(unbatched, uncached) forward pass — KV-cache serving correctness.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import lm
from repro.serve import Engine, ServeConfig

cfg = get_reduced("llama3.2-1b")
params = lm.init_params(cfg, jax.random.PRNGKey(0))
rng = np.random.default_rng(7)

engine = Engine(cfg, params, ServeConfig(max_slots=3, cache_len=128, max_new_tokens=12))
prompts = {engine.submit(rng.integers(0, cfg.vocab_size, int(rng.integers(4, 40)))): None
           for _ in range(7)}
results = engine.run()
print(f"served {len(results)} requests on 3 slots (continuous batching)")

# verify one request against the naive no-cache reference
rid = min(results)
req = [r for r in engine.done.values() if r.rid == rid][0]
seq = list(map(int, req.prompt))
ref = []
for _ in range(12):
    logits = lm.forward(cfg, params, {"tokens": jnp.asarray(seq)[None]})
    t = int(jnp.argmax(logits[0, -1]))
    ref.append(t)
    seq.append(t)
assert results[rid] == ref, "engine must match the uncached reference"
print(f"request {rid}: {len(results[rid])} tokens, bit-identical to the "
      "uncached forward pass")
