"""End-to-end driver: dedup data pipeline -> LM pretraining -> incremental
checkpoints -> restart, all through the public API.

  PYTHONPATH=src python examples/train_dedup_lm.py
  PYTHONPATH=src python examples/train_dedup_lm.py --steps 6 --corpus-mb 1 \\
      --ckpt-every 2 --crash-at 4        # reduced smoke (tests/test_examples.py)

Trains a ~1M-param llama-family model on an LM-text corpus with controlled
near-duplication from the scenario engine (``repro.scenarios``), dedups it
with the paper's chunker before tokenization, checkpoints through the CDC
store, then simulates a node failure at ``--crash-at`` and proves the
restart resumes exactly there.  ``--crash-at`` must be a multiple of
``--ckpt-every`` (the crash lands on a step with a checkpoint, like the
original 200/100 schedule).
"""
import argparse
import os
import shutil
import sys
import tempfile

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data import DedupIngest, LoaderConfig, PipelineConfig, TokenLoader
from repro.scenarios import lm_training_corpus
from repro.train import LoopConfig, OptConfig, Trainer


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--corpus-mb", type=float, default=16.0,
                    help="LM-text corpus size (scenario-engine generated)")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--crash-at", type=int, default=200,
                    help="step to kill the first trainer at; must be a "
                         "multiple of --ckpt-every and < --steps")
    ap.add_argument("--avg-chunk", type=int, default=1024,
                    help="dedup chunk grain; LM text needs the catalog's "
                         "fine 1 KiB grain to resync (docs/SCENARIOS.md)")
    ap.add_argument("--seed", type=int, default=303)
    args = ap.parse_args(argv)
    if args.crash_at % args.ckpt_every or not 0 < args.crash_at < args.steps:
        ap.error("--crash-at must be a multiple of --ckpt-every in "
                 "(0, --steps)")

    cfg = get_reduced("llama3.2-1b")

    # -- 1. data: dedup the corpus with the paper's chunker before
    #    tokenization; the scenario generator plants real near-duplicates --
    corpus = lm_training_corpus(args.corpus_mb, seed=args.seed)
    ing = DedupIngest(
        PipelineConfig(avg_chunk=args.avg_chunk, segment_bytes=1 << 20))
    unique = np.concatenate(list(ing.unique_bytes(corpus)))
    print(f"dedup ingest: {corpus.nbytes >> 20} MiB -> "
          f"{unique.nbytes >> 20} MiB "
          f"({ing.savings:.1%} duplicates removed before training)")
    unique = np.minimum(unique, cfg.vocab_size - 1).astype(np.uint8)

    loader = TokenLoader(unique, LoaderConfig(batch_size=8, seq_len=128))

    workdir = tempfile.mkdtemp(prefix="repro-train-")
    try:
        def make_trainer():
            return Trainer(
                cfg,
                OptConfig(lr=1e-3, warmup_steps=min(20, args.steps // 3),
                          total_steps=args.steps),
                LoopConfig(total_steps=args.steps,
                           ckpt_every=args.ckpt_every,
                           log_every=max(1, args.steps // 6)),
                loader,
                CheckpointManager(os.path.join(workdir, "ckpt")),
            )

        # -- 2. train, "crash" at --crash-at, restart, finish ----------------
        t1 = make_trainer()
        t1.run(jax.random.PRNGKey(0), steps=args.crash_at)  # node failure here
        print(f"-- simulated failure after step {args.crash_at - 1}; "
              f"restarting from checkpoint --")
        t2 = make_trainer()
        params, _ = t2.run(jax.random.PRNGKey(0))  # resumes, runs to --steps
        assert t2.history[0]["step"] == args.crash_at

        ck = t2.ckpt
        print(f"loss: {t1.history[0]['loss']:.3f} -> "
              f"{t2.history[-1]['loss']:.3f}")
        print(f"checkpoint store dedup savings: {ck.dedup_savings:.1%} "
              f"(adjacent checkpoints share chunks)")
        return {
            "ingest_savings": float(ing.savings),
            "ckpt_savings": float(ck.dedup_savings),
            "resume_step": int(t2.history[0]["step"]),
            "final_step": int(t2.history[-1]["step"]),
            "first_loss": float(t1.history[0]["loss"]),
            "final_loss": float(t2.history[-1]["loss"]),
        }
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
