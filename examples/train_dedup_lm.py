"""End-to-end driver: dedup data pipeline -> LM pretraining -> incremental
checkpoints -> restart, all through the public API.

  PYTHONPATH=src python examples/train_dedup_lm.py

Trains a ~1M-param llama-family model for a few hundred steps on a
deduplicated byte corpus, checkpoints through the CDC store, then simulates
a node failure and proves the restart is bit-deterministic.
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data import DedupIngest, LoaderConfig, PipelineConfig, TokenLoader
from repro.data.corpus import load_dataset
from repro.train import LoopConfig, OptConfig, Trainer

STEPS = 300
cfg = get_reduced("llama3.2-1b")

# -- 1. data: dedup the corpus with the paper's chunker before tokenization --
corpus = load_dataset("DEV", 16)  # backup-like corpus: heavy duplication
ing = DedupIngest(PipelineConfig(avg_chunk=8192, segment_bytes=1 << 20))
unique = np.concatenate(list(ing.unique_bytes(corpus)))
print(f"dedup ingest: {corpus.nbytes >> 20} MiB -> {unique.nbytes >> 20} MiB "
      f"({ing.savings:.1%} duplicates removed before training)")
unique = np.minimum(unique, cfg.vocab_size - 1).astype(np.uint8)

loader = TokenLoader(unique, LoaderConfig(batch_size=8, seq_len=128))

workdir = tempfile.mkdtemp(prefix="repro-train-")
try:
    def make_trainer():
        return Trainer(
            cfg,
            OptConfig(lr=1e-3, warmup_steps=20, total_steps=STEPS),
            LoopConfig(total_steps=STEPS, ckpt_every=100, log_every=50),
            loader,
            CheckpointManager(os.path.join(workdir, "ckpt")),
        )

    # -- 2. train, "crash" at step 200, restart, finish ----------------------
    t1 = make_trainer()
    t1.run(jax.random.PRNGKey(0), steps=200)  # node failure here
    print("-- simulated failure after step 199; restarting from checkpoint --")
    t2 = make_trainer()
    params, _ = t2.run(jax.random.PRNGKey(0))  # resumes at 200, runs to 300
    assert t2.history[0]["step"] == 200

    ck = t2.ckpt
    print(f"loss: {t1.history[0]['loss']:.3f} -> {t2.history[-1]['loss']:.3f}")
    print(f"checkpoint store dedup savings: {ck.dedup_savings:.1%} "
          f"(adjacent checkpoints share chunks)")
finally:
    shutil.rmtree(workdir, ignore_errors=True)
