"""Quickstart: SeqCDC chunking + deduplication in ten lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import make_chunker
from repro.data import snapshot_series
from repro.dedup.store import BlockStore

# two "backups" of the same volume, second one lightly edited (byte shifts!)
snap_a, snap_b = list(snapshot_series(base_bytes=4 << 20, snapshots=2,
                                      edit_rate=5e-5, seed=1))

store = BlockStore()
chunker = make_chunker("seqcdc", avg_size=8192)  # the paper's algorithm

for name, snap in [("A", snap_a), ("B", snap_b)]:
    bounds = chunker.chunk(snap)
    keys = store.put_stream(snap, bounds)
    print(f"snapshot {name}: {snap.nbytes >> 20} MiB -> {len(keys)} chunks, "
          f"store now holds {store.stored_bytes >> 20} MiB unique")
    assert store.get_stream(keys) == snap.tobytes()  # lossless

print(f"space savings: {store.savings:.1%} (Eq. 1 of the paper)")

# contrast with fixed-size chunking (XC baseline): byte shifts kill dedup
store_xc = BlockStore()
xc = make_chunker("fixed", avg_size=8192)
for snap in (snap_a, snap_b):
    store_xc.put_stream(snap, xc.chunk(snap))
print(f"fixed-size savings: {store_xc.savings:.1%} — byte-shifting problem")
