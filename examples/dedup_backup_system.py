"""A miniature backup system on the paper's full pipeline (paper SSII):
chunking -> fingerprinting -> index -> content-addressed storage, with
algorithm choice and accounting, plus the distributed-index variant.

  PYTHONPATH=src python examples/dedup_backup_system.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import available, make_chunker
from repro.data import snapshot_series
from repro.dedup.store import BlockStore

print("registered chunkers:", ", ".join(available()))

# nightly "backups" of a mutating 8 MiB volume
snapshots = list(snapshot_series(base_bytes=8 << 20, snapshots=6,
                                 edit_rate=3e-5, seed=42))

for algo in ("fixed", "fastcdc", "ram", "seqcdc"):
    chunker = make_chunker(algo, avg_size=8192)
    store = BlockStore()
    manifests = []
    for snap in snapshots:
        manifests.append(store.put_stream(snap, chunker.chunk(snap)))
    # restore the oldest backup and verify integrity
    assert store.get_stream(manifests[0]) == snapshots[0].tobytes()
    logical = store.logical_bytes >> 20
    stored = store.stored_bytes >> 20
    print(f"{algo:8s}: {logical} MiB logical -> {stored} MiB stored "
          f"({store.savings:.1%} savings, {len(store.blocks)} unique chunks)")

print("\nSeqCDC achieves CDC-grade savings at a fraction of the chunking "
      "cost — the paper's thesis, end to end.")
