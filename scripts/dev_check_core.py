"""Dev sanity: all SeqCDC implementations agree with the slow oracle, the
fused Pallas fingerprint kernel (CPU interpret mode) is bit-identical to
the numpy reference over the same case sweep, and the fused single-dispatch
chunk+fingerprint pipeline kernel is bit-identical to the composed split
path (pipeline_impl="fused" vs "split") over the same cases, and packed
multi-segment rows (packing_impl="segments") chunk bit-identically to
per-segment rows on both the split and fused paths."""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import oracle, seqcdc
from repro.core.params import SeqCDCParams, paper_params

rng = np.random.default_rng(0)

# Small params so events are dense on small inputs.
small = SeqCDCParams(
    avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
    min_size=64, max_size=512,
)

cases = []
for n in [0, 1, 5, 63, 64, 65, 100, 1000, 5000, 20000]:
    cases.append(rng.integers(0, 256, n, dtype=np.uint8))
# low-entropy / adversarial
cases.append(np.zeros(5000, dtype=np.uint8))
cases.append(np.arange(5000, dtype=np.uint32).astype(np.uint8))  # sawtooth inc
cases.append((255 - np.arange(5000, dtype=np.uint32) % 256).astype(np.uint8))
cases.append(rng.integers(0, 4, 20000, dtype=np.uint8))  # low entropy

fail = 0
for params in [small, paper_params(8192), paper_params(4096), paper_params(16384),
               SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6,
                            skip_size=32, min_size=64, max_size=512,
                            mode="decreasing")]:
    for i, d in enumerate(cases):
        ref = oracle.boundaries_slow(d, params)
        ev = oracle.boundaries_numpy(d, params).tolist()
        if ev != ref:
            print(f"[numpy-event] params={params.avg_size} case{i} n={d.size}: {ev[:6]} vs {ref[:6]}")
            fail += 1
        for name, fn in [
            ("two_phase_wide", lambda x: seqcdc.boundaries_two_phase(x, params, step_impl="wide")),
            ("two_phase_gather", lambda x: seqcdc.boundaries_two_phase(x, params, step_impl="gather")),
            ("sequential", lambda x: seqcdc.boundaries_sequential(x, params)),
        ]:
            if d.size == 0:
                continue
            b, c = fn(jnp.asarray(d))
            got = np.asarray(b)[: int(c)].tolist()
            if got != ref:
                print(f"[{name}] params avg={params.avg_size} case{i} n={d.size}:")
                print("  got", got[:8], "... len", len(got))
                print("  ref", ref[:8], "... len", len(ref))
                fail += 1

# fingerprint parity: the fused Pallas kernel (interpret mode on CPU) must
# match the host numpy reference bit-for-bit on real chunker output
from repro.core.automaton import max_chunks_for
from repro.dedup.fingerprint import chunk_fingerprints, fingerprints_numpy

for i, d in enumerate(cases):
    if d.size == 0:
        continue
    b, c = seqcdc.boundaries_two_phase(jnp.asarray(d), small)
    mc = max_chunks_for(d.size, small)
    fp, _ = chunk_fingerprints(jnp.asarray(d), b, c, max_chunks=mc,
                               fp_impl="pallas")
    want = fingerprints_numpy(d, np.asarray(b)[: int(c)])
    if not np.array_equal(np.asarray(fp)[: int(c)], want):
        print(f"[fp-pallas] case{i} n={d.size}: kernel != numpy reference")
        fail += 1

# fused pipeline parity: the single-dispatch chunk+fingerprint kernel
# (pipeline_impl="fused", CPU interpret) must match the composed split
# path bit-for-bit — bounds, counts, fps, and lengths
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref

for params in [small, paper_params(8192)]:
    for i, d in enumerate(cases):
        if d.size == 0:
            continue
        mc = max_chunks_for(d.size, params)
        x = jnp.asarray(d)[None]
        want = kernel_ref.fused_pipeline(x, params, max_chunks=mc)
        got = kernel_ops.fused_pipeline(x, params, max_chunks=mc)
        for w, g, part in zip(want, got, ("bounds", "counts", "fps", "lens")):
            if not np.array_equal(np.asarray(w), np.asarray(g)):
                print(f"[fused-pipeline] params={params.avg_size} case{i} "
                      f"n={d.size}: {part} != split reference")
                fail += 1

# packing parity: a packed multi-segment row (segment-reset automaton,
# split and fused paths) must chunk bit-identically to running every
# segment as its own row — bounds, counts, fps, and lengths
for params in [small, paper_params(8192)]:
    segss = [
        [cases[6], cases[5][:1], np.zeros(300, np.uint8), cases[7][:500]],
        [np.zeros(1, np.uint8)] * 5 + [cases[8][:900]],
    ]
    for i, segs in enumerate(segss):
        S = 4096
        total = sum(s.size for s in segs)
        assert total <= S
        data = np.zeros(S, np.uint8)
        sep = np.full(S, total, np.int32)
        ends = np.zeros(len(segs), np.int32)
        off = 0
        for gi, s in enumerate(segs):
            data[off:off + s.size] = s
            sep[off:off + s.size] = off + s.size
            ends[gi] = off + s.size
            off += s.size
        mc = S // params.min_size + 2 * len(segs) + 2
        want = kernel_ref.packed_pipeline(
            data[None], [[s.size for s in segs]], params, max_chunks=mc)
        for label, got in [
            ("fused", kernel_ops.packed_pipeline(
                jnp.asarray(data)[None], jnp.asarray(sep)[None],
                jnp.asarray(ends)[None], params, max_chunks=mc)),
        ]:
            for w, g, part in zip(want, got,
                                  ("bounds", "counts", "fps", "lens")):
                if not np.array_equal(np.asarray(w), np.asarray(g)):
                    print(f"[packed-{label}] params={params.avg_size} "
                          f"mix{i}: {part} != per-segment reference")
                    fail += 1
        sb, sc = seqcdc.boundaries_packed_batch(
            jnp.asarray(data)[None], jnp.asarray(sep)[None],
            jnp.asarray(ends)[None], params, max_chunks=mc)
        if not (np.array_equal(np.asarray(sb), want[0])
                and np.array_equal(np.asarray(sc), want[1])):
            print(f"[packed-split] params={params.avg_size} mix{i}: "
                  f"bounds/counts != per-segment reference")
            fail += 1

# codec round-trip: a compressed DirBlockStore must restore bit-identical
# bytes, reopen under a *different* codec preference (old blocks keep their
# recorded codec), and keep sweep/raw-byte accounting codec-independent
import tempfile

from repro.dedup.store import BlockStore, DirBlockStore

with tempfile.TemporaryDirectory() as _root:
    _zs = DirBlockStore(_root, codec="zlib")
    _raw = BlockStore(codec="none")
    _payloads = [bytes(c[:4096].tobytes()) for c in cases if c.size]
    for p in _payloads:
        if _zs.put(p) != _raw.put(p):
            print("[codec] zlib store key != raw store key")
            fail += 1
    for k in list(_zs.refs):
        if _zs.get(k) != _raw.get(k):
            print(f"[codec] zlib round-trip mismatch for {k[:12]}")
            fail += 1
    if _zs.stored_bytes != _raw.stored_bytes:
        print("[codec] stored_bytes (raw accounting) differs under zlib")
        fail += 1
    if _zs.compressed_bytes > _zs.stored_bytes:
        print("[codec] compressed_bytes exceeds raw stored_bytes")
        fail += 1
    _zs.sync()
    # mixed reopen: codec="none" reads the zlib blocks and writes raw
    _re = DirBlockStore(_root, codec="none")
    for k in list(_re.refs):
        if _re.get(k) != _raw.get(k):
            print(f"[codec] codec-less reopen cannot read zlib block {k[:12]}")
            fail += 1
    # sweep with empty roots reclaims every *raw* byte on both stores
    if _re.sweep({})[1] != _raw.sweep({})[1]:
        print("[codec] sweep freed-bytes accounting differs under zlib")
        fail += 1

print("FAILURES:", fail)
sys.exit(1 if fail else 0)
