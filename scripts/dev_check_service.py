"""Dev sanity: the streaming dedup service round-trips and dedups.

Fast smoke check (seconds, small params) for the service subsystem:
scheduler exactness vs the per-stream chunker, SHA-verified restore,
delete/GC accounting back to zero.  Exits non-zero on any failure.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import seqcdc
from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.service import ChunkScheduler, DedupService

fail = 0

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)
rng = np.random.default_rng(0)

# 1) scheduler == per-stream two-phase, bit for bit
sched = ChunkScheduler(P, slots=4, min_bucket=1024)
streams = [rng.integers(0, 256, n, dtype=np.uint8)
           for n in (0, 1, 3, 100, 512, 1000, 4096, 20000)]
streams += [np.zeros(5000, dtype=np.uint8),
            (np.arange(7000) % 256).astype(np.uint8)]
for i, s in enumerate(streams):
    sched.submit(s, tag=i)
for r in sched.drain():
    d = streams[r.tag]
    if d.size:
        b, c = seqcdc.boundaries_two_phase(jnp.asarray(d), P)
        want = seqcdc.bounds_to_numpy(b, c)
    else:
        want = []
    if r.bounds.tolist() != want:
        print(f"[scheduler] stream {r.tag} (n={d.size}) diverged")
        fail += 1

# 2) service round trip + dedup on a version series
svc = DedupService(params=P, slots=4, min_bucket=1024)
versions = list(snapshot_series(base_bytes=1 << 18, snapshots=4,
                                edit_rate=2e-5, seed=1))
for i, v in enumerate(versions):
    svc.submit(f"v{i}", v)
svc.flush()
for i, v in enumerate(versions):
    if svc.get(f"v{i}") != v.tobytes():
        print(f"[restore] v{i} not byte-identical")
        fail += 1
st = svc.stats()
if st.dedup_ratio < 1.5:
    print(f"[dedup] ratio {st.dedup_ratio:.2f}x < 1.5x on a version series")
    fail += 1

# 3) delete + GC return the store to empty
for i in range(len(versions)):
    svc.delete(f"v{i}")
if svc.store.stored_bytes != 0 or svc.store.logical_bytes != 0:
    print(f"[gc] accounting not zero after deletes: "
          f"stored={svc.store.stored_bytes} logical={svc.store.logical_bytes}")
    fail += 1

if fail:
    print(f"FAIL ({fail})")
    sys.exit(1)
print(f"service dev check OK: ratio {st.dedup_ratio:.2f}x, "
      f"{st.batches} device batches, occupancy {st.batch_occupancy:.0%}")
