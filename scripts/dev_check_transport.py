"""Dev sanity: remote shard transport survives a mid-flush SIGKILL.

Seconds-fast smoke for the transport subsystem (docs/SHARDING.md): spawns
two real shard-server processes via ``ShardedDedupService.open(...,
transport="remote")``, checks N=2-over-RPC equals the in-process service
byte-for-byte, SIGKILLs one server mid-flush and asserts the clean
``AsyncWriteError`` abort (nothing committed, name un-stranded), then
restarts the server on the same root and verifies the depot state is fully
recoverable (restores + gc).  Exits non-zero on failure.
"""
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.service import AsyncWriteError, DedupService, ShardedDedupService

fail = 0

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)
versions = list(snapshot_series(base_bytes=1 << 16, snapshots=3,
                                edit_rate=2e-5, seed=4))

single = DedupService(params=P, slots=4, min_bucket=1024)
for i, v in enumerate(versions):
    single.submit(f"v{i}", v)
single.flush()
want = single.stats()

with tempfile.TemporaryDirectory() as tmp:
    root = os.path.join(tmp, "depot")

    # 1) two shard-server processes: byte totals and restores equal in-process
    svc = ShardedDedupService.open(root, 2, transport="remote",
                                   params=P, slots=4, min_bucket=1024)
    for i, v in enumerate(versions):
        svc.submit(f"v{i}", v)
    svc.flush()
    st = svc.stats()
    if (st.stored_bytes, st.unique_chunks) != (want.stored_bytes,
                                               want.unique_chunks):
        print("[remote N=2] byte totals diverged from in-process service")
        fail += 1
    for i, v in enumerate(versions):
        if svc.get(f"v{i}") != v.tobytes():
            print(f"[remote N=2] restore v{i} not byte-identical")
            fail += 1

    # 2) SIGKILL shard server 1 mid-flush: clean AsyncWriteError, no commit
    victim = svc._servers[1]
    orig_put = svc.stores[1].put_blocks  # the coalesced writer hot path

    def killing_put(chunks):
        victim.kill()
        return orig_put(chunks)

    svc.stores[1].put_blocks = killing_put
    rng = np.random.default_rng(0)
    svc.submit("doomed", rng.integers(0, 256, 8000, dtype=np.uint8))
    try:
        svc.flush()
        print("[crash] flush survived a SIGKILLed shard server")
        fail += 1
    except AsyncWriteError:
        pass
    except Exception as e:  # noqa: BLE001
        print(f"[crash] expected AsyncWriteError, got {type(e).__name__}: {e}")
        fail += 1
    if "doomed" in svc.names():
        print("[crash] aborted flush committed a recipe")
        fail += 1
    svc.close()

    # 3) restartable: fresh servers on the same roots serve the full depot
    svc2 = ShardedDedupService.open(root, 2, transport="remote",
                                    params=P, slots=4, min_bucket=1024)
    for i, v in enumerate(versions):
        if svc2.get(f"v{i}") != v.tobytes():
            print(f"[restart] restore v{i} not byte-identical")
            fail += 1
    svc2.gc()  # reclaims shard-0 orphans the doomed flush left behind
    data = rng.integers(0, 256, 8000, dtype=np.uint8)
    svc2.put("doomed", data)  # the aborted name is not stranded
    if svc2.get("doomed") != data.tobytes():
        print("[restart] resubmitted object does not restore")
        fail += 1
    handles = list(svc2._servers)  # close() clears the list
    svc2.close()
    if any(h.proc.poll() is None for h in handles):
        print("[restart] shard server processes leaked past close()")
        fail += 1

if fail:
    print(f"FAIL ({fail})")
    sys.exit(1)
print(f"transport dev check OK: remote N=2 == in-process "
      f"({want.unique_chunks} unique chunks), SIGKILL aborts cleanly, "
      f"depot restartable")
