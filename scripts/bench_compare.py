"""Perf-regression gate: diff a fresh bench run against a committed baseline.

    python scripts/bench_compare.py BENCH_quick.json BENCH_ci_quick.json

Matches rows between the two reports by their identity fields (bench title
plus every configuration axis — shards, transport, impl selections, corpus
shape, workload scenario) and checks each watched metric of every matched
pair against a
tolerance band, exiting non-zero when any check fails — the first
automated consumer of the BENCH_*.json trajectory (docs/OBSERVABILITY.md).

Two tolerance classes, because the two failure modes differ:

* **throughput** (``ingest_gbps``, ``restore_gbps``, ``gbits_per_s``,
  ``speedup_vs_*``) is machine-dependent — CI hardware is not the host
  that recorded the committed baseline, and quick-budget corpora are
  small enough that jit compile time dominates.  The band is deliberately
  loose (fail below ``--throughput-ratio`` x baseline, default 0.25):
  it catches an order-of-magnitude collapse (a kernel silently falling
  back to the scalar path), not a noisy 20%.
* **quality** (``occupancy``/``batch_occupancy``/``row_fill``, absolute
  ``--occupancy-tol``; ``dedup_ratio``, relative ``--dedup-tol``) is
  machine-independent: same code + same seeded corpus = same value, so
  the bands are tight.  These are the real regression signals — a packing
  or boundary change that wastes device rows or loses dedup shows up
  here on any hardware.

A baseline row with no fresh counterpart fails the gate too (a benchmark
that silently stopped running is a coverage regression, not a pass), as
does a fresh report whose ``meta.failed_modules`` is non-empty.  Fresh
rows with no baseline counterpart are reported but pass — that's how new
benchmarks land before their first committed baseline.

Exit codes: 0 = within bands, 1 = regression, 2 = unusable input.
Stdlib-only, like everything under ``repro.obs``.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Dict, List, Optional, Tuple

#: the fields that *identify* a row (everything else is a measurement);
#: absent fields simply don't participate in the key, so reports from
#: before/after a new axis was added still match on the shared axes
IDENTITY_FIELDS = (
    "bench", "budget", "figure", "primitive", "dist", "shards",
    "async_flush", "transport", "mask_impl", "step_impl", "fp_impl",
    "pipeline_impl", "packing_impl", "fingerprints", "stream_mb",
    "block_w", "buckets", "streams", "versions", "scenario", "codec",
)

#: watched metrics -> tolerance class ("throughput" | "occupancy" | "dedup");
#: all are higher-better
WATCHED = {
    "ingest_gbps": "throughput",
    "restore_gbps": "throughput",
    "raw_chunk_gbps": "throughput",
    "gbits_per_s": "throughput",
    "speedup_vs_reference": "throughput",
    "speedup_vs_split": "throughput",
    "occupancy": "occupancy",
    "batch_occupancy": "occupancy",
    "row_fill": "occupancy",
    "dedup_ratio": "dedup",
    # machine-independent like dedup_ratio: same seeded corpus + same
    # codec = same compressed payload, so the tight relative band applies
    "compressed_ratio": "dedup",
}


@dataclasses.dataclass
class Tolerances:
    throughput_ratio: float = 0.25  # fail below this fraction of baseline
    occupancy_tol: float = 0.10     # absolute drop allowed
    dedup_tol: float = 0.01         # relative drop allowed


def row_key(row: dict) -> Tuple:
    """Hashable identity of one result row (its configuration axes)."""
    return tuple((f, row[f]) for f in IDENTITY_FIELDS if f in row)


def _index(report: dict) -> Dict[Tuple, dict]:
    out: Dict[Tuple, dict] = {}
    for row in report.get("results", []):
        out[row_key(row)] = row
    return out


def _check(metric: str, base: float, fresh: float,
           tol: Tolerances) -> Tuple[bool, str]:
    """-> (ok, band description) for one watched metric pair."""
    kind = WATCHED[metric]
    if kind == "throughput":
        floor = base * tol.throughput_ratio
        return fresh >= floor, f">= {floor:.4g} ({tol.throughput_ratio}x)"
    if kind == "occupancy":
        floor = base - tol.occupancy_tol
        return fresh >= floor, f">= {floor:.4g} (-{tol.occupancy_tol} abs)"
    # strict: a drop of exactly the tolerance still fails, so "a >=1%
    # relative dedup loss fails the gate" holds with no FP edge case
    floor = base * (1.0 - tol.dedup_tol)
    return fresh > floor, f"> {floor:.4g} (-{tol.dedup_tol:.0%} rel)"


def compare(baseline: dict, fresh: dict,
            tol: Optional[Tolerances] = None) -> Tuple[List[dict], List[str]]:
    """Diff two bench reports -> (per-metric comparison rows, failures).

    Every returned comparison row carries ``bench``/``config``/``metric``/
    ``baseline``/``fresh``/``band``/``ok``; ``failures`` is the list of
    human-readable failure lines (empty = the gate passes).
    """
    tol = tol or Tolerances()
    rows: List[dict] = []
    failures: List[str] = []
    failed_mods = fresh.get("meta", {}).get("failed_modules") or []
    if failed_mods:
        failures.append(f"fresh run had failed modules: {failed_mods}")
    base_idx, fresh_idx = _index(baseline), _index(fresh)
    for key, brow in base_idx.items():
        frow = fresh_idx.get(key)
        config = ", ".join(f"{f}={v}" for f, v in key if f != "bench")
        bench = brow.get("bench", "?")
        if frow is None:
            failures.append(
                f"baseline row missing from fresh run: {bench} [{config}]"
            )
            continue
        for metric, _kind in WATCHED.items():
            if metric not in brow or metric not in frow:
                continue
            base_v, fresh_v = float(brow[metric]), float(frow[metric])
            ok, band = _check(metric, base_v, fresh_v, tol)
            rows.append({
                "bench": bench, "config": config, "metric": metric,
                "baseline": base_v, "fresh": fresh_v, "band": band,
                "ok": ok,
            })
            if not ok:
                failures.append(
                    f"REGRESSION {metric}: {fresh_v:.4g} vs baseline "
                    f"{base_v:.4g} (band {band}) in {bench} [{config}]"
                )
    extra = [k for k in fresh_idx if k not in base_idx]
    for key in extra:
        bench = fresh_idx[key].get("bench", "?")
        config = ", ".join(f"{f}={v}" for f, v in key if f != "bench")
        rows.append({
            "bench": bench, "config": config, "metric": "(new row)",
            "baseline": None, "fresh": None,
            "band": "no baseline yet", "ok": True,
        })
    return rows, failures


def _load(path: str) -> dict:
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable bench report ({e})", file=sys.stderr)
        raise SystemExit(2) from e
    if not isinstance(doc, dict) or "results" not in doc:
        print(f"{path}: not a benchmarks/run.py report", file=sys.stderr)
        raise SystemExit(2)
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_*.json")
    ap.add_argument("fresh", help="freshly produced BENCH_*.json")
    ap.add_argument("--throughput-ratio", type=float,
                    default=Tolerances.throughput_ratio,
                    help="fail when throughput < RATIO x baseline "
                         "(machine-dependent, so loose by default)")
    ap.add_argument("--occupancy-tol", type=float,
                    default=Tolerances.occupancy_tol,
                    help="absolute occupancy/row_fill drop allowed")
    ap.add_argument("--dedup-tol", type=float,
                    default=Tolerances.dedup_tol,
                    help="relative dedup_ratio drop allowed")
    args = ap.parse_args(argv)
    tol = Tolerances(throughput_ratio=args.throughput_ratio,
                     occupancy_tol=args.occupancy_tol,
                     dedup_tol=args.dedup_tol)
    rows, failures = compare(_load(args.baseline), _load(args.fresh), tol)
    compared = sum(1 for r in rows if r["metric"] != "(new row)")
    print(f"compared {compared} metrics across "
          f"{len({(r['bench'], r['config']) for r in rows})} rows "
          f"({args.baseline} -> {args.fresh})")
    for r in rows:
        if r["metric"] == "(new row)":
            print(f"  NEW   {r['bench']} [{r['config']}]")
        elif not r["ok"]:
            print(f"  FAIL  {r['metric']}: {r['fresh']:.4g} "
                  f"(baseline {r['baseline']:.4g}, band {r['band']}) "
                  f"{r['bench']} [{r['config']}]")
    if failures:
        print(f"\n{len(failures)} failure(s):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("all watched metrics within tolerance bands")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
