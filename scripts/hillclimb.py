import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb profiler: one cell -> roofline terms + the heaviest ops.

  PYTHONPATH=src python scripts/hillclimb.py --arch phi3-medium-14b \\
      --shape prefill_32k [--multi] [--dump /tmp/cell.hlo]

Prints the three roofline terms and the top-K most expensive collectives /
memory movers / dots from the trip-count-weighted HLO cost model — the
"profile" against which optimization hypotheses are formed (EXPERIMENTS.md
SSPerf).
"""
import argparse
import re
import sys
import time
from collections import defaultdict

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--dump", default=None)
    ap.add_argument("--topk", type=int, default=12)
    args = ap.parse_args()

    from repro.configs import SHAPES, get_config
    from repro.launch.dryrun import build_cell
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analyze
    from repro.roofline.hlo_cost import HloCostModel, _shape_elems_bytes

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi)
    t0 = time.time()
    jfn, cell_args = build_cell(cfg, shape, mesh)
    with mesh:
        compiled = jfn.lower(*cell_args).compile()
    print(f"compiled in {time.time()-t0:.1f}s")
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)

    rl = analyze.from_compiled(args.arch, args.shape, "mesh", mesh.size,
                               compiled, cfg=cfg, shape_cfg=shape)
    mem = compiled.memory_analysis()
    print(f"\nterms: compute={rl.t_compute:.3f}s memory={rl.t_memory:.3f}s "
          f"collective={rl.t_collective:.3f}s bottleneck={rl.bottleneck}")
    print(f"useful_flops_ratio={rl.useful_flops_ratio:.3f} "
          f"roofline_fraction={rl.roofline_fraction:.4f}")
    print(f"peak HBM/dev ~ {(getattr(mem,'argument_size_in_bytes',0)+getattr(mem,'temp_size_in_bytes',0))/2**30:.1f} GiB "
          f"(args {getattr(mem,'argument_size_in_bytes',0)/2**30:.1f} + temp {getattr(mem,'temp_size_in_bytes',0)/2**30:.1f})")

    # per-op attribution with loop multipliers
    model = HloCostModel(text)
    colls, movers, dots = [], [], []

    def walk(comp, mult, seen):
        for inst in model.comps.get(comp, []):
            line = inst.line
            if inst.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
                trip = int(mt.group(1)) if mt else 1
                if mb:
                    walk(mb.group(1), mult * trip, seen)
                continue
            if inst.op in ("fusion", "call"):
                mc = re.search(r"calls=%?([\w.\-]+)", line)
                if mc and mc.group(1) in model.comps:
                    walk(mc.group(1), mult, seen)
            c = model.inst_cost(comp, inst, True)
            meta = re.search(r'op_name="([^"]+)"', line)
            tag = meta.group(1)[-90:] if meta else inst.name
            if c.coll_bytes:
                colls.append((c.coll_bytes * mult, inst.op, inst.shape[:60], tag))
            if c.bytes:
                movers.append((c.bytes * mult, inst.op, inst.shape[:60], tag))
            if inst.op == "dot" and c.flops:
                dots.append((c.flops * mult, inst.op, inst.shape[:60], tag))

    walk(model.entry, 1.0, set())
    for title, rowsrc, unit in [("collectives", colls, "GiB"),
                                ("memory movers", movers, "GiB"),
                                ("dots", dots, "GFLOP")]:
        print(f"\n== top {title} (per device, loop-weighted) ==")
        rowsrc.sort(reverse=True)
        for v, op, shp, tag in rowsrc[: args.topk]:
            val = v / 2**30 if unit == "GiB" else v / 1e9
            print(f"  {val:12.2f} {unit}  {op:20s} {shp:60s} {tag}")


if __name__ == "__main__":
    main()
