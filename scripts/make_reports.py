"""Generate EXPERIMENTS.md SSDry-run / SSRoofline tables from results/*.json.

  PYTHONPATH=src python scripts/make_reports.py results/dryrun_single.json \\
      [results/dryrun_multi.json ...] > results/roofline_tables.md
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PiB"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main(paths):
    records = []
    for p in paths:
        with open(p) as f:
            records.extend(json.load(f))

    print("### Dry-run (lower + compile, per cell)\n")
    print("| arch | shape | mesh | status | compile | per-dev peak HBM | args |")
    print("|---|---|---|---|---|---|---|")
    for r in records:
        status = r["status"]
        if status.startswith("skipped"):
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip (long_500k "
                  f"needs sub-quadratic attn) | - | - | - |")
            continue
        if status != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAILED** | - | - | - |")
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']}s "
            f"| {fmt_bytes(r.get('peak_bytes'))} | {fmt_bytes(r.get('argument_bytes'))} |"
        )

    print("\n### Roofline (per-device terms, seconds/step; v5e constants)\n")
    print("| arch | shape | mesh | t_compute | t_memory | t_collective | bottleneck "
          "| MODEL_FLOPS/HLO | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in records:
        if r["status"] != "ok":
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {fmt_s(r['t_compute'])} "
            f"| {fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} "
            f"| **{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |"
        )

    print("\n### Collective mix (per-device bytes by op)\n")
    print("| arch | shape | mesh | total | mix |")
    print("|---|---|---|---|---|")
    for r in records:
        if r["status"] != "ok" or not r.get("collective_by_type"):
            continue
        mix = ", ".join(
            f"{k}:{fmt_bytes(v)}" for k, v in sorted(
                r["collective_by_type"].items(), key=lambda kv: -kv[1])
        )
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {fmt_bytes(r['collective_bytes_per_device'])} | {mix} |")


if __name__ == "__main__":
    main(sys.argv[1:] or ["results/dryrun_single.json"])
