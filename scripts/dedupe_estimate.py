"""Corpus dedupe estimator: how much would chunk-level dedup save on this data?

Walks files (or generates a synthetic file-version series), drives every
object through the streaming DedupService — batched SeqCDC chunking, SHA-256
content-addressed store — and reports logical vs stored bytes, the dedup
ratio, and the chunk-size distribution, in the spirit of the related
dedupe-estimator tools' ``de stats``.

    python scripts/dedupe_estimate.py PATH [PATH...]     # files / directories
    python scripts/dedupe_estimate.py --synthetic 8      # 8 synthetic versions
    python scripts/dedupe_estimate.py PATH --avg-chunk 4096 --json
    python scripts/dedupe_estimate.py PATH --store /tmp/depot  # persistent

With --store the chunk store and recipes persist, so re-running over new
file versions estimates *incremental* transfer (only new chunk bytes), the
cross-revision workload of the related repos.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.service import DedupService  # noqa: E402


def iter_files(paths, max_file_bytes: int, skipped: dict | None = None):
    """Deterministic walk: (object name, path) for every regular file.

    Names are unique across all roots (root label prefix when several paths
    are given, ``#N`` suffix on residual collisions) so same-named files
    never silently overwrite each other in the estimate.

    Symlinks, files over ``max_file_bytes``, and unreadable entries are
    excluded from the walk — and *counted* into ``skipped`` (keys ``files``
    / ``bytes``) when given, so the report can say what the estimate omits
    instead of silently under-measuring.
    """
    seen: dict = {}

    def unique(name: str) -> str:
        if name not in seen:
            seen[name] = 1
            return name
        # probe until free: a generated "<name>#N" can itself collide with a
        # real file literally named that way, so record every result in seen
        while True:
            seen[name] += 1
            candidate = f"{name}#{seen[name]}"
            if candidate not in seen:
                seen[candidate] = 1
                return candidate

    multi = len(paths) > 1
    for root in paths:
        label = os.path.basename(os.path.normpath(root))
        if os.path.isfile(root):
            yield unique(label), root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames.sort()
            for fn in sorted(filenames):
                path = os.path.join(dirpath, fn)
                try:
                    if os.path.islink(path) or os.path.getsize(path) > max_file_bytes:
                        if skipped is not None:
                            skipped["files"] += 1
                            if not os.path.islink(path):
                                skipped["bytes"] += os.path.getsize(path)
                        continue
                except OSError:
                    if skipped is not None:
                        skipped["files"] += 1
                    continue
                rel = os.path.relpath(path, root)
                yield unique(os.path.join(label, rel) if multi else rel), path


def synthetic_versions(count: int, base_mb: int, edit_rate: float, seed: int):
    from repro.data.corpus import snapshot_series

    series = snapshot_series(base_bytes=base_mb << 20, snapshots=count,
                             edit_rate=edit_rate, seed=seed)
    for i, snap in enumerate(series):
        yield f"v{i:03d}.bin", snap


def human(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024
    return f"{n:.1f} GiB"


def print_report(st, ingested: int, with_fp: bool = True):
    print(f"objects          {st.objects} ({ingested} ingested this run)")
    print(f"logical bytes    {st.logical_bytes:>14,}  ({human(st.logical_bytes)})")
    print(f"stored bytes     {st.stored_bytes:>14,}  ({human(st.stored_bytes)})")
    print(f"dedup ratio      {st.dedup_ratio:14.2f}x")
    if st.codec != "none":
        # compressed_ratio = dedup x compression, the estimators' headline
        print(f"compressed bytes {st.compressed_bytes:>14,}  "
              f"({human(st.compressed_bytes)}, codec={st.codec})")
        print(f"compressed ratio {st.compressed_ratio:14.2f}x  "
              "(dedup x compression)")
    print(f"space savings    {st.space_savings:14.1%}")
    print(f"chunks           {st.total_chunks:>14,}  ({st.unique_chunks:,} unique)")
    if st.total_chunks:
        mean = st.logical_bytes / st.total_chunks
        print(f"mean chunk       {mean:14.0f}  bytes")
    if with_fp:
        print(f"fp-estimated     {st.fp_estimated_savings:14.1%}  "
              "(62-bit fingerprint, cumulative over all ingests)")
    print(f"device batches   {st.batches:>14,}  ({st.batch_occupancy:.0%} occupancy)")
    if st.chunk_size_hist:
        print("\nchunk-size distribution (log2 buckets):")
        peak = max(st.chunk_size_hist.values())
        for b, cnt in st.chunk_size_hist.items():
            bar = "#" * max(1, round(40 * cnt / peak))
            lo, hi = 1 << b, (1 << (b + 1)) - 1
            print(f"  {human(lo):>9} - {human(hi):>9}  {cnt:>9,}  {bar}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", help="files or directories to estimate")
    ap.add_argument("--avg-chunk", type=int, default=8192)
    ap.add_argument("--store", default=None,
                    help="persistent store directory (default: in-memory)")
    ap.add_argument("--synthetic", type=int, default=0, metavar="N",
                    help="ingest N synthetic file versions instead of paths")
    ap.add_argument("--synthetic-mb", type=int, default=4)
    ap.add_argument("--edit-rate", type=float, default=5e-5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-file-mb", type=int, default=256)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--flush-every", type=int, default=64,
                    help="commit cadence (objects buffered per flush)")
    ap.add_argument("--codec", default=None, choices=["none", "zlib", "lz4"],
                    help="per-chunk store codec (default: the depot's "
                         "manifest codec, else $REPRO_STORE_CODEC)")
    ap.add_argument("--no-fp", action="store_true",
                    help="skip accelerator fingerprints (faster on CPU; "
                         "drops only the fp-estimated line)")
    ap.add_argument("--json", action="store_true", help="machine-readable output")
    args = ap.parse_args(argv)

    if not args.paths and not args.synthetic:
        ap.error("give PATHs or --synthetic N")
    for path in args.paths:
        if not os.path.exists(path):
            ap.error(f"path does not exist: {path}")

    kw = dict(avg_chunk=args.avg_chunk, slots=args.slots,
              with_fingerprints=not args.no_fp, codec=args.codec)
    if args.store:
        svc = DedupService.open(args.store, **kw)
    else:
        svc = DedupService(**kw)

    skipped = {"files": 0, "bytes": 0}
    if args.synthetic:
        objects = synthetic_versions(args.synthetic, args.synthetic_mb,
                                     args.edit_rate, args.seed)
    else:
        objects = iter_files(args.paths, args.max_file_mb << 20, skipped)

    ingested = 0
    queued = 0
    for name, src in objects:
        if isinstance(src, str):
            with open(src, "rb") as f:
                data = np.frombuffer(f.read(), dtype=np.uint8)
        else:
            data = src
        svc.submit(name, data, overwrite=True)
        ingested += 1
        queued += 1
        if queued >= args.flush_every:
            svc.flush()
            queued = 0
    svc.flush()

    st = svc.stats()
    if args.json:
        out = {
            "objects": st.objects,
            "ingested": ingested,
            "logical_bytes": st.logical_bytes,
            "stored_bytes": st.stored_bytes,
            "dedup_ratio": st.dedup_ratio,
            "codec": st.codec,
            "compressed_bytes": st.compressed_bytes,
            "compressed_ratio": st.compressed_ratio,
            "space_savings": st.space_savings,
            "total_chunks": st.total_chunks,
            "unique_chunks": st.unique_chunks,
            "chunk_size_hist": {str(k): v for k, v in st.chunk_size_hist.items()},
        }
        if not args.no_fp:
            out["fp_estimated_savings"] = st.fp_estimated_savings
        out["skipped_files"] = skipped["files"]
        out["skipped_bytes"] = skipped["bytes"]
        print(json.dumps(out, indent=2))
    else:
        print_report(st, ingested, with_fp=not args.no_fp)
        if skipped["files"]:
            print(f"\nskipped          {skipped['files']} files "
                  f"({human(skipped['bytes'])}) — symlinks, > --max-file-mb, "
                  f"or unreadable; the estimate excludes them")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
