"""Dev sanity: the observability layer measures without perturbing.

Seconds-fast smoke for ``repro.obs`` and its wiring (docs/OBSERVABILITY.md):

  1. registry arithmetic — counters, gauges, histogram percentiles, merge;
  2. tracing-on bit-identity — the same corpus ingested with ``REPRO_TRACE``
     set produces byte-identical stores/restores, and the trace file holds
     parseable span records for every instrumented stage;
  3. remote telemetry — a 2-shard remote service's ``metrics()`` returns
     live per-server snapshots whose RPC calls/bytes agree exactly with the
     client-side counters, op by op;
  4. causal tracing across the wire — a remote ``put()`` with ``REPRO_TRACE``
     set (before the servers spawn, so they inherit it) emits spans that
     reconstruct into one connected tree: client, writer-thread, and
     shard-server spans all share the request's ``trace_id`` and every
     ``parent_id`` resolves inside the file;
  5. ``scripts/obs_report.py`` renders all three artifact kinds, including
     the per-request latency and critical-path views of the causal trace.

Exits non-zero on failure.
"""
import json
import os
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.obs import MetricsRegistry, merge_snapshots
from repro.service import DedupService, ShardedDedupService

fail = 0

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)
versions = list(snapshot_series(base_bytes=1 << 16, snapshots=3,
                                edit_rate=2e-5, seed=11))

# 1) registry arithmetic
reg = MetricsRegistry()
for v in (0.010, 0.011, 0.012, 0.9):
    reg.observe("lat_s", v)
reg.inc("n", 7)
reg.set_gauge("depth", 3)
snap = reg.snapshot()
h = snap["histograms"]["lat_s"]
if not (0.008 < h["p50"] < 0.014 and 0.5 < h["p99"] < 1.3):
    print(f"[registry] percentile resolution off: p50={h['p50']} p99={h['p99']}")
    fail += 1
merged = merge_snapshots([snap, snap, None])
if merged["counters"]["n"] != 14 or merged["histograms"]["lat_s"]["count"] != 8:
    print("[registry] merge_snapshots did not sum (None must be skipped)")
    fail += 1


def ingest(svc):
    for i, v in enumerate(versions):
        svc.submit(f"v{i}", v)
    svc.flush()
    return [svc.get(f"v{i}") for i in range(len(versions))]


# 2) tracing-on bit-identity + span records per stage
with tempfile.TemporaryDirectory() as tmp:
    trace = os.path.join(tmp, "trace.jsonl")
    base = ingest(DedupService(params=P, slots=4, min_bucket=1024))
    os.environ["REPRO_TRACE"] = trace
    try:
        traced = ingest(DedupService(params=P, slots=4, min_bucket=1024))
    finally:
        del os.environ["REPRO_TRACE"]
    if base != traced:
        print("[trace] restores diverged with REPRO_TRACE set")
        fail += 1
    names = set()
    with open(trace) as f:
        for line in f:
            names.add(json.loads(line)["name"])
    for want in ("sched.dispatch", "service.flush", "service.get"):
        if want not in names:
            print(f"[trace] no {want!r} span in the trace (saw {sorted(names)})")
            fail += 1

    # 3) remote telemetry: client/server agreement, op by op
    svc = ShardedDedupService.open(os.path.join(tmp, "depot"), 2,
                                   transport="remote", params=P, slots=4,
                                   min_bucket=1024)
    try:
        ingest(svc)
        m = svc.metrics()
        if any(s is None for s in m["shards"]) or len(m["shards"]) != 2:
            print(f"[remote] expected 2 live shard snapshots, got {m['shards']}")
            fail += 1
        cc = m["service"]["counters"]
        sc = (m["aggregate"] or {}).get("counters", {})
        for k, v in cc.items():
            for mine, theirs in (("rpc.client.calls{", "rpc.server.calls{"),
                                 ("rpc.client.send_bytes{",
                                  "rpc.server.recv_bytes{")):
                if k.startswith(mine) and sc.get(theirs + k[len(mine):]) != v:
                    print(f"[remote] {k}={v} != server "
                          f"{sc.get(theirs + k[len(mine):])}")
                    fail += 1
    finally:
        svc.close()

    # 4) causal tracing across the wire: one remote put -> one connected tree
    causal = os.path.join(tmp, "causal.jsonl")
    os.environ["REPRO_TRACE"] = causal  # before open(): servers inherit it
    try:
        svc = ShardedDedupService.open(os.path.join(tmp, "depot2"), 2,
                                       transport="remote", params=P, slots=4,
                                       min_bucket=1024)
        try:
            svc.put("obj", versions[0])
        finally:
            svc.close()
    finally:
        del os.environ["REPRO_TRACE"]
    recs = []
    with open(causal) as f:
        for line in f:
            try:
                recs.append(json.loads(line))
            except json.JSONDecodeError:
                pass  # torn tail line
    by_id = {r["span_id"]: r for r in recs}
    puts = [r for r in recs if r["name"] == "request" and r.get("op") == "put"]
    if len(puts) != 1:
        print(f"[causal] expected one put request root, got {len(puts)}")
        fail += 1
    else:
        root = puts[0]
        members = [r for r in recs if r["trace_id"] == root["trace_id"]]
        names = {r["name"] for r in members}
        for want in ("rpc.client", "rpc.server", "writer.task",
                     "service.flush"):
            if want not in names:
                print(f"[causal] no {want!r} span joined the put tree "
                      f"(saw {sorted(names)})")
                fail += 1
        if len({r["pid"] for r in members}) < 2:
            print("[causal] put tree never crossed a process boundary")
            fail += 1
        for r in members:
            if r["span_id"] == root["span_id"]:
                continue
            parent = by_id.get(r.get("parent_id"))
            if parent is None or parent["trace_id"] != root["trace_id"]:
                print(f"[causal] orphan span {r['name']!r}: parent_id "
                      f"{r.get('parent_id')!r} not in the put tree")
                fail += 1

    # 5) obs_report renders every artifact kind (incl. the causal views)
    mpath = os.path.join(tmp, "metrics.json")
    with open(mpath, "w") as f:
        json.dump(m, f)
    report = os.path.join(os.path.dirname(__file__), "obs_report.py")
    for art in (mpath, trace, causal):
        r = subprocess.run([sys.executable, report, art],
                           capture_output=True, text=True)
        if r.returncode != 0 or not r.stdout.strip():
            print(f"[report] obs_report.py failed on {art}: {r.stderr}")
            fail += 1
    if "critical path: slowest 'put' request" not in r.stdout:
        print("[report] causal trace rendered without a put critical path")
        fail += 1

print("dev_check_obs:", "FAIL" if fail else "OK")
sys.exit(1 if fail else 0)
