"""Umbrella dev check: tier-1 tests + service + sharded smoke, one command.

    python scripts/dev_check.py            # everything (tier-1 is slow)
    python scripts/dev_check.py --fast     # smoke checks only (seconds)

Runs, in order, reporting a pass/fail summary and exiting non-zero if any
stage failed:

  1. tier-1 pytest suite      (the ROADMAP verify command; skipped by --fast)
  2. core dev check           (scripts/dev_check_core.py)
  3. service dev check        (scripts/dev_check_service.py)
  4. sharded service check    (scripts/dev_check_sharded.py)
  5. transport check          (scripts/dev_check_transport.py)
  6. observability check      (scripts/dev_check_obs.py)
  7. scenarios check          (scripts/dev_check_scenarios.py)

This is what CI runs (.github/workflows/ci.yml); locally, ``--fast`` is the
pre-commit loop and the full form is the pre-PR gate.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _env():
    env = dict(os.environ)
    src = os.path.join(ROOT, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    return env


def _stage(name: str, cmd: list[str]) -> tuple[str, bool, float]:
    print(f"== {name}: {' '.join(cmd)}", flush=True)
    t0 = time.time()
    rc = subprocess.run(cmd, cwd=ROOT, env=_env()).returncode
    dt = time.time() - t0
    print(f"== {name}: {'OK' if rc == 0 else f'FAIL (rc={rc})'} in {dt:.1f}s",
          flush=True)
    return name, rc == 0, dt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="skip the tier-1 pytest suite (smoke checks only)")
    args = ap.parse_args(argv)

    py = sys.executable
    stages = []
    if not args.fast:
        stages.append(("tier-1 tests", [py, "-m", "pytest", "-x", "-q"]))
    stages += [
        ("core check", [py, os.path.join("scripts", "dev_check_core.py")]),
        ("service check", [py, os.path.join("scripts", "dev_check_service.py")]),
        ("sharded check", [py, os.path.join("scripts", "dev_check_sharded.py")]),
        ("transport check",
         [py, os.path.join("scripts", "dev_check_transport.py")]),
        ("obs check", [py, os.path.join("scripts", "dev_check_obs.py")]),
        ("scenarios check",
         [py, os.path.join("scripts", "dev_check_scenarios.py")]),
    ]

    results = [_stage(name, cmd) for name, cmd in stages]
    print("\n== summary")
    for name, ok, dt in results:
        print(f"  {'PASS' if ok else 'FAIL'}  {name}  ({dt:.1f}s)")
    return 0 if all(ok for _, ok, _ in results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
