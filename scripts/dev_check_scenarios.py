"""Dev sanity: the scenario engine's contracts, seconds-fast.

Smoke for ``repro.scenarios`` and its consumers (docs/SCENARIOS.md):

  1. catalog shape — >= 4 scenarios, each with a sane expected-structure
     descriptor and a positive canonical chunk grain;
  2. determinism — same seed -> byte-identical corpus (``corpus_digest``),
     different seed -> different bytes, with no jax import (the package is
     numpy + stdlib by contract, so shard servers and tests can load it);
  3. service round-trip — the tiny edit-program corpus ingests, dedups
     above 1.0, and every versioned object restores byte-exactly;
  4. the ``scenario`` axis — bench_compare's identity fields include it,
     and a doctored per-scenario dedup-ratio drop fails the gate.

Exits non-zero on failure.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__)))

fail = 0

# 2) import purity first: the package must come up without jax
before = set(sys.modules)
from repro.scenarios import (  # noqa: E402
    SCENARIOS, bench_params, corpus_digest, generate,
)
if "jax" in set(sys.modules) - before:
    print("[purity] importing repro.scenarios pulled in jax")
    fail += 1

# 1) catalog shape
if len(SCENARIOS) < 4:
    print(f"[catalog] expected >= 4 scenarios, got {sorted(SCENARIOS)}")
    fail += 1
for name, sc in SCENARIOS.items():
    exp = generate(name, "tiny").expected
    if not (0.0 < exp.duplicate_fraction < 1.0
            and 1.0 <= exp.min_dedup_ratio < exp.max_dedup_ratio):
        print(f"[catalog] {name}: bad descriptor {exp}")
        fail += 1
    if sc.avg_chunk <= 0:
        print(f"[catalog] {name}: bad avg_chunk {sc.avg_chunk}")
        fail += 1

# 2) determinism
for name, sc in SCENARIOS.items():
    d1 = corpus_digest(generate(name, "tiny"))
    d2 = corpus_digest(generate(name, "tiny"))
    d3 = corpus_digest(sc.generate("tiny", seed=sc.seed + 1))
    if d1 != d2:
        print(f"[determinism] {name}: same seed, different bytes")
        fail += 1
    if d1 == d3:
        print(f"[determinism] {name}: seed does not reach the generator")
        fail += 1

# 3) service round-trip on the tiny edit-program corpus
from repro.service import DedupService  # noqa: E402

corpus = generate("dataset_revisions", "tiny")
svc = DedupService(params=bench_params("dataset_revisions", "tiny"), slots=4,
                   min_bucket=1024, with_fingerprints=False)
for obj, data in corpus.objects:
    svc.submit(obj, data)
svc.flush()
ratio = svc.stats().dedup_ratio
if not ratio > 1.0:
    print(f"[service] tiny revision corpus did not dedup (ratio {ratio:.3f})")
    fail += 1
for obj, data in corpus.objects:
    if svc.get(obj) != data.tobytes():
        print(f"[service] restore mismatch for {obj!r}")
        fail += 1

# 4) the scenario identity axis gates per-scenario ratio drops
import bench_compare as bc  # noqa: E402

if "scenario" not in bc.IDENTITY_FIELDS:
    print("[gate] bench_compare lost the 'scenario' identity axis")
    fail += 1
row = {"bench": "scenarios", "budget": "quick", "scenario": "lm_text",
       "dedup_ratio": 1.619}
bad = dict(row, dedup_ratio=row["dedup_ratio"] * 0.99)
_, failures = bc.compare({"results": [row]}, {"results": [bad]})
if not any("dedup_ratio" in f for f in failures):
    print("[gate] a 1% scenario dedup-ratio drop passed the gate")
    fail += 1
_, failures = bc.compare({"results": [row]},
                         {"results": [dict(row, scenario="other")]})
if not any("missing" in f for f in failures):
    print("[gate] a dropped scenario row passed the gate")
    fail += 1

print("dev_check_scenarios:", "FAIL" if fail else "OK")
sys.exit(1 if fail else 0)
