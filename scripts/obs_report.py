"""Render observability data as human-readable tables.

    python scripts/obs_report.py metrics.json          # a metrics() snapshot
    python scripts/obs_report.py BENCH_quick.json      # a benchmark report
    python scripts/obs_report.py trace.jsonl           # a REPRO_TRACE log
    ... --json                                         # normalized JSON out

Accepts any of the three on-disk shapes the observability layer produces
(docs/OBSERVABILITY.md) and auto-detects which it was given:

* a ``metrics()`` dict (``{"service", "shards", "aggregate"}``) or a bare
  ``MetricsRegistry.snapshot()`` — counters/gauges as sorted tables,
  histograms as count/mean/p50/p95/p99 rows;
* a ``benchmarks/run.py`` report — provenance header plus one metrics
  section per captured service (the report's ``metrics`` key);
* a ``REPRO_TRACE`` JSONL file — per-span-name aggregation (count, total
  and p95 wall seconds, CPU/wall ratio, total bytes), plus the causal
  views the v2 trace schema enables: per-op request latency percentiles
  (p50/p95/p99 end-to-end, with the dominant phase from each request
  root's recorded partition) and the critical path of the slowest request
  per op, reconstructed from the ``trace_id``/``span_id``/``parent_id``
  linkage (spans from every process that appended to the file — writer
  threads, shard servers — stitch into one tree per request).

Stdlib-only, like everything under ``repro.obs``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.metrics import _quantiles, bucket_index  # noqa: E402


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(rows: list[dict], title: str):
    print(f"\n# {title}")
    if not rows:
        print("(empty)")
        return
    cols = list(rows[0])
    widths = [max(len(c), max(len(_fmt(r.get(c, ""))) for r in rows))
              for c in cols]
    print("  ".join(c.ljust(w) for c, w in zip(cols, widths)))
    for r in rows:
        print("  ".join(_fmt(r.get(c, "")).ljust(w)
                        for c, w in zip(cols, widths)))


def snapshot_rows(snap: dict) -> dict[str, list[dict]]:
    """One snapshot -> {"counters": rows, "gauges": rows, "histograms": rows}."""
    return {
        "counters": [{"counter": k, "value": v}
                     for k, v in sorted(snap.get("counters", {}).items())],
        "gauges": [{"gauge": k, "value": v}
                   for k, v in sorted(snap.get("gauges", {}).items())],
        "histograms": [
            {"histogram": k, "count": h["count"], "mean": h["mean"],
             "p50": h["p50"], "p95": h["p95"], "p99": h["p99"],
             "max": h["max"], "sum": h["sum"]}
            for k, h in sorted(snap.get("histograms", {}).items())
        ],
    }


def render_snapshot(snap: dict, label: str):
    for kind, rows in snapshot_rows(snap).items():
        if rows:
            _table(rows, f"{label}: {kind}")


def render_metrics(m: dict):
    """A full ``service.metrics()`` dict: service + per-shard + aggregate."""
    render_snapshot(m.get("service", {}), "service")
    shards = m.get("shards") or []
    for i, s in enumerate(shards):
        if s is None:
            print(f"\n# shard {i}: UNREACHABLE (no snapshot)")
        else:
            render_snapshot(s, f"shard {i}")
    if m.get("aggregate"):
        render_snapshot(m["aggregate"], "aggregate (all shards)")


def render_bench(report: dict):
    meta = report.get("meta", {})
    prov = meta.get("provenance", {})
    _table([{**{"budget": meta.get("budget"),
                "backend": meta.get("backend")}, **prov}],
           "benchmark run")
    metrics = report.get("metrics", {})
    if not metrics:
        print("\n(report embeds no metrics snapshots)")
    for name, m in sorted(metrics.items()):
        print(f"\n## {name}")
        render_metrics(m)


def load_trace(path: str) -> list[dict]:
    """Parse a JSONL trace, skipping blank and torn lines."""
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue  # torn tail line from a killed process
    return out


def trace_summary(records: list[dict]) -> list[dict]:
    """Aggregate trace records per span name."""
    agg: dict[str, dict] = {}
    for rec in records:
        a = agg.setdefault(rec.get("name", "?"), {
            "count": 0, "wall_s": 0.0, "cpu_s": 0.0, "bytes": 0,
            "errors": 0, "walls": [],
        })
        a["count"] += 1
        a["wall_s"] += rec.get("wall_s", 0.0)
        a["cpu_s"] += rec.get("cpu_s", 0.0)
        for k in ("bytes", "payload_bytes", "recv_bytes"):
            if k in rec:
                a["bytes"] += rec[k]
                break
        a["errors"] += 1 if "error" in rec else 0
        a["walls"].append(rec.get("wall_s", 0.0))
    rows = []
    for name, a in sorted(agg.items()):
        buckets: dict[int, int] = {}
        for w in a["walls"]:
            i = bucket_index(w)
            buckets[i] = buckets.get(i, 0) + 1
        (p95,) = _quantiles(buckets, a["count"], (0.95,))
        rows.append({
            "span": name, "count": a["count"], "wall_s": a["wall_s"],
            "p95_wall_s": p95,
            "cpu/wall": a["cpu_s"] / a["wall_s"] if a["wall_s"] else 0.0,
            "bytes": a["bytes"], "errors": a["errors"],
        })
    return rows


# -- causal views (v2 trace schema: trace_id/span_id/parent_id) -----------------
def build_trees(records: list[dict]):
    """Index the causal linkage -> (span_id -> record, span_id -> children).

    Children are sorted by start time (``ts - wall_s``; ``ts`` is recorded
    at span *end*).  A record whose ``parent_id`` is absent from the file
    (its parent's process was killed mid-write) simply roots its own
    subtree — the views below degrade instead of failing.
    """
    by_id = {r["span_id"]: r for r in records if "span_id" in r}
    children: dict[str, list[dict]] = {}
    for r in records:
        pid = r.get("parent_id")
        if pid and pid in by_id:
            children.setdefault(pid, []).append(r)
    for kids in children.values():
        kids.sort(key=lambda r: r.get("ts", 0.0) - r.get("wall_s", 0.0))
    return by_id, children


def _pct(sorted_vals: list[float], q: float) -> float:
    """Exact nearest-rank percentile (the samples are all retained here,
    unlike the registry's bucketed approximation)."""
    if not sorted_vals:
        return 0.0
    rank = max(1, -(-int(q * 1000) * len(sorted_vals) // 1000))
    return sorted_vals[min(rank, len(sorted_vals)) - 1]


def request_rows(records: list[dict]) -> list[dict]:
    """Per-op end-to-end latency percentiles with dominant-phase attribution.

    One row per ``op`` over the ``request`` root spans: count, p50/p95/p99
    of ``wall_s``, and the phase holding the largest share of the op's
    total time (from each root's recorded ``phases`` partition — the same
    numbers the ``req.latency_s{op=,phase=}`` histograms hold).
    """
    per_op: dict[str, dict] = {}
    for r in records:
        if r.get("name") != "request":
            continue
        a = per_op.setdefault(str(r.get("op", "?")),
                              {"walls": [], "phases": {}})
        a["walls"].append(r.get("wall_s", 0.0))
        for ph, secs in (r.get("phases") or {}).items():
            a["phases"][ph] = a["phases"].get(ph, 0.0) + secs
    rows = []
    for op, a in sorted(per_op.items()):
        walls = sorted(a["walls"])
        total = sum(a["phases"].values())
        dom, dom_s = ("?", 0.0)
        if a["phases"]:
            dom, dom_s = max(a["phases"].items(), key=lambda kv: kv[1])
        rows.append({
            "op": op, "count": len(walls),
            "p50_s": _pct(walls, 0.50), "p95_s": _pct(walls, 0.95),
            "p99_s": _pct(walls, 0.99), "max_s": walls[-1],
            "dominant_phase": dom,
            "dominant_share": dom_s / total if total else 0.0,
        })
    return rows


def critical_path(root: dict, children: dict[str, list[dict]]) -> list[dict]:
    """The heaviest child chain under ``root``: at each level descend into
    the child with the largest ``wall_s`` — the path a latency fix must
    shorten.  ``self_s`` is each node's wall minus its children's."""
    path = []
    node, depth = root, 0
    while node is not None:
        kids = children.get(node.get("span_id", ""), [])
        kid_wall = sum(k.get("wall_s", 0.0) for k in kids)
        label = node.get("name", "?")
        for extra in ("op", "bucket", "shard"):
            if extra in node:
                label += f" {extra}={node[extra]}"
        path.append({
            "span": ("  " * depth) + label,
            "wall_s": node.get("wall_s", 0.0),
            "self_s": max(0.0, node.get("wall_s", 0.0) - kid_wall),
            "frac_of_root": (node.get("wall_s", 0.0) /
                             root["wall_s"] if root.get("wall_s") else 0.0),
            "pid": node.get("pid", ""),
            "thread": node.get("thread", ""),
        })
        node = max(kids, key=lambda k: k.get("wall_s", 0.0),
                   default=None)
        depth += 1
    return path


def critical_path_views(records: list[dict]) -> dict[str, list[dict]]:
    """op -> critical-path rows of that op's slowest request."""
    _, children = build_trees(records)
    slowest: dict[str, dict] = {}
    for r in records:
        if r.get("name") != "request":
            continue
        op = str(r.get("op", "?"))
        if (op not in slowest
                or r.get("wall_s", 0.0) > slowest[op].get("wall_s", 0.0)):
            slowest[op] = r
    return {op: critical_path(root, children)
            for op, root in sorted(slowest.items())}


def render_trace(path: str):
    records = load_trace(path)
    _table(trace_summary(records), f"trace summary: {path}")
    req = request_rows(records)
    if req:
        _table(req, "request latency (end-to-end, per op)")
        for op, rows in critical_path_views(records).items():
            _table(rows, f"critical path: slowest {op!r} request")


def classify(path: str):
    """-> ("trace"|"bench"|"metrics"|"snapshot", parsed payload)."""
    with open(path, encoding="utf-8") as f:
        head = f.read(1)
        f.seek(0)
        if head != "{":
            return "trace", None
        try:
            doc = json.load(f)
        except json.JSONDecodeError:
            return "trace", None  # JSONL: line 2+ broke the single-doc parse
    if not isinstance(doc, dict):
        raise SystemExit(f"{path}: not an observability artifact")
    if "results" in doc and "meta" in doc:
        return "bench", doc
    if "service" in doc and "shards" in doc:
        return "metrics", doc
    if {"counters", "gauges", "histograms"} & set(doc):
        return "snapshot", doc
    # single-line JSONL traces parse as one dict; spans always carry these
    if "wall_s" in doc and "name" in doc:
        return "trace", None
    raise SystemExit(f"{path}: not an observability artifact")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="metrics JSON, BENCH_*.json, or trace JSONL")
    ap.add_argument("--json", action="store_true",
                    help="emit normalized JSON instead of tables")
    args = ap.parse_args(argv)
    kind, doc = classify(args.path)
    if kind == "trace":
        if args.json:
            records = load_trace(args.path)
            json.dump({
                "spans": trace_summary(records),
                "requests": request_rows(records),
                "critical_paths": critical_path_views(records),
            }, sys.stdout, indent=1)
            print()
        else:
            render_trace(args.path)
    elif args.json:
        json.dump(doc, sys.stdout, indent=1)
        print()
    elif kind == "bench":
        render_bench(doc)
    elif kind == "metrics":
        render_metrics(doc)
    else:
        render_snapshot(doc, args.path)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
