"""Render observability data as human-readable tables.

    python scripts/obs_report.py metrics.json          # a metrics() snapshot
    python scripts/obs_report.py BENCH_quick.json      # a benchmark report
    python scripts/obs_report.py trace.jsonl           # a REPRO_TRACE log
    ... --json                                         # normalized JSON out

Accepts any of the three on-disk shapes the observability layer produces
(docs/OBSERVABILITY.md) and auto-detects which it was given:

* a ``metrics()`` dict (``{"service", "shards", "aggregate"}``) or a bare
  ``MetricsRegistry.snapshot()`` — counters/gauges as sorted tables,
  histograms as count/mean/p50/p95/p99 rows;
* a ``benchmarks/run.py`` report — provenance header plus one metrics
  section per captured service (the report's ``metrics`` key);
* a ``REPRO_TRACE`` JSONL file — per-span-name aggregation (count, total
  and p95 wall seconds, CPU/wall ratio, total bytes).

Stdlib-only, like everything under ``repro.obs``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.metrics import _quantiles, bucket_index  # noqa: E402


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _table(rows: list[dict], title: str):
    print(f"\n# {title}")
    if not rows:
        print("(empty)")
        return
    cols = list(rows[0])
    widths = [max(len(c), max(len(_fmt(r.get(c, ""))) for r in rows))
              for c in cols]
    print("  ".join(c.ljust(w) for c, w in zip(cols, widths)))
    for r in rows:
        print("  ".join(_fmt(r.get(c, "")).ljust(w)
                        for c, w in zip(cols, widths)))


def snapshot_rows(snap: dict) -> dict[str, list[dict]]:
    """One snapshot -> {"counters": rows, "gauges": rows, "histograms": rows}."""
    return {
        "counters": [{"counter": k, "value": v}
                     for k, v in sorted(snap.get("counters", {}).items())],
        "gauges": [{"gauge": k, "value": v}
                   for k, v in sorted(snap.get("gauges", {}).items())],
        "histograms": [
            {"histogram": k, "count": h["count"], "mean": h["mean"],
             "p50": h["p50"], "p95": h["p95"], "p99": h["p99"],
             "max": h["max"], "sum": h["sum"]}
            for k, h in sorted(snap.get("histograms", {}).items())
        ],
    }


def render_snapshot(snap: dict, label: str):
    for kind, rows in snapshot_rows(snap).items():
        if rows:
            _table(rows, f"{label}: {kind}")


def render_metrics(m: dict):
    """A full ``service.metrics()`` dict: service + per-shard + aggregate."""
    render_snapshot(m.get("service", {}), "service")
    shards = m.get("shards") or []
    for i, s in enumerate(shards):
        if s is None:
            print(f"\n# shard {i}: UNREACHABLE (no snapshot)")
        else:
            render_snapshot(s, f"shard {i}")
    if m.get("aggregate"):
        render_snapshot(m["aggregate"], "aggregate (all shards)")


def render_bench(report: dict):
    meta = report.get("meta", {})
    prov = meta.get("provenance", {})
    _table([{**{"budget": meta.get("budget"),
                "backend": meta.get("backend")}, **prov}],
           "benchmark run")
    metrics = report.get("metrics", {})
    if not metrics:
        print("\n(report embeds no metrics snapshots)")
    for name, m in sorted(metrics.items()):
        print(f"\n## {name}")
        render_metrics(m)


def trace_summary(path: str) -> list[dict]:
    """Aggregate a JSONL trace per span name."""
    agg: dict[str, dict] = {}
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue  # torn tail line from a killed process
            a = agg.setdefault(rec.get("name", "?"), {
                "count": 0, "wall_s": 0.0, "cpu_s": 0.0, "bytes": 0,
                "errors": 0, "walls": [],
            })
            a["count"] += 1
            a["wall_s"] += rec.get("wall_s", 0.0)
            a["cpu_s"] += rec.get("cpu_s", 0.0)
            for k in ("bytes", "payload_bytes", "recv_bytes"):
                if k in rec:
                    a["bytes"] += rec[k]
                    break
            a["errors"] += 1 if "error" in rec else 0
            a["walls"].append(rec.get("wall_s", 0.0))
    rows = []
    for name, a in sorted(agg.items()):
        buckets: dict[int, int] = {}
        for w in a["walls"]:
            i = bucket_index(w)
            buckets[i] = buckets.get(i, 0) + 1
        (p95,) = _quantiles(buckets, a["count"], (0.95,))
        rows.append({
            "span": name, "count": a["count"], "wall_s": a["wall_s"],
            "p95_wall_s": p95,
            "cpu/wall": a["cpu_s"] / a["wall_s"] if a["wall_s"] else 0.0,
            "bytes": a["bytes"], "errors": a["errors"],
        })
    return rows


def classify(path: str):
    """-> ("trace"|"bench"|"metrics"|"snapshot", parsed payload)."""
    with open(path, encoding="utf-8") as f:
        head = f.read(1)
        f.seek(0)
        if head != "{":
            return "trace", None
        try:
            doc = json.load(f)
        except json.JSONDecodeError:
            return "trace", None  # JSONL: line 2+ broke the single-doc parse
    if not isinstance(doc, dict):
        raise SystemExit(f"{path}: not an observability artifact")
    if "results" in doc and "meta" in doc:
        return "bench", doc
    if "service" in doc and "shards" in doc:
        return "metrics", doc
    if {"counters", "gauges", "histograms"} & set(doc):
        return "snapshot", doc
    # single-line JSONL traces parse as one dict; spans always carry these
    if "wall_s" in doc and "name" in doc:
        return "trace", None
    raise SystemExit(f"{path}: not an observability artifact")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="metrics JSON, BENCH_*.json, or trace JSONL")
    ap.add_argument("--json", action="store_true",
                    help="emit normalized JSON instead of tables")
    args = ap.parse_args(argv)
    kind, doc = classify(args.path)
    if kind == "trace":
        rows = trace_summary(args.path)
        if args.json:
            json.dump(rows, sys.stdout, indent=1)
            print()
        else:
            _table(rows, f"trace summary: {args.path}")
    elif args.json:
        json.dump(doc, sys.stdout, indent=1)
        print()
    elif kind == "bench":
        render_bench(doc)
    elif kind == "metrics":
        render_metrics(doc)
    else:
        render_snapshot(doc, args.path)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
