"""Dev sanity: the sharded service equals the single service and survives.

Seconds-fast smoke for the sharded subsystem (docs/SHARDING.md): N-shard
ingest matches the 1-shard byte totals with byte-identical restores (async
flush on), owner-local GC returns every shard to zero, and the Pallas
mask path passes its bit-identity cross-check.  Exits non-zero on failure.
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.service import DedupService, ShardedDedupService

fail = 0

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)
versions = list(snapshot_series(base_bytes=1 << 17, snapshots=4,
                                edit_rate=2e-5, seed=2))

single = DedupService(params=P, slots=4, min_bucket=1024)
for i, v in enumerate(versions):
    single.submit(f"v{i}", v)
single.flush()
want = single.stats()

# 1) N-shard equivalence: identical byte totals, byte-identical restores
for n in (1, 2, 4):
    svc = ShardedDedupService(n, params=P, slots=4, min_bucket=1024,
                              async_flush=True)
    for i, v in enumerate(versions):
        svc.submit(f"v{i}", v)
    svc.flush()
    st = svc.stats()
    if (st.stored_bytes, st.unique_chunks) != (want.stored_bytes,
                                               want.unique_chunks):
        print(f"[sharded N={n}] byte totals diverged from single service")
        fail += 1
    for i, v in enumerate(versions):
        if svc.get(f"v{i}") != v.tobytes():
            print(f"[sharded N={n}] restore v{i} not byte-identical")
            fail += 1

    # 2) owner-local delete/GC: every shard back to zero
    for i in range(len(versions)):
        svc.delete(f"v{i}")
    svc.gc()
    if any(s.stored_bytes or s.logical_bytes for s in svc.stores):
        print(f"[sharded N={n}] shard accounting not zero after deletes")
        fail += 1
    svc.close()

# 3) Pallas hot path with the bit-identity guard on
svc = ShardedDedupService(2, params=P, slots=2, min_bucket=1024,
                          mask_impl="pallas", cross_check_masks=True)
data = np.random.default_rng(0).integers(0, 256, 20000, dtype=np.uint8)
svc.put("p", data)
if svc.get("p") != data.tobytes():
    print("[pallas] restore diverged")
    fail += 1
svc.close()

if fail:
    print(f"FAIL ({fail})")
    sys.exit(1)
print(f"sharded dev check OK: {want.unique_chunks} unique chunks, "
      f"ratio {want.dedup_ratio:.2f}x, N in (1,2,4) identical")
