"""Offline N→M resharding: repartition a sharded depot without re-chunking.

    PYTHONPATH=src python scripts/reshard.py --src DEPOT --dst NEW_DEPOT \\
        --shards M [--refingerprint] [--no-verify] [--json REPORT]

Streams every recipe from the N source shard roots, re-routes each chunk
with the *same* consistent-hash rule ingest uses
(``dedup.dist_index.owner_of(fp.h1, M)``), and writes M target shards plus
a rewritten recipe table.  No re-chunking and no re-hashing: boundaries and
SHA-256 keys are taken from the recipes, and the routing fingerprints come
from the per-chunk ``ObjectRecipe.fps`` the services record at commit time.
Because the rule is shared, a service reopened on the target depot routes
new ingests onto exactly the owners the resharder chose — dedup against
pre-reshard chunks keeps working.

Write order is the service's own crash protocol — blocks, then recipes,
then manifests — so an interrupted reshard leaves a target depot whose
recipes never name missing bytes (rerun with a fresh --dst, or let ``gc``
reclaim the partial blocks after deleting the target recipe table).

Verification (on by default): per-chunk, the target store's content
address must equal the recipe key (a byte flip in any source block makes
``put`` return a different SHA-256 and aborts); per-depot, logical/stored
byte totals and unique-chunk counts must match the source exactly; and
every object is reassembled from the target shards and SHA-256-checked
(``--no-verify`` skips only this last full-restore pass).

``--refingerprint`` handles legacy recipes that predate fps recording by
recomputing the 62-bit fingerprint from the chunk bytes (a polynomial pass
per chunk — still no re-chunking, boundaries stay fixed).
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.dedup.store import DirBlockStore  # noqa: E402
from repro.service.depot import (  # noqa: E402 — the depot layout owner
    pin_depot_shards,
    read_depot_shards,
    shard_roots,
)
from repro.service.objects import RecipeTable  # noqa: E402


class ReshardError(RuntimeError):
    """The repartition cannot proceed or failed verification."""


def _read_shard_count(root: str) -> int:
    n = read_depot_shards(root)
    if n is None:
        raise ReshardError(
            f"{root!r} has no sharding.json — not a sharded depot "
            f"(single-store depots open as 1-shard services first)"
        )
    return n


def _chunk_h1(recipe, i: int, chunk: bytes, refingerprint: bool):
    """Routing hash for one chunk: recorded fp preferred, recompute opt-in."""
    if recipe.fps is not None:
        return recipe.fps[i] >> 32, None
    if not refingerprint:
        raise ReshardError(
            f"recipe {recipe.name!r} records no fingerprints (pre-fps "
            f"depot); rerun with --refingerprint to recompute them from "
            f"chunk bytes (boundaries are kept, nothing is re-chunked)"
        )
    from repro.dedup.fingerprint import fingerprints_numpy

    fp = fingerprints_numpy(np.frombuffer(chunk, dtype=np.uint8),
                            np.array([len(chunk)], dtype=np.int64))[0]
    return int(fp[0]), (int(fp[0]) << 32) | int(fp[1])


def reshard(src: str, dst: str, m: int, *, refingerprint: bool = False,
            verify: bool = True) -> dict:
    """Repartition ``src`` (N shards) into ``dst`` (M shards); returns the
    verification report.  Raises :class:`ReshardError` on any mismatch."""
    from repro.dedup.dist_index import owner_of  # the one normative rule

    if m < 1:
        raise ReshardError("target shard count must be >= 1")
    n = _read_shard_count(src)
    if read_depot_shards(dst) is not None:
        raise ReshardError(f"target {dst!r} already holds a depot")
    t0 = time.time()
    src_stores = [DirBlockStore(r) for r in shard_roots(src, n)]
    recipes = RecipeTable(os.path.join(src, "recipes.json"))

    os.makedirs(dst, exist_ok=True)
    pin_depot_shards(dst, m)
    dst_stores = [DirBlockStore(r) for r in shard_roots(dst, m)]
    dst_recipes = RecipeTable(os.path.join(dst, "recipes.json"))

    chunks_moved = 0
    for name in recipes.names():
        r = recipes.get(name)
        if r.shards is not None:
            owners_old = r.shards
        elif n == 1:
            owners_old = [0] * len(r.keys)
        else:
            raise ReshardError(
                f"recipe {name!r} has no shard map in an {n}-shard depot"
            )
        new_owners = []
        new_fps = list(r.fps) if r.fps is not None else (
            [] if refingerprint else None
        )
        for i, (key, old) in enumerate(zip(r.keys, owners_old)):
            chunk = src_stores[old].get(key)
            h1, packed = _chunk_h1(r, i, chunk, refingerprint)
            if packed is not None:
                new_fps.append(packed)
            owner = int(owner_of(h1, m))
            got = dst_stores[owner].put(chunk)
            if got != key:
                raise ReshardError(
                    f"content mismatch for {name!r} chunk {i}: source shard "
                    f"{old} returned bytes hashing to {got[:12]}..., recipe "
                    f"says {key[:12]}... — source block is corrupt"
                )
            new_owners.append(owner)
            chunks_moved += 1
        dst_recipes.add(dataclasses.replace(r, shards=new_owners, fps=new_fps))

    # blocks are on disk; commit recipes, then manifests (the crash order)
    dst_recipes.sync()
    for st in dst_stores:
        st.sync()

    report = {
        "src": src, "dst": dst,
        "src_shards": n, "dst_shards": m,
        "objects": len(dst_recipes),
        "chunk_refs": chunks_moved,
        "logical_bytes": sum(st.logical_bytes for st in dst_stores),
        "stored_bytes": sum(st.stored_bytes for st in dst_stores),
        "unique_chunks": sum(st.unique_chunks for st in dst_stores),
        "seconds": round(time.time() - t0, 3),
    }
    checks = {
        "logical_bytes": sum(st.logical_bytes for st in src_stores),
        "stored_bytes": sum(st.stored_bytes for st in src_stores),
        "unique_chunks": sum(st.unique_chunks for st in src_stores),
    }
    for field, want in checks.items():
        if report[field] != want:
            raise ReshardError(
                f"{field} changed across reshard: source {want}, "
                f"target {report[field]}"
            )
    if verify:
        for name in dst_recipes.names():
            r = dst_recipes.get(name)
            data = b"".join(dst_stores[s].get(k)
                            for s, k in zip(r.shards, r.keys))
            if (len(data) != r.size
                    or hashlib.sha256(data).hexdigest() != r.sha256):
                raise ReshardError(
                    f"restore verification failed for {name!r} on the "
                    f"target depot"
                )
        report["verified_objects"] = len(dst_recipes)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("--src", required=True, help="source sharded depot root")
    ap.add_argument("--dst", required=True,
                    help="target depot root (must not already be a depot)")
    ap.add_argument("--shards", "-m", type=int, required=True,
                    help="target shard count M")
    ap.add_argument("--refingerprint", action="store_true",
                    help="recompute routing fps for pre-fps recipes "
                         "(boundaries kept; no re-chunking)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the full-restore SHA-256 pass "
                         "(totals are always verified)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the report as JSON")
    args = ap.parse_args(argv)
    try:
        report = reshard(args.src, args.dst, args.shards,
                         refingerprint=args.refingerprint,
                         verify=not args.no_verify)
    except ReshardError as e:
        print(f"reshard FAILED: {e}", file=sys.stderr)
        return 1
    print(json.dumps(report, indent=1))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
