"""Paper Figs. 1 & 7 (native CDC throughput x chunk size) and Figs. 8/9/12
(vector-accelerated throughput).

Substrate note (DESIGN.md SS2): the paper's unaccelerated-vs-AVX axis maps
here to *sequential semantics* (per-byte lax.scan / while_loop — "SEQ") vs
*two-phase vectorized* (bulk bitmaps + block automaton — "VSEQ"); XLA:CPU
emits AVX for the vectorized path, so the gap measured on this container is
a real scalar-vs-SIMD gap of the same nature as the paper's.
"""
from __future__ import annotations

from repro.core import make_chunker
from repro.core.calibrate import calibrated_kwargs

from .common import emit, random_data, time_throughput

NATIVE = ["rabin_seq", "crc_seq", "gear_seq", "fastcdc_seq", "ae_seq", "ram_seq", "seqcdc_seq"]
VECTOR = ["rabin", "crc", "gear", "fastcdc", "tttd", "ae", "ram", "seqcdc", "seqcdc_numpy"]
SIZES = [4096, 8192, 16384]

#: per-algo corpus budget (MiB, small budget) — the gather-bound hash-based
#: vector substrates run ~3-6 MB/s on CPU, the rest run 0.1-1 GB/s
_SLOW = {"rabin", "crc", "gear", "fastcdc", "tttd"}


def _mb_for(name: str, budget: str) -> int:
    if name in _SLOW:
        return 4 if budget == "small" else 16
    if name.endswith("_seq"):
        return 4 if budget == "small" else 16
    return 16 if budget == "small" else 64


def run(budget: str = "small"):
    rows = []
    for avg in SIZES:
        for group, names in (("fig7-native", NATIVE), ("fig8-vector", VECTOR)):
            for name in names:
                data = random_data(_mb_for(name, budget))
                c = make_chunker(name, avg, **calibrated_kwargs(name, avg))
                res = time_throughput(
                    lambda: c.chunk(data), data.nbytes, repeats=2, warmup=1
                )
                rows.append({"figure": group, "algo": name, "avg_kb": avg // 1024,
                             "gbps": res["gbps"], "mb": data.nbytes >> 20})
    emit(rows, "chunking throughput (figs 1/7/8/9)")
    return rows


if __name__ == "__main__":
    run()
