"""Paper Fig. 11: chunk-size CDF per algorithm (TPCC analogue, 8/16 KB)."""
from __future__ import annotations

import numpy as np

from repro.core import make_chunker
from repro.core.calibrate import calibrated_kwargs

from .common import dataset, emit

ALGOS = ["rabin", "crc", "gear", "fastcdc", "tttd", "ae", "ram", "seqcdc"]
PCTS = [1, 10, 25, 50, 75, 90, 99]


def run(budget: str = "small"):
    mb = 24 if budget == "small" else 64
    data = dataset("TPCC", mb)
    rows = []
    for avg in (8192, 16384):
        for name in ALGOS:
            c = make_chunker(name, avg, **calibrated_kwargs(name, avg))
            lens = c.chunk_lengths(data)
            pct = np.percentile(lens, PCTS)
            row = {"figure": "fig11-cdf", "algo": name, "avg_kb": avg // 1024,
                   "mean": float(lens.mean()), "n_chunks": int(lens.size)}
            row.update({f"p{p}": float(v) for p, v in zip(PCTS, pct)})
            rows.append(row)
    emit(rows, "chunk-size distribution (fig 11)")
    return rows


if __name__ == "__main__":
    run()
