"""Service-level benchmark: end-to-end ingest vs raw device chunking.

Measures, on a synthetic file-version corpus (the related repos' workload):

* raw chunking MB/s    — ``boundaries_batch`` on fixed device batches, the
  ceiling set by the accelerator pipeline alone;
* service ingest MB/s  — the full DedupService path (scheduler batching,
  host SHA-256, store, recipe commit), i.e. what a client actually sees;
* restore MB/s         — reassembly + whole-object verification.

The gap between the first two is the host-side tax (hashing dominates); the
benchmark exists so regressions in the scheduler or store show up as a
throughput number, not an anecdote.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.automaton import max_chunks_for
from repro.core.params import derived_params
from repro.core.seqcdc import boundaries_batch
from repro.service import DedupService

from . import common

MASK_IMPL = "jnp"
STEP_IMPL = "wide"
FP_IMPL = "reference"
PIPELINE_IMPL = "split"  # pinned: rows must not drift with REPRO_PIPELINE_IMPL


def _raw_chunking_gbps(corpus: np.ndarray, params, seg: int = 1 << 20,
                       batch: int = 8) -> float:
    import jax
    import jax.numpy as jnp

    n_seg = len(corpus) // seg
    if n_seg == 0:
        return 0.0
    batch = min(batch, n_seg)  # small corpora: one partial-width batch
    segs = corpus[: n_seg * seg].reshape(n_seg, seg)
    mc = max_chunks_for(seg, params)
    fn = jax.jit(lambda x: boundaries_batch(x, params, max_chunks=mc))

    def run():
        for i in range(0, n_seg - batch + 1, batch):
            b, c = fn(jnp.asarray(segs[i : i + batch]))
        jax.block_until_ready(c)

    nbytes = (n_seg // batch) * batch * seg
    return common.time_throughput(run, nbytes)["gbps"]


def run(budget: str = "small") -> None:
    params = derived_params(8192)
    versions = common.version_corpus(budget)
    corpus = np.concatenate(versions)
    total = int(corpus.size)

    raw_gbps = _raw_chunking_gbps(corpus, params)

    rows = []
    # cells: both fingerprint modes on the raw store, plus one compressing
    # cell (codec is a bench-compare identity axis: the zlib row's
    # compressed_ratio regressing or vanishing fails the gate)
    for with_fp, codec in ((False, "none"), (True, "none"), (True, "zlib")):
        # warmup pass compiles the per-bucket programs, then a timed cold store
        for _ in range(2):
            svc = DedupService(params=params, slots=8, with_fingerprints=with_fp,
                               mask_impl=MASK_IMPL, step_impl=STEP_IMPL,
                               fp_impl=FP_IMPL, pipeline_impl=PIPELINE_IMPL,
                               codec=codec)
            t0 = time.perf_counter()
            for i, v in enumerate(versions):
                svc.submit(f"v{i:03d}", v)
            svc.flush()
            ingest_s = time.perf_counter() - t0
        st = svc.stats()

        t0 = time.perf_counter()
        for i in range(len(versions)):
            svc.get(f"v{i:03d}")
        restore_s = time.perf_counter() - t0

        rows.append({
            "budget": budget,
            "shards": 1,
            "mask_impl": MASK_IMPL,
            "step_impl": STEP_IMPL,
            "fp_impl": FP_IMPL,
            "pipeline_impl": PIPELINE_IMPL,
            "fingerprints": int(with_fp),
            "codec": codec,
            "corpus_mb": total / common.MiB,
            "versions": len(versions),
            "raw_chunk_gbps": raw_gbps,
            "ingest_gbps": total / ingest_s / 1e9,
            "restore_gbps": total / restore_s / 1e9,
            "dedup_ratio": st.dedup_ratio,
            "compressed_ratio": st.compressed_ratio,
            "batch_occupancy": st.batch_occupancy,
        })
        # telemetry of the timed (second) cold-store ingest + the restores:
        # the dispatch-latency/backpressure story behind the rows above
        common.emit_metrics(f"service_fp{int(with_fp)}_{codec}", svc.metrics())
    common.emit(rows, "service: end-to-end ingest vs raw chunking")


if __name__ == "__main__":
    run("small")
