"""Benchmark harness entry point: one module per paper table/figure.

  python -m benchmarks.run            # default (small) budget
  python -m benchmarks.run --full     # paper-scale corpora
  python -m benchmarks.run --only bench_chunking
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "bench_calibrate",      # Table I / SSV
    "bench_chunking",       # Figs 1, 7, 8, 9, 12
    "bench_space_savings",  # Figs 5, 6 / Table III
    "bench_breakdown",      # Fig 10
    "bench_distribution",   # Fig 11
    "bench_shift",          # SSIV
    "bench_intrinsics",     # SSV microbench (VPU analogue)
    "bench_pipeline",       # framework-level (ingest + checkpoint)
    "bench_service",        # streaming dedup service (docs/SERVICE.md)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    budget = "full" if args.full else "small"

    mods = [m for m in MODULES if args.only is None or args.only in m]
    ok = True
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(budget)
            print(f"## {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # pragma: no cover
            ok = False
            print(f"## {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
