"""Benchmark harness entry point: one module per paper table/figure.

  python -m benchmarks.run                      # default (small) budget
  python -m benchmarks.run --full               # paper-scale corpora
  python -m benchmarks.run --only bench_chunking
  python -m benchmarks.run --json BENCH_pr2.json

Besides the stdout CSV, every run serializes all collected rows into one
JSON file (default ``BENCH_<budget>.json``) with a meta header recording
backend and the pipeline configuration defaults (``mask_impl`` /
``step_impl`` / ``shards``).  Rows that exercise a non-default
configuration carry their own ``mask_impl``/``step_impl``/``shards``
fields (the service benchmarks do); consumers should fall back to the
meta defaults for rows that don't.  This is what makes BENCH_*.json
trajectories comparable across PRs: a throughput delta can be attributed
to the code or to a config change, not guessed at.

The meta header also carries a ``provenance`` block (git SHA with a
-dirty marker, UTC timestamp, hostname, jax version, device kind) tying
each trajectory point to an exact code state and machine, and the report's
``metrics`` key embeds the service-internal telemetry snapshots the
service benchmarks capture via ``common.emit_metrics`` — dispatch
latencies, writer backpressure, RPC counts (render them with
``scripts/obs_report.py``).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import socket
import subprocess
import sys
import time


def provenance() -> dict:
    """Where/when/what a BENCH_*.json came from: git SHA (with a -dirty
    suffix when the tree has uncommitted changes), UTC timestamp, host,
    jax version, and the device kind behind the backend — enough to tie a
    throughput trajectory point back to an exact code state and machine."""
    here = os.path.dirname(os.path.abspath(__file__))

    def _git(*argv):
        try:
            return subprocess.run(
                ["git", *argv], cwd=here, capture_output=True, text=True,
                timeout=10,
            ).stdout.strip()
        except (OSError, subprocess.SubprocessError):
            return ""

    sha = _git("rev-parse", "HEAD") or None
    if sha and _git("status", "--porcelain"):
        sha += "-dirty"

    import jax

    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:  # pragma: no cover — backend with no devices
        device_kind = None
    return {
        "git_sha": sha,
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc
        ).isoformat(timespec="seconds"),
        "hostname": socket.gethostname(),
        "jax_version": jax.__version__,
        "device_kind": device_kind,
    }

MODULES = [
    "bench_calibrate",        # Table I / SSV
    "bench_chunking",         # Figs 1, 7, 8, 9, 12
    "bench_space_savings",    # Figs 5, 6 / Table III
    "bench_breakdown",        # Fig 10
    "bench_distribution",     # Fig 11
    "bench_shift",            # SSIV
    "bench_intrinsics",       # SSV microbench (VPU analogue)
    "bench_pipeline",         # framework-level (ingest + checkpoint)
    "bench_service",          # streaming dedup service (docs/SERVICE.md)
    "bench_sharded_service",  # sharded service (docs/SHARDING.md)
    "bench_scheduler_occupancy",  # adversarial length mixes (docs/SERVICE.md)
    "bench_scenarios",        # versioned-corpus workloads (docs/SCENARIOS.md)
]

#: the --quick subset: minutes-fast modules that understand the tiny
#: budget, covering the service/scheduler trajectory (what PR-over-PR
#: comparisons track) without the paper-figure sweeps; bench_intrinsics
#: rides along for its fingerprint-kernel speedup rows (fp_impl
#: "reference" vs "pallas") and the end-to-end fused-pipeline rows
#: (pipeline_impl "split" vs "fused")
QUICK_MODULES = [
    "bench_service",
    "bench_sharded_service",
    "bench_scheduler_occupancy",
    "bench_intrinsics",
    "bench_scenarios",
]

#: configuration every benchmark uses unless its rows say otherwise;
#: "scenario" tags rows from the workload catalog (repro.scenarios) —
#: synthetic-corpus benchmarks use the "none" default
DEFAULTS = {"mask_impl": "jnp", "step_impl": "wide", "fp_impl": "reference",
            "pipeline_impl": "split", "packing_impl": "off", "shards": 1,
            "transport": "local", "scenario": "none", "codec": "none"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="minutes-fast trajectory profile: tiny corpora, "
                         "service/scheduler modules only (QUICK_MODULES)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="output JSON path (default BENCH_<budget>.json)")
    args = ap.parse_args()
    if args.full and args.quick:
        ap.error("--full and --quick are mutually exclusive")
    budget = "full" if args.full else ("quick" if args.quick else "small")
    # a --only run gets its own default file so iterating on one module
    # never clobbers the canonical full-run trajectory
    json_path = args.json or (
        f"BENCH_{budget}.json" if args.only is None
        else f"BENCH_{budget}_{args.only}.json"
    )

    from . import common

    common.reset_results()
    base = QUICK_MODULES if args.quick else MODULES
    mods = [m for m in base if args.only is None or args.only in m]
    ok = True
    failures = []
    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(budget)
            print(f"## {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception as e:  # pragma: no cover
            ok = False
            failures.append(name)
            print(f"## {name} FAILED: {type(e).__name__}: {e}", file=sys.stderr)

    import jax

    report = {
        "meta": {
            "budget": budget,
            "backend": jax.default_backend(),
            "modules": mods,
            "failed_modules": failures,
            "defaults": dict(DEFAULTS),
            "provenance": provenance(),
        },
        "results": common.RESULTS,
        # service-internal telemetry captured by the benchmarks that run a
        # full service (emit_metrics): the *why* behind the throughput rows
        "metrics": common.METRICS,
    }
    with open(json_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"## wrote {len(common.RESULTS)} rows to {json_path}", file=sys.stderr)
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
