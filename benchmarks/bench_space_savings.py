"""Paper Fig. 5 / Fig. 6 / Table III: deduplication space savings.

Every algorithm x dataset x chunk size; space savings via Eq. 1 computed
from the SHA-256 content-addressed store (exact, not fingerprint-collision
bounded).  Datasets are the container-scale analogues of the paper's corpora
(data/corpus.py; DESIGN.md SS8).
"""
from __future__ import annotations

from repro.core import make_chunker
from repro.core.calibrate import calibrated_kwargs
from repro.dedup.store import BlockStore

from .common import dataset, emit

ALGOS = ["fixed", "rabin", "crc", "gear", "fastcdc", "tttd", "ae", "ram", "seqcdc"]
DATASETS = ["DEB", "DEV", "LNX", "RDS", "TPCC"]
SIZES = [4096, 8192, 16384]


def savings_for(name: str, avg: int, data) -> float:
    c = make_chunker(name, avg, **calibrated_kwargs(name, avg))
    bounds = c.chunk(data)
    store = BlockStore()
    store.put_stream(data, bounds)
    return store.savings


def run(budget: str = "small"):
    mb = 24 if budget == "small" else 64
    sizes = [8192] if budget == "small" else SIZES
    rows = []
    for ds in DATASETS:
        data = dataset(ds, mb)
        for avg in sizes:
            for name in ALGOS:
                rows.append({
                    "figure": "fig5-savings", "dataset": ds, "algo": name,
                    "avg_kb": avg // 1024,
                    "savings_pct": 100.0 * savings_for(name, avg, data),
                })
    # Fig 6: SeqCDC savings vs chunk size sweep
    for ds in DATASETS:
        data = dataset(ds, mb)
        for avg in SIZES:
            rows.append({
                "figure": "fig6-seqcdc-sweep", "dataset": ds, "algo": "seqcdc",
                "avg_kb": avg // 1024,
                "savings_pct": 100.0 * savings_for("seqcdc", avg, data),
            })
    emit(rows, "space savings (figs 5/6, table III)")
    return rows


if __name__ == "__main__":
    run()
