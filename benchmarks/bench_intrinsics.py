"""Paper SSV "x86 intrinsics performance": the VPU-primitive analogue.

The paper microbenchmarks ffs/pdep/tzcnt/popcnt because boundary detection
and skip triggering depend on them.  Our TPU mapping replaces them with
masked argmin (ffs), cumsum+argmax (pdep/tzcnt) and sum-of-bools (popcnt)
over W-wide blocks (DESIGN.md SS2); this bench times each primitive and the
two automaton step implementations built from them.

It also times the chunk-hashing hot path both ways — the jnp
searchsorted/gather/segment_sum chain (``fp_impl="reference"``) against the
fused Pallas fingerprint kernel (``fp_impl="pallas"``, docs/KERNELS.md) —
and records the speedup, the number the follow-up vector-chunking paper
says dominates once boundary detection is fast.

Finally it times the whole chunk+hash pipeline end to end both ways:
the composed split path (masks -> boundary scan -> fingerprints, three
dispatches) against the single-dispatch fused pipeline kernel
(``pipeline_impl="fused"``, kernels/fused_pipeline.py) — the fusion the
source paper's one-pass argument is about.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import paper_params
from repro.core.automaton import max_chunks_for
from repro.core.params import derived_params
from repro.core.seqcdc import boundaries_two_phase
from repro.dedup.fingerprint import chunk_fingerprints

from .common import emit, random_data, time_throughput

_BIG = jnp.int32(1 << 30)


def roofline_fraction(fn, args, measured_s: float):
    """Fraction of the modeled TPU-v5e roofline a measured run achieves.

    The compiled module's trip-count-aware flop/byte totals
    (``repro.roofline.hlo_cost``) bound the step at
    ``max(flops/peak, bytes/hbm_bw)``; the fraction is that bound over the
    measured time.  On the CPU container this is a small number — the model
    targets the accelerator, the measurement is the host — but it moves
    with the kernel's arithmetic/byte footprint, which is what a
    memory-bound chunking kernel needs watched per PR.  ``None`` when the
    HLO defeats the cost model (the column stays honest, not zero).
    """
    from repro.roofline import constants as C
    from repro.roofline.hlo_cost import HloCostModel

    try:
        compiled = fn.lower(*args).compile()
        cost = HloCostModel(compiled.as_text()).total()
        modeled_s = max(cost.flops / C.PEAK_FLOPS_BF16, cost.bytes / C.HBM_BW)
        if modeled_s <= 0.0 or measured_s <= 0.0:
            return None
        return modeled_s / measured_s
    except Exception:  # pragma: no cover — unparsable backend HLO
        return None


def _fingerprint_rows(budget: str, mb: int) -> list:
    """fp_impl="reference" vs "pallas" on one pre-chunked stream."""
    p = derived_params(8192)
    n = mb << 20
    data = jnp.asarray(random_data(mb, seed=5))
    mc = max_chunks_for(n, p)
    bounds, count = jax.block_until_ready(
        boundaries_two_phase(data, p, max_chunks=mc)
    )
    rows = []
    gbps = {}
    for impl in ("reference", "pallas"):
        fn = jax.jit(
            lambda d, b, c, impl=impl: chunk_fingerprints(
                d, b, c, max_chunks=mc, fp_impl=impl
            )
        )
        res = time_throughput(
            lambda: jax.block_until_ready(fn(data, bounds, count)), n
        )
        gbps[impl] = res["gbps"]
        rows.append({"figure": "fingerprint-kernel", "budget": budget,
                     "fp_impl": impl, "stream_mb": mb,
                     "gbits_per_s": res["gbps"],
                     "roofline_fraction": roofline_fraction(
                         fn, (data, bounds, count), res["seconds"])})
    rows[-1]["speedup_vs_reference"] = gbps["pallas"] / gbps["reference"]
    return rows


def _pipeline_rows(budget: str, mb: int) -> list:
    """pipeline_impl="split" vs "fused": end-to-end chunk+hash, one stream."""
    from repro.kernels import ops as kernel_ops

    p = derived_params(8192)
    n = mb << 20
    data = jnp.asarray(random_data(mb, seed=6))
    mc = max_chunks_for(n, p)

    def split(d):
        bounds, count = boundaries_two_phase(d, p, max_chunks=mc)
        return chunk_fingerprints(d, bounds, count, max_chunks=mc)

    impls = {
        "split": jax.jit(split),
        "fused": jax.jit(
            lambda d: kernel_ops.fused_pipeline(d, p, max_chunks=mc)
        ),
    }
    rows = []
    gbps = {}
    for impl, fn in impls.items():
        res = time_throughput(
            lambda: jax.block_until_ready(fn(data)), n
        )
        gbps[impl] = res["gbps"]
        rows.append({"figure": "fused-pipeline", "budget": budget,
                     "pipeline_impl": impl, "stream_mb": mb,
                     "gbits_per_s": res["gbps"],
                     "roofline_fraction": roofline_fraction(
                         fn, (data,), res["seconds"])})
    rows[-1]["speedup_vs_split"] = gbps["fused"] / gbps["split"]
    return rows


def run(budget: str = "small"):
    mb = {"quick": 2, "small": 8}.get(budget, 32)
    n = mb << 20
    rng = np.random.default_rng(3)
    bits = jnp.asarray(rng.random(n) < 0.01)
    W = 256
    blocks = bits.reshape(-1, W)
    iota = jnp.arange(W, dtype=jnp.int32)
    rows = []

    ffs = jax.jit(lambda b: jnp.min(jnp.where(b, iota, _BIG), axis=-1))
    popcnt = jax.jit(lambda b: jnp.sum(b, axis=-1, dtype=jnp.int32))
    nth = jax.jit(
        lambda b: jnp.argmax(jnp.cumsum(b.astype(jnp.int32), axis=-1) > 3, axis=-1)
    )
    for name, fn in [("ffs=masked-argmin", ffs), ("popcnt=sum", popcnt),
                     ("nth-set=cumsum-argmax", nth)]:
        res = time_throughput(lambda: jax.block_until_ready(fn(blocks)), n)
        rows.append({"figure": "sec5-intrinsics", "primitive": name,
                     "gbits_per_s": res["gbps"], "block_w": W})

    # automaton step cost: wide (O(W)/block) vs gather (O(1)/block)
    data = jnp.asarray(random_data(mb, seed=4))
    p = paper_params(16384)
    for impl in ("wide", "gather"):
        fn = jax.jit(
            lambda d, impl=impl: boundaries_two_phase(d, p, step_impl=impl)[1]
        )
        res = time_throughput(lambda: jax.block_until_ready(fn(data)), n)
        rows.append({"figure": "sec5-intrinsics", "primitive": f"automaton-{impl}",
                     "gbits_per_s": res["gbps"], "block_w": p.block_width,
                     "roofline_fraction": roofline_fraction(
                         fn, (data,), res["seconds"])})
    rows.extend(_fingerprint_rows(budget, mb))
    rows.extend(_pipeline_rows(budget, mb))
    emit(rows, "VPU-primitive microbench (paper SSV analogue)")
    return rows


if __name__ == "__main__":
    run()
