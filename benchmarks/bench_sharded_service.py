"""Sharded service benchmark: shard-count scaling and async-vs-sync flush.

Ingests the same synthetic file-version corpus into a
``ShardedDedupService`` for every (shards, flush-mode) cell and measures
what the scaling story actually delivers on this host:

* ingest GB/s   — submit+flush end to end (device chunking + routing +
                  per-shard store writes);
* restore GB/s  — cross-shard gather + whole-object verification;
* dedup ratio   — must be *identical* across all cells (fingerprint
                  partitioning preserves exact dedup; a drift here is a
                  correctness bug, not a perf result).

Async flush moves SHA-256 hashing and block IO onto per-shard writer
threads, so its win grows with shard count (more writers) and saturates at
the host's core count / GIL contention point — which is the honest CPU
story; on a multi-host deployment each shard's writer is a different
machine.  Every row records ``mask_impl``/``step_impl``/``shards`` so
BENCH_*.json trajectories are comparable across PRs.
"""
from __future__ import annotations

import json
import time

from repro.core.params import derived_params
from repro.service import ShardedDedupService

from . import common

MASK_IMPL = "jnp"
STEP_IMPL = "wide"
FP_IMPL = "reference"
PIPELINE_IMPL = "split"  # pinned: rows must not drift with REPRO_PIPELINE_IMPL


def _cell(versions, total: int, shards: int, async_flush: bool,
          budget: str, codec: str = "none") -> dict:
    params = derived_params(8192)
    # warmup run compiles the per-bucket programs; second run is timed
    for it in range(2):
        svc = ShardedDedupService(shards, params=params, slots=8,
                                  mask_impl=MASK_IMPL, step_impl=STEP_IMPL,
                                  fp_impl=FP_IMPL, pipeline_impl=PIPELINE_IMPL,
                                  async_flush=async_flush, codec=codec)
        t0 = time.perf_counter()
        for i, v in enumerate(versions):
            svc.submit(f"v{i:03d}", v)
        svc.flush()
        ingest_s = time.perf_counter() - t0
        if it == 0:
            svc.close()
    st = svc.stats()

    t0 = time.perf_counter()
    for i in range(len(versions)):
        svc.get(f"v{i:03d}")
    restore_s = time.perf_counter() - t0

    per = svc.shard_stats()
    uniques = [s["unique_chunks"] for s in per]
    common.emit_metrics(
        f"sharded_s{shards}_async{int(async_flush)}_{codec}", svc.metrics()
    )
    svc.close()
    return {
        "budget": budget,
        "shards": shards,
        "async_flush": int(async_flush),
        "transport": "local",
        "mask_impl": MASK_IMPL,
        "step_impl": STEP_IMPL,
        "fp_impl": FP_IMPL,
        "pipeline_impl": PIPELINE_IMPL,
        "codec": codec,
        "corpus_mb": total / common.MiB,
        "ingest_gbps": total / ingest_s / 1e9,
        "restore_gbps": total / restore_s / 1e9,
        "dedup_ratio": st.dedup_ratio,
        "compressed_ratio": st.compressed_ratio,
        "stored_bytes": st.stored_bytes,
        "unique_chunks": st.unique_chunks,
        "shard_balance": min(uniques) / max(uniques) if max(uniques) else 1.0,
    }


def run(budget: str = "small") -> list:
    versions = common.version_corpus(budget)
    total = int(sum(v.size for v in versions))
    rows = []
    shard_counts = (1, 2) if budget == "quick" else (1, 2, 4, 8)
    for shards in shard_counts:
        for async_flush in (False, True):
            rows.append(_cell(versions, total, shards, async_flush, budget))
    # one compressing cell: dedup_ratio must not move (the codec touches
    # payload bytes, never chunk identity), and the no-inflate fallback
    # bounds compressed_ratio >= dedup_ratio even on this high-entropy
    # synthetic corpus (the strict > win shows on the structured scenario
    # corpora — bench_scenarios' rows, gated by bench_compare)
    rows.append(_cell(versions, total, shard_counts[-1], True, budget,
                      codec="zlib"))
    ratios = {f"{r['dedup_ratio']:.9f}" for r in rows}
    assert len(ratios) == 1, f"dedup ratio drifted across cells: {ratios}"
    zrow = rows[-1]
    assert zrow["compressed_ratio"] >= zrow["dedup_ratio"], (
        "zlib cell inflated payloads: compressed_ratio "
        f"{zrow['compressed_ratio']:.3f} < dedup {zrow['dedup_ratio']:.3f}")
    common.emit(rows, "sharded service: shard scaling + async vs sync flush")
    return rows


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as a JSON array")
    args = ap.parse_args(argv)
    rows = run("full" if args.full else "small")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
