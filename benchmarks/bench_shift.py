"""Paper SSIV: byte-shift resistance — boundary survival + dedup between a
stream and its edited copy, per algorithm and edit kind.
"""
from __future__ import annotations

import numpy as np

from repro.core import make_chunker
from repro.core.calibrate import calibrated_kwargs
from repro.dedup.store import BlockStore

from .common import emit, random_data

ALGOS = ["fixed", "rabin", "gear", "fastcdc", "ae", "ram", "seqcdc"]
EDITS = [("insert", 7), ("delete", 13), ("overwrite", 64)]


def _edit(data: np.ndarray, kind: str, size: int, pos: int, rng) -> np.ndarray:
    if kind == "insert":
        return np.concatenate([data[:pos], rng.integers(0, 256, size, dtype=np.uint8), data[pos:]])
    if kind == "delete":
        return np.concatenate([data[:pos], data[pos + size:]])
    out = data.copy()
    out[pos : pos + size] = rng.integers(0, 256, size, dtype=np.uint8)
    return out


def run(budget: str = "small"):
    mb = 16 if budget == "small" else 64
    data = random_data(mb, seed=11)
    rng = np.random.default_rng(12)
    pos = data.size // 2
    rows = []
    for name in ALGOS:
        c = make_chunker(name, 8192, **calibrated_kwargs(name, 8192))
        b0 = c.chunk(data)
        store = BlockStore()
        store.put_stream(data, b0)
        base_stored = store.stored_bytes
        for kind, size in EDITS:
            edited = _edit(data, kind, size, pos, rng)
            b1 = c.chunk(edited)
            s2 = BlockStore()
            s2.put_stream(data, b0)
            s2.put_stream(edited, b1)
            # bytes the edited copy added beyond the original (lower = better)
            delta = s2.stored_bytes - base_stored
            rows.append({
                "figure": "sec4-shift", "algo": name, "edit": kind,
                "edit_bytes": size,
                "new_bytes": int(delta),
                "amplification": delta / max(size, 1),
            })
    emit(rows, "byte-shift resistance (paper SSIV)")
    return rows


if __name__ == "__main__":
    run()
