"""Scheduler occupancy under adversarial stream-length mixes (ROADMAP item).

The chunk scheduler's half-octave length buckets cap *row* padding at 50%,
but real traffic decides how much of that budget is spent: a bimodal mix
keeps two bucket populations half-full, a heavy tail scatters rare huge
streams into solo dispatches, and an all-tiny stream rides the
``min_bucket`` floor where a 300-byte request pays for a 16 KiB row.  This
benchmark ingests the same total byte budget under each distribution and
reports what the batching actually delivers:

* ``occupancy``       — real payload fraction of device traffic
                        (``SchedulerStats.occupancy``);
* ``pad_waste_pct``   — the complement: % of device bytes that were padding
                        (length padding within rows + zero rows);
* ``row_fill``        — dispatched rows that carried a request (from
                        ``SchedulerStats.device_rows``; partial batches no
                        longer ship zero rows, so this is 1.0 unless a
                        regression reintroduces them);
* ``buckets``/``dispatches``/``tail_pct`` — compiled-shape count, device
                        batches, and the host-side exact-tail fraction.

Every distribution runs under both ``packing_impl`` modes: ``off`` is the
pure length-bucket baseline, ``segments`` shelf-packs sub-``min_bucket``
streams into shared rows — the knob that rescues the ``all_tiny`` mix from
the min-bucket floor (a ~0.03 occupancy baseline) to near-full rows.

Chunking math is identical across rows (same params, same two-phase
pipeline); only the arrival-length distribution varies, so any occupancy
delta is pure batching behavior, not chunking speed.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core.params import derived_params
from repro.service import ChunkScheduler

from . import common

MASK_IMPL = "jnp"
STEP_IMPL = "wide"

#: stream-length distributions (drawn until the byte budget is filled)
def _bimodal(rng):
    if rng.random() < 0.8:
        return int(rng.integers(512, 2048))
    return int(rng.integers(256 << 10, 1 << 20))


def _heavy_tail(rng):
    # lognormal body with a hard floor/cap: occasional multi-hundred-KiB
    # streams over a mass of small ones
    return int(np.clip(rng.lognormal(mean=9.0, sigma=1.6), 256, 2 << 20))


def _all_tiny(rng):
    return int(rng.integers(100, 1000))


def _uniform(rng):
    return int(rng.integers(4 << 10, 64 << 10))


DISTRIBUTIONS = {
    "uniform": _uniform,      # control: the shape batching likes
    "bimodal": _bimodal,
    "heavy_tail": _heavy_tail,
    "all_tiny": _all_tiny,
}


def _lengths(draw, total: int, rng) -> list:
    out, acc = [], 0
    while acc < total:
        n = draw(rng)
        out.append(n)
        acc += n
    return out


def run(budget: str = "small") -> list:
    total = {"quick": 2, "small": 8}.get(budget, 32) * common.MiB
    params = derived_params(8192)
    rows = []
    for packing_impl in ("off", "segments"):
        for name, draw in DISTRIBUTIONS.items():
            rng = np.random.default_rng(17)
            lengths = _lengths(draw, total, rng)
            # fingerprints off: occupancy is a property of batching, and the
            # fp pass only dilutes the signal with unrelated device time
            sched = ChunkScheduler(params, slots=8, mask_impl=MASK_IMPL,
                                   step_impl=STEP_IMPL,
                                   packing_impl=packing_impl,
                                   with_fingerprints=False)
            payload = rng.integers(0, 256, int(sum(lengths)), dtype=np.uint8)
            off = 0
            for n in lengths:
                sched.submit(payload[off:off + n])
                off += n
            results = sched.drain()
            assert len(results) == len(lengths)
            st = sched.stats
            rows.append({
                "budget": budget,
                "dist": name,
                "packing_impl": packing_impl,
                "streams": len(lengths),
                "stream_mb": st.stream_bytes / common.MiB,
                "device_mb": st.device_bytes / common.MiB,
                "occupancy": st.occupancy,
                "pad_waste_pct": 100.0 * (1.0 - st.occupancy),
                "row_fill": ((st.device_rows - st.padded_rows)
                             / max(1, st.device_rows)),
                "packed_streams": st.packed_streams,
                "dispatches": st.dispatches,
                "buckets": len(sched._jit_cache),
                "tail_pct": 100.0 * st.tail_bytes / max(1, st.stream_bytes),
                "mask_impl": MASK_IMPL,
                "step_impl": STEP_IMPL,
            })
    common.emit(rows, "scheduler occupancy: adversarial length mixes")
    return rows


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    budget = "full" if args.full else ("quick" if args.quick else "small")
    rows = run(budget)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
