"""Scenario engine benchmark: dedup-ratio-vs-throughput per workload.

Runs the full service — batched ingest, exact SHA-accounted dedup,
SHA-verified restore — over every catalog scenario
(``repro.scenarios``: dataset revisions, backup snapshots, LM text,
container images) and emits one row per scenario with both sides of the
trade the CDC survey (arxiv 2409.06066) plots: the dedup ratio the
workload's structure allows and the throughput the pipeline delivers on
it.  The ``scenario`` field is a bench-compare identity axis
(scripts/bench_compare.py), so a per-scenario ratio regression fails CI
exactly like a speed regression.

Determinism contract: the corpora are seeded (same seed -> identical
bytes, cross-process) and the chunking is bit-deterministic, so
``dedup_ratio``/``chunks``/``objects`` are exact per seed — only the
``*_gbps`` columns are machine-dependent.  Each row also carries the
generator's expected-structure descriptor (``dup_fraction`` and the
contract band); a ratio outside the band fails the module, which fails
``benchmarks/run.py`` and therefore the gate.
"""
from __future__ import annotations

import time

from repro.scenarios import SCENARIOS, bench_params, generate
from repro.service import DedupService

from . import common

# pinned: scenario rows must not drift with REPRO_* env defaults
MASK_IMPL = "jnp"
STEP_IMPL = "wide"
FP_IMPL = "reference"
PIPELINE_IMPL = "split"
PACKING_IMPL = "off"
# scenario rows run with per-chunk compression on: the compressed_ratio
# column (dedup x compression, the estimators' headline number) is gated
# by bench_compare next to the pure dedup_ratio — which the codec must
# not move (chunk identity is codec-independent)
CODEC = "zlib"


def run(budget: str = "small") -> list:
    budget = "quick" if budget == "quick" else ("full" if budget == "full"
                                                else "small")
    rows = []
    band_failures = []
    for name in SCENARIOS:
        corpus = generate(name, budget)
        total = corpus.logical_bytes
        # warmup pass compiles the per-bucket programs, then a timed cold
        # store (the bench_service idiom): quick-budget corpora are small
        # enough that jit compile would otherwise dominate ingest_s
        for _ in range(2):
            svc = DedupService(
                params=bench_params(name, budget), slots=8,
                mask_impl=MASK_IMPL, step_impl=STEP_IMPL, fp_impl=FP_IMPL,
                pipeline_impl=PIPELINE_IMPL, packing_impl=PACKING_IMPL,
                codec=CODEC,
            )
            t0 = time.perf_counter()
            for obj_name, data in corpus.objects:
                svc.submit(obj_name, data)
            svc.flush()
            ingest_s = time.perf_counter() - t0

        # restore is idempotent, so best-of-N timing keeps the quick-budget
        # rows (a few MiB, single-pass ~ms) out of wall-clock-noise land
        def restore():
            for obj_name, _ in corpus.objects:
                svc.get(obj_name)  # SHA-256 verified restore

        restore_gbps = common.time_throughput(restore, total)["gbps"]

        st = svc.stats()
        exp = corpus.expected
        if not exp.check_ratio(st.dedup_ratio):
            band_failures.append(
                f"{name}: dedup_ratio {st.dedup_ratio:.3f} outside contract "
                f"band [{exp.min_dedup_ratio}, {exp.max_dedup_ratio}]")
        rows.append({
            "budget": budget,
            "scenario": name,
            "seed": corpus.seed,
            "avg_chunk": svc.params.avg_size,
            "shards": 1,
            "mask_impl": MASK_IMPL,
            "step_impl": STEP_IMPL,
            "fp_impl": FP_IMPL,
            "pipeline_impl": PIPELINE_IMPL,
            "packing_impl": PACKING_IMPL,
            "codec": CODEC,
            "fingerprints": 1,
            "objects": len(corpus.objects),
            "corpus_mb": total / common.MiB,
            "ingest_gbps": total / ingest_s / 1e9,
            "restore_gbps": restore_gbps,
            "dedup_ratio": st.dedup_ratio,
            "compressed_ratio": st.compressed_ratio,
            "space_savings": st.space_savings,
            "dup_fraction": exp.duplicate_fraction,
            "band_lo": exp.min_dedup_ratio,
            "band_hi": exp.max_dedup_ratio,
            "chunks": st.total_chunks,
            "unique_chunks": st.unique_chunks,
        })
        common.emit_metrics(f"scenario_{name}", svc.metrics())
    common.emit(rows, "scenarios: versioned-corpus dedup ratio vs throughput")
    if band_failures:
        raise AssertionError(
            "scenario dedup-ratio contract violated: "
            + "; ".join(band_failures))
    return rows


def main(argv=None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    budget = "full" if args.full else ("quick" if args.quick else "small")
    rows = run(budget)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
