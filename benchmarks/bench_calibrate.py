"""Paper SSV "Obtaining parameter values": Monte-Carlo calibration.

Re-runs the randomized-data simulation that produced core/calibrate.py's
frozen CALIBRATED table and reports achieved average chunk sizes for both
the paper's Table I parameters and the re-calibrated ones on this substrate.
"""
from __future__ import annotations

from repro.core import make_chunker
from repro.core.calibrate import CALIBRATED, calibrated_kwargs
from repro.core.params import paper_params

from .common import emit, random_data


def run(budget: str = "small"):
    mb = 4 if budget == "small" else 16
    data = random_data(mb, seed=0)
    rows = []
    for avg in (4096, 8192, 16384):
        paper = make_chunker("seqcdc_numpy", avg, params=paper_params(avg))
        calib = make_chunker("seqcdc_numpy", avg, **calibrated_kwargs("seqcdc", avg))
        rows.append({
            "figure": "tab1-calibration", "avg_target": avg,
            "paper_mean": float(paper.chunk_lengths(data).mean()),
            "calibrated_mean": float(calib.chunk_lengths(data).mean()),
            "calibrated_params": str(CALIBRATED[avg]["seqcdc"]).replace(",", ";"),
        })
    emit(rows, "parameter calibration (table I, paper SSV)")
    return rows


if __name__ == "__main__":
    run()
