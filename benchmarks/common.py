"""Shared benchmark utilities: timing, corpora, CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.data import corpus as corpus_mod

GiB = 1 << 30
MiB = 1 << 20


def time_throughput(fn: Callable[[], None], nbytes: int, *, repeats: int = 3,
                    warmup: int = 1) -> Dict[str, float]:
    """Best-of-N wall-clock throughput (GB/s) after warmup (jit compile)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    best = min(times)
    return {"seconds": best, "gbps": nbytes / best / 1e9}


_CORPUS_CACHE: Dict[tuple, np.ndarray] = {}


def dataset(name: str, mb: int) -> np.ndarray:
    key = (name, mb)
    if key not in _CORPUS_CACHE:
        _CORPUS_CACHE[key] = corpus_mod.load_dataset(name, mb)
    return _CORPUS_CACHE[key]


def version_corpus(budget: str) -> List[np.ndarray]:
    """The shared service-benchmark workload: a synthetic file-version
    series.  One definition so bench_service and bench_sharded_service rows
    in BENCH_*.json are computed on the *same* corpus and stay comparable.
    Budgets: ``quick`` (trajectory smoke), ``small`` (default), else full."""
    base_mb, snaps = {"quick": (1, 3), "small": (2, 4)}.get(budget, (16, 8))
    return list(corpus_mod.snapshot_series(
        base_bytes=base_mb * MiB, snapshots=snaps, edit_rate=5e-5, seed=7))


def random_data(mb: int, seed: int = 0) -> np.ndarray:
    key = ("RAND", mb, seed)
    if key not in _CORPUS_CACHE:
        _CORPUS_CACHE[key] = np.random.default_rng(seed).integers(
            0, 256, mb * MiB, dtype=np.uint8
        )
    return _CORPUS_CACHE[key]


#: rows collected across every emit() since the last reset — the harness
#: (benchmarks/run.py) serializes these into BENCH_*.json so per-PR
#: trajectories are machine-comparable, not just stdout CSV.
RESULTS: List[Dict] = []

#: metrics snapshots collected via emit_metrics() — serialized by run.py
#: under the report's top-level "metrics" key, so a BENCH_*.json carries
#: the service-internal telemetry (dispatch latency, writer backpressure,
#: RPC counts) that produced its throughput rows (docs/OBSERVABILITY.md)
METRICS: Dict[str, Dict] = {}


def reset_results():
    RESULTS.clear()
    METRICS.clear()


def emit_metrics(name: str, snapshot: Dict):
    """Attach one service ``metrics()`` snapshot to the run report."""
    METRICS[name] = snapshot


def emit(rows: List[Dict], title: str):
    if not rows:
        return
    for r in rows:
        RESULTS.append({"bench": title, **r})
    cols = list(rows[0])
    print(f"\n# {title}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r.get(c)) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)
