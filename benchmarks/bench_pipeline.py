"""Framework-level benches beyond the paper's tables: the dedup ingest
pipeline (chunk -> fingerprint -> dedup on the accelerator path) and the
CDC-incremental checkpoint store (the paper's technique applied to training
state).
"""
from __future__ import annotations

import os
import tempfile

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DedupIngest, PipelineConfig, snapshot_series

from .common import emit, time_throughput


def run(budget: str = "small"):
    rows = []
    base = (4 if budget == "small" else 16) << 20
    snaps = list(snapshot_series(base_bytes=base, snapshots=4, edit_rate=2e-5, seed=9))
    corpus = np.concatenate(snaps)

    cfg = PipelineConfig(avg_chunk=8192, segment_bytes=1 << 20, batch_segments=8)
    ing = DedupIngest(cfg)

    def consume():
        total = 0
        for u in ing.unique_bytes(corpus):
            total += len(u)
        return total

    res = time_throughput(consume, corpus.nbytes, repeats=1, warmup=0)
    rows.append({
        "bench": "ingest-pipeline", "mb": corpus.nbytes >> 20,
        "gbps": res["gbps"], "savings_pct": 100 * ing.savings,
    })

    # CDC checkpoint store: 4 adjacent "training" checkpoints
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(os.path.join(d, "ck"), avg_chunk=64 * 1024, keep=10)
        key = jax.random.PRNGKey(0)
        w = np.array(jax.random.normal(key, (1 << 20,)))  # 4 MB of "weights"
        import time as _t

        t0 = _t.perf_counter()
        for step in range(4):
            w[step * 100 : step * 100 + 50] += 0.01  # small update per step
            mgr.save(step, {"params": {"w": w.copy()}})
        dt = _t.perf_counter() - t0
        rows.append({
            "bench": "cdc-checkpoint-store", "mb": 4 * w.nbytes >> 20,
            "gbps": 4 * w.nbytes / dt / 1e9,
            "savings_pct": 100 * mgr.dedup_savings,
        })
    emit(rows, "framework pipelines (ingest + checkpoint dedup)")
    return rows


if __name__ == "__main__":
    run()
