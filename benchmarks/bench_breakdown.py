"""Paper Fig. 10: SeqCDC optimization breakdown at 16 KB chunks.

BASE      = sequential scan, no content-defined skipping (SkipTrigger = inf)
SEQ       = sequential scan + content-defined skipping
VBASE     = two-phase vectorized, no content-defined skipping
VSEQ      = two-phase vectorized + content-defined skipping
VSEQ-G    = VSEQ with the O(1)-gather automaton step (beyond-paper, SSPerf)

On TPU the mask phase reads every byte regardless of skipping (DESIGN.md
SS2), so the VBASE->VSEQ gain comes from the automaton phase doing fewer
block-events — the breakdown quantifies exactly how much of the paper's
CPU-side skip benefit survives the bulk-parallel translation per dataset.
"""
from __future__ import annotations

import dataclasses

from repro.core.chunker import SeqCDCChunker, SeqCDCSequentialChunker
from repro.core.params import paper_params

from .common import dataset, emit, time_throughput

DATASETS = ["DEB", "DEV", "LNX", "RDS", "TPCC"]


def _variants(avg: int):
    p = paper_params(avg)
    no_skip = dataclasses.replace(p, skip_trigger=1 << 20)
    return {
        "BASE": (SeqCDCSequentialChunker, {"params": no_skip}),
        "SEQ": (SeqCDCSequentialChunker, {"params": p}),
        "VBASE": (SeqCDCChunker, {"params": no_skip, "step_impl": "wide"}),
        "VSEQ": (SeqCDCChunker, {"params": p, "step_impl": "wide"}),
        "VSEQ-G": (SeqCDCChunker, {"params": p, "step_impl": "gather"}),
    }


def run(budget: str = "small"):
    avg = 16384
    rows = []
    mb_seq = 2 if budget == "small" else 8
    mb_vec = 16 if budget == "small" else 64
    for ds in DATASETS:
        for name, (cls, kw) in _variants(avg).items():
            mb = mb_seq if name in ("BASE", "SEQ") else mb_vec
            data = dataset(ds, mb)
            c = cls(avg, **kw)
            res = time_throughput(lambda: c.chunk(data), data.nbytes)
            rows.append({"figure": "fig10-breakdown", "dataset": ds,
                         "variant": name, "gbps": res["gbps"], "mb": mb})
    emit(rows, "SeqCDC optimization breakdown (fig 10)")
    return rows


if __name__ == "__main__":
    run()
