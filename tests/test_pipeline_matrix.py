"""Differential harness: the impl matrix must be bit-identical everywhere.

Every combination of ``pipeline_impl`` x ``mask_impl`` x ``fp_impl`` x
``packing_impl`` x shard count must produce *exactly* the same service
state — same recipes (chunk keys, lengths, packed fingerprints, object
digests), same stored bytes, same restored streams — because every
selector is documented as bit-identical and the sharded router consumes
the fingerprints the device produced.  This file makes that a tested invariant instead of a
convention: a baseline service (split / jnp / reference / 1 store) ingests
an adversarial corpus, and every other configuration is diffed against it
field by field.

Corpora are chosen for the failure modes the kernels have: all-tiny
streams (bucket-floor padding, host-tail exactification), constant bytes
(max-size-forced cuts, scan leapfrogging), empty and 1-byte objects,
shared blocks across objects (dedup hits), and a 64 KiB-max-size corpus
whose 65535/65536-byte chunks sit on the fingerprint limb-exactness
boundary.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.params import SeqCDCParams, derived_params
from repro.service import DedupService, ShardedDedupService

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)

PIPELINES = ("split", "fused")
MASKS = ("jnp", "pallas")
FPS = ("reference", "pallas")
PACKINGS = ("off", "segments")
SHARDS = (1, 2, 4)


def _adversarial_corpus():
    """(name, bytes) pairs hitting the chunker/scheduler edge regimes."""
    rng = np.random.default_rng(42)
    base = rng.integers(0, 256, 3000, dtype=np.uint8).tobytes()
    corpus = [
        ("empty", b""),
        ("one-byte", b"\x42"),
        ("tiny-pair", b"ab"),
        # max-size-forced cuts: constant bytes never form a monotone run
        ("zeros", bytes(2900)),
        ("random", base),
        # dedup hits: shares every chunk with "random", plus a new tail
        ("random-v2", base + rng.integers(0, 256, 700, dtype=np.uint8).tobytes()),
        ("low-entropy", rng.integers(0, 4, 2500, dtype=np.uint8).tobytes()),
    ]
    # all-tiny streams ride the min_bucket floor (the 96%-pad-waste regime)
    for i in range(12):
        n = int(rng.integers(5, 120))
        corpus.append((f"tiny-{i}", rng.integers(0, 256, n, dtype=np.uint8)
                       .tobytes()))
    return corpus


CORPUS = _adversarial_corpus()


def _ingest(svc, corpus=CORPUS):
    for name, data in corpus:
        svc.submit(name, data)
    svc.flush()
    return svc


def _service_state(svc, corpus=CORPUS):
    """Everything that must be bit-identical across the matrix."""
    recs = {}
    for name, _ in corpus:
        r = svc.recipes.get(name)
        recs[name] = (r.size, r.sha256, tuple(r.keys), tuple(r.chunk_lens),
                      tuple(r.fps or ()))
    stats = svc.stats()
    restored = {name: svc.get(name) for name, _ in corpus}
    return recs, (stats.stored_bytes, stats.unique_chunks,
                  stats.total_chunks, stats.logical_bytes), restored


def _assert_same_state(got, want, label):
    recs_g, stats_g, restored_g = got
    recs_w, stats_w, restored_w = want
    assert stats_g == stats_w, f"{label}: accounting diverged"
    for name in recs_w:
        assert recs_g[name] == recs_w[name], f"{label}: recipe {name!r}"
        assert restored_g[name] == restored_w[name], f"{label}: bytes {name!r}"


@pytest.fixture(scope="module")
def baseline_state():
    svc = _ingest(DedupService(params=P, slots=2, min_bucket=1024))
    state = _service_state(svc)
    # the corpus really does restore to what went in
    for name, data in CORPUS:
        assert state[2][name] == data
    return state


@pytest.mark.parametrize("packing_impl", PACKINGS)
@pytest.mark.parametrize("fp_impl", FPS)
@pytest.mark.parametrize("mask_impl", MASKS)
@pytest.mark.parametrize("pipeline_impl", PIPELINES)
def test_matrix_single_store(pipeline_impl, mask_impl, fp_impl,
                             packing_impl, baseline_state):
    svc = _ingest(DedupService(
        params=P, slots=2, min_bucket=1024, pipeline_impl=pipeline_impl,
        mask_impl=mask_impl, fp_impl=fp_impl, packing_impl=packing_impl,
        cross_check_pipeline=True, cross_check_packing=True,
    ))
    label = f"{pipeline_impl}/{mask_impl}/{fp_impl}/{packing_impl}"
    _assert_same_state(_service_state(svc), baseline_state, label)
    if pipeline_impl == "fused":  # the guard ran, not just the dispatch
        assert svc.scheduler._pipeline_checked_buckets
    if packing_impl == "segments":  # likewise for the packing guard
        assert svc.scheduler._packing_checked, label


@pytest.mark.parametrize("packing_impl", PACKINGS)
@pytest.mark.parametrize("num_shards", SHARDS)
@pytest.mark.parametrize("pipeline_impl", PIPELINES)
def test_matrix_sharded(pipeline_impl, num_shards, packing_impl,
                        baseline_state):
    with ShardedDedupService(
        num_shards, params=P, slots=2, min_bucket=1024,
        pipeline_impl=pipeline_impl, packing_impl=packing_impl,
        cross_check_pipeline=True, cross_check_packing=True,
    ) as svc:
        _ingest(svc)
        label = f"shards={num_shards}/{pipeline_impl}/{packing_impl}"
        _assert_same_state(_service_state(svc), baseline_state, label)
        # the shard maps themselves must agree: routing consumed the
        # device fingerprints, which were just asserted identical
        for name, _ in CORPUS:
            r = svc.recipes.get(name)
            assert len(r.shards) == len(r.keys), label


def _scenario_corpus():
    """Tiny-budget scenario-engine corpora (dataset revisions + backup
    snapshots): realistic versioned objects — seeded edit programs over
    structured rows, daily snapshots over a mixed-entropy disk base — so
    the matrix also covers the shifted-duplicate workload CDC exists for,
    not just the synthetic edge regimes above."""
    from repro.scenarios import generate

    corpus = []
    for name in ("dataset_revisions", "backup_snapshots"):
        c = generate(name, "tiny")
        corpus.extend((f"{name}/{obj}", data) for obj, data in c.objects)
    return corpus


@pytest.fixture(scope="module")
def scenario_state():
    corpus = _scenario_corpus()
    svc = _ingest(DedupService(params=P, slots=2, min_bucket=1024), corpus)
    state = _service_state(svc, corpus)
    for name, data in corpus:  # versioned corpora restore byte-exactly
        assert state[2][name] == data.tobytes()
    return corpus, state


@pytest.mark.parametrize("packing_impl", PACKINGS)
@pytest.mark.parametrize("pipeline_impl", PIPELINES)
def test_matrix_scenario_corpora(pipeline_impl, packing_impl, scenario_state):
    corpus, want = scenario_state
    svc = _ingest(DedupService(
        params=P, slots=2, min_bucket=1024, pipeline_impl=pipeline_impl,
        packing_impl=packing_impl, cross_check_pipeline=True,
        cross_check_packing=True,
    ), corpus)
    _assert_same_state(_service_state(svc, corpus), want,
                       f"scenario/{pipeline_impl}/{packing_impl}")


def test_matrix_scenario_corpora_sharded(scenario_state):
    corpus, want = scenario_state
    with ShardedDedupService(2, params=P, slots=2, min_bucket=1024) as svc:
        _ingest(svc, corpus)
        _assert_same_state(_service_state(svc, corpus), want,
                           "scenario/shards=2")


def test_matrix_limb_boundary_chunks():
    """64 KiB max-size params: 65535/65536-byte chunks sit on the
    fingerprint limb-exactness bound; fused and split must still agree."""
    p64 = derived_params(32768)
    corpus = [
        ("ff", b"\xff" * (65536 + 65535)),
        ("zeros", bytes(70000)),
    ]
    base = _ingest(DedupService(params=p64, slots=1, min_bucket=1024),
                   corpus)
    fused = _ingest(DedupService(params=p64, slots=1, min_bucket=1024,
                                 pipeline_impl="fused",
                                 cross_check_pipeline=True), corpus)
    _assert_same_state(_service_state(fused, corpus),
                       _service_state(base, corpus), "limb/fused")


@settings(max_examples=5, deadline=None)
@given(data=st.binary(min_size=0, max_size=2500),
       pipeline_impl=st.sampled_from(PIPELINES),
       mask_impl=st.sampled_from(MASKS),
       fp_impl=st.sampled_from(FPS),
       packing_impl=st.sampled_from(PACKINGS),
       num_shards=st.sampled_from(SHARDS))
def test_property_matrix_random_corpus(data, pipeline_impl, mask_impl,
                                       fp_impl, packing_impl, num_shards):
    """Random corpora through a random matrix cell vs the baseline cell:
    three objects (the stream, a duplicate-rich variant, a tiny slice) so
    dedup actually fires."""
    corpus = [("a", data), ("b", data + data[: len(data) // 2]),
              ("c", data[:7])]
    base = _ingest(DedupService(params=P, slots=2, min_bucket=1024), corpus)
    with ShardedDedupService(
        num_shards, params=P, slots=2, min_bucket=1024,
        pipeline_impl=pipeline_impl, mask_impl=mask_impl, fp_impl=fp_impl,
        packing_impl=packing_impl,
    ) as svc:
        _ingest(svc, corpus)
        _assert_same_state(
            _service_state(svc, corpus), _service_state(base, corpus),
            f"prop {pipeline_impl}/{mask_impl}/{fp_impl}/{packing_impl}"
            f"/N={num_shards}",
        )
