"""CDC checkpoint store: roundtrip, incremental dedup, retention, crash
safety, elastic (resharded) restore.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager


def _tree(seed, shape=(64, 64)):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, shape),
        "nested": {"b": jnp.arange(100, dtype=jnp.int32)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(0)
    mgr.save(5, {"params": t}, {"next_step": 6})
    step, state, extra = mgr.restore(tree_like={"params": t})
    assert step == 5 and extra["next_step"] == 6
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(state["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_incremental_dedup(tmp_path):
    """Adjacent checkpoints share most chunks -> high store savings."""
    mgr = CheckpointManager(str(tmp_path), avg_chunk=4096)
    base = np.random.default_rng(0).standard_normal((512, 256)).astype(np.float32)
    for step in range(4):
        t = {"w": jnp.asarray(base.copy())}
        base[step, :8] += 1.0  # tiny delta per "training step"
        mgr.save(step, {"params": t})
    assert mgr.dedup_savings > 0.6, mgr.dedup_savings


def test_retention_and_block_release(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in range(5):
        mgr.save(step, {"params": _tree(step)})
    assert mgr.steps() == [3, 4]
    # blocks from dropped manifests were released (store has only live data)
    step, state, _ = mgr.restore(tree_like={"params": _tree(0)})
    assert step == 4


def test_latest_pointer_crash_safety(tmp_path):
    """A torn manifest write never corrupts the newest committed checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": _tree(1)})
    # simulate a crash mid-save of step 2: orphan tmp manifest
    with open(os.path.join(str(tmp_path), "manifest-00000002.json.tmp"), "w") as f:
        f.write('{"step": 2, "trees": {INVALID')
    mgr2 = CheckpointManager(str(tmp_path))
    assert mgr2.latest_step() == 1
    step, state, _ = mgr2.restore(tree_like={"params": _tree(1)})
    assert step == 1


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    trees = {}
    for step in (1, 2, 3):
        trees[step] = _tree(step)
        mgr.save(step, {"params": trees[step]})
    step, state, _ = mgr.restore(step=2, tree_like={"params": trees[2]})
    np.testing.assert_array_equal(
        np.asarray(state["params"]["a"]), np.asarray(trees[2]["a"])
    )


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoints are mesh-agnostic: restore onto a different sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

    mgr = CheckpointManager(str(tmp_path))
    t = _tree(3)
    mgr.save(1, {"params": t})
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, PS()), t)
    step, placed, _ = mgr.restore_sharded({"params": t}, {"params": sh})
    assert step == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(placed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(4)
    mgr.save_async(7, {"params": t})
    mgr.wait()
    step, state, _ = mgr.restore(tree_like={"params": t})
    assert step == 7


def test_bf16_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32)).astype(jnp.bfloat16)}
    mgr.save(1, {"params": t})
    step, state, _ = mgr.restore(tree_like={"params": t})
    got = state["params"]["w"]
    assert got.dtype == np.dtype("bfloat16") or str(got.dtype) == "bfloat16"
    np.testing.assert_array_equal(np.asarray(got, np.float32), np.asarray(t["w"], np.float32))
