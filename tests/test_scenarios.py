"""Scenario engine contracts: determinism + golden dedup-ratio pins.

Two layers, mirroring tests/test_occupancy.py:

* the *generator* contract — ``generate(name, budget)`` is a pure
  function of (name, budget, seed): byte-identical in-process, in a fresh
  subprocess, and sensitive to the seed (``corpus_digest`` is the
  canonical fingerprint);
* the *service* contract — ``benchmarks/bench_scenarios.py`` run at the
  quick budget must land every scenario's measured dedup ratio inside a
  pinned band (chunking is bit-deterministic, so on any machine these are
  exact per seed; the bands absorb deliberate chunker tuning, not
  regressions).  The row pins double as a check that the bench emits the
  ``scenario`` identity axis bench_compare gates on.
"""
import os
import subprocess
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.scenarios import (
    BUDGETS,
    SCENARIOS,
    bench_params,
    corpus_digest,
    generate,
)

REPO = os.path.join(os.path.dirname(__file__), "..")

#: golden quick-budget dedup-ratio pins (measured; ~±5% bands — tighter
#: than the catalog's contract bands, which absorb budget-level variety)
GOLDEN_QUICK = {
    "dataset_revisions": (2.60, 2.87),
    "backup_snapshots": (2.75, 3.05),
    "lm_text": (1.54, 1.70),
    "container_images": (2.13, 2.35),
}


# -- generator contract ------------------------------------------------------

class TestCatalog:
    def test_catalog_shape(self):
        assert len(SCENARIOS) >= 4
        assert set(GOLDEN_QUICK) == set(SCENARIOS)
        for name, sc in SCENARIOS.items():
            assert sc.name == name
            assert sc.avg_chunk > 0
            assert sc.summary

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_descriptor_sanity(self, name):
        corpus = generate(name, "tiny")
        exp = corpus.expected
        assert 0.0 < exp.duplicate_fraction < 1.0
        assert 1.0 <= exp.min_dedup_ratio < exp.max_dedup_ratio
        assert corpus.logical_bytes > 0
        names = [n for n, _ in corpus.objects]
        assert len(names) == len(set(names))  # objects individually named
        for _, data in corpus.objects:
            assert data.dtype.name == "uint8" and data.size > 0

    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_all_budgets_declared(self, name):
        # every budget tier must generate (loud KeyError for table gaps);
        # only the cheap tiers are materialized here
        for budget in BUDGETS[:2]:
            assert generate(name, budget).budget == budget
        with pytest.raises(KeyError):
            SCENARIOS[name].generate("nonexistent")

    def test_same_seed_same_bytes(self):
        for name in SCENARIOS:
            a, b = generate(name, "tiny"), generate(name, "tiny")
            assert corpus_digest(a) == corpus_digest(b), name

    def test_different_seed_different_bytes(self):
        for name, sc in SCENARIOS.items():
            a = generate(name, "tiny")
            b = sc.generate("tiny", seed=sc.seed + 1)
            assert corpus_digest(a) != corpus_digest(b), name

    def test_cross_process_determinism(self):
        """The digest must agree with a fresh interpreter: the generators
        depend on nothing but numpy PCG64 streams (no hash(), no time, no
        filesystem) — the contract that makes BENCH rows and golden pins
        portable."""
        code = (
            "from repro.scenarios import SCENARIOS, corpus_digest, generate\n"
            "for n in sorted(SCENARIOS):\n"
            "    print(n, corpus_digest(generate(n, 'tiny')))\n"
        )
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True,
                             cwd=REPO).stdout.split()
        theirs = dict(zip(out[::2], out[1::2]))
        ours = {n: corpus_digest(generate(n, "tiny"))
                for n in sorted(SCENARIOS)}
        assert theirs == ours

    def test_bench_params_per_scenario_grain(self):
        # lm_text dedups at a finer canonical grain (docs/SCENARIOS.md);
        # tiny corpora always chunk at 1 KiB so matrix cells stay fast
        assert bench_params("lm_text", "quick").avg_size == 1024
        assert bench_params("dataset_revisions", "quick").avg_size == 8192
        assert bench_params("dataset_revisions", "tiny").avg_size == 1024


# -- service contract: golden pins via the benchmark -------------------------

@pytest.fixture(scope="module")
def scenario_rows():
    from benchmarks.bench_scenarios import run

    return {r["scenario"]: r for r in run(budget="quick")}


def test_every_scenario_reported(scenario_rows):
    assert set(scenario_rows) == set(SCENARIOS)


@pytest.mark.parametrize("name", sorted(GOLDEN_QUICK))
def test_golden_dedup_ratio_pins(name, scenario_rows):
    lo, hi = GOLDEN_QUICK[name]
    r = scenario_rows[name]
    assert lo <= r["dedup_ratio"] <= hi, (name, r["dedup_ratio"])
    # and the catalog's own (looser) contract band agrees
    assert r["band_lo"] <= r["dedup_ratio"] <= r["band_hi"], name


def test_rows_carry_the_compare_identity_axes(scenario_rows):
    """bench_compare matches rows on these fields; losing one would make
    scenario rows collide or silently stop being gated."""
    for name, r in scenario_rows.items():
        for field in ("scenario", "budget", "mask_impl", "step_impl",
                      "fp_impl", "pipeline_impl", "packing_impl",
                      "fingerprints", "shards"):
            assert field in r, (name, field)
        assert r["scenario"] == name
        assert r["dedup_ratio"] > 1.0  # every workload actually dedups
        assert r["ingest_gbps"] > 0 and r["restore_gbps"] > 0


def test_dedup_consistent_with_chunk_accounting(scenario_rows):
    for name, r in scenario_rows.items():
        assert r["unique_chunks"] <= r["chunks"], name
        assert 0.0 < r["space_savings"] < 1.0, name
        assert r["space_savings"] == pytest.approx(1 - 1 / r["dedup_ratio"])
