"""repro.obs + its wiring: registry math, tracing, end-to-end telemetry.

Covers the observability contract (docs/OBSERVABILITY.md):

* registry units — counter/gauge/histogram arithmetic, log-bucket
  resolution, label rendering, snapshot merging (including the
  disjoint-bucket and empty-snapshot edges), and the per-family labeled
  series cardinality cap;
* tracing — off by default and free, JSONL records when ``REPRO_TRACE``
  names a file, *bit-identical results* with tracing on, causal
  trace/span/parent linkage across threads and processes, and sink
  durability (per-line flush, torn tail lines, atexit close);
* layer wiring — scheduler dispatch metrics, service ingest/restore
  counters, writer metrics through a real flush, and per-request
  ``req.latency_s{op=,phase=}`` attribution whose phases tile the
  request's wall time;
* the wire — a remote sharded service's ``metrics()`` aggregates live
  per-server snapshots whose RPC counts and byte totals agree exactly
  with the client side, op by op; and a remote ``put``/``get`` emits
  spans forming a single connected tree per request (protocol v3 trace
  meta propagation).
"""
import json
import os
import threading

import numpy as np
import pytest

from repro.core.params import SeqCDCParams
from repro.obs import (
    BUCKETS_PER_OCTAVE,
    MetricsRegistry,
    PhaseClock,
    bucket_index,
    bucket_value,
    current_context,
    enabled,
    labeled,
    merge_snapshots,
    scope,
    span,
)
from repro.service import DedupService, ShardedDedupService

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


def _mk_service(**kw):
    return DedupService(params=P, slots=4, min_bucket=1024, **kw)


def _corpus(rng, n=60000):
    data = rng.integers(0, 256, n, dtype=np.uint8)
    return [data, np.concatenate([data[: n // 2], data[: n // 2]])]


# -- registry units -------------------------------------------------------------
class TestRegistry:
    def test_counters_and_gauges(self):
        r = MetricsRegistry()
        r.inc("c")
        r.inc("c", 4)
        r.set_gauge("g", 2)
        r.set_gauge("g", 9)  # last write wins
        assert r.counter("c") == 5
        assert r.gauge("g") == 9
        assert r.counter("missing") == 0
        assert r.gauge("missing", 7.5) == 7.5

    def test_bucket_roundtrip_resolution(self):
        # geometric buckets: the representative value of any value's bucket
        # is within half an octave step (~9%) of the value
        ratio = 2.0 ** (1.0 / BUCKETS_PER_OCTAVE)
        for v in (1e-6, 0.003, 0.5, 1.0, 7.0, 1234.5):
            rep = bucket_value(bucket_index(v))
            assert rep / v < ratio ** 0.5 + 1e-9
            assert v / rep < ratio ** 0.5 + 1e-9
        assert bucket_value(bucket_index(0.0)) == 0.0
        assert bucket_value(bucket_index(-3.0)) == 0.0

    def test_histogram_percentiles(self):
        r = MetricsRegistry()
        for _ in range(98):
            r.observe("h", 0.001)
        r.observe("h", 1.0)
        r.observe("h", 2.0)
        h = r.snapshot()["histograms"]["h"]
        assert h["count"] == 100
        assert h["min"] == 0.001 and h["max"] == 2.0
        assert 0.0009 < h["p50"] < 0.0011
        assert 0.0009 < h["p95"] < 0.0011
        assert 0.9 < h["p99"] < 1.1
        assert h["sum"] == pytest.approx(98 * 0.001 + 3.0)

    def test_time_context_manager(self):
        r = MetricsRegistry()
        with r.time("t_s"):
            pass
        h = r.snapshot()["histograms"]["t_s"]
        assert h["count"] == 1 and h["max"] < 1.0

    def test_labeled_rendering(self):
        assert labeled("x") == "x"
        assert labeled("x", shard=3, op="put") == "x{op=put,shard=3}"
        # sorted keys: the same labels always render the same string
        assert labeled("x", b=1, a=2) == labeled("x", a=2, b=1) == "x{a=2,b=1}"

    def test_merge_snapshots(self):
        r = MetricsRegistry()
        r.inc("n", 3)
        r.set_gauge("depth", 2)
        r.observe("h", 0.5)
        r.observe("h", 4.0)
        s = r.snapshot()
        m = merge_snapshots([s, s, None])  # None = unreachable shard
        assert m["counters"]["n"] == 6
        assert m["gauges"]["depth"] == 4  # gauges sum (fleet backlog)
        assert m["histograms"]["h"]["count"] == 4
        assert m["histograms"]["h"]["min"] == 0.5
        assert m["histograms"]["h"]["max"] == 4.0
        # merged quantiles come from the union's buckets, not an average
        assert m["histograms"]["h"]["p99"] == pytest.approx(
            s["histograms"]["h"]["p99"])

    def test_clear(self):
        r = MetricsRegistry()
        r.inc("a")
        r.observe("b", 1)
        r.clear()
        snap = r.snapshot()
        assert snap["counters"] == {} and snap["histograms"] == {}

    def test_snapshot_json_serializable(self):
        r = MetricsRegistry()
        r.inc("a", 2)
        r.observe("b", 0.25)
        json.dumps(r.snapshot())  # must not raise


# -- tracing --------------------------------------------------------------------
class TestTracing:
    def test_off_by_default_and_null_span(self, monkeypatch):
        monkeypatch.delenv("REPRO_TRACE", raising=False)
        assert not enabled()
        sp = span("x", a=1)
        with sp as s:
            s["b"] = 2  # attrs on the null span are dropped, not errors
        assert span("y") is span("z")  # the shared no-op instance

    def test_jsonl_records(self, tmp_path, monkeypatch):
        trace = tmp_path / "t.jsonl"
        monkeypatch.setenv("REPRO_TRACE", str(trace))
        assert enabled()
        with span("unit.work", bytes=64) as sp:
            sp["rows"] = 3
        with pytest.raises(ValueError):
            with span("unit.fail"):
                raise ValueError("boom")
        recs = [json.loads(l) for l in trace.read_text().splitlines()]
        assert [r["name"] for r in recs] == ["unit.work", "unit.fail"]
        ok = recs[0]
        assert ok["bytes"] == 64 and ok["rows"] == 3
        assert ok["wall_s"] >= 0 and ok["cpu_s"] >= 0
        assert ok["pid"] == os.getpid()
        assert recs[1]["error"] == "ValueError"

    def test_tracing_does_not_change_results(self, rng, tmp_path, monkeypatch):
        """The acceptance contract: same stores, same restored bytes,
        tracing on vs off."""
        corpus = _corpus(rng)

        def run():
            svc = _mk_service()
            for i, v in enumerate(corpus):
                svc.submit(f"o{i}", v)
            svc.flush()
            st = svc.stats()
            return ([svc.get(f"o{i}") for i in range(len(corpus))],
                    st.stored_bytes, st.unique_chunks)

        monkeypatch.delenv("REPRO_TRACE", raising=False)
        base = run()
        monkeypatch.setenv("REPRO_TRACE", str(tmp_path / "t.jsonl"))
        assert run() == base
        names = {json.loads(l)["name"]
                 for l in (tmp_path / "t.jsonl").read_text().splitlines()}
        assert {"sched.dispatch", "service.flush", "service.get"} <= names


# -- layer wiring ---------------------------------------------------------------
class TestServiceMetrics:
    def test_ingest_and_restore_counters(self, rng):
        svc = _mk_service()
        corpus = _corpus(rng)
        total = sum(int(v.size) for v in corpus)
        for i, v in enumerate(corpus):
            svc.submit(f"o{i}", v)
        svc.flush()
        svc.get("o0")
        m = svc.metrics()
        c = m["service"]["counters"]
        assert c["ingest.objects"] == len(corpus)
        assert c["ingest.bytes"] == total
        assert c["ingest.chunks"] > 0
        # corpus[1] is half-repeated, so hits must exist
        assert 0 < c["ingest.dedup_hit_chunks"] < c["ingest.chunks"]
        assert c["restore.objects"] == 1
        assert c["restore.bytes"] == int(corpus[0].size)
        assert m["shards"] == [] and m["aggregate"] is None

    def test_scheduler_dispatch_metrics(self, rng):
        svc = _mk_service()
        svc.put("a", rng.integers(0, 256, 50000, dtype=np.uint8))
        snap = svc.metrics()["service"]
        assert snap["counters"]["sched.dispatches"] >= 1
        assert snap["counters"]["sched.device_bytes"] >= 50000
        hname = labeled("sched.dispatch_s", pipeline=svc.scheduler.pipeline_impl,
                        mask=svc.scheduler.mask_impl, fp=svc.scheduler.fp_impl)
        h = snap["histograms"][hname]
        assert h["count"] == snap["counters"]["sched.dispatches"]
        occ = [g for g in snap["gauges"] if g.startswith("sched.occupancy{")]
        assert occ, "no per-bucket occupancy gauge was set"
        assert all(0 < snap["gauges"][g] <= 1 for g in occ)

    def test_flush_and_get_latency_histograms(self, rng):
        svc = _mk_service()
        svc.put("a", rng.integers(0, 256, 30000, dtype=np.uint8))
        svc.get("a")
        hists = svc.metrics()["service"]["histograms"]
        assert hists["service.flush_s"]["count"] == 1
        assert hists["service.get_s"]["count"] == 1

    def test_registries_are_per_service(self, rng):
        a, b = _mk_service(), _mk_service()
        a.put("x", rng.integers(0, 256, 20000, dtype=np.uint8))
        assert a.obs.counter("ingest.objects") == 1
        assert b.obs.counter("ingest.objects") == 0

    def test_sharded_local_metrics(self, rng):
        svc = ShardedDedupService(2, params=P, slots=4, min_bucket=1024)
        try:
            corpus = _corpus(rng)
            for i, v in enumerate(corpus):
                svc.submit(f"o{i}", v)
            svc.flush()
            svc.get("o0")
            m = svc.metrics()
            c = m["service"]["counters"]
            assert c["ingest.objects"] == len(corpus)
            assert c["ingest.fp_dup_chunks"] > 0  # the repeated half
            # writer metrics are labeled per shard and both shards wrote
            wrote = [s for s in range(2)
                     if c.get(labeled("writer.tasks", shard=s), 0) > 0]
            assert wrote == [0, 1]
            assert m["shards"] == []  # local transport: no server processes
        finally:
            svc.close()


# -- the wire -------------------------------------------------------------------
@pytest.mark.timeout(120)
class TestRemoteMetrics:
    def test_metrics_op_and_client_server_agreement(self, rng, tmp_path):
        """The acceptance test: ``metrics()`` on a remote sharded service
        returns live per-shard-server snapshots, and the client- and
        server-side RPC counters agree exactly, op by op — calls, and the
        symmetric blob-byte accounting."""
        svc = ShardedDedupService.open(str(tmp_path / "depot"), 2,
                                       transport="remote", params=P,
                                       slots=4, min_bucket=1024)
        try:
            corpus = _corpus(rng)
            for i, v in enumerate(corpus):
                svc.submit(f"o{i}", v)
            svc.flush()
            for i in range(len(corpus)):
                svc.get(f"o{i}")
            m = svc.metrics()
            assert len(m["shards"]) == 2
            assert all(s is not None for s in m["shards"])
            cc = m["service"]["counters"]
            sc = m["aggregate"]["counters"]
            pairs = [("rpc.client.calls{", "rpc.server.calls{"),
                     ("rpc.client.send_bytes{", "rpc.server.recv_bytes{"),
                     ("rpc.client.recv_bytes{", "rpc.server.send_bytes{")]
            checked = 0
            for k, v in cc.items():
                for mine, theirs in pairs:
                    if not k.startswith(mine):
                        continue
                    if mine == "rpc.client.recv_bytes{" and "op=metrics" in k:
                        # a snapshot is taken *inside* the metrics dispatch,
                        # so it cannot include its own response bytes
                        continue
                    assert sc.get(theirs + k[len(mine):]) == v, k
                    checked += 1
            assert checked >= 6  # at least put_blocks/get_blocks/metrics
            # real traffic flowed both ways
            assert cc[labeled("rpc.client.calls", op="put_blocks")] >= 2
            assert cc[labeled("rpc.client.send_bytes", op="put_blocks")] > 0
            assert cc[labeled("rpc.client.recv_bytes", op="get_blocks")] > 0
            # server-side exact dedup hits: corpus[1]'s repeated half
            assert sc["store.dedup_hit_chunks"] > 0
            # per-op server latency histograms exist for the hot ops
            assert m["aggregate"]["histograms"][
                labeled("rpc.server.latency_s", op="put_blocks")]["count"] >= 2
        finally:
            svc.close()

    def test_dead_server_degrades_to_none(self, rng, tmp_path):
        svc = ShardedDedupService.open(str(tmp_path / "depot"), 2,
                                       transport="remote", params=P,
                                       slots=4, min_bucket=1024)
        try:
            svc.put("x", rng.integers(0, 256, 20000, dtype=np.uint8))
            svc._servers[1].kill()
            m = svc.metrics()
            assert m["shards"][0] is not None
            assert m["shards"][1] is None
            # aggregate still builds from the reachable shard
            assert m["aggregate"]["counters"]
        finally:
            svc.close()

    def test_protocol_rejects_version_mismatch(self):
        # the reserved "trace" meta entry shipped with VERSION 3 (a v2
        # peer would pass it into op handler kwargs) and the codec
        # handshake + pre-compressed put_blocks meta with VERSION 4 (a v3
        # server would store compressed payloads as raw chunk bytes), so
        # mixed deployments must fail loudly at the first frame, not on a
        # surprise argument or silently corrupted store
        from repro.service.transport import protocol as proto
        assert proto.VERSION == 4
        assert proto.OP_NAMES[proto.OP_METRICS] == "metrics"
        assert proto.OP_NAMES[proto.OP_HELLO] == "hello"


def _report_mod():
    """scripts/obs_report.py, imported the way its CLI runs."""
    import sys
    scripts = os.path.join(os.path.dirname(__file__), "..", "scripts")
    if scripts not in sys.path:
        sys.path.insert(0, scripts)
    import obs_report
    return obs_report


# -- cardinality guard ----------------------------------------------------------
class TestCardinalityGuard:
    def test_labeled_series_capped_with_overflow_counter(self):
        r = MetricsRegistry(max_labeled_series=4)
        for i in range(10):
            r.inc(labeled("g", bucket=i))
        snap = r.snapshot()
        kept = [k for k in snap["counters"] if k.startswith("g{")]
        assert len(kept) == 4  # first four admitted, in arrival order
        assert snap["counters"][
            labeled("obs.series_dropped", family="g")] == 6

    def test_existing_series_and_unlabeled_names_never_dropped(self):
        r = MetricsRegistry(max_labeled_series=1)
        r.inc(labeled("g", bucket=0))
        r.inc(labeled("g", bucket=1))  # over the cap: dropped
        r.inc(labeled("g", bucket=0), 5)  # existing: still counts
        r.inc("plain", 3)  # unlabeled: exempt from the guard
        assert r.counter(labeled("g", bucket=0)) == 6
        assert r.counter(labeled("g", bucket=1)) == 0
        assert r.counter("plain") == 3

    def test_cap_is_per_family_and_per_kind(self):
        r = MetricsRegistry(max_labeled_series=2)
        for i in range(3):
            r.inc(labeled("a", i=i))
            r.inc(labeled("b", i=i))
            r.observe(labeled("a", i=i), 1.0)
            r.set_gauge(labeled("a", i=i), 1.0)
        snap = r.snapshot()
        assert len([k for k in snap["counters"] if k.startswith("a{")]) == 2
        assert len([k for k in snap["counters"] if k.startswith("b{")]) == 2
        assert len([k for k in snap["histograms"] if k.startswith("a{")]) == 2
        assert len([k for k in snap["gauges"] if k.startswith("a{")]) == 2
        # one drop per kind for a's third label set, one for b's
        assert snap["counters"][
            labeled("obs.series_dropped", family="a")] == 3
        assert snap["counters"][
            labeled("obs.series_dropped", family="b")] == 1

    def test_clear_resets_family_budgets(self):
        r = MetricsRegistry(max_labeled_series=1)
        r.set_gauge(labeled("q", s=0), 1.0)
        r.set_gauge(labeled("q", s=1), 2.0)  # dropped
        assert r.gauge(labeled("q", s=1), -1.0) == -1.0
        r.clear()
        r.set_gauge(labeled("q", s=1), 2.0)  # budget is fresh again
        assert r.gauge(labeled("q", s=1)) == 2.0

    def test_service_registries_carry_the_default_cap(self):
        assert _mk_service().obs._max_labeled_series == \
            MetricsRegistry.DEFAULT_MAX_LABELED_SERIES


# -- merge_snapshots edges -------------------------------------------------------
class TestMergeSnapshotEdges:
    def test_disjoint_bucket_sets(self):
        # shards whose latencies never overlap: the union's percentiles
        # must span both tails, and min/max come from different shards
        a, b = MetricsRegistry(), MetricsRegistry()
        for _ in range(50):
            a.observe("h", 0.001)
        for _ in range(50):
            b.observe("h", 100.0)
        a.inc("only_a", 1)
        b.inc("only_b", 2)
        m = merge_snapshots([a.snapshot(), b.snapshot()])
        h = m["histograms"]["h"]
        assert h["count"] == 100
        assert h["min"] == 0.001 and h["max"] == 100.0
        assert h["sum"] == pytest.approx(50 * 0.001 + 50 * 100.0)
        assert h["p50"] == pytest.approx(0.001, rel=0.1)  # low shard
        assert h["p99"] == pytest.approx(100.0, rel=0.1)  # high shard
        assert m["counters"] == {"only_a": 1, "only_b": 2}

    def test_empty_and_none_only_snapshots(self):
        assert merge_snapshots([]) == {
            "counters": {}, "gauges": {}, "histograms": {}}
        assert merge_snapshots([None, None]) == {
            "counters": {}, "gauges": {}, "histograms": {}}
        fresh = MetricsRegistry().snapshot()
        m = merge_snapshots([None, fresh, {}])
        assert m["histograms"] == {} and m["counters"] == {}

    def test_zero_count_histogram_does_not_poison_min_max(self):
        r = MetricsRegistry()
        r.observe("h", 2.0)
        empty = {"counters": {}, "gauges": {},
                 "histograms": {"h": {"count": 0, "sum": 0.0, "min": 0.0,
                                      "max": 0.0, "buckets": {}}}}
        h = merge_snapshots([empty, r.snapshot()])["histograms"]["h"]
        assert h["count"] == 1
        assert h["min"] == 2.0 and h["max"] == 2.0  # not clamped to 0.0

    def test_percentiles_rederive_as_a_single_merged_registry(self):
        # the acceptance property: merging shard snapshots must equal one
        # registry that saw every observation (bucket-exact, not averaged)
        vals = [0.0003 * (1.31 ** i) for i in range(60)]
        parts = [MetricsRegistry() for _ in range(3)]
        union = MetricsRegistry()
        for i, v in enumerate(vals):
            parts[i % 3].observe("h", v)
            union.observe("h", v)
        merged = merge_snapshots([p.snapshot() for p in parts])
        mh, uh = merged["histograms"]["h"], union.snapshot()["histograms"]["h"]
        assert mh["buckets"] == uh["buckets"]
        for stat in ("count", "min", "max", "p50", "p95", "p99"):
            assert mh[stat] == uh[stat], stat
        assert mh["sum"] == pytest.approx(uh["sum"])


# -- phase clock -----------------------------------------------------------------
class TestPhaseClock:
    def test_phases_tile_the_total_exactly(self):
        c = PhaseClock()
        with c.phase("a"):
            with c.phase("b"):  # nested: b owns its time, not a
                pass
        total, phases = c.stop()
        assert set(phases) == {PhaseClock.OTHER, "a", "b"}
        assert all(s >= 0.0 for s in phases.values())
        assert sum(phases.values()) == pytest.approx(total, rel=1e-9,
                                                     abs=1e-12)
        # idempotent: a second stop returns the same partition
        assert c.stop() == (total, phases)

    def test_move_reattributes_and_clamps(self):
        c = PhaseClock()
        with c.phase("a"):
            pass
        c.move("a", "tail", 999.0)  # clamped to what a actually holds
        c.move("missing", "x", 1.0)  # no-op: nothing to move
        total, phases = c.stop()
        assert phases["a"] == 0.0
        assert phases["tail"] > 0.0
        assert "x" not in phases
        assert sum(phases.values()) == pytest.approx(total, rel=1e-9,
                                                     abs=1e-12)

    def test_stop_drains_abandoned_phases(self):
        # an error path can leave phases open; stop() closes them so the
        # partition still tiles the total
        c = PhaseClock()
        c.phase("a").__enter__()
        c.phase("b").__enter__()
        total, phases = c.stop()
        assert {"a", "b"} <= set(phases)
        assert sum(phases.values()) == pytest.approx(total, rel=1e-9,
                                                     abs=1e-12)


# -- causal tracing --------------------------------------------------------------
class TestCausalTracing:
    def test_parent_linkage_and_trace_ids(self, tmp_path, monkeypatch):
        trace = tmp_path / "t.jsonl"
        monkeypatch.setenv("REPRO_TRACE", str(trace))
        with span("outer"):
            ctx = current_context()
            assert set(ctx) == {"trace_id", "span_id"}
            with span("inner"):
                inner_ctx = current_context()
                assert inner_ctx["trace_id"] == ctx["trace_id"]
                assert inner_ctx["span_id"] != ctx["span_id"]
        with span("second"):
            pass
        recs = {json.loads(l)["name"]: json.loads(l)
                for l in trace.read_text().splitlines()}
        outer, inner, second = recs["outer"], recs["inner"], recs["second"]
        assert inner["trace_id"] == outer["trace_id"]
        assert inner["parent_id"] == outer["span_id"]
        assert "parent_id" not in outer  # a root span
        assert second["trace_id"] != outer["trace_id"]  # new root, new trace

    def test_context_is_none_outside_spans_and_when_off(self, tmp_path,
                                                        monkeypatch):
        monkeypatch.delenv("REPRO_TRACE", raising=False)
        assert current_context() is None
        with span("nullspan"):
            assert current_context() is None  # null spans push nothing
        monkeypatch.setenv("REPRO_TRACE", str(tmp_path / "t.jsonl"))
        assert current_context() is None  # on, but no span open

    def test_scope_adopts_context_across_a_thread(self, tmp_path,
                                                  monkeypatch):
        trace = tmp_path / "t.jsonl"
        monkeypatch.setenv("REPRO_TRACE", str(trace))
        seen = {}
        with span("root"):
            ctx = current_context()

            def work():
                seen["inherited"] = current_context()  # fresh thread: none
                with scope(ctx), span("child"):
                    pass

            t = threading.Thread(target=work, name="seam")
            t.start()
            t.join()
        assert seen["inherited"] is None
        recs = {json.loads(l)["name"]: json.loads(l)
                for l in trace.read_text().splitlines()}
        child, root = recs["child"], recs["root"]
        assert child["trace_id"] == root["trace_id"]
        assert child["parent_id"] == root["span_id"]
        assert child["thread"] == "seam"

    def test_scope_tolerates_none_and_malformed_contexts(self, monkeypatch):
        monkeypatch.delenv("REPRO_TRACE", raising=False)
        with scope(None):
            assert current_context() is None
        with scope({"trace_id": "half"}):  # no span_id: ignored
            assert current_context() is None


# -- sink durability -------------------------------------------------------------
class TestSinkDurability:
    def test_flushed_per_line_and_close_reopens(self, tmp_path, monkeypatch):
        from repro.obs import trace as trace_mod
        path = tmp_path / "t.jsonl"
        monkeypatch.setenv("REPRO_TRACE", str(path))
        with span("a"):
            pass
        # flushed per record: the line is on disk while the cached handle
        # stays open (a concurrent reader sees whole lines, never buffers)
        assert path.read_text().endswith("\n")
        assert len(path.read_text().splitlines()) == 1
        trace_mod._close_sink()
        trace_mod._close_sink()  # idempotent (atexit may run it again)
        with span("b"):
            pass  # reopens the sink transparently, in append mode
        names = [json.loads(l)["name"] for l in path.read_text().splitlines()]
        assert names == ["a", "b"]

    def test_torn_tail_line_is_skipped_by_the_report(self, tmp_path,
                                                     monkeypatch):
        path = tmp_path / "t.jsonl"
        monkeypatch.setenv("REPRO_TRACE", str(path))
        with span("whole", bytes=5):
            pass
        with open(path, "a", encoding="utf-8") as f:
            f.write('{"name": "torn", "wall_')  # process killed mid-write
        rep = _report_mod()
        recs = rep.load_trace(str(path))
        assert [r["name"] for r in recs] == ["whole"]
        rows = rep.trace_summary(recs)
        assert rows[0]["span"] == "whole" and rows[0]["count"] == 1

    def test_unwritable_sink_never_raises(self, tmp_path, monkeypatch):
        # REPRO_TRACE pointing at a directory: the emit fails with OSError,
        # which tracing swallows — observability must not take work down
        monkeypatch.setenv("REPRO_TRACE", str(tmp_path))
        with span("x", a=1) as sp:
            sp["b"] = 2


# -- per-request latency attribution ---------------------------------------------
class TestRequestAttribution:
    @staticmethod
    def _phases_of(hists: dict, op: str) -> dict:
        prefix = f"req.latency_s{{op={op},phase="
        return {k[len(prefix):-1]: v for k, v in hists.items()
                if k.startswith(prefix)}

    def test_put_and_get_phases_reconcile_single_store(self, rng):
        svc = _mk_service()
        for i in range(3):
            svc.put(f"o{i}", rng.integers(0, 256, 30000, dtype=np.uint8))
        svc.get("o0")
        snap = svc.metrics()["service"]
        c, h = snap["counters"], snap["histograms"]
        assert c[labeled("req.requests", op="put")] == 3
        assert c[labeled("req.requests", op="get")] == 1
        # put = submit + flush joins the outer request: no op=flush series
        assert labeled("req.requests", op="flush") not in c
        for op in ("put", "get"):
            total = h[labeled("req.total_s", op=op)]
            phases = self._phases_of(h, op)
            assert phases, f"no phase series for op={op}"
            # the acceptance property: the phase partition tiles each
            # request's wall time, so the sums reconcile exactly
            assert sum(v["sum"] for v in phases.values()) == pytest.approx(
                total["sum"], rel=1e-6, abs=1e-9)
            assert all(v["count"] == total["count"]
                       for v in phases.values())
        assert {"chunk-dispatch", "commit", "sync"} <= set(
            self._phases_of(h, "put"))
        assert {"rpc", "verify"} <= set(self._phases_of(h, "get"))

    def test_sharded_phases_include_routing_and_queue_wait(self, rng):
        svc = ShardedDedupService(2, params=P, slots=4, min_bucket=1024)
        try:
            svc.put("a", rng.integers(0, 256, 60000, dtype=np.uint8))
            for i, v in enumerate(_corpus(rng)):
                svc.submit(f"o{i}", v)
            svc.flush()  # a standalone flush is its own op
            svc.get("o0")
            svc.delete("o1")
            snap = svc.metrics()["service"]
            c, h = snap["counters"], snap["histograms"]
            assert c[labeled("req.requests", op="put")] == 1
            assert c[labeled("req.requests", op="flush")] == 1
            assert c[labeled("req.requests", op="delete")] == 1
            assert {"chunk-dispatch", "routing", "writer-queue-wait",
                    "commit", "fp", "sync"} <= set(self._phases_of(h, "put"))
            assert {"routing", "rpc", "verify"} <= set(
                self._phases_of(h, "get"))
            for op in ("put", "flush", "get", "delete"):
                total = h[labeled("req.total_s", op=op)]
                phases = self._phases_of(h, op)
                assert sum(v["sum"] for v in phases.values()) == \
                    pytest.approx(total["sum"], rel=1e-6, abs=1e-9)
        finally:
            svc.close()

    def test_request_root_span_carries_id_and_phase_partition(
            self, rng, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TRACE", str(tmp_path / "t.jsonl"))
        svc = _mk_service()
        svc.put("a", rng.integers(0, 256, 30000, dtype=np.uint8))
        recs = [json.loads(l) for l in
                (tmp_path / "t.jsonl").read_text().splitlines()]
        roots = [r for r in recs if r["name"] == "request"]
        assert len(roots) == 1 and roots[0]["op"] == "put"
        root = roots[0]
        assert len(root["req"]) == 12  # 6 random bytes, hex
        # the recorded partition reconciles with the root's wall time
        # (small skew: the clock brackets the span, both ways, by ns)
        assert sum(root["phases"].values()) == pytest.approx(
            root["wall_s"], abs=0.05)
        # every other span this request emitted descends from the root
        assert all(r["trace_id"] == root["trace_id"] for r in recs)


# -- the wire: causal trees across processes -------------------------------------
@pytest.mark.timeout(120)
class TestRemoteTraceTree:
    def test_remote_put_emits_one_connected_tree(self, rng, tmp_path,
                                                 monkeypatch):
        """The acceptance test: with ``REPRO_TRACE`` set, one remote-
        transport ``put`` yields spans — client threads, writer threads,
        shard-server processes — that reconstruct into a single tree:
        every ``writer.task`` and ``rpc.server`` span carries the request's
        ``trace_id`` and a ``parent_id`` resolving inside the file."""
        trace_path = tmp_path / "trace.jsonl"
        # set before open: the spawned shard servers inherit the env
        monkeypatch.setenv("REPRO_TRACE", str(trace_path))
        svc = ShardedDedupService.open(str(tmp_path / "depot"), 2,
                                       transport="remote", params=P,
                                       slots=4, min_bucket=1024)
        try:
            svc.put("obj", rng.integers(0, 256, 60000, dtype=np.uint8))
            svc.get("obj")
        finally:
            svc.close()
        rep = _report_mod()
        recs = rep.load_trace(str(trace_path))
        by_id = {r["span_id"]: r for r in recs}
        roots = {r["op"]: r for r in recs if r["name"] == "request"}
        assert set(roots) == {"put", "get"}
        for op, root in roots.items():
            members = [r for r in recs if r["trace_id"] == root["trace_id"]]
            # connected: every non-root member's parent is in the file and
            # on the same trace — walking up always reaches the root
            for r in members:
                if r["span_id"] == root["span_id"]:
                    assert "parent_id" not in r
                    continue
                hops = 0
                node = r
                while node["span_id"] != root["span_id"]:
                    node = by_id[node["parent_id"]]
                    assert node["trace_id"] == root["trace_id"]
                    hops += 1
                    assert hops < 50
            names = {r["name"] for r in members}
            assert {"request", "rpc.client", "rpc.server"} <= names, op
            # the tree crosses process boundaries: server spans carry a
            # different pid than the client's
            pids = {r["pid"] for r in members}
            assert os.getpid() in pids and len(pids) >= 2, op
        # the put tree owns the flush work and the writer seam
        put_members = [r for r in recs
                       if r["trace_id"] == roots["put"]["trace_id"]]
        put_names = {r["name"] for r in put_members}
        assert {"service.flush", "sched.dispatch", "writer.task"} <= put_names
        # every writer.task in the file descends from the put request
        # (submit happens inside its flush; queue-wait is attributed there)
        tasks = [r for r in recs if r["name"] == "writer.task"]
        assert tasks
        assert all(r["trace_id"] == roots["put"]["trace_id"] for r in tasks)
        assert all("queue_wait_s" in r for r in tasks)
        # ops issued outside any request (shutdown at close) root their own
        # traces rather than being orphaned into a request's tree
        for r in recs:
            if r["name"] == "rpc.server" and r.get("op") == "shutdown":
                assert r["trace_id"] not in {
                    roots["put"]["trace_id"], roots["get"]["trace_id"]}

    def test_report_renders_critical_path_and_request_rows(
            self, rng, tmp_path, monkeypatch, capsys):
        trace_path = tmp_path / "trace.jsonl"
        monkeypatch.setenv("REPRO_TRACE", str(trace_path))
        svc = ShardedDedupService.open(str(tmp_path / "depot"), 2,
                                       transport="remote", params=P,
                                       slots=4, min_bucket=1024)
        try:
            svc.put("obj", rng.integers(0, 256, 60000, dtype=np.uint8))
            svc.get("obj")
        finally:
            svc.close()
        rep = _report_mod()
        recs = rep.load_trace(str(trace_path))
        rows = rep.request_rows(recs)
        by_op = {r["op"]: r for r in rows}
        assert {"put", "get"} <= set(by_op)
        for r in rows:
            assert r["count"] >= 1
            assert 0.0 < r["p50_s"] <= r["p95_s"] <= r["p99_s"] <= r["max_s"]
            assert r["dominant_phase"] != "?"
            assert 0.0 < r["dominant_share"] <= 1.0
        paths = rep.critical_path_views(recs)
        assert {"put", "get"} <= set(paths)
        put_path = paths["put"]
        assert put_path[0]["span"].startswith("request op=put")
        assert put_path[0]["frac_of_root"] == pytest.approx(1.0)
        assert len(put_path) >= 3  # descends through flush into real work
        top_wall = put_path[0]["wall_s"]
        for row in put_path:
            assert 0.0 <= row["self_s"] <= row["wall_s"] <= top_wall + 1e-9
        # and the CLI renders it without tripping over the artifact kind
        assert rep.main([str(trace_path)]) == 0
        out = capsys.readouterr().out
        assert "request latency (end-to-end, per op)" in out
        assert "critical path: slowest 'put' request" in out
