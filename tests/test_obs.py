"""repro.obs + its wiring: registry math, tracing, end-to-end telemetry.

Covers the observability contract (docs/OBSERVABILITY.md):

* registry units — counter/gauge/histogram arithmetic, log-bucket
  resolution, label rendering, snapshot merging;
* tracing — off by default and free, JSONL records when ``REPRO_TRACE``
  names a file, and *bit-identical results* with tracing on;
* layer wiring — scheduler dispatch metrics, service ingest/restore
  counters, writer metrics through a real flush;
* the wire — a remote sharded service's ``metrics()`` aggregates live
  per-server snapshots whose RPC counts and byte totals agree exactly
  with the client side, op by op.
"""
import json
import os

import numpy as np
import pytest

from repro.core.params import SeqCDCParams
from repro.obs import (
    BUCKETS_PER_OCTAVE,
    MetricsRegistry,
    bucket_index,
    bucket_value,
    enabled,
    labeled,
    merge_snapshots,
    span,
)
from repro.service import DedupService, ShardedDedupService

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


def _mk_service(**kw):
    return DedupService(params=P, slots=4, min_bucket=1024, **kw)


def _corpus(rng, n=60000):
    data = rng.integers(0, 256, n, dtype=np.uint8)
    return [data, np.concatenate([data[: n // 2], data[: n // 2]])]


# -- registry units -------------------------------------------------------------
class TestRegistry:
    def test_counters_and_gauges(self):
        r = MetricsRegistry()
        r.inc("c")
        r.inc("c", 4)
        r.set_gauge("g", 2)
        r.set_gauge("g", 9)  # last write wins
        assert r.counter("c") == 5
        assert r.gauge("g") == 9
        assert r.counter("missing") == 0
        assert r.gauge("missing", 7.5) == 7.5

    def test_bucket_roundtrip_resolution(self):
        # geometric buckets: the representative value of any value's bucket
        # is within half an octave step (~9%) of the value
        ratio = 2.0 ** (1.0 / BUCKETS_PER_OCTAVE)
        for v in (1e-6, 0.003, 0.5, 1.0, 7.0, 1234.5):
            rep = bucket_value(bucket_index(v))
            assert rep / v < ratio ** 0.5 + 1e-9
            assert v / rep < ratio ** 0.5 + 1e-9
        assert bucket_value(bucket_index(0.0)) == 0.0
        assert bucket_value(bucket_index(-3.0)) == 0.0

    def test_histogram_percentiles(self):
        r = MetricsRegistry()
        for _ in range(98):
            r.observe("h", 0.001)
        r.observe("h", 1.0)
        r.observe("h", 2.0)
        h = r.snapshot()["histograms"]["h"]
        assert h["count"] == 100
        assert h["min"] == 0.001 and h["max"] == 2.0
        assert 0.0009 < h["p50"] < 0.0011
        assert 0.0009 < h["p95"] < 0.0011
        assert 0.9 < h["p99"] < 1.1
        assert h["sum"] == pytest.approx(98 * 0.001 + 3.0)

    def test_time_context_manager(self):
        r = MetricsRegistry()
        with r.time("t_s"):
            pass
        h = r.snapshot()["histograms"]["t_s"]
        assert h["count"] == 1 and h["max"] < 1.0

    def test_labeled_rendering(self):
        assert labeled("x") == "x"
        assert labeled("x", shard=3, op="put") == "x{op=put,shard=3}"
        # sorted keys: the same labels always render the same string
        assert labeled("x", b=1, a=2) == labeled("x", a=2, b=1) == "x{a=2,b=1}"

    def test_merge_snapshots(self):
        r = MetricsRegistry()
        r.inc("n", 3)
        r.set_gauge("depth", 2)
        r.observe("h", 0.5)
        r.observe("h", 4.0)
        s = r.snapshot()
        m = merge_snapshots([s, s, None])  # None = unreachable shard
        assert m["counters"]["n"] == 6
        assert m["gauges"]["depth"] == 4  # gauges sum (fleet backlog)
        assert m["histograms"]["h"]["count"] == 4
        assert m["histograms"]["h"]["min"] == 0.5
        assert m["histograms"]["h"]["max"] == 4.0
        # merged quantiles come from the union's buckets, not an average
        assert m["histograms"]["h"]["p99"] == pytest.approx(
            s["histograms"]["h"]["p99"])

    def test_clear(self):
        r = MetricsRegistry()
        r.inc("a")
        r.observe("b", 1)
        r.clear()
        snap = r.snapshot()
        assert snap["counters"] == {} and snap["histograms"] == {}

    def test_snapshot_json_serializable(self):
        r = MetricsRegistry()
        r.inc("a", 2)
        r.observe("b", 0.25)
        json.dumps(r.snapshot())  # must not raise


# -- tracing --------------------------------------------------------------------
class TestTracing:
    def test_off_by_default_and_null_span(self, monkeypatch):
        monkeypatch.delenv("REPRO_TRACE", raising=False)
        assert not enabled()
        sp = span("x", a=1)
        with sp as s:
            s["b"] = 2  # attrs on the null span are dropped, not errors
        assert span("y") is span("z")  # the shared no-op instance

    def test_jsonl_records(self, tmp_path, monkeypatch):
        trace = tmp_path / "t.jsonl"
        monkeypatch.setenv("REPRO_TRACE", str(trace))
        assert enabled()
        with span("unit.work", bytes=64) as sp:
            sp["rows"] = 3
        with pytest.raises(ValueError):
            with span("unit.fail"):
                raise ValueError("boom")
        recs = [json.loads(l) for l in trace.read_text().splitlines()]
        assert [r["name"] for r in recs] == ["unit.work", "unit.fail"]
        ok = recs[0]
        assert ok["bytes"] == 64 and ok["rows"] == 3
        assert ok["wall_s"] >= 0 and ok["cpu_s"] >= 0
        assert ok["pid"] == os.getpid()
        assert recs[1]["error"] == "ValueError"

    def test_tracing_does_not_change_results(self, rng, tmp_path, monkeypatch):
        """The acceptance contract: same stores, same restored bytes,
        tracing on vs off."""
        corpus = _corpus(rng)

        def run():
            svc = _mk_service()
            for i, v in enumerate(corpus):
                svc.submit(f"o{i}", v)
            svc.flush()
            st = svc.stats()
            return ([svc.get(f"o{i}") for i in range(len(corpus))],
                    st.stored_bytes, st.unique_chunks)

        monkeypatch.delenv("REPRO_TRACE", raising=False)
        base = run()
        monkeypatch.setenv("REPRO_TRACE", str(tmp_path / "t.jsonl"))
        assert run() == base
        names = {json.loads(l)["name"]
                 for l in (tmp_path / "t.jsonl").read_text().splitlines()}
        assert {"sched.dispatch", "service.flush", "service.get"} <= names


# -- layer wiring ---------------------------------------------------------------
class TestServiceMetrics:
    def test_ingest_and_restore_counters(self, rng):
        svc = _mk_service()
        corpus = _corpus(rng)
        total = sum(int(v.size) for v in corpus)
        for i, v in enumerate(corpus):
            svc.submit(f"o{i}", v)
        svc.flush()
        svc.get("o0")
        m = svc.metrics()
        c = m["service"]["counters"]
        assert c["ingest.objects"] == len(corpus)
        assert c["ingest.bytes"] == total
        assert c["ingest.chunks"] > 0
        # corpus[1] is half-repeated, so hits must exist
        assert 0 < c["ingest.dedup_hit_chunks"] < c["ingest.chunks"]
        assert c["restore.objects"] == 1
        assert c["restore.bytes"] == int(corpus[0].size)
        assert m["shards"] == [] and m["aggregate"] is None

    def test_scheduler_dispatch_metrics(self, rng):
        svc = _mk_service()
        svc.put("a", rng.integers(0, 256, 50000, dtype=np.uint8))
        snap = svc.metrics()["service"]
        assert snap["counters"]["sched.dispatches"] >= 1
        assert snap["counters"]["sched.device_bytes"] >= 50000
        hname = labeled("sched.dispatch_s", pipeline=svc.scheduler.pipeline_impl,
                        mask=svc.scheduler.mask_impl, fp=svc.scheduler.fp_impl)
        h = snap["histograms"][hname]
        assert h["count"] == snap["counters"]["sched.dispatches"]
        occ = [g for g in snap["gauges"] if g.startswith("sched.occupancy{")]
        assert occ, "no per-bucket occupancy gauge was set"
        assert all(0 < snap["gauges"][g] <= 1 for g in occ)

    def test_flush_and_get_latency_histograms(self, rng):
        svc = _mk_service()
        svc.put("a", rng.integers(0, 256, 30000, dtype=np.uint8))
        svc.get("a")
        hists = svc.metrics()["service"]["histograms"]
        assert hists["service.flush_s"]["count"] == 1
        assert hists["service.get_s"]["count"] == 1

    def test_registries_are_per_service(self, rng):
        a, b = _mk_service(), _mk_service()
        a.put("x", rng.integers(0, 256, 20000, dtype=np.uint8))
        assert a.obs.counter("ingest.objects") == 1
        assert b.obs.counter("ingest.objects") == 0

    def test_sharded_local_metrics(self, rng):
        svc = ShardedDedupService(2, params=P, slots=4, min_bucket=1024)
        try:
            corpus = _corpus(rng)
            for i, v in enumerate(corpus):
                svc.submit(f"o{i}", v)
            svc.flush()
            svc.get("o0")
            m = svc.metrics()
            c = m["service"]["counters"]
            assert c["ingest.objects"] == len(corpus)
            assert c["ingest.fp_dup_chunks"] > 0  # the repeated half
            # writer metrics are labeled per shard and both shards wrote
            wrote = [s for s in range(2)
                     if c.get(labeled("writer.tasks", shard=s), 0) > 0]
            assert wrote == [0, 1]
            assert m["shards"] == []  # local transport: no server processes
        finally:
            svc.close()


# -- the wire -------------------------------------------------------------------
@pytest.mark.timeout(120)
class TestRemoteMetrics:
    def test_metrics_op_and_client_server_agreement(self, rng, tmp_path):
        """The acceptance test: ``metrics()`` on a remote sharded service
        returns live per-shard-server snapshots, and the client- and
        server-side RPC counters agree exactly, op by op — calls, and the
        symmetric blob-byte accounting."""
        svc = ShardedDedupService.open(str(tmp_path / "depot"), 2,
                                       transport="remote", params=P,
                                       slots=4, min_bucket=1024)
        try:
            corpus = _corpus(rng)
            for i, v in enumerate(corpus):
                svc.submit(f"o{i}", v)
            svc.flush()
            for i in range(len(corpus)):
                svc.get(f"o{i}")
            m = svc.metrics()
            assert len(m["shards"]) == 2
            assert all(s is not None for s in m["shards"])
            cc = m["service"]["counters"]
            sc = m["aggregate"]["counters"]
            pairs = [("rpc.client.calls{", "rpc.server.calls{"),
                     ("rpc.client.send_bytes{", "rpc.server.recv_bytes{"),
                     ("rpc.client.recv_bytes{", "rpc.server.send_bytes{")]
            checked = 0
            for k, v in cc.items():
                for mine, theirs in pairs:
                    if not k.startswith(mine):
                        continue
                    if mine == "rpc.client.recv_bytes{" and "op=metrics" in k:
                        # a snapshot is taken *inside* the metrics dispatch,
                        # so it cannot include its own response bytes
                        continue
                    assert sc.get(theirs + k[len(mine):]) == v, k
                    checked += 1
            assert checked >= 6  # at least put_blocks/get_blocks/metrics
            # real traffic flowed both ways
            assert cc[labeled("rpc.client.calls", op="put_blocks")] >= 2
            assert cc[labeled("rpc.client.send_bytes", op="put_blocks")] > 0
            assert cc[labeled("rpc.client.recv_bytes", op="get_blocks")] > 0
            # server-side exact dedup hits: corpus[1]'s repeated half
            assert sc["store.dedup_hit_chunks"] > 0
            # per-op server latency histograms exist for the hot ops
            assert m["aggregate"]["histograms"][
                labeled("rpc.server.latency_s", op="put_blocks")]["count"] >= 2
        finally:
            svc.close()

    def test_dead_server_degrades_to_none(self, rng, tmp_path):
        svc = ShardedDedupService.open(str(tmp_path / "depot"), 2,
                                       transport="remote", params=P,
                                       slots=4, min_bucket=1024)
        try:
            svc.put("x", rng.integers(0, 256, 20000, dtype=np.uint8))
            svc._servers[1].kill()
            m = svc.metrics()
            assert m["shards"][0] is not None
            assert m["shards"][1] is None
            # aggregate still builds from the reachable shard
            assert m["aggregate"]["counters"]
        finally:
            svc.close()

    def test_protocol_rejects_version_mismatch(self):
        # OP_METRICS shipped with VERSION 2: a v1 peer must fail loudly at
        # the first frame, not choke on an unknown op mid-stream
        from repro.service.transport import protocol as proto
        assert proto.VERSION == 2
        assert proto.OP_NAMES[proto.OP_METRICS] == "metrics"
