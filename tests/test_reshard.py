"""scripts/reshard.py: offline N→M repartitioning with verified totals.

Acceptance: a populated 2-shard depot reshards into 3 shards (and back)
with every object restorable and logical/stored byte totals preserved —
and, because the resharder uses the same consistent-hash rule as ingest,
a service reopened on the target depot keeps deduplicating against the
repartitioned chunks.
"""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.service import ShardedDedupService

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)

_SPEC = importlib.util.spec_from_file_location(
    "reshard",
    os.path.join(os.path.dirname(__file__), "..", "scripts", "reshard.py"),
)
reshard_mod = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(reshard_mod)


def _build_depot(root: str, shards: int, seed: int = 3):
    objs = list(snapshot_series(base_bytes=1 << 16, snapshots=4,
                                edit_rate=3e-5, seed=seed))
    objs.append(np.zeros(0, dtype=np.uint8))  # empty object round-trips too
    svc = ShardedDedupService.open(root, shards, params=P, slots=4,
                                   min_bucket=1024)
    for i, o in enumerate(objs):
        svc.submit(f"o{i:03d}", o)
    svc.flush()
    stats = svc.stats()
    svc.close()
    return objs, stats


def _open(root: str, shards: int) -> ShardedDedupService:
    return ShardedDedupService.open(root, shards, params=P, slots=4,
                                    min_bucket=1024)


def test_reshard_2_to_3_and_back(tmp_path):
    A, B, C = (str(tmp_path / x) for x in "ABC")
    objs, want = _build_depot(A, 2)

    report = reshard_mod.reshard(A, B, 3)
    assert report["verified_objects"] == len(objs)
    assert report["stored_bytes"] == want.stored_bytes
    assert report["logical_bytes"] == want.logical_bytes
    assert report["unique_chunks"] == want.unique_chunks

    svc = _open(B, 3)
    got = svc.stats()
    assert (got.stored_bytes, got.logical_bytes, got.unique_chunks) == \
        (want.stored_bytes, want.logical_bytes, want.unique_chunks)
    for i, o in enumerate(objs):
        assert svc.get(f"o{i:03d}") == o.tobytes()
    per = svc.shard_stats()
    assert sum(s["unique_chunks"] for s in per) == want.unique_chunks
    assert sum(1 for s in per if s["unique_chunks"]) == 3  # actually spread

    # routing agreement: the resharder placed chunks exactly where ingest
    # routing would — re-ingesting identical content stores zero new bytes
    before = svc.stats().stored_bytes
    svc.put("dup-of-o000", objs[0])
    assert svc.stats().stored_bytes == before
    svc.delete("dup-of-o000")
    svc.close()

    # ... and back, through the CLI entry point
    rc = reshard_mod.main(["--src", B, "--dst", C, "--shards", "2",
                           "--json", str(tmp_path / "report.json")])
    assert rc == 0
    with open(tmp_path / "report.json") as f:
        back = json.load(f)
    assert back["stored_bytes"] == want.stored_bytes
    svc = _open(C, 2)
    for i, o in enumerate(objs):
        assert svc.get(f"o{i:03d}") == o.tobytes()
    assert svc.stats().unique_chunks == want.unique_chunks
    svc.close()


def test_reshard_refuses_existing_target_and_bad_source(tmp_path):
    A = str(tmp_path / "A")
    _build_depot(A, 2)
    with pytest.raises(reshard_mod.ReshardError, match="already holds"):
        reshard_mod.reshard(A, A, 3)
    with pytest.raises(reshard_mod.ReshardError, match="sharding.json"):
        reshard_mod.reshard(str(tmp_path / "nowhere"), str(tmp_path / "B"), 2)


def test_reshard_detects_corrupt_source_block(tmp_path):
    A, B = str(tmp_path / "A"), str(tmp_path / "B")
    _build_depot(A, 2)
    # flip bytes in one stored block: its content no longer matches its key
    blocks_dir = os.path.join(A, "shard-00", "blocks")
    victim = os.path.join(blocks_dir, sorted(os.listdir(blocks_dir))[0])
    with open(victim, "r+b") as f:
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(reshard_mod.ReshardError, match="corrupt"):
        reshard_mod.reshard(A, B, 3)


def test_reshard_pre_fps_recipes_need_refingerprint(tmp_path):
    A = str(tmp_path / "A")
    objs, want = _build_depot(A, 2)
    # simulate a depot from before fps were recorded in recipes
    recipes_path = os.path.join(A, "recipes.json")
    with open(recipes_path) as f:
        table = json.load(f)
    for r in table["objects"]:
        r.pop("fps", None)
    with open(recipes_path, "w") as f:
        json.dump(table, f)

    with pytest.raises(reshard_mod.ReshardError, match="refingerprint"):
        reshard_mod.reshard(A, str(tmp_path / "B1"), 3)

    report = reshard_mod.reshard(A, str(tmp_path / "B2"), 3,
                                 refingerprint=True)
    assert report["stored_bytes"] == want.stored_bytes
    svc = _open(str(tmp_path / "B2"), 3)
    for i, o in enumerate(objs):
        assert svc.get(f"o{i:03d}") == o.tobytes()
    # recomputed fps route identically to ingest-recorded ones
    before = svc.stats().stored_bytes
    svc.put("dup", objs[1])
    assert svc.stats().stored_bytes == before
    svc.close()
