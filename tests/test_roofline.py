"""Roofline extraction: HLO cost model validation + collective ring model.

The central claim: our trip-count-aware analyzer matches XLA's own
cost_analysis on scan-free modules, and corrects the known while-body
undercount on scanned modules (scan == unrolled to within a few percent).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.roofline import analyze, constants
from repro.roofline.hlo_cost import HloCostModel


def _compile_train(cfg, B=2, S=64):
    from repro.models import lm
    from repro.train import OptConfig, make_train_step, optim

    fn = make_train_step(cfg, OptConfig())
    params = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: optim.init(OptConfig(), params))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    return jax.jit(fn).lower(params, opt, batch).compile()


def test_matches_xla_on_unrolled():
    from repro.configs import get_reduced

    cfg = get_reduced("llama3.2-1b").replace(
        n_layers=4, scan_layers=False, attn_q_block=64
    )
    comp = _compile_train(cfg)
    ca = comp.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    cost = HloCostModel(comp.as_text()).total()
    assert cost.flops == pytest.approx(float(ca["flops"]), rel=0.05)
    if jax.default_backend() == "cpu":
        # XLA:CPU's "bytes accessed" accounting for fused computations varies
        # by XLA version (observed ~2x across releases); the bytes comparison
        # is only meaningful against the TPU compiler the model targets.
        pytest.skip("bytes-accessed check is TPU-only (XLA:CPU accounting "
                    "is version-dependent)")
    assert cost.bytes == pytest.approx(float(ca["bytes accessed"]), rel=0.35)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-moe-30b-a3b", "xlstm-125m"])
def test_scan_equals_unrolled(arch):
    """The raison d'être: scanned-module flops == unrolled truth."""
    from repro.configs import get_reduced

    cfg = get_reduced(arch)
    nl = max(cfg.n_layers, 6)
    ref = HloCostModel(
        _compile_train(cfg.replace(n_layers=nl, scan_layers=False, attn_q_block=64)).as_text()
    ).total()
    got_model = HloCostModel(
        _compile_train(cfg.replace(n_layers=nl, scan_layers=True, attn_q_block=64)).as_text()
    )
    got = got_model.total()
    assert not got_model.unknown_trip_whiles
    assert got.flops == pytest.approx(ref.flops, rel=0.05), arch
    assert got.bytes < 1.8 * ref.bytes  # bounded loop-carry overhead


def test_microbatch_flops_not_undercounted():
    from repro.configs import get_reduced

    cfg = get_reduced("llama3.2-1b").replace(n_layers=2, attn_q_block=64)
    full = HloCostModel(_compile_train(cfg, B=8).as_text()).total()
    micro = HloCostModel(
        _compile_train(cfg.replace(microbatch=4), B=8).as_text()
    ).total()
    assert micro.flops == pytest.approx(full.flops, rel=0.1)


def test_collective_ring_model_parse():
    text = """
HloModule test
ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p), replica_groups=[1,8]<=[8], to_apply=%add
}
"""
    cost = HloCostModel(text).total()
    want = 2 * 1024 * 4 * (8 - 1) / 8
    assert cost.coll_bytes == pytest.approx(want)
    assert "all-reduce" in cost.coll_by_type


def test_collective_inside_while_multiplied():
    text = """
HloModule test
%body (t: (s32[], f32[256])) -> (s32[], f32[256]) {
  %t = (s32[], f32[256]{0}) parameter(0)
  %g = f32[256]{0} get-tuple-element(%t), index=1
  %ar = f32[256]{0} all-reduce(%g), replica_groups=[1,4]<=[4], to_apply=%add
  %i = s32[] get-tuple-element(%t), index=0
  ROOT %out = (s32[], f32[256]) tuple(%i, %ar)
}
%cond (t: (s32[], f32[256])) -> pred[] {
  %t = (s32[], f32[256]{0}) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %c = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (p: f32[256]) -> f32[256] {
  %p = f32[256]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[256]) tuple(%z, %p)
  %w = (s32[], f32[256]) while(%t0), condition=%cond, body=%body
  ROOT %r = f32[256]{0} get-tuple-element(%w), index=1
}
"""
    cost = HloCostModel(text).total()
    want = 10 * 2 * 256 * 4 * (4 - 1) / 4
    assert cost.coll_bytes == pytest.approx(want)


def test_roofline_terms():
    stats = analyze.CollectiveStats(per_device_bytes=50e9, by_type={}, count=1)
    rl = analyze.Roofline(
        "a", "s", "m", 256,
        flops_per_device=197e12,  # exactly 1 second of compute
        bytes_per_device=819e9,  # exactly 1 second of HBM
        collective=stats,  # exactly 1 second of ICI
        model_flops=197e12 * 256 * 0.5,
    )
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(1.0)
    assert rl.t_collective == pytest.approx(1.0)
    assert rl.roofline_fraction == pytest.approx(0.5)


def test_model_flops_for():
    from repro.configs import SHAPES, get_config

    cfg = get_config("llama3.2-1b")
    mf_train = analyze.model_flops_for(cfg, SHAPES["train_4k"])
    assert 6e15 < mf_train < 1e16  # ~7.8e15 for a 1.24B model at 1M tokens
    mf_dec = analyze.model_flops_for(cfg, SHAPES["decode_32k"])
    assert mf_dec == pytest.approx(mf_train / 3 / (256 * 4096) * 128, rel=0.01)
