"""Dedup substrate: fingerprints, index, block store, ingest pipeline."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import make_chunker
from repro.core.automaton import max_chunks_for
from repro.core.params import SeqCDCParams
from repro.core.seqcdc import boundaries_two_phase
from repro.data import DedupIngest, PipelineConfig, snapshot_series
from repro.dedup import (
    BlockStore,
    DirBlockStore,
    FingerprintIndex,
    chunk_fingerprints,
    dedup_stats,
    fingerprints_numpy,
    space_savings,
)

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


def test_fingerprint_jax_matches_numpy(rng):
    data = rng.integers(0, 256, 10_000, dtype=np.uint8)
    b, c = boundaries_two_phase(jnp.asarray(data), P)
    mc = max_chunks_for(data.size, P)
    fp, lens = chunk_fingerprints(jnp.asarray(data), b, c, max_chunks=mc)
    nb = np.asarray(b)[: int(c)]
    want = fingerprints_numpy(data, nb)
    np.testing.assert_array_equal(np.asarray(fp)[: int(c)], want)
    np.testing.assert_array_equal(
        np.asarray(lens)[: int(c)], np.diff(np.concatenate([[0], nb]))
    )


def test_fingerprint_detects_duplicates(rng):
    chunk = rng.integers(0, 256, 300, dtype=np.uint8)
    data = np.concatenate([chunk, chunk, chunk])
    bounds = np.array([300, 600, 900])
    fp = fingerprints_numpy(data, bounds)
    assert (fp[0] == fp[1]).all() and (fp[1] == fp[2]).all()


def test_fingerprint_distinguishes(rng):
    """1-byte difference flips the fingerprint (w.h.p.)."""
    a = rng.integers(0, 256, 500, dtype=np.uint8)
    b = a.copy()
    b[250] ^= 1
    fa = fingerprints_numpy(a, np.array([500]))
    fb = fingerprints_numpy(b, np.array([500]))
    assert not (fa == fb).all()


def test_dedup_stats_matches_host_index(rng):
    data = rng.integers(0, 4, 40_000, dtype=np.uint8)  # low entropy -> dups
    b, c = boundaries_two_phase(jnp.asarray(data), P)
    mc = max_chunks_for(data.size, P)
    fp, lens = chunk_fingerprints(jnp.asarray(data), b, c, max_chunks=mc)
    stats = jax.tree.map(int, dedup_stats(fp, lens))
    idx = FingerprintIndex()
    idx.add_batch(np.asarray(fp), np.asarray(lens))
    assert stats["original_bytes"] == idx.original_bytes == data.size
    assert stats["dedup_bytes"] == idx.dedup_bytes
    assert 0.0 <= space_savings(stats) <= 1.0


def test_block_store_roundtrip(rng):
    data = rng.integers(0, 256, 10_000, dtype=np.uint8)
    c = make_chunker("seqcdc_numpy", 8192, params=P)
    bounds = c.chunk(data)
    s = BlockStore()
    keys = s.put_stream(data, bounds)
    assert s.get_stream(keys) == data.tobytes()
    # storing again dedups 100%
    before = s.stored_bytes
    s.put_stream(data, bounds)
    assert s.stored_bytes == before
    assert s.savings == pytest.approx(0.5)


def test_put_stream_rejects_malformed_bounds(rng):
    """Regression: malformed bounds used to slice silently — an empty or
    negative window stored a zero-length chunk, a short final bound
    dropped the data tail from the stream, and out-of-range bounds threw
    a confusing numpy error.  All three now fail loudly up front, and the
    store is left untouched (no partial ingest)."""
    data = rng.integers(0, 256, 1000, dtype=np.uint8)
    s = BlockStore()
    for bad in (
        [300, 300, 1000],   # empty window
        [300, 200, 1000],   # non-monotonic window
        [300, 1001],        # beyond len(data)
        [300, 900],         # short: tail silently dropped pre-fix
    ):
        with pytest.raises(ValueError):
            s.put_stream(data, np.asarray(bad))
        assert s.stored_bytes == 0 and not s.refs  # nothing half-stored
    keys = s.put_stream(data, np.asarray([300, 1000]))
    assert s.get_stream(keys) == data.tobytes()
    assert s.put_stream(np.zeros(0, dtype=np.uint8), np.asarray([], int)) == []


def test_dir_block_store_crash_safety(tmp_path, rng):
    root = str(tmp_path / "store")
    s = DirBlockStore(root)
    data = rng.integers(0, 256, 5000, dtype=np.uint8)
    key = s.put(data.tobytes())
    # a crashed writer leaves only a .tmp file: simulate + verify reload
    orphan = os.path.join(root, "blocks", "deadbeef.tmp")
    with open(orphan, "wb") as f:
        f.write(b"partial")
    s.sync_manifest()
    s2 = DirBlockStore(root)
    assert s2.get(key) == data.tobytes()
    assert s2.stored_bytes == s.stored_bytes


def test_release_refcounting(rng):
    s = BlockStore()
    k = s.put(b"hello world" * 10)
    s.put(b"hello world" * 10)
    s.release(k)
    assert k in s.blocks  # still one ref
    s.release(k)
    assert k not in s.blocks


def test_ingest_pipeline_savings(rng):
    """Snapshot series with few edits -> high dedup in the ingest pipeline."""
    snaps = list(snapshot_series(base_bytes=1 << 20, snapshots=4,
                                 edit_rate=2e-5, seed=5))
    corpus = np.concatenate(snaps)
    cfg = PipelineConfig(avg_chunk=4096, segment_bytes=1 << 18, batch_segments=4)
    ing = DedupIngest(cfg)
    out_bytes = sum(len(u) for u in ing.unique_bytes(corpus))
    assert ing.savings > 0.5, ing.savings
    assert out_bytes < corpus.size * 0.55


def test_ingest_token_batches(rng):
    corpus = rng.integers(0, 256, 1 << 20, dtype=np.uint8)
    cfg = PipelineConfig(avg_chunk=4096, segment_bytes=1 << 18,
                         batch_segments=2, seq_len=128, batch_size=4)
    ing = DedupIngest(cfg)
    batches = []
    for b in ing.token_batches(corpus):
        batches.append(b)
        if len(batches) >= 3:
            break
    assert all(b.shape == (4, 129) for b in batches)
