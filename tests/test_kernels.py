"""Pallas kernels vs pure-jnp oracles: shape/dtype sweep, bit-exact.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
on TPU the same BlockSpecs compile to Mosaic.  Every kernel must match its
ref.py oracle exactly across lengths that exercise padding, halo, and
multi-tile grids.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.extremum import block_max_pallas
from repro.kernels.gear_hash import gear_hash_pallas
from repro.kernels.seqcdc_masks import seqcdc_masks_pallas

LENGTHS = [1, 2, 31, 32, 100, 1023, 1024, 1025, 4096, 70000]


@pytest.mark.parametrize("n", LENGTHS)
@pytest.mark.parametrize("L", [3, 5, 7])
@pytest.mark.parametrize("mode", ["increasing", "decreasing"])
def test_seqcdc_masks_kernel(n, L, mode, rng):
    data = jnp.asarray(rng.integers(0, 256, n, dtype=np.uint8))
    cand_k, opp_k = seqcdc_masks_pallas(data, L, mode, interpret=True)
    cand_r, opp_r = ref.seqcdc_masks(data, L, mode)
    np.testing.assert_array_equal(np.asarray(cand_k), np.asarray(cand_r))
    np.testing.assert_array_equal(np.asarray(opp_k), np.asarray(opp_r))


@pytest.mark.parametrize("tile", [1024, 4096])
def test_seqcdc_masks_tile_sweep(tile, rng):
    data = jnp.asarray(rng.integers(0, 256, 10_000, dtype=np.uint8))
    cand_k, opp_k = seqcdc_masks_pallas(data, 5, tile=tile, interpret=True)
    cand_r, opp_r = ref.seqcdc_masks(data, 5, "increasing")
    np.testing.assert_array_equal(np.asarray(cand_k), np.asarray(cand_r))
    np.testing.assert_array_equal(np.asarray(opp_k), np.asarray(opp_r))


@pytest.mark.parametrize("n", LENGTHS)
def test_gear_hash_kernel(n, rng):
    data = jnp.asarray(rng.integers(0, 256, n, dtype=np.uint8))
    hk = gear_hash_pallas(data, interpret=True)
    hr = ref.gear_hash(data)  # sequential scan oracle
    np.testing.assert_array_equal(np.asarray(hk), np.asarray(hr))


def test_gear_parallel_equals_sequential(rng):
    data = jnp.asarray(rng.integers(0, 256, 5000, dtype=np.uint8))
    np.testing.assert_array_equal(
        np.asarray(ref.gear_hash_parallel(data)), np.asarray(ref.gear_hash(data))
    )


@pytest.mark.parametrize("n", [128, 1000, 65536, 70001])
@pytest.mark.parametrize("block", [64, 128])
def test_block_max_kernel(n, block, rng):
    data = jnp.asarray(rng.integers(0, 256, n, dtype=np.uint8))
    got = block_max_pallas(data, block=block, interpret=True)
    nb = (n + block - 1) // block
    padded = np.zeros(nb * block, dtype=np.uint8)
    padded[:n] = np.asarray(data)
    want = padded.reshape(nb, block).max(axis=1)
    np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=30, deadline=None)
@given(data=st.binary(min_size=1, max_size=3000), L=st.integers(3, 7))
def test_property_masks_kernel(data, L):
    arr = jnp.asarray(np.frombuffer(data, dtype=np.uint8))
    cand_k, opp_k = seqcdc_masks_pallas(arr, L, interpret=True)
    cand_r, opp_r = ref.seqcdc_masks(arr, L, "increasing")
    np.testing.assert_array_equal(np.asarray(cand_k), np.asarray(cand_r))
    np.testing.assert_array_equal(np.asarray(opp_k), np.asarray(opp_r))


@pytest.mark.parametrize(
    "B,S,H,hd,qb,kvb",
    [(2, 64, 2, 16, 16, 16), (1, 128, 4, 32, 32, 64),
     (2, 96, 3, 8, 32, 32), (1, 256, 2, 64, 64, 64)],
)
def test_flash_kernel(B, S, H, hd, qb, kvb):
    """Pallas flash attention == materialized-softmax oracle (shape sweep)."""
    import jax
    from repro.kernels.flash_attn import flash_attention_pallas

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.4
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.4
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.4
    got = flash_attention_pallas(q, k, v, q_block=qb, kv_block=kvb, interpret=True)
    want = ref.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_kernel_noncausal():
    import jax
    from repro.kernels.flash_attn import flash_attention_pallas

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(x, (1, 64, 2, 16)) * 0.4 for x in ks)
    got = flash_attention_pallas(q, k, v, causal=False, q_block=32, kv_block=32,
                                 interpret=True)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    import jax
    from repro.kernels.flash_attn import flash_attention_pallas

    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (
        (jax.random.normal(x, (1, 64, 2, 16)) * 0.4).astype(jnp.bfloat16)
        for x in ks
    )
    got = flash_attention_pallas(q, k, v, q_block=16, kv_block=16, interpret=True)
    want = ref.flash_attention(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_ops_dispatch(rng):
    """Public wrappers auto-select interpret mode on CPU."""
    data = jnp.asarray(rng.integers(0, 256, 2048, dtype=np.uint8))
    cand, opp = ops.seqcdc_masks(data, 5)
    assert cand.shape == (2048,) and opp.dtype == jnp.bool_
    h = ops.gear_hash(data)
    assert h.dtype == jnp.uint32
    m = ops.block_max(data, block=128)
    assert m.shape == (16,)


def test_full_pipeline_with_pallas_masks(rng):
    """Two-phase SeqCDC with the Pallas phase-1 == numpy oracle."""
    from repro.core import oracle
    from repro.core.params import SeqCDCParams
    from repro.core.seqcdc import boundaries_two_phase

    p = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6,
                     skip_size=32, min_size=64, max_size=512)
    data = rng.integers(0, 256, 50_000, dtype=np.uint8)
    b, c = boundaries_two_phase(jnp.asarray(data), p, mask_impl="pallas")
    got = np.asarray(b)[: int(c)].tolist()
    assert got == oracle.boundaries_slow(data, p)
