"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The tier-1 suite property-tests the SeqCDC implementations with hypothesis
when available; in environments without it (this container bakes only the
jax toolchain) we still want the property tests to *run* rather than skip,
so this module provides ``given`` / ``settings`` / ``strategies`` with the
same call surface, drawing examples from a seeded ``numpy`` generator.

No shrinking, no example database — just a fixed, reproducible sweep of
``max_examples`` random draws per test (seeded from the test name, so every
run explores the same inputs and failures are replayable).
"""
from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


def _binary(min_size: int = 0, max_size: int = 64) -> _Strategy:
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return rng.integers(0, 256, n, dtype=np.uint8).tobytes()

    return _Strategy(draw)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def _sampled_from(elements) -> _Strategy:
    seq = list(elements)
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])


strategies = types.SimpleNamespace(
    binary=_binary, integers=_integers, sampled_from=_sampled_from
)


def settings(max_examples: int = 20, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 20)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)

        # hide the strategy-filled params from pytest's fixture resolution
        # (inspect.signature stops unwrapping at an explicit __signature__)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats
        ])
        return wrapper

    return deco
