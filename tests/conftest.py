"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 real device
(the 512-device setup belongs exclusively to launch/dryrun.py subprocesses).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
