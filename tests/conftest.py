"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 real device
(the 512-device setup belongs exclusively to launch/dryrun.py subprocesses).
"""
import os
import signal
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "timeout(seconds): hard per-test wall-clock limit via SIGALRM — "
        "required on tests that spawn subprocesses (shard servers, mesh "
        "runs), so a hung child fails the test instead of the whole suite",
    )


@pytest.fixture(autouse=True)
def _per_test_timeout(request):
    """SIGALRM-based @pytest.mark.timeout(s) (no pytest-timeout in the env).

    Main-thread only, Unix only — both true for this suite; elsewhere the
    marker degrades to a no-op rather than failing collection.
    """
    marker = request.node.get_closest_marker("timeout")
    if marker is None or not hasattr(signal, "SIGALRM"):
        yield
        return
    seconds = int(marker.args[0])

    def _expired(signum, frame):
        raise TimeoutError(
            f"test exceeded its {seconds}s timeout "
            f"(a spawned subprocess probably hung)"
        )

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
