"""Training runtime: optimizer math, grad accumulation, schedule, loop
fault-tolerance (checkpoint/restart bit-determinism), straggler monitor.
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data import LoaderConfig, TokenLoader
from repro.models import lm
from repro.train import (
    LoopConfig,
    OptConfig,
    StragglerMonitor,
    Trainer,
    grads_and_metrics,
    make_train_step,
    opt_init,
    opt_update,
)
from repro.train.optim import schedule

KEY = jax.random.PRNGKey(0)


def test_adamw_matches_reference():
    """Our AdamW == straightforward numpy reference on a small problem."""
    cfg = OptConfig(lr=0.1, betas=(0.9, 0.999), eps=1e-8, weight_decay=0.01,
                    grad_clip=1e9, warmup_steps=0, total_steps=10**9,
                    min_lr_frac=1.0)
    p = {"w": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([0.5])}
    g = {"w": jnp.array([0.1, 0.2, -0.3]), "b": jnp.array([1.0])}
    st = opt_init(cfg, p)
    new_p, st, _ = opt_update(cfg, g, st, p)

    # numpy reference (bias-corrected adam + decoupled decay on >=2D only —
    # both leaves here are 1-D so no decay applies)
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.asarray(g["w"]) ** 2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = np.asarray(p["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)


def test_weight_decay_only_on_matrices():
    cfg = OptConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9, warmup_steps=0,
                    min_lr_frac=1.0)
    p = {"mat": jnp.ones((4, 4)), "vec": jnp.ones((4,))}
    g = jax.tree.map(jnp.zeros_like, p)
    st = opt_init(cfg, p)
    new_p, _, _ = opt_update(cfg, g, st, p)
    assert float(new_p["mat"][0, 0]) < 1.0  # decayed
    assert float(new_p["vec"][0]) == 1.0  # not decayed


def test_grad_clip():
    cfg = OptConfig(lr=1.0, grad_clip=1.0, warmup_steps=0, min_lr_frac=1.0,
                    weight_decay=0.0)
    p = {"w": jnp.zeros((3,))}
    g = {"w": jnp.array([30.0, 40.0, 0.0])}  # norm 50
    st = opt_init(cfg, p)
    _, _, m = opt_update(cfg, g, st, p)
    assert float(m["grad_norm"]) == pytest.approx(50.0)


def test_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in [0, 5, 10, 60, 110, 200]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1, abs=1e-6)
    assert lrs[5] == pytest.approx(0.1, abs=1e-6)


def test_microbatch_equals_full_batch():
    """Grad accumulation over 4 microbatches == single-shot gradients."""
    cfg = get_reduced("llama3.2-1b")
    params = lm.init_params(cfg, KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (8, 32), 0, cfg.vocab_size),
    }
    g_full, m_full = grads_and_metrics(cfg, params, batch)
    g_micro, m_micro = grads_and_metrics(cfg.replace(microbatch=4), params, batch)
    assert float(m_full["loss"]) == pytest.approx(float(m_micro["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_micro)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6)


def _mk_trainer(tmp, total=24, ckpt_every=8):
    from repro.checkpoint import CheckpointManager

    cfg = get_reduced("llama3.2-1b")
    corpus = np.random.default_rng(0).integers(0, 200, 60_000, dtype=np.uint8)
    loader = TokenLoader(corpus, LoaderConfig(batch_size=4, seq_len=32))
    ckpt = CheckpointManager(os.path.join(tmp, "ck"), keep=2)
    tr = Trainer(
        cfg,
        OptConfig(lr=1e-3, warmup_steps=4, total_steps=total),
        LoopConfig(total_steps=total, ckpt_every=ckpt_every, log_every=0),
        loader,
        ckpt,
    )
    return tr


def test_loop_restart_bit_determinism(tmp_path):
    """Run 24 steps straight; run 16 + crash + resume to 24: identical params."""
    t_full = _mk_trainer(str(tmp_path / "a"))
    p_full, _ = t_full.run(KEY)

    t_ab = _mk_trainer(str(tmp_path / "b"))
    t_ab.run(KEY, steps=16)  # "crash" after step 15 (ckpt at step 15)
    t_resume = _mk_trainer(str(tmp_path / "b"))
    p_resume, _ = t_resume.run(KEY)
    assert t_resume.history[0]["step"] == 16  # resumed, not restarted
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resume)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loader_restart_determinism():
    corpus = np.random.default_rng(0).integers(0, 256, 10_000, dtype=np.uint8)
    l1 = TokenLoader(corpus, LoaderConfig(batch_size=4, seq_len=16))
    l2 = TokenLoader(corpus, LoaderConfig(batch_size=4, seq_len=16))
    for step in (0, 7, 123):
        a, _ = l1.batch_at(step)
        b, _ = l2.batch_at(step)
        np.testing.assert_array_equal(a, b)


def test_loader_host_sharding():
    corpus = np.random.default_rng(0).integers(0, 256, 10_000, dtype=np.uint8)
    full = TokenLoader(corpus, LoaderConfig(batch_size=8, seq_len=16))
    h0 = TokenLoader(corpus, LoaderConfig(batch_size=8, seq_len=16, host_index=0, host_count=2))
    h1 = TokenLoader(corpus, LoaderConfig(batch_size=8, seq_len=16, host_index=1, host_count=2))
    f, _ = full.batch_at(3)
    a, _ = h0.batch_at(3)
    b, _ = h1.batch_at(3)
    np.testing.assert_array_equal(np.concatenate([a, b]), f)


def test_straggler_monitor():
    events = []
    mon = StragglerMonitor(factor=3.0, alpha=0.5, policy=events.append)
    for _ in range(5):
        mon.observe(0, 0.1)
    mon.observe(5, 1.0)  # 10x the EWMA -> event
    assert len(mon.events) == 1 and events[0]["dt"] == 1.0
    mon.observe(6, 0.1)
    assert len(mon.events) == 1
