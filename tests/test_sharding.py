"""Sharding rules: PartitionSpec derivation, divisibility fallbacks."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as PS

from repro.distributed.sharding import ShardingRules, default_rules, rules_for_config


class FakeMesh:
    """Duck-typed mesh: ShardingRules only reads .shape and .axis_names."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def _mesh(multi=False):
    return FakeMesh(
        {"pod": 2, "data": 16, "model": 16} if multi else {"data": 16, "model": 16}
    )


def _rules(fsdp="none", multi=False):
    mesh = _mesh(multi)
    return ShardingRules(mesh, default_rules(mesh, fsdp))


def _rules_cfg(arch, multi=False):
    from repro.configs import get_config

    mesh = _mesh(multi)
    return ShardingRules(mesh, rules_for_config(mesh, get_config(arch)))


def test_batch_sharding():
    r = _rules()
    assert r.spec_for(("batch", "seq"), (256, 4096)) == PS("data", None)


def test_batch_multi_pod():
    r = _rules(multi=True)
    assert r.spec_for(("batch", "seq"), (256, 4096)) == PS(("pod", "data"), None)


def test_batch_too_small_falls_back():
    r = _rules(multi=True)
    # B=32 shards over (pod, data)=32; B=16 only over pod? prefix logic: the
    # longest divisible prefix of ("pod","data") for 16 is ("pod",) = 2... 16%2==0
    assert r.spec_for(("batch", "seq"), (32, 128)) == PS(("pod", "data"), None)
    assert r.spec_for(("batch", "seq"), (1, 128)) == PS(None, None)


def test_heads_shard_when_divisible():
    r = _rules()
    spec = r.spec_for(("embed", "heads", "head_dim"), (4096, 32, 128))
    assert spec == PS(None, "model", None)


def test_heads_fallback_padded_activation_tp():
    """40 or 56 heads don't divide 16: the whole arch switches to
    padded-activation head TP (rules_for_config — one consistent decision):
    attention WEIGHTS replicate over model (FSDP shards embed), and the
    padded attention ACTIVATIONS shard heads_act on model."""
    for arch, h, d, pad in [("phi3-medium-14b", 40, 5120, 48),
                            ("llava-next-34b", 56, 7168, 64)]:
        r = _rules_cfg(arch)  # both archs use fsdp -> embed sharded on data
        q = r.spec_for(("embed", "heads", "head_dim"), (d, h, 128))
        assert q == PS("data", None, None), (arch, q)
        kv = r.spec_for(("embed", "kv_heads", "head_dim"), (d, 8, 128))
        assert kv == PS("data", None, None), (arch, kv)
        # padded activations shard the model axis
        act = r.spec_for(("batch", None, "heads_act", None), (256, 1024, pad, 128))
        assert act == PS("data", None, "model", None), (arch, act)


def test_decode_cache_seq_sharded():
    """GQA decode caches shard the sequence dim when kv doesn't divide."""
    r = _rules_cfg("llama3.2-1b")
    spec = r.spec_for(("batch", "seq_kv", "kv_heads", "head_dim"), (128, 32768, 8, 64))
    assert spec == PS("data", "model", None, None)
    # MHA (kv=32) prefers kv sharding; seq stays unsharded
    r2 = _rules_cfg("musicgen-large")
    spec2 = r2.spec_for(("batch", "seq_kv", "kv_heads", "head_dim"), (128, 32768, 32, 64))
    assert spec2[2] == "model" and spec2[1] is None


def test_small_kv_heads_replicated():
    """GQA kv=8 on a 16-way model axis: kv replicated, Q still head-sharded
    (NOT a per-tensor head_dim fallback — that would desync Q vs K/V)."""
    r = _rules_cfg("llama3.2-1b")  # fsdp=data -> embed on data
    q = r.spec_for(("embed", "heads", "head_dim"), (2048, 32, 64))
    assert q == PS("data", "model", None)
    kv = r.spec_for(("embed", "kv_heads", "head_dim"), (2048, 8, 64))
    assert kv == PS("data", None, None)


def test_fsdp_embeds():
    r = _rules(fsdp="data")
    spec = r.spec_for(("embed", "mlp"), (4096, 14336))
    assert spec == PS("data", "model")
    r0 = _rules(fsdp="none")
    assert r0.spec_for(("embed", "mlp"), (4096, 14336)) == PS(None, "model")


def test_fsdp_pod_data_multi():
    r = _rules(fsdp="pod_data", multi=True)
    spec = r.spec_for(("embed", "mlp"), (8192, 29568))
    assert spec == PS(("pod", "data"), "model")


def test_no_axis_used_twice():
    r = _rules()
    spec = r.spec_for(("vocab", "embed"), (151936, 2048))
    used = [s for s in spec if s is not None]
    flat = []
    for s in used:
        flat.extend(s if isinstance(s, tuple) else [s])
    assert len(flat) == len(set(flat))


def test_moe_expert_sharding():
    r = _rules()
    spec = r.spec_for(("experts", "embed", "expert_mlp"), (128, 2048, 768))
    assert spec == PS("model", None, None)


def test_one_dim_params_replicated():
    r = _rules()
    assert r.spec_for(("embed",), (4096,)) == PS(None)


def test_stack_dim_never_sharded():
    r = _rules()
    spec = r.spec_for(("stack", "embed", "mlp"), (48, 2048, 768))
    assert spec[0] is None


def test_cache_template_shardings():
    """Decode-cell cache specs derive cleanly for every arch."""
    from repro.configs import ARCHS, get_config
    from repro.models import transformer as tfm

    r = _rules()
    for arch in ARCHS:
        cfg = get_config(arch)
        tpls = tfm.stack_cache_template(cfg, 128, 1024)
        specs = [r.pspec_tree(t) for t in tpls]
        assert len(specs) == len(tpls)
