"""The perf-regression gate: scripts/bench_compare.py.

Doctored-report tests: each tolerance class must fail on an injected
regression of its own kind and pass within its band; row-coverage loss
fails; new rows pass.  The committed BENCH_quick.json must self-compare
clean (that is the invariant CI relies on).
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "scripts"))

import bench_compare as bc  # noqa: E402

REPO = os.path.join(os.path.dirname(__file__), "..")


def report(rows, failed_modules=()):
    return {"meta": {"budget": "quick",
                     "failed_modules": list(failed_modules)},
            "results": rows}


def row(**over):
    base = {"bench": "svc", "budget": "quick", "shards": 2,
            "transport": "local", "ingest_gbps": 1.0, "occupancy": 0.9,
            "dedup_ratio": 1.5}
    base.update(over)
    return base


class TestCompare:
    def test_identical_reports_pass(self):
        rows, failures = bc.compare(report([row()]), report([row()]))
        assert failures == []
        assert all(r["ok"] for r in rows)
        # every watched metric present in the row was compared
        assert {r["metric"] for r in rows} == {
            "ingest_gbps", "occupancy", "dedup_ratio"}

    def test_throughput_collapse_fails_but_noise_passes(self):
        base = report([row()])
        # a 2x slowdown is machine noise at quick budget: inside the band
        _, failures = bc.compare(base, report([row(ingest_gbps=0.5)]))
        assert failures == []
        # a 10x collapse (kernel fell back to scalar) is a regression
        _, failures = bc.compare(base, report([row(ingest_gbps=0.1)]))
        assert len(failures) == 1 and "ingest_gbps" in failures[0]

    def test_occupancy_band_is_absolute_and_tight(self):
        base = report([row()])
        _, failures = bc.compare(base, report([row(occupancy=0.85)]))
        assert failures == []  # -0.05 abs: within the 0.1 band
        _, failures = bc.compare(base, report([row(occupancy=0.7)]))
        assert len(failures) == 1 and "occupancy" in failures[0]

    def test_dedup_ratio_band_is_relative_and_tight(self):
        base = report([row()])
        _, failures = bc.compare(base, report([row(dedup_ratio=1.495)]))
        assert failures == []  # -0.3% rel: inside the 1% band
        _, failures = bc.compare(base, report([row(dedup_ratio=1.4)]))
        assert len(failures) == 1 and "dedup_ratio" in failures[0]

    def test_missing_baseline_row_fails_coverage(self):
        # a benchmark that silently stopped running is a regression too
        base = report([row(), row(shards=4)])
        _, failures = bc.compare(base, report([row()]))
        assert len(failures) == 1 and "missing" in failures[0]

    def test_new_fresh_row_passes(self):
        rows, failures = bc.compare(
            report([row()]), report([row(), row(shards=8)])
        )
        assert failures == []
        assert any(r["metric"] == "(new row)" for r in rows)

    def test_failed_modules_fail_the_gate(self):
        _, failures = bc.compare(
            report([row()]),
            report([row()], failed_modules=["bench_service"]),
        )
        assert len(failures) == 1 and "failed modules" in failures[0]

    def test_identity_includes_config_axes(self):
        # same bench title, different transport: distinct rows, no match
        base = report([row(transport="local")])
        _, failures = bc.compare(base, report([row(transport="remote")]))
        assert any("missing" in f for f in failures)

    def test_custom_tolerances(self):
        tol = bc.Tolerances(throughput_ratio=0.9)
        _, failures = bc.compare(report([row()]),
                                 report([row(ingest_gbps=0.5)]), tol)
        assert len(failures) == 1  # the same 2x drop now out of band


class TestScenarioAxis:
    """The scenario engine's identity axis (benchmarks/bench_scenarios.py):
    per-scenario rows are distinct gate targets, and a workload's dedup
    ratio is gated as tightly as any other quality metric."""

    def test_scenario_is_an_identity_axis(self):
        # same bench, different scenario: distinct rows that never match
        base = report([row(scenario="backup_snapshots")])
        _, failures = bc.compare(base, report([row(scenario="lm_text")]))
        assert any("missing" in f for f in failures)
        assert "scenario" in bc.IDENTITY_FIELDS

    def test_scenario_rows_compare_independently(self):
        base = report([row(scenario="backup_snapshots", dedup_ratio=3.0),
                       row(scenario="lm_text", dedup_ratio=1.6)])
        # only the doctored scenario fails; the healthy one stays green
        fresh = report([row(scenario="backup_snapshots", dedup_ratio=3.0),
                        row(scenario="lm_text", dedup_ratio=1.5)])
        _, failures = bc.compare(base, fresh)
        assert len(failures) == 1
        assert "dedup_ratio" in failures[0] and "lm_text" in failures[0]

    def test_exactly_one_percent_drop_fails(self):
        # the acceptance contract: a >=1% relative dedup loss fails, with
        # no pass-at-the-boundary edge case
        base = report([row(scenario="dataset_revisions", dedup_ratio=2.734)])
        fresh = report([row(scenario="dataset_revisions",
                            dedup_ratio=2.734 * 0.99)])
        _, failures = bc.compare(base, fresh)
        assert len(failures) == 1 and "dedup_ratio" in failures[0]

    def test_dropped_scenario_row_is_a_coverage_failure(self):
        base = report([row(scenario="dataset_revisions"),
                       row(scenario="container_images")])
        _, failures = bc.compare(
            base, report([row(scenario="dataset_revisions")]))
        assert len(failures) == 1
        assert "missing" in failures[0] and "container_images" in failures[0]


class TestCLI:
    def test_committed_baseline_self_compares_clean(self, capsys):
        path = os.path.join(REPO, "BENCH_quick.json")
        assert bc.main([path, path]) == 0
        out = capsys.readouterr().out
        assert "within tolerance bands" in out

    def test_doctored_report_fails_cli(self, tmp_path, capsys):
        path = os.path.join(REPO, "BENCH_quick.json")
        doc = json.load(open(path))
        doctored = 0
        for r in doc["results"]:
            if "dedup_ratio" in r:
                r["dedup_ratio"] *= 0.5
                doctored += 1
        assert doctored  # the committed report does carry the metric
        bad = tmp_path / "doctored.json"
        bad.write_text(json.dumps(doc))
        assert bc.main([path, str(bad)]) == 1
        assert "REGRESSION dedup_ratio" in capsys.readouterr().err

    def test_doctored_scenario_ratio_fails_cli(self, tmp_path, capsys):
        """Acceptance pin: a 1% relative dedup-ratio drop in any scenario
        row of the committed baseline fails the gate."""
        path = os.path.join(REPO, "BENCH_quick.json")
        doc = json.load(open(path))
        doctored = 0
        for r in doc["results"]:
            if r.get("scenario") not in (None, "none"):
                r["dedup_ratio"] *= 0.99
                doctored += 1
        assert doctored >= 4  # the committed baseline carries the catalog
        bad = tmp_path / "doctored_scenarios.json"
        bad.write_text(json.dumps(doc))
        assert bc.main([path, str(bad)]) == 1
        err = capsys.readouterr().err
        assert err.count("REGRESSION dedup_ratio") == doctored

    def test_unusable_input_exits_2(self, tmp_path):
        junk = tmp_path / "junk.json"
        junk.write_text("{not json")
        with pytest.raises(SystemExit) as ei:
            bc.main([str(junk), str(junk)])
        assert ei.value.code == 2
