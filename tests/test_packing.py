"""Segment-packed device rows: the packed pipeline's exactness contract.

``packing_impl="segments"`` concatenates sub-``min_bucket`` streams into
shared device rows; the whole feature rests on one invariant — a packed
row chunks and fingerprints *bit-identically* to running each stream
alone.  This file pins that invariant at every layer:

* kernel level: directed edge cases (1-byte, empty, exactly-``min_size``,
  the 65535-byte limb boundary, skip-overshoot segment endings, segment
  ends landing exactly on Pallas tile edges) plus a property sweep of
  random segment mixes, each checked against ``ref.packed_pipeline``
  (the per-stream host oracle re-offset into row coordinates) on both the
  packed split path and the packed fused kernel;
* scheduler level: a packed ``ChunkScheduler`` returns the same
  ``ChunkResult``s as a packing-off one, including edge-length streams;
* guard level: corrupting either packed device runner makes the
  first-dispatch cross-check raise ``PackingDivergenceError``.

The property tests run under hypothesis when available and under the
seeded ``_hyp_fallback`` sweep otherwise (same call surface).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

import repro.service.scheduler as sched_mod
from repro.core.params import SeqCDCParams
from repro.core.seqcdc import boundaries_packed_batch
from repro.dedup.fingerprint import chunk_fingerprints
from repro.kernels import ops, ref
from repro.service import ChunkScheduler, PackingDivergenceError

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


def _pack(streams, S, G=None):
    """Rows of byte-strings -> (data, sep, ends, seg_lens) device operands,
    the same layout ``ChunkScheduler._dispatch_packed_rows`` builds."""
    if G is None:
        G = max(len(row) for row in streams)
    B = len(streams)
    data = np.zeros((B, S), np.uint8)
    sep = np.zeros((B, S), np.int32)
    ends = np.zeros((B, G), np.int32)
    seg_lens = []
    for bi, row in enumerate(streams):
        off = 0
        for gi, s in enumerate(row):
            m = len(s)
            if m:
                data[bi, off:off + m] = np.frombuffer(bytes(s), np.uint8)
            sep[bi, off:off + m] = off + m
            ends[bi, gi] = off + m
            off += m
        sep[bi, off:] = off
        ends[bi, len(row):] = off
        seg_lens.append([len(s) for s in row])
    return data, sep, ends, seg_lens


def _assert_matches_oracle(streams, S, *, fused=True, label=""):
    """Both packed device paths must equal the per-stream host oracle."""
    data, sep, ends, seg_lens = _pack(streams, S)
    G = ends.shape[1]
    mc = S // P.min_size + 2 * G + 2
    ob, oc, of, ol = ref.packed_pipeline(data, seg_lens, P, max_chunks=mc)
    sb, sc = boundaries_packed_batch(
        jnp.asarray(data), jnp.asarray(sep), jnp.asarray(ends), P,
        max_chunks=mc)
    sf, sl = jax.vmap(lambda d, b, c: chunk_fingerprints(
        d, b, c, max_chunks=mc, fp_impl="reference"))(
        jnp.asarray(data), sb, sc)
    np.testing.assert_array_equal(oc, np.asarray(sc), f"{label}: split counts")
    np.testing.assert_array_equal(ob, np.asarray(sb), f"{label}: split bounds")
    np.testing.assert_array_equal(of, np.asarray(sf), f"{label}: split fps")
    np.testing.assert_array_equal(ol, np.asarray(sl), f"{label}: split lens")
    if fused:
        kb, kc, kf, kl = ops.packed_pipeline(
            jnp.asarray(data), jnp.asarray(sep), jnp.asarray(ends), P,
            max_chunks=mc)
        np.testing.assert_array_equal(oc, np.asarray(kc),
                                      f"{label}: fused counts")
        np.testing.assert_array_equal(ob, np.asarray(kb),
                                      f"{label}: fused bounds")
        np.testing.assert_array_equal(of, np.asarray(kf),
                                      f"{label}: fused fps")
        np.testing.assert_array_equal(ol, np.asarray(kl),
                                      f"{label}: fused lens")


# -- kernel-level directed edges ------------------------------------------------

def test_directed_edge_segments(rng):
    """1-byte, empty, and exactly-min_size segments next to normal ones."""
    r = lambda n: rng.integers(0, 256, n, dtype=np.uint8).tobytes()
    _assert_matches_oracle(
        [[r(1), b"", r(P.min_size), r(300), r(1)],
         [b"", b"", r(700)],
         [r(1)] * 8,
         [r(P.min_size)] * 4],
        S=1024, label="edges")


def test_skip_overshoot_endings(rng):
    """Segments ending mid-skip: constant bytes never form a candidate run,
    so the automaton is skipping (or riding the max-size window) when it
    hits the segment end — the overshoot must resolve as the end cut and
    the next segment must restart cleanly."""
    z = lambda n: bytes(n)
    low = lambda n: rng.integers(0, 3, n, dtype=np.uint8).tobytes()
    cases = [[z(70), z(100), z(130)],
             [z(600), low(200), z(65)],
             [low(511), z(513)],
             # ends placed all over one skip_size window
             [z(64 + q) for q in range(0, P.skip_size, 5)]]
    _assert_matches_oracle(cases, S=1024, label="skip-overshoot")


def test_segment_ends_on_tile_edges(rng):
    """Segment boundaries exactly on (and one byte around) the Pallas tile
    edge: the fused kernel's carry/stash hand-off across tiles must not
    bleed hash state across a segment reset."""
    from repro.kernels.fused_pipeline import packed_pipeline_batch

    r = lambda n: rng.integers(0, 256, n, dtype=np.uint8).tobytes()
    streams = [[r(1024), r(512), r(512)],
               [r(1023), r(1), r(1024)],
               [r(1), r(1023), r(1024)],
               [r(1025), r(1023)]]
    S = 2048
    data, sep, ends, seg_lens = _pack(streams, S)
    mc = S // P.min_size + 2 * ends.shape[1] + 2
    ob, oc, of, ol = ref.packed_pipeline(data, seg_lens, P, max_chunks=mc)
    kb, kc, kf, kl = packed_pipeline_batch(
        jnp.asarray(data), jnp.asarray(sep), jnp.asarray(ends), P,
        max_chunks=mc, tile=1024, interpret=True)
    np.testing.assert_array_equal(oc, np.asarray(kc))
    np.testing.assert_array_equal(ob, np.asarray(kb))
    np.testing.assert_array_equal(of, np.asarray(kf))
    np.testing.assert_array_equal(ol, np.asarray(kl))


def test_limb_boundary_row(rng):
    """A 65535-byte segment plus a 1-byte one fill a 65536-wide row — the
    exactness bound of the 16-bit limb cumsums the fingerprints ride on.
    (Split path only: the invariant under test is the hash math at the
    row-length limit, not the fused kernel's tiling.)"""
    r = lambda n: rng.integers(0, 256, n, dtype=np.uint8).tobytes()
    _assert_matches_oracle([[r(65535), r(1)]], S=65536, fused=False,
                           label="limb-boundary")


def test_packed_row_too_wide_rejected():
    """The fused packed kernel's in-graph prefix operands are only exact
    for rows <= 65536 entries; wider rows must refuse loudly."""
    data = np.zeros((1, 1 << 17), np.uint8)
    sep = np.full((1, 1 << 17), 100, np.int32)
    ends = np.full((1, 2), 100, np.int32)
    with pytest.raises(ValueError, match="narrower"):
        ops.packed_pipeline(jnp.asarray(data), jnp.asarray(sep),
                            jnp.asarray(ends), P, max_chunks=8)


# -- kernel-level property sweep --------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), mode=st.sampled_from(
    ("random", "lowent", "zeros", "mixed")))
def test_property_random_segment_mixes(seed, mode):
    """Random segment mixes (entropy regime per `mode`) packed into 2 KiB
    rows: both packed device paths must equal the per-stream oracle."""
    rng = np.random.default_rng(seed)
    S = 2048

    def seg(n):
        if mode == "zeros":
            return bytes(n)
        if mode == "lowent":
            return rng.integers(0, 4, n, dtype=np.uint8).tobytes()
        if mode == "mixed" and rng.random() < 0.5:
            return bytes(n)
        return rng.integers(0, 256, n, dtype=np.uint8).tobytes()

    streams = []
    for _ in range(int(rng.integers(1, 4))):
        row, fill = [], 0
        while fill < S:
            n = int(rng.integers(0, 900))
            if fill + n > S:
                break
            row.append(seg(n))
            fill += n
        if not row:
            row = [seg(1)]
        streams.append(row)
    _assert_matches_oracle(streams, S, label=f"prop/{mode}/{seed}")


# -- scheduler level ---------------------------------------------------------------

def test_scheduler_packed_equals_off(rng):
    """Edge-length traffic through a packed scheduler returns the same
    ChunkResults as the packing-off scheduler (which is itself pinned
    bit-identical to per-stream chunking by test_service.py)."""
    lengths = [0, 1, 2, P.seq_length - 1, P.min_size, P.max_size,
               P.max_size + 1, 100, 555, 1000, 1023]
    streams = [rng.integers(0, 256, n, dtype=np.uint8) for n in lengths]
    streams += [np.zeros(700, dtype=np.uint8),
                (np.arange(900) % 256).astype(np.uint8)]

    def run(packing):
        sched = ChunkScheduler(P, slots=4, min_bucket=1024,
                               packing_impl=packing,
                               cross_check_packing=(packing == "segments"))
        for i, s in enumerate(streams):
            sched.submit(s, tag=i)
        return sched, sched.drain()

    _, off = run("off")
    sched_on, on = run("segments")
    assert [r.tag for r in on] == [r.tag for r in off]
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a.bounds, b.bounds, f"tag {a.tag}")
        np.testing.assert_array_equal(a.fps, b.fps, f"tag {a.tag}")
        np.testing.assert_array_equal(a.lengths, b.lengths, f"tag {a.tag}")
    # every sub-bucket stream actually rode a packed row (the empty one
    # short-circuits; the 1023/1000-byte ones are still < min_bucket)
    assert sched_on.stats.packed_streams == len(streams) - 1
    assert sched_on.stats.tail_bytes == 0  # packed results skip the redo
    assert sched_on._packing_checked  # the guard ran on the first dispatch
    snap = sched_on.obs.snapshot()
    assert snap["counters"]["sched.cross_checks{kind=packing}"] == 1
    # occupancy gauges for packed dispatches live on their own series
    assert any("packed=1" in k for k in snap["gauges"]), snap["gauges"]


def test_scheduler_pack_queue_flushes_on_capacity():
    """The pack queue dispatches on its own once a device batch of packed
    rows is payload-full — no drain() needed (continuous batching)."""
    sched = ChunkScheduler(P, slots=2, min_bucket=1024,
                           packing_impl="segments")
    rng = np.random.default_rng(1)
    n = 0
    while sched.stats.dispatches == 0:
        sched.submit(rng.integers(0, 256, 800, dtype=np.uint8))
        n += 1
        assert n < 100, "pack queue never dispatched"
    # 2 slots x 1024 bytes of capacity / 800-byte streams: fires at 3
    assert n == 3
    assert sched.stats.packed_streams == 3


@settings(max_examples=6, deadline=None)
@given(data=st.binary(min_size=0, max_size=1500))
def test_property_scheduler_roundtrip(data):
    """Any byte-string (plus tiny derived variants) chunks identically
    through the packed and unpacked schedulers."""
    corpus = [data, data[:1], data[: len(data) // 2], data + data[:100]]

    def run(packing):
        sched = ChunkScheduler(P, slots=4, min_bucket=1024,
                               packing_impl=packing)
        for i, d in enumerate(corpus):
            sched.submit(np.frombuffer(d, dtype=np.uint8), tag=i)
        return sched.drain()

    for a, b in zip(run("off"), run("segments")):
        np.testing.assert_array_equal(a.bounds, b.bounds)
        np.testing.assert_array_equal(a.fps, b.fps)


# -- knob / guard plumbing ---------------------------------------------------------

def test_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_PACKING_IMPL", "segments")
    assert ChunkScheduler(P, min_bucket=1024).packing_impl == "segments"
    monkeypatch.delenv("REPRO_PACKING_IMPL")
    assert ChunkScheduler(P, min_bucket=1024).packing_impl == "off"


def test_bad_packing_impl_rejected():
    with pytest.raises(ValueError, match="packing_impl"):
        ChunkScheduler(P, min_bucket=1024, packing_impl="zip")


def test_min_bucket_beyond_limb_limit_rejected():
    """Packed rows lean on the 65536-entry limb-exactness bound, so a
    min_bucket above it must refuse packing up front, not corrupt hashes."""
    with pytest.raises(ValueError, match="min_bucket"):
        ChunkScheduler(P, min_bucket=1 << 17, packing_impl="segments")
    # same geometry is fine with packing off
    ChunkScheduler(P, min_bucket=1 << 17, packing_impl="off")


def _tiny_streams(rng, count, lo=100, hi=900):
    return [rng.integers(0, 256, int(rng.integers(lo, hi)), dtype=np.uint8)
            for _ in range(count)]


def test_divergence_injection_split(monkeypatch, rng):
    """A corrupted packed split runner must trip PackingDivergenceError on
    the first dispatch.  min_bucket=4096 gives this test its own device
    shape, so the corrupted function is what actually gets traced."""
    real = sched_mod._run_packed_split

    def corrupt(x, sep, ends, p, mc, mask_impl, fp_impl, with_fp):
        b, c, f, l = real(x, sep, ends, p, mc, mask_impl, fp_impl, with_fp)
        return b.at[:, 0].add(1), c, f, l

    monkeypatch.setattr(sched_mod, "_run_packed_split", corrupt)
    sched = ChunkScheduler(P, slots=2, min_bucket=4096,
                           packing_impl="segments", cross_check_packing=True)
    for s in _tiny_streams(rng, 3):
        sched.submit(s)
    with pytest.raises(PackingDivergenceError, match="diverged"):
        sched.drain()


def test_divergence_injection_fused(monkeypatch, rng):
    """Same guard through the fused packed kernel path (its own 8 KiB
    shape), corrupting a fingerprint instead of a boundary."""
    real = sched_mod._run_packed_fused

    def corrupt(x, sep, ends, p, mc):
        b, c, f, l = real(x, sep, ends, p, mc)
        return b, c, f.at[:, 0, 0].add(1), l

    monkeypatch.setattr(sched_mod, "_run_packed_fused", corrupt)
    sched = ChunkScheduler(P, slots=2, min_bucket=8192,
                           packing_impl="segments", pipeline_impl="fused",
                           cross_check_packing=True)
    for s in _tiny_streams(rng, 3):
        sched.submit(s)
    with pytest.raises(PackingDivergenceError, match="diverged"):
        sched.drain()


def test_guard_off_by_default(rng):
    """Without cross_check_packing nothing replays: one packed dispatch,
    no cross-check counter."""
    sched = ChunkScheduler(P, slots=2, min_bucket=1024,
                           packing_impl="segments")
    for s in _tiny_streams(rng, 3, lo=200, hi=400):
        sched.submit(s)
    sched.drain()
    snap = sched.obs.snapshot()
    assert "sched.cross_checks{kind=packing}" not in snap["counters"]
    assert not sched._packing_checked
