"""CDC baseline algorithms: invariants + native/vectorized bit-equality.

The paper evaluates SeqCDC against 7 hash-based/hashless baselines; each of
ours ships in a native (per-byte scan) and a vectorized (two-phase) substrate
that must produce identical boundaries — the same property SS-CDC and
VectorCDC report for their accelerations.
"""
import numpy as np
import pytest

from repro.core import available, make_chunker

ALGOS = ["seqcdc", "fixed", "gear", "crc", "rabin", "fastcdc", "tttd", "ae", "ram"]
PAIRS = [  # (vectorized, native) substrates of the same algorithm
    ("seqcdc", "seqcdc_seq"),
    ("seqcdc", "seqcdc_numpy"),
    ("gear", "gear_seq"),
    ("crc", "crc_seq"),
    ("rabin", "rabin_seq"),
    ("fastcdc", "fastcdc_seq"),
    ("ae", "ae_seq"),
    ("ram", "ram_seq"),
]


@pytest.fixture(scope="module")
def data(rng=None):
    return np.random.default_rng(1).integers(0, 256, 1 << 20, dtype=np.uint8)


def test_registry_complete():
    names = available()
    for a in ALGOS:
        assert a in names, a


@pytest.mark.parametrize("name", ALGOS)
def test_boundary_invariants(name, data):
    c = make_chunker(name, 8192)
    bounds = c.chunk(data)
    assert bounds[-1] == data.size
    assert (np.diff(bounds) > 0).all()
    lens = np.diff(np.concatenate([[0], bounds]))
    assert (lens <= c.max_size).all(), name
    assert (lens[:-1] >= c.min_size).all(), name


@pytest.mark.parametrize("name", ALGOS)
def test_avg_size_in_band(name, data):
    """Achieved average within a sane band of the target (random data)."""
    c = make_chunker(name, 8192)
    lens = c.chunk_lengths(data)
    mean = lens.mean()
    assert 0.25 * 8192 <= mean <= 2.1 * 8192, (name, mean)


@pytest.mark.parametrize("vec,seq", PAIRS)
def test_native_equals_vectorized(vec, seq, data):
    sub = data[: 1 << 18]
    b_vec = make_chunker(vec, 8192).chunk(sub)
    b_seq = make_chunker(seq, 8192).chunk(sub)
    np.testing.assert_array_equal(b_vec, b_seq, err_msg=f"{vec} != {seq}")


@pytest.mark.parametrize("name", ["seqcdc", "gear", "ae", "ram", "fastcdc"])
def test_determinism(name, data):
    sub = data[: 1 << 17]
    c = make_chunker(name, 4096)
    np.testing.assert_array_equal(c.chunk(sub), c.chunk(sub))


def test_fixed_is_exact():
    c = make_chunker("fixed", 4096)
    bounds = c.chunk(np.zeros(10_000, dtype=np.uint8))
    assert bounds.tolist() == [4096, 8192, 10000]


@pytest.mark.parametrize("name", ["seqcdc", "gear", "rabin", "ae", "ram"])
def test_content_defined_shift_resistance(name):
    """CDC property: boundaries re-synchronize after an insertion."""
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, 1 << 19, dtype=np.uint8)
    c = make_chunker(name, 4096)
    b0 = set(c.chunk(data).tolist())
    pos = 1 << 18
    edit = np.concatenate([data[:pos], rng.integers(0, 256, 11, dtype=np.uint8), data[pos:]])
    b1 = [b - 11 for b in c.chunk(edit).tolist() if b >= pos + 11]
    survive = sum(b in b0 for b in b1) / max(len(b1), 1)
    assert survive > 0.85, (name, survive)


def test_fixed_has_no_shift_resistance():
    """The motivating contrast (paper SSI): fixed-size chunking loses all
    boundaries after an unaligned insertion."""
    rng = np.random.default_rng(8)
    data = rng.integers(0, 256, 1 << 18, dtype=np.uint8)
    c = make_chunker("fixed", 4096)
    b0 = c.chunk(data)
    edit = np.concatenate([rng.integers(0, 256, 1, dtype=np.uint8), data])
    b1 = c.chunk(edit)
    # same offsets -> chunk contents all differ: dedup between the two
    # versions is ~0 even though 99.999% of bytes are shared
    from repro.dedup.store import BlockStore

    s = BlockStore()
    s.put_stream(data, b0)
    before = s.stored_bytes
    s.put_stream(edit, b1)
    assert s.stored_bytes >= 2 * before * 0.99


def test_calibrated_params_hit_targets():
    from repro.core.calibrate import calibrated_chunker

    data = np.random.default_rng(3).integers(0, 256, 4 << 20, dtype=np.uint8)
    for avg in (4096, 8192, 16384):
        c = calibrated_chunker("seqcdc_numpy", avg)
        mean = c.chunk_lengths(data).mean()
        assert 0.7 * avg <= mean <= 1.4 * avg, (avg, mean)
