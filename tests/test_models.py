"""Per-architecture smoke + decode-equivalence tests (reduced configs).

For every assigned arch: one forward/train step on CPU asserting shapes and
finiteness, and the serving-correctness property: prefill(prompt) then
decode(token) logits == forward(prompt+token) logits at the last position.
This exercises KV caches (full + rolling window), MLA absorbed decode, MoE
dispatch, mLSTM chunkwise-vs-step, sLSTM and RG-LRU recurrences.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_config, get_reduced, param_count, shape_applicable
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=64, key=KEY):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    elif cfg.input_mode == "embeddings":
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model)) * 0.02
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    else:
        st = S - cfg.img_tokens
        batch["tokens"] = jax.random.randint(ks[0], (B, st), 0, cfg.vocab_size)
        batch["embeds"] = jax.random.normal(ks[1], (B, cfg.img_tokens, cfg.d_model)) * 0.02
        lab = np.array(jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size))
        lab[:, : cfg.img_tokens] = -1
        batch["labels"] = jnp.asarray(lab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_reduced(arch)
    params = lm.init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits = lm.forward(cfg, params, batch)
    B = batch["labels"].shape[0]
    S = batch["labels"].shape[1]
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch
    loss, metrics = lm.loss_and_metrics(cfg, params, batch)
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_reduces_loss(arch):
    """A few steps on one repeated batch must reduce the loss (overfit)."""
    from repro.train import OptConfig, make_train_step, opt_init

    cfg = get_reduced(arch)
    params = lm.init_params(cfg, KEY)
    batch = make_batch(cfg)
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=1, total_steps=20, weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    opt = opt_init(opt_cfg, params)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (arch, losses)
    assert np.isfinite(losses).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """prefill+decode logits == full forward logits (serving correctness)."""
    cfg = get_reduced(arch)
    if cfg.n_experts:
        # capacity drops depend on sequence length, so a capacity-limited
        # forward is not bit-comparable with decode; lift the cap (dropless)
        cfg = cfg.replace(capacity_factor=8.0)
    params = lm.init_params(cfg, KEY)
    B, S = 2, 32
    if cfg.input_mode == "mixed":
        cfg = cfg.replace(img_tokens=8)
    batch = make_batch(cfg, B=B, S=S)
    batch.pop("labels")

    full_logits = lm.forward(cfg, params, batch)  # (B, S, V)
    lg_p, caches = lm.prefill_step(cfg, params, batch, cache_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(lg_p), np.asarray(full_logits[:, -1]), rtol=2e-4, atol=2e-4
    )

    # decode one more token and compare against forward over S+1
    tok = jnp.argmax(lg_p, -1)[:, None].astype(jnp.int32)
    lg_d, _ = lm.decode_step(cfg, params, caches, tok, jnp.int32(S))
    if cfg.input_mode == "tokens":
        batch2 = {"tokens": jnp.concatenate([batch["tokens"], tok], axis=1)}
    elif cfg.input_mode == "mixed":
        batch2 = {
            "tokens": jnp.concatenate([batch["tokens"], tok], axis=1),
            "embeds": batch["embeds"],
        }
    else:
        w = params["embed"] if cfg.tie_embeddings else params["unembed"].T
        emb = w[tok[:, 0]][:, None, :]
        batch2 = {"embeds": jnp.concatenate([batch["embeds"], emb], axis=1)}
    full2 = lm.forward(cfg, params, batch2)
    np.testing.assert_allclose(
        np.asarray(lg_d), np.asarray(full2[:, -1]), rtol=3e-4, atol=3e-4,
        err_msg=f"{arch} decode != forward",
    )


@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("kv_heads,pad_to", [(4, 0), (2, 0), (2, 8), (1, 8)])
def test_flash_equals_reference(window, kv_heads, pad_to):
    """Online-softmax chunked attention == materialized-score reference."""
    from repro.models.attention import _flash_attention, causal_attention

    cfg = get_reduced("llama3.2-1b").replace(
        n_heads=4, n_kv_heads=kv_heads, head_dim=16,
        attn_q_block=16, attn_kv_block=0, tp_head_pad=pad_to,
    )
    B, S, H, hd = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.3
    k = jax.random.normal(ks[1], (B, S, kv_heads, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, S, kv_heads, hd)) * 0.3
    ref = causal_attention(q, k, v, cfg, window=window)
    for kvb in (16, 32, 64):
        got = _flash_attention(
            q, k, v, cfg.replace(attn_kv_block=kvb), 1.0 / hd**0.5,
            window=window, pad_to=pad_to,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5,
            err_msg=f"kvb={kvb} window={window}",
        )


def test_mlstm_chunkwise_equals_step():
    """mLSTM chunkwise-parallel form == token-by-token recurrence."""
    from repro.configs import get_reduced
    from repro.models import ssm
    from repro.models.layers import init_tree

    cfg = get_reduced("xlstm-125m")
    t = ssm.mlstm_template(cfg)
    p = init_tree(t, KEY)
    B, S = 2, 64
    du = int(cfg.d_model * cfg.mlstm_proj_factor)
    xu = jax.random.normal(jax.random.PRNGKey(1), (B, S, du)) * 0.1
    h_chunk, st_chunk = ssm.mlstm_chunkwise(p, xu, cfg)
    st = ssm.mlstm_init_state(B, cfg.n_heads, du // cfg.n_heads)
    hs = []
    for t_ in range(S):
        h, st = ssm.mlstm_step(p, xu[:, t_ : t_ + 1], cfg, st)
        hs.append(h)
    h_seq = jnp.concatenate(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk.C), np.asarray(st.C), rtol=2e-4, atol=2e-4)


def test_rglru_scan_equals_step():
    from repro.configs import get_reduced
    from repro.models import rglru
    from repro.models.layers import init_tree

    cfg = get_reduced("recurrentgemma-2b")
    p = init_tree(rglru.rglru_template(cfg), KEY)
    B, S = 2, 48
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.1
    out_scan, st_scan = rglru.rglru_block(p, x, cfg)
    st = rglru.rglru_init_state(B, cfg.lru_width, cfg.conv_width)
    outs = []
    for t_ in range(S):
        o, st = rglru.rglru_block(p, x[:, t_ : t_ + 1], cfg, state=st, decode=True)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_scan), np.asarray(out_seq), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_scan.h), np.asarray(st.h), rtol=2e-4, atol=2e-4)


def test_moe_all_experts_used():
    """Router load-balance: on random inputs every expert receives tokens."""
    cfg = get_reduced("qwen3-moe-30b-a3b")
    from repro.models import moe
    from repro.models.layers import init_tree

    p = init_tree(moe.moe_template(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 128, cfg.d_model)) * 0.5
    out, aux = moe.moe_ffn(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) > 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_full_configs_param_counts():
    """Analytic parameter counts of the full (unreduced) configs are in the
    right ballpark for the public models."""
    expect = {
        "qwen3-moe-30b-a3b": (30e9, 0.4),
        "deepseek-v3-671b": (671e9, 0.25),
        "phi3-medium-14b": (14e9, 0.3),
        "llama3.2-1b": (1.24e9, 0.25),
        "qwen2-72b": (72e9, 0.25),
        "granite-8b": (8e9, 0.3),
        "recurrentgemma-2b": (2.7e9, 0.45),
    }
    for arch, (want, tol) in expect.items():
        total, active = param_count(get_config(arch))
        assert abs(total - want) / want < tol, (arch, total, want)
        assert active <= total


def test_deepseek_active_params():
    total, active = param_count(get_config("deepseek-v3-671b"))
    assert 25e9 < active < 50e9, active  # ~37B active


def test_shape_applicability():
    assert not shape_applicable(get_config("qwen2-72b"), SHAPES["long_500k"])
    assert shape_applicable(get_config("xlstm-125m"), SHAPES["long_500k"])
    assert shape_applicable(get_config("recurrentgemma-2b"), SHAPES["long_500k"])
