"""Reduced-step smoke for examples/train_dedup_lm.py.

The example is the repo's end-to-end demo — scenario-engine corpus ->
dedup-before-tokenization -> LM pretraining -> CDC-store checkpoints ->
crash/restart — and nothing else executes it, so a drift in any public
API it touches would otherwise only surface for a human running it by
hand.  This loads the script as a module (importlib, no subprocess: same
jax runtime, coverage sees it) and runs ``main`` with a seconds-fast
configuration: 6 steps, ~1 MiB corpus, checkpoint every 2, crash at 4.
"""
import importlib.util
import os
import sys

import pytest

EXAMPLE = os.path.join(os.path.dirname(__file__), "..", "examples",
                       "train_dedup_lm.py")


def _load_example():
    spec = importlib.util.spec_from_file_location("train_dedup_lm", EXAMPLE)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_train_example_smoke():
    mod = _load_example()
    out = mod.main(["--steps", "6", "--corpus-mb", "1",
                    "--ckpt-every", "2", "--crash-at", "4"])
    # the crash/restart contract: the second trainer resumed exactly at
    # the checkpointed step and ran to completion
    assert out["resume_step"] == 4
    assert out["final_step"] == 5
    # the scenario corpus has planted duplicates and the ingest found a
    # nontrivial share of them (~33% constructed; band absorbs tuning)
    assert 0.15 <= out["ingest_savings"] <= 0.60
    # the model really trained (both raw losses are finite and ordered
    # enough for 6 steps on a byte LM)
    assert out["first_loss"] > out["final_loss"] > 0


def test_train_example_rejects_bad_crash_schedule():
    mod = _load_example()
    with pytest.raises(SystemExit):
        mod.main(["--steps", "6", "--ckpt-every", "4", "--crash-at", "3"])
