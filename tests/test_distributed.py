"""Multi-device behaviour (8 placeholder CPU devices via subprocess):
distributed fingerprint index, sharded train step, dry-run cell on a tiny
mesh.  Subprocesses are required because XLA fixes the device count at first
init and the main test process must keep seeing 1 device.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_py(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_distributed_dedup_matches_host():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.dedup.dist_index import distributed_dedup
        from repro.dedup import dedup_stats

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        n = 8 * 512
        fp = rng.integers(0, 50, (n, 2)).astype(np.uint32)  # few distinct -> dups
        lengths = rng.integers(1, 1000, n).astype(np.int32)
        lengths[::17] = 0  # padding rows
        fn = distributed_dedup(mesh, "data", capacity_factor=4.0)
        with mesh:
            got = jax.tree.map(int, fn(jnp.asarray(fp), jnp.asarray(lengths)))
        assert got.pop("overflow_total") == 0, got
        # host reference — dedup by (fp1, fp2) over valid rows; note equal
        # fingerprints may carry different lengths (synthetic), dedup keeps first
        want = jax.tree.map(int, dedup_stats(jnp.asarray(fp), jnp.asarray(lengths)))
        assert got["original_bytes"] == want["original_bytes"]
        assert got["unique_chunks"] == want["unique_chunks"]
        assert got["total_chunks"] == want["total_chunks"]
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_py("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.configs import get_reduced
        from repro.models import lm
        from repro.train import OptConfig, make_train_step, opt_init
        from repro.distributed.sharding import ShardingRules, default_rules
        from repro.launch import specs as S

        cfg = get_reduced("llama3.2-1b")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = ShardingRules(mesh, default_rules(mesh, cfg.fsdp))
        key = jax.random.PRNGKey(0)
        params = lm.init_params(cfg, key)
        opt_cfg = OptConfig(lr=1e-3, warmup_steps=1)
        opt = opt_init(opt_cfg, params)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
                 "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
        step = make_train_step(cfg, opt_cfg)

        # single device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        p_sh = rules.sharding_tree(S.params_template(cfg))
        from repro.train import optim
        o_sh = optim.OptState(p_sh, p_sh, NamedSharding(mesh, PS()))
        b_sh = rules.sharding_tree(S.batch_template(cfg, type("S", (), {"global_batch": 8, "seq_len": 32, "kind": "train"})()))
        sharded = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh), out_shardings=(p_sh, o_sh, None))
        with mesh:
            p2, o2, m2 = sharded(
                jax.device_put(params, p_sh), jax.device_put(opt, o_sh),
                jax.device_put(batch, b_sh))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-5)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_tiny_mesh():
    """The dry-run machinery end-to-end on an 8-device (4,2) mesh."""
    out = run_py("""
        import jax
        from repro.configs import SHAPES, get_reduced
        from repro.launch.dryrun import build_cell
        from repro.roofline import analyze

        cfg = get_reduced("qwen3-moe-30b-a3b").replace(fsdp="data")
        shape = type("S", (), {"name": "t", "seq_len": 128, "global_batch": 8, "kind": "train"})()
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        jfn, args = build_cell(cfg, shape, mesh)
        with mesh:
            compiled = jfn.lower(*args).compile()
        mem = compiled.memory_analysis()
        rl = analyze.from_compiled("t", "t", "m", 8, compiled, cfg=cfg, shape_cfg=shape)
        assert rl.flops_per_device > 0
        assert rl.t_compute > 0 and rl.t_memory > 0
        print("OK", rl.bottleneck)
    """)
    assert "OK" in out


def test_elastic_restore_across_meshes(tmp_path):
    """Save params sharded on a (4,2) mesh, restore onto (2,4) — elasticity."""
    out = run_py(f"""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as PS
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_reduced
        from repro.models import lm
        from repro.distributed.sharding import ShardingRules, default_rules
        from repro.launch import specs as S

        cfg = get_reduced("llama3.2-1b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        mgr = CheckpointManager({str(tmp_path)!r})

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        sh_a = ShardingRules(mesh_a, default_rules(mesh_a, "data")).sharding_tree(S.params_template(cfg))
        placed = jax.device_put(params, sh_a)
        mgr.save(1, {{"params": placed}})

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        sh_b = ShardingRules(mesh_b, default_rules(mesh_b, "none")).sharding_tree(S.params_template(cfg))
        step, state, _ = mgr.restore_sharded({{"params": params}}, {{"params": sh_b}})
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK")
    """)
    assert "OK" in out
