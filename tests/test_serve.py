"""Serving engine: greedy correctness vs naive forward, continuous batching."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import lm
from repro.serve import Engine, ServeConfig

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("llama3.2-1b")
    params = lm.init_params(cfg, KEY)
    return cfg, params


def _naive_greedy(cfg, params, prompt, n):
    seq = list(map(int, prompt))
    out = []
    for _ in range(n):
        logits = lm.forward(cfg, params, {"tokens": jnp.asarray(seq)[None]})
        t = int(jnp.argmax(logits[0, -1]))
        out.append(t)
        seq.append(t)
    return out


def test_engine_matches_naive_greedy(setup):
    cfg, params = setup
    prompt = np.arange(9) % cfg.vocab_size
    want = _naive_greedy(cfg, params, prompt, 8)
    eng = Engine(cfg, params, ServeConfig(max_slots=2, cache_len=64, max_new_tokens=8))
    rid = eng.submit(prompt)
    got = eng.run()[rid]
    assert got == want


def test_continuous_batching_mixed_lengths(setup):
    """More requests than slots, different prompt lengths: all finish and
    each matches its single-request reference output."""
    cfg, params = setup
    prompts = [np.arange(3 + 5 * i) % cfg.vocab_size for i in range(5)]
    eng = Engine(cfg, params, ServeConfig(max_slots=2, cache_len=96, max_new_tokens=6))
    rids = [eng.submit(p) for p in prompts]
    results = eng.run()
    assert set(results) == set(rids)
    for rid, p in zip(rids, prompts):
        assert results[rid] == _naive_greedy(cfg, params, p, 6), f"req {rid}"


def test_recurrent_arch_serving():
    """The engine works for SSM archs too (state caches, not KV)."""
    cfg = get_reduced("xlstm-125m")
    params = lm.init_params(cfg, KEY)
    prompt = np.arange(7) % cfg.vocab_size
    want = _naive_greedy(cfg, params, prompt, 5)
    eng = Engine(cfg, params, ServeConfig(max_slots=2, cache_len=64, max_new_tokens=5))
    rid = eng.submit(prompt)
    assert eng.run()[rid] == want


def test_hybrid_arch_serving():
    cfg = get_reduced("recurrentgemma-2b")
    params = lm.init_params(cfg, KEY)
    prompt = np.arange(11) % cfg.vocab_size
    want = _naive_greedy(cfg, params, prompt, 5)
    eng = Engine(cfg, params, ServeConfig(max_slots=2, cache_len=64, max_new_tokens=5))
    rid = eng.submit(prompt)
    assert eng.run()[rid] == want
