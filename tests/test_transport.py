"""Remote shard transport: protocol framing, server ops, and the acceptance
property — ``ShardedDedupService(transport="remote")`` with N shard server
*processes* produces identical dedup totals and byte-identical SHA-verified
restores vs the in-process service, including SIGKILL crash injection
between block and manifest writes with recovery on restart.
"""
import os
import socket
import threading

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

from repro.core.params import SeqCDCParams
from repro.data.corpus import snapshot_series
from repro.service import (
    AsyncWriteError,
    DedupService,
    ShardedDedupService,
)
from repro.service.objects import ObjectRecipe
from repro.service.transport import (
    ProtocolError,
    RemoteShardClient,
    ShardServerProcess,
    ShardTransportError,
)
from repro.service.transport import protocol as proto
from repro.service.transport.shard_server import ShardServer

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


def _corpus(seed: int, versions: int = 4, base: int = 1 << 16):
    rng = np.random.default_rng(seed)
    objs = list(snapshot_series(base_bytes=base, snapshots=versions,
                                edit_rate=3e-5, seed=seed))
    objs.append(rng.integers(0, 256, int(rng.integers(1, 5000)), dtype=np.uint8))
    objs.append(np.zeros(0, dtype=np.uint8))  # empty object
    return objs


def _ingest(svc, objs):
    for i, o in enumerate(objs):
        svc.submit(f"o{i:03d}", o)
    svc.flush()


# -- protocol framing -----------------------------------------------------------

def test_frame_roundtrip_and_versioning():
    a, b = socket.socketpair()
    try:
        proto.send_frame(a, proto.OP_PUT_BLOCKS, {"sizes": [3, 2]}, b"abcde")
        op, meta, blob = proto.recv_frame(b)
        assert (op, meta, blob) == (proto.OP_PUT_BLOCKS,
                                    {"sizes": [3, 2]}, b"abcde")
        assert proto.split_blob(blob, meta["sizes"]) == [b"abc", b"de"]

        # version mismatch is rejected before any payload is interpreted
        hdr = proto.HEADER.pack(proto.MAGIC, proto.VERSION + 1,
                                proto.OP_PING, 0, 0, 0)
        a.sendall(hdr)
        with pytest.raises(ProtocolError, match="version"):
            proto.recv_frame(b)

        a.sendall(b"XXXX" + bytes(proto.HEADER.size - 4))
        with pytest.raises(ProtocolError, match="magic"):
            proto.recv_frame(b)
    finally:
        a.close()
        b.close()


def test_frame_eof_and_blob_mismatch():
    a, b = socket.socketpair()
    a.sendall(proto.HEADER.pack(proto.MAGIC, proto.VERSION, 1, 0, 2, 0))
    a.close()  # dies mid-frame
    with pytest.raises(ConnectionError):
        proto.recv_frame(b)
    b.close()
    with pytest.raises(ProtocolError):
        proto.split_blob(b"abc", [1, 1])  # declared sizes under-run the blob


def test_remote_error_mapping():
    with pytest.raises(KeyError):
        proto.raise_remote({"etype": "KeyError", "message": "k"})
    with pytest.raises(ShardTransportError, match="OSError"):
        proto.raise_remote({"etype": "OSError", "message": "disk gone"})


# -- server op set (in-process server: no subprocess cost) ----------------------

@pytest.fixture
def served(tmp_path):
    srv = ShardServer(str(tmp_path / "shard"), port=0)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    client = RemoteShardClient("127.0.0.1", srv.port)
    yield srv, client
    client.close()
    srv.shutdown()
    srv.close()
    t.join(timeout=10)


def test_server_op_set(served):
    srv, c = served
    assert c.ping()["ok"] is True

    keys = c.put_blocks([b"aaaa", b"bbbb", b"aaaa"])
    assert keys[0] == keys[2] != keys[1]
    assert c.get_blocks(keys) == [b"aaaa", b"bbbb", b"aaaa"]
    assert c.get(keys[1]) == b"bbbb"
    st = c.stat()
    assert (st["stored_bytes"], st["unique_chunks"]) == (8, 2)
    assert c.stored_bytes == 8 and c.logical_bytes == 12
    assert c.unique_chunks == 2

    assert c.release(keys[0]) is False  # refcount 2 -> 1
    assert c.release(keys[0]) is True   # freed
    assert c.release("unknown") is False
    assert sorted(c.scan_keys()) == sorted([keys[1]])

    c.put_recipe(ObjectRecipe(name="x", size=4, sha256="00", keys=[keys[1]],
                              chunk_lens=[4], shards=[0]))
    c.sync()  # put_manifest: durable store manifest + recipe table
    assert c.stat()["objects"] == 1

    with pytest.raises(KeyError):
        c.get("0" * 64)

    # gc_mark/gc_sweep: recomputed liveness repairs drift, drops garbage
    orphan = c.put_blocks([b"orphan"])[0]
    freed_blocks, freed_bytes, repaired = c.sweep({keys[1]: 3})
    assert freed_blocks == 1 and freed_bytes == len(b"orphan")
    assert repaired == 1  # keys[1] refcount 1 -> 3
    assert c.logical_bytes == 12 and c.stored_bytes == 4
    assert orphan not in c.scan_keys()


def test_client_is_thread_safe(served):
    _, c = served
    errs = []

    def worker(tag):
        try:
            for i in range(50):
                payload = f"{tag}-{i}".encode()
                key = c.put(payload)
                assert c.get(key) == payload
        except Exception as e:  # noqa: BLE001
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errs
    assert c.unique_chunks == 200


# -- the acceptance property: remote N-vs-local, real server processes ----------

@pytest.mark.timeout(600)
@settings(max_examples=2, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_remote_sharded_equals_inprocess_property(tmp_path_factory, seed):
    """transport="remote" with N in {1,2,4} shard server processes: dedup
    totals identical and restores byte-identical to the in-process N=1
    service (the ISSUE 3 acceptance property)."""
    objs = _corpus(seed)
    single = DedupService(params=P, slots=4, min_bucket=1024)
    _ingest(single, objs)
    want = single.stats()
    restores = {f"o{i:03d}": single.get(f"o{i:03d}") for i in range(len(objs))}

    for n in (1, 2, 4):
        root = str(tmp_path_factory.mktemp(f"remote-{seed}-{n}"))
        svc = ShardedDedupService.open(root, n, transport="remote",
                                       params=P, slots=4, min_bucket=1024)
        try:
            _ingest(svc, objs)
            got = svc.stats()
            assert got.stored_bytes == want.stored_bytes, f"N={n}"
            assert got.logical_bytes == want.logical_bytes, f"N={n}"
            assert got.unique_chunks == want.unique_chunks, f"N={n}"
            assert got.total_chunks == want.total_chunks, f"N={n}"
            for name, data in restores.items():
                assert svc.get(name) == data, f"N={n} {name}"
        finally:
            svc.close()
        assert all(h.proc.returncode is not None for h in svc._servers or [])


@pytest.mark.timeout(600)
def test_remote_delete_gc_and_depot_interchange(tmp_path, rng):
    """Deletes/GC work over the wire, and the depot written by remote
    servers reopens under the local transport (identical on-disk layout)."""
    root = str(tmp_path / "depot")
    objs = _corpus(21, versions=3)
    svc = ShardedDedupService.open(root, 2, transport="remote",
                                   params=P, slots=4, min_bucket=1024)
    _ingest(svc, objs)
    names = svc.names()
    freed = svc.delete(names[-1])
    assert freed >= 0
    g = svc.gc()
    assert g.freed_blocks == 0  # nothing orphaned by a clean delete
    stats_remote = svc.stats()
    svc.close()

    local = ShardedDedupService.open(root, 2, params=P, slots=4,
                                     min_bucket=1024)
    assert local.names() == names[:-1]
    assert sum(st.stored_bytes for st in local.stores) == \
        stats_remote.stored_bytes
    for i, o in enumerate(objs[:-1]):
        if f"o{i:03d}" in names[:-1]:
            assert local.get(f"o{i:03d}") == o.tobytes()
    local.close()


# -- crash injection ------------------------------------------------------------

@pytest.mark.timeout(600)
def test_sigkill_during_block_write_aborts_cleanly(tmp_path, rng):
    """SIGKILL a shard server while its writer is putting blocks: the flush
    fails with AsyncWriteError *before* any recipe is committed, the name
    is not stranded, and a respawned server serves the depot again."""
    root = str(tmp_path / "depot")
    svc = ShardedDedupService.open(root, 2, transport="remote",
                                   params=P, slots=2, min_bucket=1024)
    keep = rng.integers(0, 256, 8000, dtype=np.uint8)
    svc.put("keep", keep)

    victim = svc._servers[1]
    orig_put = svc.stores[1].put_blocks  # the coalesced writer hot path

    def killing_put(chunks):
        victim.kill()  # SIGKILL, mid-flush: blocks for shard 0 may have landed
        return orig_put(chunks)

    svc.stores[1].put_blocks = killing_put
    svc.submit("lost", rng.integers(0, 256, 8000, dtype=np.uint8))
    with pytest.raises(AsyncWriteError):
        svc.flush()
    assert svc.names() == ["keep"]  # nothing committed
    svc.stores[1].put_blocks = orig_put
    svc.close()

    svc2 = ShardedDedupService.open(root, 2, transport="remote",
                                    params=P, slots=2, min_bucket=1024)
    try:
        assert svc2.names() == ["keep"]
        assert svc2.get("keep") == keep.tobytes()
        svc2.gc()  # reclaims any shard-0 blocks the dead flush stranded
        # resubmission of the aborted name works against the new server
        lost = rng.integers(0, 256, 8000, dtype=np.uint8)
        svc2.put("lost", lost)
        assert svc2.get("lost") == lost.tobytes()
    finally:
        svc2.close()


@pytest.mark.timeout(600)
def test_sigkill_between_block_and_manifest_write(tmp_path, rng):
    """The acceptance crash case: blocks landed (writer barrier passed) and
    recipes committed, then one shard server is SIGKILLed before its
    manifest sync.  On restart every committed object restores
    byte-identically and gc() repairs the stale manifest accounting."""
    root = str(tmp_path / "depot")
    svc = ShardedDedupService.open(root, 2, transport="remote",
                                   params=P, slots=2, min_bucket=1024)
    objs = _corpus(31, versions=3)
    _ingest(svc, objs)  # a committed baseline
    want_stored = svc.stats().stored_bytes

    victim = svc._servers[1]
    orig_sync = svc.stores[1].sync

    def killing_sync():
        victim.kill()  # blocks + recipes durable; manifest sync never runs
        return orig_sync()

    svc.stores[1].sync = killing_sync
    extra = rng.integers(0, 256, 12_000, dtype=np.uint8)
    svc.submit("extra", extra)
    with pytest.raises(ShardTransportError):
        svc.flush()
    svc.stores[1].sync = orig_sync
    # recipes committed before the kill: "extra" is a named object whose
    # blocks all landed pre-barrier — the blocks→recipes order held
    assert "extra" in svc.names()
    svc.close()

    svc2 = ShardedDedupService.open(root, 2, transport="remote",
                                    params=P, slots=2, min_bucket=1024)
    try:
        assert svc2.get("extra") == extra.tobytes()
        for i, o in enumerate(objs):
            assert svc2.get(f"o{i:03d}") == o.tobytes()
        svc2.gc()  # re-adopts shard-1 blocks its stale manifest missed
        got = svc2.stats()
        assert got.stored_bytes > want_stored  # "extra"'s unique chunks
        # accounting is self-consistent again: a second gc is a no-op
        g = svc2.gc()
        assert (g.freed_blocks, g.repaired_refs) == (0, 0)
    finally:
        svc2.close()


# -- codec over the wire (protocol v4) ------------------------------------------

def _zserver(root):
    srv = ShardServer(str(root), port=0, codec="zlib")
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    return srv, t


def _low_entropy(rng, n=40_000):
    return np.repeat(rng.integers(0, 8, n // 50, dtype=np.uint8), 50)[:n]


def test_hello_negotiates_wire_codec(tmp_path, monkeypatch):
    """OP_HELLO picks the best codec both ends speak: zlib sticks, an
    unavailable lz4 preference degrades to zlib, no preference stays raw
    (and never sends a hello at all — v3 clients keep working).  The env
    default is cleared so "no preference" really means none (under
    REPRO_STORE_CODEC=zlib an argless client rightly negotiates zlib)."""
    monkeypatch.delenv("REPRO_STORE_CODEC", raising=False)
    srv, t = _zserver(tmp_path / "shard")
    try:
        c = RemoteShardClient("127.0.0.1", srv.port, codec="zlib")
        assert c.codec == "zlib"
        c.close()
        c = RemoteShardClient("127.0.0.1", srv.port)  # no preference
        assert c.codec == "none"
        assert c.ping()["ok"] is True  # raw client against a zlib store
        c.close()
    finally:
        srv.shutdown()
        srv.close()
        t.join(timeout=10)


def test_precompressed_put_blocks_roundtrip_and_accounting(tmp_path, rng):
    """put_blocks with a negotiated codec compresses on the *client*, ships
    payload bytes, and the server adopts them as-is: keys are the raw-byte
    SHAs, gets return raw bytes, stat shows compressed < stored while
    stored_bytes stays raw (the accounting contract over the wire)."""
    srv, t = _zserver(tmp_path / "shard")
    try:
        c = RemoteShardClient("127.0.0.1", srv.port, codec="zlib")
        low = _low_entropy(rng).tobytes()
        high = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
        keys = c.put_blocks([low, high, low])
        assert keys[0] == keys[2] != keys[1]
        assert c.get_blocks(keys) == [low, high, low]
        st = c.stat()
        assert st["stored_bytes"] == len(low) + len(high)  # raw accounting
        assert st["compressed_bytes"] < st["stored_bytes"]  # low compressed
        assert st["compressed_ratio"] > 1.0
        assert c.compressed_bytes == st["compressed_bytes"]
        # the compressible chunk is on disk under its codec suffix
        assert os.path.exists(tmp_path / "shard" / "blocks" / (keys[0] + ".z"))
        assert os.path.exists(tmp_path / "shard" / "blocks" / keys[1])
        c.close()
    finally:
        srv.shutdown()
        srv.close()
        t.join(timeout=10)


def test_block_corruption_crosses_wire_typed(tmp_path, rng):
    """A corrupt compressed block raises BlockCorruptionError on the server
    and arrives as the same *typed* error at the client — not a generic
    ShardTransportError — so the service maps it to IntegrityError."""
    from repro.dedup.store import BlockCorruptionError

    srv, t = _zserver(tmp_path / "shard")
    try:
        c = RemoteShardClient("127.0.0.1", srv.port, codec="zlib")
        key = c.put(_low_entropy(rng).tobytes())
        path = tmp_path / "shard" / "blocks" / (key + ".z")
        assert path.exists()
        path.write_bytes(b"definitely not zlib")
        with pytest.raises(BlockCorruptionError):
            c.get(key)
        c.close()
    finally:
        srv.shutdown()
        srv.close()
        t.join(timeout=10)


@pytest.mark.timeout(600)
def test_remote_zlib_restores_match_inprocess_raw(tmp_path, rng):
    """The acceptance differential, remote leg: codec=zlib over real shard
    server processes restores byte-identically to the in-process raw
    service, with identical dedup (raw) totals and a compressed_ratio that
    beats dedup alone on a compressible corpus."""
    objs = _corpus(11, versions=3) + [_low_entropy(rng)]
    ref = DedupService(params=P, slots=4, min_bucket=1024, codec="none")
    _ingest(ref, objs)
    want = ref.stats()

    root = str(tmp_path / "depot")
    svc = ShardedDedupService.open(root, 2, transport="remote", codec="zlib",
                                   params=P, slots=4, min_bucket=1024)
    try:
        _ingest(svc, objs)
        got = svc.stats()
        assert got.stored_bytes == want.stored_bytes  # codec-independent
        assert got.unique_chunks == want.unique_chunks
        assert got.dedup_ratio == want.dedup_ratio
        assert got.codec == "zlib"
        assert got.compressed_bytes < got.stored_bytes
        assert got.compressed_ratio > got.dedup_ratio
        for s in svc.shard_stats():
            assert s["compressed_bytes"] <= s["stored_bytes"]
        for i, o in enumerate(objs):
            assert svc.get(f"o{i:03d}") == o.tobytes() == ref.get(f"o{i:03d}")
    finally:
        svc.close()


@pytest.mark.timeout(600)
def test_sigkill_before_manifest_sync_compressed_depot(tmp_path, rng):
    """The satellite crash matrix over the wire: compressed blocks land and
    recipes commit, then a shard server dies before its manifest sync.  The
    depot reopens under a *different* codec preference (codec-less), every
    object restores byte-identically from the mixed-codec block dir, and
    gc() re-adopts the orphaned compressed blocks with raw-size
    accounting."""
    root = str(tmp_path / "depot")
    svc = ShardedDedupService.open(root, 2, transport="remote", codec="zlib",
                                   params=P, slots=2, min_bucket=1024)
    objs = [_low_entropy(rng, 30_000), _low_entropy(rng, 20_000)]
    _ingest(svc, objs)
    want_stored = svc.stats().stored_bytes

    victim = svc._servers[1]
    orig_sync = svc.stores[1].sync

    def killing_sync():
        victim.kill()  # compressed blocks + recipes durable; no manifest
        return orig_sync()

    svc.stores[1].sync = killing_sync
    extra = _low_entropy(rng, 25_000)
    svc.submit("extra", extra)
    with pytest.raises(ShardTransportError):
        svc.flush()
    svc.stores[1].sync = orig_sync
    assert "extra" in svc.names()
    svc.close()

    # reopen with the opposite codec preference: old .z blocks must still
    # decode (per-key self-describing layout), new writes would be raw
    svc2 = ShardedDedupService.open(root, 2, transport="remote", codec="none",
                                    params=P, slots=2, min_bucket=1024)
    try:
        assert svc2.get("extra") == extra.tobytes()
        for i, o in enumerate(objs):
            assert svc2.get(f"o{i:03d}") == o.tobytes()
        svc2.gc()  # re-adopts the compressed orphans, raw-size accounted
        got = svc2.stats()
        assert got.stored_bytes > want_stored  # "extra" counted in raw bytes
        g = svc2.gc()  # accounting self-consistent: second gc is a no-op
        assert (g.freed_blocks, g.repaired_refs) == (0, 0)
    finally:
        svc2.close()


@pytest.mark.timeout(300)
def test_spawn_failure_is_loud(tmp_path):
    """A server that cannot bind reports a ShardTransportError, and the
    already-spawned siblings are killed (no orphan processes)."""
    blocker = socket.socket()
    blocker.bind(("127.0.0.1", 0))
    blocker.listen(1)
    port = blocker.getsockname()[1]
    with pytest.raises(ShardTransportError):
        ShardServerProcess.spawn(str(tmp_path / "s"), port=port, timeout=30)
    blocker.close()
