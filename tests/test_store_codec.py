"""Compression-aware store: codec round-trips, mixed-codec depots, cold
tiering, crash recovery, and the raw-vs-compressed accounting contract.

The contract under test (docs/SERVICE.md):

* restores are SHA-bit-identical whatever the codec or tier — compression
  changes payload bytes, never chunk identity;
* ``stored_bytes`` stays *raw* unique bytes (dedup_ratio is codec-
  independent), ``compressed_bytes`` is the payload actually held, and
  every GC/sweep/repair figure is in raw bytes;
* depots are per-key self-describing: v1 (codec-less) manifests reopen
  under a compressing codec and vice versa, and a crash between a block
  write and the manifest sync is healed by ``gc``/``sweep`` regardless of
  which codec wrote the orphan.
"""
import json
import os
import zlib

import numpy as np
import pytest

from repro.core.params import SeqCDCParams
from repro.dedup.store import (
    CODEC_ENV,
    BlockCorruptionError,
    BlockStore,
    DirBlockStore,
    available_codecs,
    decode_block,
    encode_block,
    negotiate_codec,
    resolve_codec,
    sha256_key,
)
from repro.service import DedupService

P = SeqCDCParams(avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
                 min_size=64, max_size=512)


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def _compressible(rng, n=60_000):
    """Low-entropy bytes: zlib shrinks them several-fold."""
    return np.repeat(rng.integers(0, 8, n // 50, dtype=np.uint8), 50)[:n]


# -- codec helpers ---------------------------------------------------------------

def test_codec_resolution_and_negotiation(monkeypatch):
    assert resolve_codec("zlib") == "zlib"
    assert resolve_codec("none") == "none"
    with pytest.raises(ValueError, match="unknown codec"):
        resolve_codec("snappy")
    monkeypatch.delenv(CODEC_ENV, raising=False)
    assert resolve_codec(None) == "none"
    monkeypatch.setenv(CODEC_ENV, "zlib")
    assert resolve_codec(None) == "zlib"
    # lz4 degrades to zlib when the peer lacks it; zlib is stdlib-universal
    assert negotiate_codec("zlib", ("none", "zlib")) == "zlib"
    assert negotiate_codec("lz4", ("none", "zlib")) == "zlib"
    assert negotiate_codec("lz4", ("none",)) == "none"
    assert "zlib" in available_codecs()


def test_encode_block_incompressible_falls_back_to_raw(rng):
    raw = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    codec, payload = encode_block("zlib", raw)
    # high-entropy bytes don't shrink: stored raw, never inflated
    assert codec == "none" and payload == raw
    low = _compressible(rng).tobytes()
    codec, payload = encode_block("zlib", low)
    assert codec == "zlib" and len(payload) < len(low)
    assert decode_block(codec, payload, len(low)) == low


def test_decode_block_corruption_is_typed():
    with pytest.raises(BlockCorruptionError, match="decode"):
        decode_block("zlib", b"not zlib at all")
    with pytest.raises(BlockCorruptionError, match="raw"):
        decode_block("zlib", zlib.compress(b"abc"), raw_size=99)


def test_lz4_requested_but_missing_is_loud():
    if "lz4" in available_codecs():
        pytest.skip("lz4 installed in this environment")
    with pytest.raises(ValueError, match="lz4"):
        resolve_codec("lz4")


# -- accounting contract ---------------------------------------------------------

def test_keys_and_raw_accounting_are_codec_independent(rng):
    """Same bytes, any codec: same keys, same stored/logical accounting."""
    chunks = [_compressible(rng).tobytes(),
              rng.integers(0, 256, 5000, dtype=np.uint8).tobytes(),
              b"x" * 10_000]
    raw, comp = BlockStore(codec="none"), BlockStore(codec="zlib")
    for c in chunks:
        assert raw.put(c) == comp.put(c) == sha256_key(c)
    assert comp.stored_bytes == raw.stored_bytes
    assert comp.logical_bytes == raw.logical_bytes
    assert comp.compressed_bytes < comp.stored_bytes
    assert raw.compressed_bytes == raw.stored_bytes
    st = comp.stat()
    assert st["compressed_ratio"] > 1.0
    assert st["compressed_bytes"] == comp.compressed_bytes
    for k in list(comp.refs):
        assert comp.get(k) == raw.get(k)


def test_release_and_drop_return_accounting_to_zero(rng):
    s = BlockStore(codec="zlib")
    low = _compressible(rng).tobytes()
    a = s.put(low)
    b = s.put(rng.integers(0, 256, 3000, dtype=np.uint8).tobytes())
    s.put(low)  # dup of a
    assert s.refs[a] == 2
    assert s.release(a) is False  # still referenced
    assert s.release(a) is True
    assert s.drop(b) == 3000  # raw bytes reclaimed, payload was raw too
    assert (s.stored_bytes, s.compressed_bytes, s.logical_bytes) == (0, 0, 0)


# -- DirBlockStore: layout, reopen matrix, tiering -------------------------------

def test_dir_store_zlib_roundtrip_and_suffix_layout(tmp_path, rng):
    s = DirBlockStore(str(tmp_path), codec="zlib")
    low = _compressible(rng).tobytes()
    high = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    kl, kh = s.put(low), s.put(high)
    # compressed block lives under its codec suffix; incompressible raw
    assert os.path.exists(tmp_path / "blocks" / (kl + ".z"))
    assert os.path.exists(tmp_path / "blocks" / kh)
    assert s.get(kl) == low and s.get(kh) == high
    assert s.chunk_size(kl) == len(low)  # raw size, not payload size
    s.sync()
    m = json.loads((tmp_path / "manifest.json").read_text())
    assert m["version"] == 2 and m["codec"] == "zlib"
    assert m["key_codecs"] == {kl: "zlib"}
    assert m["stored_bytes"] == len(low) + len(high)
    assert m["compressed_bytes"] == s.compressed_bytes < m["stored_bytes"]


def test_v1_manifest_reopens_under_zlib_and_back(tmp_path, rng):
    """The back-compat matrix: codec-less depot -> zlib preference and a
    zlib depot -> codec-less preference both read every old block."""
    root = str(tmp_path)
    s1 = DirBlockStore(root, codec="none")
    low = _compressible(rng).tobytes()
    k1 = s1.put(low)
    s1.sync()
    # fake a v1 manifest: exactly what pre-codec stores wrote
    m = json.loads((tmp_path / "manifest.json").read_text())
    (tmp_path / "manifest.json").write_text(json.dumps({
        "refs": m["refs"], "sizes": m["sizes"],
        "logical_bytes": m["logical_bytes"],
        "stored_bytes": m["stored_bytes"],
    }))

    s2 = DirBlockStore(root, codec="zlib")
    assert s2.get(k1) == low  # old raw block readable
    assert s2.compressed_bytes == s2.stored_bytes  # v1: payload == raw
    low2 = _compressible(rng).tobytes() + b"!"
    k2 = s2.put(low2)  # new block compresses
    assert s2.key_codec.get(k2) == "zlib"
    assert s2.compressed_bytes < s2.stored_bytes
    s2.sync()

    s3 = DirBlockStore(root, codec="none")  # explicit codec beats manifest
    assert s3.codec == "none"
    assert s3.get(k1) == low
    assert s3.get(k2) == low2  # zlib block still decoded per its key

    s4 = DirBlockStore(root)  # no preference: manifest codec wins
    assert s4.codec == "zlib"


def test_manifest_codec_survives_env_default(tmp_path, rng, monkeypatch):
    monkeypatch.setenv(CODEC_ENV, "zlib")
    s = DirBlockStore(str(tmp_path))
    assert s.codec == "zlib"  # env default for a fresh depot
    s.put(_compressible(rng).tobytes())
    s.sync()
    monkeypatch.delenv(CODEC_ENV, raising=False)
    assert DirBlockStore(str(tmp_path)).codec == "zlib"  # manifest wins now


def test_cold_tiering_demotes_lru_and_restores_identically(tmp_path, rng):
    budget = 100_000
    s = DirBlockStore(str(tmp_path), codec="zlib", hot_bytes=budget)
    blobs = {}
    for i in range(8):
        b = (_compressible(rng) + i).astype(np.uint8).tobytes()
        blobs[s.put(b)] = b
    hot_raw = sum(s._hot.values())
    assert hot_raw <= budget  # LRU demotion kept the hot tier in budget
    demoted = [k for k in blobs if s.key_codec.get(k) == "zlib"]
    assert demoted  # something actually went cold
    for k, b in blobs.items():
        assert s.get(k) == b  # hot and cold both restore bit-identically
    assert s.compressed_bytes < s.stored_bytes
    # reopen: hot set rebuilt from raw keys, everything still readable
    s.sync()
    s2 = DirBlockStore(str(tmp_path), hot_bytes=budget)
    assert sum(s2._hot.values()) <= budget
    for k, b in blobs.items():
        assert s2.get(k) == b


def test_tiering_requires_compressing_codec(tmp_path):
    with pytest.raises(ValueError, match="hot_bytes"):
        DirBlockStore(str(tmp_path), codec="none", hot_bytes=1024)


def test_crashed_demotion_leaves_raw_authoritative(tmp_path, rng):
    """Both forms on disk (crash between the demotion's rename and the raw
    unlink): reads serve the recorded-raw form; scan sweeps the derived
    compressed copy; accounting stays raw-consistent."""
    s = DirBlockStore(str(tmp_path), codec="zlib")
    low = _compressible(rng).tobytes()
    k = s.put(low)  # stored compressed
    # simulate the inverse crash too: raw copy appears next to the .z one
    with open(tmp_path / "blocks" / k, "wb") as f:
        f.write(low)
    s.sync()
    keys = s.scan_keys()
    assert keys == [k]
    assert not os.path.exists(tmp_path / "blocks" / (k + ".z"))  # swept
    assert s.get(k) == low  # self-heals to the on-disk raw form
    assert s.stored_bytes == len(low)


# -- crash recovery (the satellite matrix) ---------------------------------------

def test_kill_between_block_rename_and_manifest_sync(tmp_path, rng):
    """Compressed blocks land, the manifest never syncs; reopen with a
    *different* codec preference and verify sweep/repair_ref re-adopt the
    orphans with raw-size byte accounting."""
    root = str(tmp_path)
    s = DirBlockStore(root, codec="zlib")
    low = _compressible(rng).tobytes()
    k_live = s.put(low)
    s.sync()  # manifest knows k_live
    extra = _compressible(rng).tobytes() + b"tail"
    k_orphan = s.put(extra)  # block file renamed in; no sync() = crash here
    del s

    s2 = DirBlockStore(root, codec="none")  # different preference on reopen
    assert s2.scan_keys() == sorted([k_live, k_orphan])
    assert k_orphan not in s2.refs  # manifest is stale, orphan unadopted
    # repair against recomputed liveness: both keys live once
    freed_blocks, freed_bytes, repaired = s2.sweep({k_live: 1, k_orphan: 1})
    assert (freed_blocks, freed_bytes) == (0, 0)
    assert repaired == 1  # the orphan was re-adopted
    assert s2.stored_bytes == len(low) + len(extra)  # raw sizes, both codecs
    assert s2.get(k_orphan) == extra
    assert s2.compressed_bytes < s2.stored_bytes  # zlib payload kept as-is

    # and the GC direction: orphan unreferenced -> freed bytes are raw
    s3root = str(tmp_path / "gc")
    s3 = DirBlockStore(s3root, codec="zlib")
    s3.sync()
    k_dead = s3.put(extra)
    del s3  # crash before sync: k_dead is an on-disk orphan
    s4 = DirBlockStore(s3root, codec="zlib")
    freed_blocks, freed_bytes, _ = s4.sweep({})
    assert freed_blocks == 1
    assert freed_bytes == len(extra)  # raw bytes, though stored compressed
    assert s4.scan_keys() == []
    assert k_dead not in s4.refs


def test_drop_tolerates_concurrently_vanished_orphan(tmp_path, rng):
    """The TOCTOU fix: drop on an on-disk orphan whose file vanishes under
    it (a racing sweep) returns 0 instead of raising."""
    s = DirBlockStore(str(tmp_path), codec="zlib")
    low = _compressible(rng).tobytes()
    k = s.put(low)
    s.refs.pop(k)  # make it an on-disk orphan (never entered this manifest)
    s._forget_meta(k)
    assert s.drop(k) == len(low)  # reports raw bytes even for .z orphans
    assert s.drop(k) == 0  # already gone: the racing-sweep outcome
    assert s.drop("0" * 64) == 0  # never existed


def test_tmp_files_swept_on_scan(tmp_path, rng):
    s = DirBlockStore(str(tmp_path), codec="zlib")
    k = s.put(_compressible(rng).tobytes())
    torn = tmp_path / "blocks" / ("f" * 64 + ".z.tmp")
    torn.write_bytes(b"torn write")
    assert s.scan_keys() == [k]
    assert not torn.exists()


# -- service-level differential matrix -------------------------------------------

@pytest.mark.parametrize("codec,hot_bytes", [
    ("none", 0), ("zlib", 0), ("zlib", 40_000),
])
def test_service_restore_bit_identical_across_codecs(tmp_path, rng, codec,
                                                     hot_bytes):
    """The acceptance pin, local transport: codec x tiering never changes
    restored bytes, object names, or the dedup (raw) accounting."""
    objs = [_compressible(rng),
            rng.integers(0, 256, 30_000, dtype=np.uint8),
            np.zeros(0, dtype=np.uint8)]
    ref = DedupService(params=P, slots=4, min_bucket=1024, codec="none")
    svc = DedupService.open(str(tmp_path / codec), params=P, slots=4,
                            min_bucket=1024, codec=codec,
                            hot_bytes=hot_bytes)
    for i, o in enumerate(objs):
        ref.submit(f"o{i}", o)
        svc.submit(f"o{i}", o)
    ref.flush()
    svc.flush()
    for i, o in enumerate(objs):
        assert svc.get(f"o{i}") == ref.get(f"o{i}") == o.tobytes()
    a, b = ref.stats(), svc.stats()
    assert a.stored_bytes == b.stored_bytes  # raw accounting, codec-free
    assert a.dedup_ratio == b.dedup_ratio
    assert a.unique_chunks == b.unique_chunks
    if codec == "zlib":
        assert b.compressed_ratio > b.dedup_ratio
        assert b.codec == "zlib"


def test_corrupt_compressed_block_raises_integrity_error(tmp_path, rng):
    svc = DedupService.open(str(tmp_path), params=P, slots=4,
                            min_bucket=1024, codec="zlib")
    svc.put("obj", _compressible(rng))
    r = svc.recipes.get("obj")
    k = r.keys[0]
    path = tmp_path / "blocks" / (k + ".z")
    assert path.exists()
    path.write_bytes(b"garbage that is not zlib")
    from repro.service import IntegrityError

    with pytest.raises(IntegrityError):
        svc.get("obj")
