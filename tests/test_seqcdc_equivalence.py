"""SeqCDC: every implementation agrees bit-for-bit with the slow oracle.

The paper's semantics (DESIGN.md SS4) have one normative transcription
(oracle.boundaries_slow); the event-driven numpy oracle, the two-phase
vectorized JAX pipeline (wide and gather automaton steps), and the
lax.while_loop sequential form must all reproduce it exactly — including the
content-defined skip counter resets, sub-minimum regions, and max-size cuts.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no hypothesis in this env: deterministic fallback
    from _hyp_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import oracle, seqcdc
from repro.core.params import SeqCDCParams, paper_params

SMALL = SeqCDCParams(
    avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
    min_size=64, max_size=512,
)
SMALL_DEC = SeqCDCParams(
    avg_size=256, seq_length=3, skip_trigger=6, skip_size=32,
    min_size=64, max_size=512, mode="decreasing",
)


def _all_impls(data: np.ndarray, p: SeqCDCParams):
    ref = oracle.boundaries_slow(data, p)
    out = {"numpy": oracle.boundaries_numpy(data, p).tolist()}
    if data.size:
        d = jnp.asarray(data)
        for name, fn in [
            ("wide", lambda x: seqcdc.boundaries_two_phase(x, p, step_impl="wide")),
            ("gather", lambda x: seqcdc.boundaries_two_phase(x, p, step_impl="gather")),
            ("event", lambda x: seqcdc.boundaries_two_phase(x, p, step_impl="event")),
            ("sequential", lambda x: seqcdc.boundaries_sequential(x, p)),
        ]:
            b, c = fn(d)
            out[name] = np.asarray(b)[: int(c)].tolist()
    return ref, out


@pytest.mark.parametrize("params", [SMALL, SMALL_DEC], ids=["inc", "dec"])
@pytest.mark.parametrize("n", [0, 1, 5, 63, 64, 65, 100, 1000, 20000])
def test_impls_match_oracle_random(params, n, rng):
    data = rng.integers(0, 256, n, dtype=np.uint8)
    ref, out = _all_impls(data, params)
    for name, got in out.items():
        assert got == ref, f"{name} diverged at n={n}"


@pytest.mark.parametrize(
    "data",
    [
        np.zeros(5000, dtype=np.uint8),
        np.full(5000, 255, dtype=np.uint8),
        (np.arange(5000) % 256).astype(np.uint8),  # sawtooth increasing
        (255 - np.arange(5000) % 256).astype(np.uint8),  # sawtooth decreasing
        np.tile(np.array([1, 2], dtype=np.uint8), 2500),  # period-2
    ],
    ids=["zeros", "max", "saw-inc", "saw-dec", "alt"],
)
def test_impls_match_oracle_adversarial(data):
    for params in (SMALL, SMALL_DEC):
        ref, out = _all_impls(data, params)
        for name, got in out.items():
            assert got == ref, name


def test_paper_params_match_oracle(rng):
    data = rng.integers(0, 256, 200_000, dtype=np.uint8)
    for avg in (4096, 8192, 16384):
        ref, out = _all_impls(data, paper_params(avg))
        for name, got in out.items():
            assert got == ref, (name, avg)


@settings(max_examples=60, deadline=None)
@given(
    data=st.binary(min_size=0, max_size=4000),
    seq_length=st.integers(3, 6),
    skip_trigger=st.integers(1, 20),
    skip_size=st.sampled_from([16, 32, 64]),
    mode=st.sampled_from(["increasing", "decreasing"]),
)
def test_property_equivalence(data, seq_length, skip_trigger, skip_size, mode):
    """Property: all implementations == oracle for arbitrary params/data."""
    p = SeqCDCParams(
        avg_size=128, seq_length=seq_length, skip_trigger=skip_trigger,
        skip_size=skip_size, min_size=32, max_size=256, mode=mode,
    )
    arr = np.frombuffer(data, dtype=np.uint8)
    ref, out = _all_impls(arr, p)
    for name, got in out.items():
        assert got == ref, name


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=1, max_size=8000))
def test_property_boundary_invariants(data):
    """Chunks respect [min, max] except the final remainder chunk."""
    arr = np.frombuffer(data, dtype=np.uint8)
    p = SMALL
    bounds = oracle.boundaries_numpy(arr, p)
    assert bounds[-1] == arr.size
    lens = np.diff(np.concatenate([[0], bounds]))
    assert (lens[:-1] >= p.min_size).all() or lens.size <= 1
    assert (lens <= p.max_size).all()


def test_byte_shift_resistance(rng):
    """Paper SSIV: an insertion mid-stream only perturbs nearby boundaries."""
    data = rng.integers(0, 256, 300_000, dtype=np.uint8)
    p = paper_params(8192)
    b0 = set(oracle.boundaries_numpy(data, p).tolist())
    pos = 150_000
    shifted = np.concatenate([data[:pos], rng.integers(0, 256, 7, dtype=np.uint8), data[pos:]])
    b1 = oracle.boundaries_numpy(shifted, p)
    # boundaries before the edit are identical; after it, the +7-shifted
    # boundary set re-synchronizes (most boundaries survive the shift)
    before = [b for b in b1 if b < pos]
    assert all(b in b0 for b in before)
    after = [b - 7 for b in b1 if b >= pos + 7]
    survive = sum(b in b0 for b in after) / max(len(after), 1)
    assert survive > 0.9, f"only {survive:.2%} of downstream boundaries survived"


def test_block_width_invariant():
    """The automaton's W-block invariant: W <= min(skip, sub-min)."""
    for avg in (4096, 8192, 16384):
        p = paper_params(avg)
        assert p.block_width <= min(p.skip_size, p.min_size - p.seq_length)
        assert p.block_width & (p.block_width - 1) == 0  # power of two


def test_batched_matches_single(rng):
    data = rng.integers(0, 256, (4, 8192), dtype=np.uint8)
    bounds, counts = seqcdc.boundaries_batch(jnp.asarray(data), SMALL)
    for i in range(4):
        ref = oracle.boundaries_slow(data[i], SMALL)
        got = np.asarray(bounds[i])[: int(counts[i])].tolist()
        assert got == ref


# -- batch entry points: edge cases vs the sequential backend -------------------

def test_two_phase_empty_stream():
    """n=0: zero chunks, sentinel-only bounds (both backends agree)."""
    empty = jnp.zeros((0,), jnp.uint8)
    b2, c2 = seqcdc.boundaries_two_phase(empty, SMALL)
    bs, cs = seqcdc.boundaries_sequential(empty, SMALL)
    assert int(c2) == int(cs) == 0
    assert seqcdc.bounds_to_numpy(b2, c2) == []


def test_batch_empty_streams():
    bounds, counts = seqcdc.boundaries_batch(jnp.zeros((3, 0), jnp.uint8), SMALL)
    assert bounds.shape[0] == 3
    assert np.asarray(counts).tolist() == [0, 0, 0]
    assert seqcdc.bounds_to_numpy(bounds, counts) == [[], [], []]


@pytest.mark.parametrize("n", [1, 2])  # shorter than seq_length=3
def test_batch_shorter_than_seq_length(n, rng):
    data = rng.integers(0, 256, (3, n), dtype=np.uint8)
    bounds, counts = seqcdc.boundaries_batch(jnp.asarray(data), SMALL)
    for i, row in enumerate(seqcdc.bounds_to_numpy(bounds, counts)):
        wb, wc = seqcdc.boundaries_sequential(jnp.asarray(data[i]), SMALL)
        assert row == seqcdc.bounds_to_numpy(wb, wc) == [n]


def test_batch_exactly_max_size(rng):
    """Streams of exactly max_size bytes: single full-size chunk cases and
    candidate-rich rows alike match the sequential backend."""
    n = SMALL.max_size
    rows = np.stack([
        np.zeros(n, dtype=np.uint8),  # no candidates: one max-size cut
        rng.integers(0, 256, n, dtype=np.uint8),
        (np.arange(n) % 256).astype(np.uint8),
    ])
    bounds, counts = seqcdc.boundaries_batch(jnp.asarray(rows), SMALL)
    got = seqcdc.bounds_to_numpy(bounds, counts)
    for i in range(rows.shape[0]):
        b, c = seqcdc.boundaries_sequential(jnp.asarray(rows[i]), SMALL)
        assert got[i] == seqcdc.bounds_to_numpy(b, c)
        assert got[i][-1] == n
    assert got[0] == [n]  # constant row: exactly the max-size cut


def test_batch_mixed_content_rows(rng):
    """One device batch mixing random/constant/monotone/periodic rows equals
    the sequential backend row by row (vmap has no cross-row leakage)."""
    n = 4096
    rows = np.stack([
        rng.integers(0, 256, n, dtype=np.uint8),
        np.zeros(n, dtype=np.uint8),
        np.full(n, 255, dtype=np.uint8),
        (np.arange(n) % 256).astype(np.uint8),
        (255 - np.arange(n) % 256).astype(np.uint8),
        np.tile(np.array([1, 2], dtype=np.uint8), n // 2),
    ])
    for params in (SMALL, SMALL_DEC):
        bounds, counts = seqcdc.boundaries_batch(jnp.asarray(rows), params)
        got = seqcdc.bounds_to_numpy(bounds, counts)
        for i in range(rows.shape[0]):
            b, c = seqcdc.boundaries_sequential(jnp.asarray(rows[i]), params)
            assert got[i] == seqcdc.bounds_to_numpy(b, c), f"row {i}"


def test_bounds_to_numpy_shapes():
    b = jnp.asarray([[10, 20, 1 << 30], [5, 1 << 30, 1 << 30]], jnp.int32)
    c = jnp.asarray([2, 1], jnp.int32)
    assert seqcdc.bounds_to_numpy(b, c) == [[10, 20], [5]]
    assert seqcdc.bounds_to_numpy(b[0], c[0]) == [10, 20]
    assert seqcdc.bounds_to_numpy(b[0], 0) == []
    with pytest.raises(ValueError):
        seqcdc.bounds_to_numpy(b, jnp.asarray([1, 2, 3]))
